"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest

from repro.core import HybridConfig, HybridKVManager, translate
from repro.kernels.utopia_rsw.ops import utopia_rsw
from repro.kernels.utopia_rsw.ref import rsw_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref, normalize
from repro.models.attention import dense_attention


def _populated_manager(hash_name="modulo", seqs=6, blocks=20):
    cfg = HybridConfig(total_slots=256, restseg_fraction=0.75, assoc=8,
                       max_seqs=16, max_blocks_per_seq=32,
                       hash_name=hash_name)
    m = HybridKVManager(cfg)
    for sid in range(seqs):
        m.register_sequence(sid)
        for b in range(blocks):
            m.allocate_block(sid, b)
    return m


class TestRSWKernel:
    @pytest.mark.parametrize("hash_name", ["modulo", "xor_fold",
                                           "prime_displacement", "mersenne",
                                           "multiplicative"])
    def test_matches_ref_and_core(self, hash_name):
        m = _populated_manager(hash_name)
        ts = m.device_state()
        ff = ts.flex.table.reshape(-1)
        vpns = jnp.arange(16 * 32, dtype=jnp.int32)
        got = utopia_rsw(vpns, ts.rest.tar, ts.rest.sf, ff,
                         hash_name=hash_name)
        want = rsw_ref(vpns, ts.rest.tar, ts.rest.sf, ff,
                       hash_name=hash_name)
        for a, b in zip(got, want):
            npt.assert_array_equal(np.asarray(a), np.asarray(b))
        tr = translate(ts, vpns)
        npt.assert_array_equal(
            np.asarray(got[0]),
            np.where(np.asarray(tr.mapped), np.asarray(tr.slot), -1))

    @pytest.mark.parametrize("tile", [32, 128, 256])
    def test_tile_sizes_and_padding(self, tile):
        m = _populated_manager()
        ts = m.device_state()
        ff = ts.flex.table.reshape(-1)
        vpns = jnp.arange(100, dtype=jnp.int32)   # not a tile multiple
        got = utopia_rsw(vpns, ts.rest.tar, ts.rest.sf, ff, tile=tile)
        want = rsw_ref(vpns, ts.rest.tar, ts.rest.sf, ff)
        for a, b in zip(got, want):
            npt.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_large_vpn_tags_exact(self):
        """Tags (vpn+1) at and above 2^24 must match exactly.

        A float32 one-hot matmul rounds odd tags ≥ 2^24 to the nearest
        even value, so vpn=2^24 (tag 2^24+1) silently missed — and worse,
        a query for a *different* vpn whose tag rounds onto an installed
        one falsely hit.  The kernel now recombines 16-bit tag halves in
        int32; this pins both directions against the oracle.
        """
        n_sets, assoc = 4, 4
        tar = np.zeros((n_sets, assoc), np.int32)
        big = [(1 << 24), (1 << 24) + 6, (1 << 25) + 3, (1 << 26) + 9]
        for v in big:
            s = v % n_sets
            way = int(np.nonzero(tar[s] == 0)[0][0])
            tar[s, way] = v + 1                       # odd tags ≥ 2^24
        sf = (tar != 0).sum(axis=1).astype(np.int32)
        flex = -np.ones(16, np.int32)
        # installed vpns, near-miss neighbours (tags that round onto the
        # installed ones in f32), and small controls
        queries = big + [v + 1 for v in big] + [v - 1 for v in big] + [0, 7]
        vpns = jnp.asarray(queries, jnp.int32)
        got = utopia_rsw(vpns, jnp.asarray(tar), jnp.asarray(sf),
                         jnp.asarray(flex))
        want = rsw_ref(vpns, jnp.asarray(tar), jnp.asarray(sf),
                       jnp.asarray(flex))
        for a, b in zip(got, want):
            npt.assert_array_equal(np.asarray(a), np.asarray(b))
        # installed vpns hit the RestSeg; their neighbours must not
        n = len(big)
        assert np.asarray(got[1][:n]).all(), "installed vpns must RSW-hit"
        assert not np.asarray(got[1][n:3 * n]).any(), \
            "rounded-tag neighbours must miss"

    def test_host_agreement(self):
        m = _populated_manager()
        ts = m.device_state()
        ff = ts.flex.table.reshape(-1)
        for sid in range(6):
            for b in range(20):
                vpn = m.cfg.vpn(m.seq_slot(sid), b)
                got = utopia_rsw(jnp.array([vpn], jnp.int32), ts.rest.tar,
                                 ts.rest.sf, ff)
                assert int(got[0][0]) == m.lookup(sid, b)[0]


class TestFlashKernel:
    @pytest.mark.parametrize("shape", [
        (2, 128, 4, 2, 32), (1, 256, 8, 8, 16), (2, 64, 4, 1, 64),
        (1, 128, 6, 3, 32),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    def test_vs_dense(self, shape, dtype, causal):
        B, S, H, KV, D = shape
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), dtype)
        k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
        v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
        out = flash_attention(q, k, v, causal=causal, q_tile=64, kv_tile=64)
        ref = dense_attention(q, k, v, causal=causal)
        tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
        npt.assert_allclose(np.asarray(out, np.float32),
                            np.asarray(ref, np.float32), rtol=tol, atol=tol)


class TestPagedAttentionKernel:
    @pytest.mark.parametrize("shape", [
        (3, 8, 2, 32, 16, 6, 64), (2, 4, 4, 16, 8, 4, 32),
        (1, 8, 1, 64, 32, 8, 96),
    ])
    def test_vs_ref_with_holes(self, shape):
        B, H, KV, D, bs, nblk, nslots = shape
        ks = jax.random.split(jax.random.PRNGKey(7), 4)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (nslots, bs, KV, D))
        vp = jax.random.normal(ks[2], (nslots, bs, KV, D))
        slots = jax.random.randint(ks[3], (B, nblk), 0, nslots)
        slots = slots.at[0, nblk // 2].set(-1)          # hole
        ctx = jnp.asarray(np.random.RandomState(0).randint(
            1, bs * nblk, B), jnp.int32)
        out_k = paged_attention(q, kp, vp, slots, ctx, use_kernel=True)
        o, m, l = paged_attention_ref(q, kp, vp, slots, ctx)
        npt.assert_allclose(np.asarray(out_k), np.asarray(normalize(o, l)),
                            rtol=2e-5, atol=2e-5)

    def test_striped_token_shards_combine(self):
        """Model-axis token striping: shard partials must combine exactly."""
        B, H, KV, D, bs, nblk, nslots = 2, 4, 2, 16, 16, 4, 32
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (nslots, bs, KV, D))
        vp = jax.random.normal(ks[2], (nslots, bs, KV, D))
        slots = jax.random.randint(ks[3], (B, nblk), 0, nslots)
        ctx = jnp.array([60, 37], jnp.int32)
        o, m, l = paged_attention_ref(q, kp, vp, slots, ctx)
        full = np.asarray(normalize(o, l))
        TP = 4
        outs = []
        for t in range(TP):
            lo = t * (bs // TP)
            kp_t = kp[:, lo:lo + bs // TP]
            vp_t = vp[:, lo:lo + bs // TP]
            outs.append(paged_attention_ref(
                q, kp_t, vp_t, slots, ctx, tok_offset=lo, tok_stride=1,
                block_tokens=bs))
        m_glob = np.max([np.asarray(x[1]) for x in outs], axis=0)
        o_sum = sum(np.asarray(x[0]) * np.exp(np.asarray(x[1]) - m_glob)
                    [..., None] for x in outs)
        l_sum = sum(np.asarray(x[2]) * np.exp(np.asarray(x[1]) - m_glob)
                    for x in outs)
        npt.assert_allclose(o_sum / np.maximum(l_sum, 1e-30)[..., None],
                            full, rtol=2e-5, atol=2e-5)
