"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest

from repro.core import HybridConfig, HybridKVManager, translate
from repro.kernels.utopia_rsw.ops import utopia_rsw
from repro.kernels.utopia_rsw.ref import rsw_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref, normalize
from repro.models.attention import dense_attention


def _populated_manager(hash_name="modulo", seqs=6, blocks=20):
    cfg = HybridConfig(total_slots=256, restseg_fraction=0.75, assoc=8,
                       max_seqs=16, max_blocks_per_seq=32,
                       hash_name=hash_name)
    m = HybridKVManager(cfg)
    for sid in range(seqs):
        m.register_sequence(sid)
        for b in range(blocks):
            m.allocate_block(sid, b)
    return m


class TestRSWKernel:
    @pytest.mark.parametrize("hash_name", ["modulo", "xor_fold",
                                           "prime_displacement", "mersenne",
                                           "multiplicative"])
    def test_matches_ref_and_core(self, hash_name):
        m = _populated_manager(hash_name)
        ts = m.device_state()
        ff = ts.flex.table.reshape(-1)
        vpns = jnp.arange(16 * 32, dtype=jnp.int32)
        got = utopia_rsw(vpns, ts.rest.tar, ts.rest.sf, ff,
                         hash_name=hash_name)
        want = rsw_ref(vpns, ts.rest.tar, ts.rest.sf, ff,
                       hash_name=hash_name)
        for a, b in zip(got, want):
            npt.assert_array_equal(np.asarray(a), np.asarray(b))
        tr = translate(ts, vpns)
        npt.assert_array_equal(
            np.asarray(got[0]),
            np.where(np.asarray(tr.mapped), np.asarray(tr.slot), -1))

    @pytest.mark.parametrize("tile", [32, 128, 256])
    def test_tile_sizes_and_padding(self, tile):
        m = _populated_manager()
        ts = m.device_state()
        ff = ts.flex.table.reshape(-1)
        vpns = jnp.arange(100, dtype=jnp.int32)   # not a tile multiple
        got = utopia_rsw(vpns, ts.rest.tar, ts.rest.sf, ff, tile=tile)
        want = rsw_ref(vpns, ts.rest.tar, ts.rest.sf, ff)
        for a, b in zip(got, want):
            npt.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_large_vpn_tags_exact(self):
        """Tags (vpn+1) at and above 2^24 must match exactly.

        A float32 one-hot matmul rounds odd tags ≥ 2^24 to the nearest
        even value, so vpn=2^24 (tag 2^24+1) silently missed — and worse,
        a query for a *different* vpn whose tag rounds onto an installed
        one falsely hit.  The kernel now recombines 16-bit tag halves in
        int32; this pins both directions against the oracle.
        """
        n_sets, assoc = 4, 4
        tar = np.zeros((n_sets, assoc), np.int32)
        big = [(1 << 24), (1 << 24) + 6, (1 << 25) + 3, (1 << 26) + 9]
        for v in big:
            s = v % n_sets
            way = int(np.nonzero(tar[s] == 0)[0][0])
            tar[s, way] = v + 1                       # odd tags ≥ 2^24
        sf = (tar != 0).sum(axis=1).astype(np.int32)
        flex = -np.ones(16, np.int32)
        # installed vpns, near-miss neighbours (tags that round onto the
        # installed ones in f32), and small controls
        queries = big + [v + 1 for v in big] + [v - 1 for v in big] + [0, 7]
        vpns = jnp.asarray(queries, jnp.int32)
        got = utopia_rsw(vpns, jnp.asarray(tar), jnp.asarray(sf),
                         jnp.asarray(flex))
        want = rsw_ref(vpns, jnp.asarray(tar), jnp.asarray(sf),
                       jnp.asarray(flex))
        for a, b in zip(got, want):
            npt.assert_array_equal(np.asarray(a), np.asarray(b))
        # installed vpns hit the RestSeg; their neighbours must not
        n = len(big)
        assert np.asarray(got[1][:n]).all(), "installed vpns must RSW-hit"
        assert not np.asarray(got[1][n:3 * n]).any(), \
            "rounded-tag neighbours must miss"

    def test_host_agreement(self):
        m = _populated_manager()
        ts = m.device_state()
        ff = ts.flex.table.reshape(-1)
        for sid in range(6):
            for b in range(20):
                vpn = m.cfg.vpn(m.seq_slot(sid), b)
                got = utopia_rsw(jnp.array([vpn], jnp.int32), ts.rest.tar,
                                 ts.rest.sf, ff)
                assert int(got[0][0]) == m.lookup(sid, b)[0]


class TestFlashKernel:
    @pytest.mark.parametrize("shape", [
        (2, 128, 4, 2, 32), (1, 256, 8, 8, 16), (2, 64, 4, 1, 64),
        (1, 128, 6, 3, 32),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    def test_vs_dense(self, shape, dtype, causal):
        B, S, H, KV, D = shape
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), dtype)
        k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
        v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
        out = flash_attention(q, k, v, causal=causal, q_tile=64, kv_tile=64)
        ref = dense_attention(q, k, v, causal=causal)
        tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
        npt.assert_allclose(np.asarray(out, np.float32),
                            np.asarray(ref, np.float32), rtol=tol, atol=tol)


class TestPagedAttentionKernel:
    @pytest.mark.parametrize("shape", [
        (3, 8, 2, 32, 16, 6, 64), (2, 4, 4, 16, 8, 4, 32),
        (1, 8, 1, 64, 32, 8, 96),
    ])
    def test_vs_ref_with_holes(self, shape):
        B, H, KV, D, bs, nblk, nslots = shape
        ks = jax.random.split(jax.random.PRNGKey(7), 4)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (nslots, bs, KV, D))
        vp = jax.random.normal(ks[2], (nslots, bs, KV, D))
        slots = jax.random.randint(ks[3], (B, nblk), 0, nslots)
        slots = slots.at[0, nblk // 2].set(-1)          # hole
        ctx = jnp.asarray(np.random.RandomState(0).randint(
            1, bs * nblk, B), jnp.int32)
        out_k = paged_attention(q, kp, vp, slots, ctx, use_kernel=True)
        o, m, l = paged_attention_ref(q, kp, vp, slots, ctx)
        npt.assert_allclose(np.asarray(out_k), np.asarray(normalize(o, l)),
                            rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("shape", [
        # B, Q, H, KV, D, bs, nblk, nslots
        (2, 4, 8, 2, 32, 16, 6, 64), (3, 1, 4, 4, 16, 8, 4, 32),
        (1, 16, 8, 1, 64, 32, 8, 96),
    ])
    def test_multi_token_query_vs_ref(self, shape):
        """Q>1 queries (prefix-KV chunked prefill): Pallas-interpret vs
        the jnp oracle over ragged ctx_len — block-interior, exact block
        boundaries, and an EMPTY-prefix row (ctx 0, l == 0 so the
        flash-decoding combine drops the part exactly) — plus a hole."""
        B, Q, H, KV, D, bs, nblk, nslots = shape
        ks = jax.random.split(jax.random.PRNGKey(11), 4)
        q = jax.random.normal(ks[0], (B, Q, H, D))
        kp = jax.random.normal(ks[1], (nslots, bs, KV, D))
        vp = jax.random.normal(ks[2], (nslots, bs, KV, D))
        slots = jax.random.randint(ks[3], (B, nblk), 0, nslots)
        slots = slots.at[0, nblk // 2].set(-1)          # hole
        ctx = np.random.RandomState(1).randint(1, bs * nblk, B)
        ctx[0] = 0                                      # empty prefix
        if B > 1:
            ctx[1] = bs * (nblk // 2)                   # block boundary
        ctx = jnp.asarray(ctx, jnp.int32)
        from repro.kernels.paged_attention.paged_attention import (
            paged_attention_pallas)
        got = paged_attention_pallas(q, kp, vp, slots, ctx, interpret=True)
        want = paged_attention_ref(q, kp, vp, slots, ctx)
        for a, b in zip(got, want):
            assert a.shape == b.shape == (B, Q, H) + ((D,) if a.ndim == 4
                                                      else ())
            npt.assert_allclose(np.asarray(a), np.asarray(b),
                                rtol=2e-5, atol=2e-5)
        # empty-prefix row: zero weight everywhere, so normalize -> 0
        npt.assert_array_equal(np.asarray(normalize(got[0], got[2]))[0], 0.0)

    @pytest.mark.parametrize("K", [1, 3, 4])
    @pytest.mark.parametrize("pattern", ["ragged", "boundary",
                                         "all_rejected", "all_accepted"])
    def test_verify_shaped_per_query_ctx_vs_ref(self, K, pattern):
        """Speculative-verify shapes: Q = K+1 queries with PER-QUERY
        context extents ctx_q[b, i] = pos_b + i + 1 (the sequential
        causal mask inside one pool read).  Pallas-interpret must match
        the jnp oracle, and each query column must equal an independent
        single-extent call — the property that makes spec-on greedy
        streams token-identical to sequential decode.

        Patterns: ``ragged`` starts rows mid-block, ``boundary`` starts
        exactly at a block boundary so the window straddles it,
        ``all_rejected`` re-verifies from the same base every row (the
        worst case: next step's window repeats the position), and
        ``all_accepted`` chains two adjacent windows (row 1 starts where
        row 0's window committed)."""
        B, H, KV, D, bs, nblk, nslots = 2, 4, 2, 16, 8, 6, 64
        Q = K + 1
        ks = jax.random.split(jax.random.PRNGKey(21 + K), 4)
        q = jax.random.normal(ks[0], (B, Q, H, D))
        kp = jax.random.normal(ks[1], (nslots, bs, KV, D))
        vp = jax.random.normal(ks[2], (nslots, bs, KV, D))
        slots = jax.random.randint(ks[3], (B, nblk), 0, nslots)
        base = {
            "ragged": np.array([bs - 2, 3 * bs - 1]),   # straddles blocks
            "boundary": np.array([bs, 2 * bs]),
            "all_rejected": np.array([7, 7]),
            "all_accepted": np.array([5, 5 + Q]),
        }[pattern]
        ctx_q = jnp.asarray(base[:, None] + 1 + np.arange(Q)[None, :],
                            jnp.int32)
        from repro.kernels.paged_attention.paged_attention import (
            paged_attention_pallas)
        got = paged_attention_pallas(q, kp, vp, slots, ctx_q,
                                     interpret=True)
        want = paged_attention_ref(q, kp, vp, slots, ctx_q)
        for a, b in zip(got, want):
            npt.assert_allclose(np.asarray(a), np.asarray(b),
                                rtol=2e-5, atol=2e-5)
        # column i == an independent single-extent call (bitwise, for the
        # ref path: the verify step's token-identity foundation)
        for i in range(Q):
            o1, m1, l1 = paged_attention_ref(q[:, i], kp, vp, slots,
                                             ctx_q[:, i])
            npt.assert_array_equal(np.asarray(o1),
                                   np.asarray(want[0][:, i]))
            npt.assert_array_equal(np.asarray(m1),
                                   np.asarray(want[1][:, i]))
            npt.assert_array_equal(np.asarray(l1),
                                   np.asarray(want[2][:, i]))

    def test_per_query_ctx_zero_extent_column_drops(self):
        """A query column with extent 0 yields l == 0 (dropped exactly by
        any downstream flash-decoding combine)."""
        B, Q, H, KV, D, bs, nblk, nslots = 1, 3, 4, 2, 16, 8, 4, 32
        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        q = jax.random.normal(ks[0], (B, Q, H, D))
        kp = jax.random.normal(ks[1], (nslots, bs, KV, D))
        vp = jax.random.normal(ks[2], (nslots, bs, KV, D))
        slots = jax.random.randint(ks[3], (B, nblk), 0, nslots)
        ctx_q = jnp.asarray([[0, 5, 9]], jnp.int32)
        from repro.kernels.paged_attention.paged_attention import (
            paged_attention_pallas)
        for fn in (paged_attention_ref, paged_attention_pallas):
            o, m, l = fn(q, kp, vp, slots, ctx_q)
            npt.assert_array_equal(np.asarray(l)[:, 0], 0.0)
            npt.assert_array_equal(
                np.asarray(normalize(o, l))[:, 0], 0.0)
            assert (np.asarray(l)[:, 1:] > 0).all()

    def test_q1_query_rank_round_trip(self):
        """A (B,H,D) decode query and its (B,1,H,D) chunk form produce
        identical results in BOTH implementations (one code path, two
        ranks)."""
        B, H, KV, D, bs, nblk, nslots = 2, 4, 2, 16, 8, 4, 32
        ks = jax.random.split(jax.random.PRNGKey(5), 4)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (nslots, bs, KV, D))
        vp = jax.random.normal(ks[2], (nslots, bs, KV, D))
        slots = jax.random.randint(ks[3], (B, nblk), 0, nslots)
        ctx = jnp.asarray([13, 27], jnp.int32)
        from repro.kernels.paged_attention.paged_attention import (
            paged_attention_pallas)
        for fn in (paged_attention_ref, paged_attention_pallas):
            o3, m3, l3 = fn(q, kp, vp, slots, ctx)
            o4, m4, l4 = fn(q[:, None], kp, vp, slots, ctx)
            npt.assert_array_equal(np.asarray(o3), np.asarray(o4[:, 0]))
            npt.assert_array_equal(np.asarray(m3), np.asarray(m4[:, 0]))
            npt.assert_array_equal(np.asarray(l3), np.asarray(l4[:, 0]))

    def test_multi_token_prefix_plus_chunk_merge_matches_dense(self):
        """End-to-end prefix-KV attention identity: Q chunk queries over
        [pool prefix] ∪ [own causal K/V], combined with the online-softmax
        merge, equals ONE dense causal attention over the concatenated
        sequence."""
        from repro.models.attention import (dense_attention,
                                            causal_attention_parts,
                                            merge_attention_parts)
        B, Q, H, KV, D, bs, nblk, nslots = 2, 8, 4, 2, 16, 8, 4, 32
        P = bs * nblk                                   # prefix tokens
        ks = jax.random.split(jax.random.PRNGKey(9), 6)
        q = jax.random.normal(ks[0], (B, Q, H, D))
        kpre = jax.random.normal(ks[1], (B, P, KV, D))
        vpre = jax.random.normal(ks[2], (B, P, KV, D))
        kc = jax.random.normal(ks[3], (B, Q, KV, D))
        vc = jax.random.normal(ks[4], (B, Q, KV, D))
        # lay the prefix into pool slots (row b uses slots b*nblk + j)
        kp = jnp.zeros((nslots, bs, KV, D)).at[:2 * nblk].set(
            kpre.reshape(B * nblk, bs, KV, D))
        vp = jnp.zeros((nslots, bs, KV, D)).at[:2 * nblk].set(
            vpre.reshape(B * nblk, bs, KV, D))
        slots = (jnp.arange(B)[:, None] * nblk
                 + jnp.arange(nblk)[None, :]).astype(jnp.int32)
        ctx = jnp.full((B,), P, jnp.int32)
        pool = paged_attention_ref(q, kp, vp, slots, ctx)
        own = causal_attention_parts(q, kc, vc)
        merged = merge_attention_parts([pool, own])
        dense = dense_attention(
            q, jnp.concatenate([kpre, kc], axis=1),
            jnp.concatenate([vpre, vc], axis=1), causal=True, q_offset=P)
        npt.assert_allclose(np.asarray(merged), np.asarray(dense),
                            rtol=2e-5, atol=2e-5)

    def test_striped_token_shards_combine(self):
        """Model-axis token striping: shard partials must combine exactly."""
        B, H, KV, D, bs, nblk, nslots = 2, 4, 2, 16, 16, 4, 32
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (nslots, bs, KV, D))
        vp = jax.random.normal(ks[2], (nslots, bs, KV, D))
        slots = jax.random.randint(ks[3], (B, nblk), 0, nslots)
        ctx = jnp.array([60, 37], jnp.int32)
        o, m, l = paged_attention_ref(q, kp, vp, slots, ctx)
        full = np.asarray(normalize(o, l))
        TP = 4
        outs = []
        for t in range(TP):
            lo = t * (bs // TP)
            kp_t = kp[:, lo:lo + bs // TP]
            vp_t = vp[:, lo:lo + bs // TP]
            outs.append(paged_attention_ref(
                q, kp_t, vp_t, slots, ctx, tok_offset=lo, tok_stride=1,
                block_tokens=bs))
        m_glob = np.max([np.asarray(x[1]) for x in outs], axis=0)
        o_sum = sum(np.asarray(x[0]) * np.exp(np.asarray(x[1]) - m_glob)
                    [..., None] for x in outs)
        l_sum = sum(np.asarray(x[2]) * np.exp(np.asarray(x[1]) - m_glob)
                    for x in outs)
        npt.assert_allclose(o_sum / np.maximum(l_sum, 1e-30)[..., None],
                            full, rtol=2e-5, atol=2e-5)
