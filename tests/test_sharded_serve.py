"""SPMD sharded serving (ISSUE 7, DESIGN.md §sharded-serving).

The engine over a real ``(data, model)`` mesh shards the KV pool and the
TAR/SF/flex translation structures across the ``model`` axis and
translates ONCE per step per shard.  The contracts pinned here:

* differential oracle — token streams on ``(1, 2)`` and ``(2, 2)``
  meshes are BIT-IDENTICAL to ``mesh_shape=None`` across greedy+sampled
  x spec on/off x chunked admission x preempt/resume overload;
* the sharded translate primitive equals the single-device
  ``translate_step`` (hence the host ``translate()`` oracle) field for
  field, including out-of-range write masking;
* hot-path pins survive sharding: the sharded hybrid lookup is traced
  exactly once per serve_step, and ``Engine.step()`` still performs ONE
  device->host fetch;
* mesh-aware accounting — per-shard rsw_hits / flex_walks / swap bytes
  / spec counters sum EXACTLY to the globals (``stats()["shards"]``),
  and ``Engine.check_invariants()`` proves the padded device mirrors
  against the host tables;
* partition math — the logical->physical slot permutation is a
  bijection, identity at one shard, and pass-through for sentinels.

Mesh tests run in subprocesses that set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before importing
jax (single-host SPMD over 8 real host devices, the CI recipe).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import HybridConfig
from repro.core.partition import Partition

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)          # the script pins its own devices
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0 and "ALL_OK" in out.stdout, (
        out.stdout[-2000:], out.stderr[-4000:])


_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import ARCHS, reduced
    from repro.models import model_dims, init_params
    from repro.serve import Engine, EngineConfig, Request
    from repro.serve.sampling import SamplingParams
    cfg = dataclasses.replace(reduced(ARCHS["granite-8b"]), num_layers=2)
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(2), cfg, dims)
    bs = cfg.kv_block_size
""")


# ------------------------------------------------------- partition math

def _parts():
    cfgs = [HybridConfig(total_slots=48, restseg_fraction=0.5, assoc=4,
                         max_seqs=4, max_blocks_per_seq=8),
            HybridConfig(total_slots=16, restseg_fraction=0.5, assoc=8,
                         max_seqs=4, max_blocks_per_seq=8),
            HybridConfig(total_slots=32, restseg_fraction=0.0,
                         mode="flexible_only", max_seqs=4,
                         max_blocks_per_seq=8)]
    return [(c, m) for c in cfgs for m in (1, 2, 4)]


@pytest.mark.parametrize("cfg,m", _parts())
def test_phys_is_a_shard_contiguous_bijection(cfg, m):
    """phys() permutes every logical slot into exactly one shard-local
    range, each range holds slots_per_shard entries, and each slot lands
    on the shard that owns it (set owner for RestSeg, block-range owner
    for FlexSeg)."""
    part = Partition.for_hybrid(cfg, m)
    n = part.rest_slots + part.flex_slots
    sl = np.arange(n)
    ph = part.phys(sl)
    assert len(set(ph.tolist())) == n                    # injective
    assert (ph >= 0).all() and (ph < part.pool_slots).all()
    owners = ph // part.slots_per_shard
    np.testing.assert_array_equal(owners, part.shard_of_slot(sl))
    # RestSeg slots go to the shard owning their SET
    if part.rest_slots:
        sets = sl[:part.rest_slots] // part.assoc
        np.testing.assert_array_equal(owners[:part.rest_slots],
                                      part.shard_of_set(sets))


def test_phys_identity_at_one_shard():
    cfg = HybridConfig(total_slots=48, restseg_fraction=0.5, assoc=4,
                       max_seqs=4, max_blocks_per_seq=8)
    part = Partition.for_hybrid(cfg, 1)
    sl = np.arange(cfg.total_slots)
    np.testing.assert_array_equal(part.phys(sl), sl)


def test_phys_negative_sentinels_pass_through():
    cfg = HybridConfig(total_slots=48, restseg_fraction=0.5, assoc=4,
                       max_seqs=4, max_blocks_per_seq=8)
    part = Partition.for_hybrid(cfg, 2)
    sl = np.asarray([-1, 0, -1, 5])
    ph = part.phys(sl)
    assert (ph[[0, 2]] == -1).all()
    assert (ph[[1, 3]] >= 0).all()


def test_mesh_too_big_raises_clear_error():
    """Requesting more devices than exist fails with an actionable
    message (the XLA_FLAGS recipe), not an obscure jax error."""
    from repro.launch.mesh import make_local_mesh
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_local_mesh(data=64, model=64)


# ------------------------------------------- sharded translate vs oracle

def test_sharded_translate_matches_single_device_oracle():
    """translate_step_sharded under shard_map over 2 and 4 shards equals
    translate_step on the unsharded tables, every StepTranslation field,
    for a randomized alloc/share/promote table state and positions that
    include out-of-range write probes."""
    script = _PRELUDE + textwrap.dedent("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import HybridConfig, HybridKVManager, SWAP
        from repro.core.partition import Partition
        from repro.serve.decode import (DecodeSpec, translate_step,
                                        translate_step_sharded)

        hcfg = HybridConfig(total_slots=48, restseg_fraction=0.5, assoc=4,
                            max_seqs=4, max_blocks_per_seq=8,
                            promote_freq_threshold=2,
                            promote_cost_threshold=4)
        m = HybridKVManager(hcfg)
        rng = np.random.RandomState(0)
        live = []
        for _ in range(80):
            op = rng.randint(6)
            if op == 0 and len(live) < hcfg.max_seqs:
                sid = int(rng.randint(1000))
                if sid not in live:
                    m.register_sequence(sid); live.append(sid)
            elif op in (1, 2) and live:
                m.allocate_block(live[rng.randint(len(live))],
                                 int(rng.randint(hcfg.max_blocks_per_seq)))
            elif op == 3 and len(live) >= 2:
                s, d = rng.choice(len(live), 2, replace=False)
                m.share_prefix(live[s], live[d], 1 + int(rng.randint(3)))
            elif op == 5 and m.blocks:
                vpns = np.array([v for v, i in m.blocks.items()
                                 if i.seg != SWAP], np.int64)
                if vpns.size:
                    m.record_device_stats(vpns, rng.rand(vpns.size) < 0.5,
                                          np.full(vpns.size, 3))
                    m.run_promotions()
            m.take_pending_copies()

        spec = DecodeSpec(block_size=hcfg.block_size,
                          max_blocks_per_seq=hcfg.max_blocks_per_seq,
                          slots_per_group=hcfg.total_slots,
                          n_sets=hcfg.num_sets, assoc=hcfg.assoc,
                          hash_name=hcfg.hash_name)
        B = hcfg.max_seqs
        positions = jnp.asarray(np.r_[
            rng.randint(0, hcfg.max_blocks_per_seq * hcfg.block_size,
                        B - 1),
            hcfg.max_blocks_per_seq * hcfg.block_size + 3], jnp.int32)
        tar = jnp.asarray(m.tar)[None]
        sf = jnp.asarray(m.sf)[None]
        flex = jnp.asarray(m.flex_table.reshape(-1))[None]
        ref = translate_step(tar, sf, flex, positions, spec)

        for M in (2, 4):
            part = Partition.for_hybrid(hcfg, M)
            tar_h = np.zeros((part.n_sets_padded,) + m.tar.shape[1:],
                             m.tar.dtype)
            tar_h[:m.tar.shape[0]] = m.tar
            sf_h = np.zeros(part.n_sets_padded, m.sf.dtype)
            sf_h[:m.sf.shape[0]] = m.sf
            flat = m.flex_table.reshape(-1)
            flex_h = np.full(part.vpn_padded, -1, flat.dtype)
            flex_h[:flat.size] = flat
            mesh = jax.make_mesh((1, M), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            put = lambda a, s: jax.device_put(a, NamedSharding(mesh, s))
            sspec = dataclasses.replace(spec, kv_shards=M)
            fn = jax.shard_map(
                lambda t, s, f: translate_step_sharded(
                    t, s, f, positions, sspec, part),
                mesh=mesh,
                in_specs=(P(None, "model", None), P(None, "model"),
                          P(None, "model")),
                out_specs=P(), check_vma=False)
            got = fn(put(tar_h[None], P(None, "model", None)),
                     put(sf_h[None], P(None, "model")),
                     put(flex_h[None], P(None, "model")))
            for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            print(f"M={M} matches")
        print("ALL_OK")
    """)
    _run(script)


# --------------------------------------------------- differential oracle

def test_streams_bit_identical_across_meshes():
    """(1,2) and (2,2) meshes reproduce the mesh=None token streams bit
    for bit across greedy+sampled x spec on/off, WITH chunked admission
    (a 6-block prompt under a 2-block prefill budget drives the sharded
    prefix-KV chunk path), and the per-shard counters sum exactly to the
    globals while ``Engine.check_invariants()`` holds."""
    script = _PRELUDE + textwrap.dedent("""
        def run(mesh_shape, sampling=None, spec=None):
            eng = Engine(cfg, params, EngineConfig(
                max_batch=4, max_seq_len=8 * bs, auto_release=True,
                prefill_budget=2 * bs, mesh_shape=mesh_shape,
                spec_decode=spec))
            rng = np.random.RandomState(7)
            lens = [2, 6, 2, 3]              # blocks; 6 > budget: chunked
            for i, L in enumerate(lens):
                eng.submit(Request(
                    seq_id=i, prompt=rng.randint(0, cfg.vocab_size, L * bs),
                    max_new_tokens=10,
                    sampling=sampling or SamplingParams()))
            outs = {}
            for _ in range(400):
                for ro in eng.poll():
                    outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
                if not eng.has_unfinished():
                    break
            else:
                raise AssertionError("failed to drain")
            eng.check_invariants()
            return outs, eng.stats()

        SAMPLED = SamplingParams(temperature=0.8, top_k=40, seed=123)
        for spec in (None, "ngram"):
            for sampling in (None, SAMPLED):
                base, bst = run(None, sampling, spec)
                assert all(len(v) == 10 for v in base.values())
                for ms in ((1, 2), (2, 2)):
                    got, gst = run(ms, sampling, spec)
                    assert got == base, (ms, spec, sampling is not None)
                    assert len(gst["shards"]) == 2
                    for key in ("rsw_hits", "flex_walks", "spec_drafted",
                                "spec_accepted"):
                        tot = sum(s[key] for s in gst["shards"])
                        # per-shard sums == this run's global == the
                        # single-device run's global (NOT scaled by M)
                        assert tot == gst[key] == bst[key], (
                            key, tot, gst[key], bst[key])
                    print("OK", ms, spec, sampling is not None, flush=True)
        print("ALL_OK")
    """)
    _run(script)


def test_overload_preempt_resume_bit_identical_on_mesh():
    """The ISSUE-6 overload ladder composes with sharding: 12 requests
    on a 4-sequence pool preempt to the host tier and resume, and the
    streams on (1,2)/(2,2) meshes equal the uncontended single-device
    oracle token for token.  Swap traffic is attributed per shard with
    exact sums (KV bytes to each block's owner, replicated rows to
    shard 0)."""
    script = _PRELUDE + textwrap.dedent("""
        def run(headroom, mesh_shape):
            eng = Engine(cfg, params, EngineConfig(
                max_batch=4, max_seq_len=8 * bs, pool_headroom=headroom,
                auto_release=True, mesh_shape=mesh_shape))
            rng = np.random.RandomState(7)
            for i in range(12):
                eng.submit(Request(
                    seq_id=i, prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                    max_new_tokens=20, sampling=SamplingParams()))
            outs = {}
            for _ in range(900):
                for ro in eng.poll():
                    outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
                eng.manager.check_invariants()
                if not eng.has_unfinished():
                    break
            else:
                raise AssertionError("failed to drain")
            eng.check_invariants()
            return outs, eng.stats()

        oracle, _ = run(2.0, None)
        for ms in ((1, 2), (2, 2)):
            tight, st = run(0.5, ms)
            for sid in oracle:
                assert tight[sid] == oracle[sid], (sid, ms)
            ov = st["overload"]
            assert ov["preempted_seqs"] > 0, "tier never exercised"
            assert ov["swap_bytes_in"] == ov["swap_bytes_out"] > 0
            so = sum(s["swap_bytes_out"] for s in st["shards"])
            si = sum(s["swap_bytes_in"] for s in st["shards"])
            assert so == ov["swap_bytes_out"] and si == ov["swap_bytes_in"]
            print("OK overload", ms, flush=True)
        print("ALL_OK")
    """)
    _run(script)


# ---------------------------------------------------- hot-path pins

def test_translate_once_and_single_fetch_under_sharding():
    """The PR-1 hot-path contracts hold per shard: the sharded hybrid
    lookup is traced exactly ONCE per serve_step (one translate dispatch
    per step per shard — not per layer, not per shard-pair), and
    ``Engine.step()`` performs exactly ONE device->host fetch, spec
    decoding included."""
    script = _PRELUDE + textwrap.dedent("""
        from repro.serve import decode as decode_mod
        from repro.serve.decode import make_serve_step

        eng = Engine(cfg, params, EngineConfig(
            max_batch=4, max_seq_len=4 * bs, mesh_shape=(1, 2)))
        rng = np.random.RandomState(3)
        for sid in (1, 2):
            eng.add_request(Request(
                seq_id=sid, prompt=rng.randint(0, cfg.vocab_size, bs),
                max_new_tokens=32, sampling=SamplingParams()))

        # translate-once per shard: count sharded-lookup traces in a
        # fresh (un-jitted) serve_step over the engine's own state
        calls = []
        orig = decode_mod._hybrid_lookup_sharded
        def counting(*a, **k):
            calls.append(1)
            return orig(*a, **k)
        decode_mod._hybrid_lookup_sharded = counting
        step = make_serve_step(cfg, eng.dims, eng.spec, mesh=eng.mesh,
                               dtype=eng.dstate["k_pool"].dtype,
                               part=eng.partition)
        B = eng.dstate["ctx_len"].shape[0]
        jax.make_jaxpr(lambda p, d, t: step(p, d, t, sample=False))(
            eng.params, eng.dstate, jnp.zeros((B,), jnp.int32))
        assert len(calls) == 1, f"lookup traced {len(calls)}x"
        decode_mod._hybrid_lookup_sharded = orig
        print("translate-once OK", flush=True)

        # single fetch per step, in steady-state decode
        for _ in range(2):
            eng.step()
        fetches = []
        orig_get = jax.device_get
        def counting_get(x):
            fetches.append(1)
            return orig_get(x)
        jax.device_get = counting_get
        for _ in range(3):
            fetches.clear()
            out = eng.step()
            assert len(out) == 2
            assert len(fetches) == 1, len(fetches)
        jax.device_get = orig_get
        print("single-fetch OK", flush=True)

        # the same pin with speculative decoding on the mesh
        sp = Engine(cfg, params, EngineConfig(
            max_batch=4, max_seq_len=4 * bs, mesh_shape=(1, 2),
            spec_decode="ngram"))
        for sid in (1, 2):
            sp.add_request(Request(
                seq_id=sid, prompt=rng.randint(0, cfg.vocab_size, bs),
                max_new_tokens=32, sampling=SamplingParams()))
        for _ in range(2):
            sp.step()
        jax.device_get = counting_get
        for _ in range(3):
            fetches.clear()
            sp.step()
            assert len(fetches) == 1, len(fetches)
        jax.device_get = orig_get
        print("ALL_OK")
    """)
    _run(script)
