"""Training substrate: optimizers, schedules, compression, checkpointing,
fault tolerance, and the end-to-end resilient loop."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model_dims, FwdOptions
from repro.train import TrainConfig, make_train_step, init_state
from repro.optim import (make_optimizer, clip_by_global_norm, global_norm,
                         warmup_cosine, warmup_linear)
from repro.dist import compression
from repro.data import DataConfig, SyntheticLM, PackedFileDataset, host_slice
from repro.ckpt import CheckpointManager
from repro.runtime import (FaultInjector, InjectedFault, StragglerMonitor,
                           ResilientLoop)


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adamw", "adafactor"])
    def test_converges_on_quadratic(self, name):
        opt = make_optimizer(name, weight_decay=0.0)
        params = {"a": {"w": jnp.ones((8, 16)) * 3.0},
                  "b": jnp.ones((5,)) * -2.0}
        state = opt.init(params)

        @jax.jit
        def step(params, state, i):
            loss, g = jax.value_and_grad(
                lambda p: sum(jnp.sum(x ** 2)
                              for x in jax.tree.leaves(p)))(params)
            params, state = opt.update(g, state, params, i, 0.05)
            return params, state, loss

        loss0 = None
        for i in range(200):
            params, state, loss = step(params, state, jnp.asarray(i))
            loss0 = loss0 if loss0 is not None else float(loss)
        assert float(loss) < 0.05 * loss0

    def test_adafactor_state_is_factored(self):
        opt = make_optimizer("adafactor")
        params = {"w": jnp.ones((64, 128)), "b": jnp.ones((9,))}
        st = opt.init(params)
        assert st["v"]["w"]["vr"].shape == (64,)
        assert st["v"]["w"]["vc"].shape == (128,)
        assert st["v"]["b"]["v"].shape == (9,)

    def test_grad_clip(self):
        g = {"w": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        npt.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
        g2 = {"w": jnp.full((10,), 1e-3)}
        same, _ = clip_by_global_norm(g2, 1.0)
        npt.assert_allclose(np.asarray(same["w"]), np.asarray(g2["w"]))


class TestSchedules:
    def test_warmup_cosine_shape(self):
        f = warmup_cosine(1.0, 10, 100)
        assert float(f(jnp.asarray(0))) == 0.0
        npt.assert_allclose(float(f(jnp.asarray(10))), 1.0, rtol=1e-5)
        assert float(f(jnp.asarray(100))) <= 0.2
        assert float(f(jnp.asarray(55))) < float(f(jnp.asarray(20)))


class TestCompression:
    def test_int8_roundtrip_bounded(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3
        q, s = compression.quantize_int8(x)
        err = np.abs(np.asarray(compression.dequantize_int8(q, s) - x))
        assert err.max() <= float(s) * 0.5 + 1e-6

    def test_error_feedback_preserves_sum(self):
        """EF: sum of compressed grads over steps ~= sum of true grads."""
        key = jax.random.PRNGKey(1)
        ef = compression.EFState(residual=jnp.zeros(64))
        total_true = jnp.zeros(64)
        total_hat = jnp.zeros(64)
        for i in range(50):
            key, k = jax.random.split(key)
            g = jax.random.normal(k, (64,)) * 0.1
            g_hat, ef = compression.compress_with_ef(g, ef)
            total_true += g
            total_hat += g_hat
        # residual bounds the discrepancy
        npt.assert_allclose(np.asarray(total_hat + ef.residual),
                            np.asarray(total_true), rtol=1e-4, atol=1e-4)


class TestData:
    def test_deterministic_restartable(self):
        data = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                      global_batch=8, seed=3))
        b1 = data.batch_at(7)
        b2 = data.batch_at(7)
        npt.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = data.batch_at(8)
        assert not np.array_equal(b1["tokens"], b3["tokens"])
        assert b1["tokens"].max() < 100 and b1["tokens"].min() >= 0

    def test_host_sharding_disjoint(self):
        data = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                      global_batch=8, seed=3))
        h0 = data.batch_at(0, host_index=0, host_count=2)
        h1 = data.batch_at(0, host_index=1, host_count=2)
        assert h0["tokens"].shape[0] == 4
        assert not np.array_equal(h0["tokens"], h1["tokens"])

    def test_packed_file_dataset(self, tmp_path):
        path = os.path.join(tmp_path, "tokens.bin")
        np.arange(10000, dtype=np.uint32).tofile(path)
        ds = PackedFileDataset(path, DataConfig(vocab_size=50000, seq_len=32,
                                                global_batch=4, seed=0))
        b = ds.batch_at(0)
        assert b["tokens"].shape == (4, 32)
        npt.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


class TestCheckpoint:
    def test_atomic_commit_and_prune(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        state = {"w": jnp.arange(8.0), "step": jnp.asarray(3)}
        for s in (10, 20, 30):
            mgr.save(s, state, blocking=True)
        assert mgr.all_steps() == [20, 30]
        # a dir without COMMIT must be invisible
        os.makedirs(os.path.join(tmp_path, "step_40"))
        assert mgr.latest_step() == 30

    def test_restore_roundtrip_and_shape_guard(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state = {"w": jnp.arange(8.0), "step": jnp.asarray(7)}
        mgr.save(5, state, blocking=True)
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            state)
        restored, step = mgr.restore(like)
        assert step == 5
        npt.assert_array_equal(restored["w"], np.arange(8.0))
        bad = {"w": jax.ShapeDtypeStruct((9,), jnp.float32),
               "step": like["step"]}
        with pytest.raises(ValueError):
            mgr.restore(bad)


class TestFaultTolerance:
    def test_straggler_monitor(self):
        mon = StragglerMonitor(n_hosts=4, threshold=1.5)
        for step in range(10):
            for h in range(4):
                mon.record(h, 1.0 if h != 2 else 3.0)
        assert mon.stragglers() == [2]

    def test_resilient_loop_restart_and_replay(self, tmp_path):
        cfg = reduced(ARCHS["granite-8b"])
        dims = model_dims(cfg, tp=1)
        tc = TrainConfig(lr=1e-3, warmup_steps=5, total_steps=40,
                         microbatches=2, grad_compression=True,
                         dtype=jnp.float32)
        state = init_state(jax.random.PRNGKey(0), cfg, dims, tc)
        step_fn = jax.jit(make_train_step(
            cfg, dims, tc, FwdOptions(dtype=jnp.float32, remat=True)))
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=8, seed=1))
        ckpt = CheckpointManager(str(tmp_path), keep_last=2)
        loop = ResilientLoop(ckpt, data, step_fn, ckpt_every=10,
                             injector=FaultInjector([17]))
        rep = loop.run(state, total_steps=25)
        assert rep.restarts == 1 and rep.final_step == 25
        assert rep.losses[-1] < rep.losses[0]
        # replayed steps 10..16 must match the first pass bit-for-bit
        npt.assert_allclose(rep.losses[10:17], rep.losses[17:24], rtol=1e-6)

    def test_retry_budget_exhausted(self, tmp_path):
        cfg = reduced(ARCHS["granite-8b"])
        dims = model_dims(cfg, tp=1)
        tc = TrainConfig(dtype=jnp.float32)
        state = init_state(jax.random.PRNGKey(0), cfg, dims, tc)
        step_fn = jax.jit(make_train_step(
            cfg, dims, tc, FwdOptions(dtype=jnp.float32)))
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=4, seed=1))
        ckpt = CheckpointManager(str(tmp_path))
        loop = ResilientLoop(ckpt, data, step_fn, ckpt_every=100,
                             max_restarts=1,
                             injector=FaultInjector([2, 3]))
        with pytest.raises(InjectedFault):
            loop.run(state, total_steps=10)
