"""Scheduler tests (ISSUE 3): pluggable admission policies.

* policy unit tests against the Scheduler protocol (no engine, no jit);
* priority-with-aging non-starvation — deterministic bound check plus a
  hypothesis property test over priorities / aging rates / queue depths;
* engine-level: the FIFO scheduler reproduces the PR-2 hard-coded deque
  admission bit-for-bit (chunk log compared against a reference
  simulation of the old algorithm), and shortest-prompt-first /
  priority policies reorder admission as specified.
"""
from collections import deque

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import (Engine, EngineConfig, Request, SamplingParams,
                         FIFOScheduler, ShortestPromptFirst,
                         PriorityAgingScheduler, make_scheduler)


def _req(sid, blocks=1, priority=0, bs=4):
    return Request(seq_id=sid, prompt=np.zeros(blocks * bs, np.int64),
                   priority=priority)


# ------------------------------------------------------------- unit tests

def test_fifo_is_submission_order():
    s = FIFOScheduler()
    reqs = [_req(i) for i in range(4)]
    for r in reqs:
        s.add(r, 0)
    order = []
    while len(s):
        r = s.select(now=9)
        s.pop(r)
        order.append(r.seq_id)
    assert order == [0, 1, 2, 3]


def test_spf_orders_by_prompt_length_then_arrival():
    s = ShortestPromptFirst()
    for sid, blocks in ((0, 3), (1, 1), (2, 2), (3, 1)):
        s.add(_req(sid, blocks), 0)
    order = []
    while len(s):
        r = s.select(now=0)
        s.pop(r)
        order.append(r.seq_id)
    assert order == [1, 3, 2, 0]          # 1-block ties drain FIFO


def test_priority_zero_aging_is_strict_priority():
    s = PriorityAgingScheduler(aging_rate=0.0)
    for sid, pri in ((0, 1), (1, 5), (2, 5), (3, 0)):
        s.add(_req(sid, priority=pri), 0)
    order = []
    for now in range(4):
        r = s.select(now)
        s.pop(r)
        order.append(r.seq_id)
    assert order == [1, 2, 0, 3]          # equal priorities drain FIFO


def test_priority_aging_overtakes_fresh_arrivals():
    """A low-priority request waiting long enough beats a fresher
    high-priority one: effective = priority + rate * wait."""
    s = PriorityAgingScheduler(aging_rate=1.0)
    s.add(_req(0, priority=0), 0)
    s.add(_req(1, priority=3), 5)
    # at now=5: eff(0) = 5, eff(1) = 3 -> the aged request wins
    assert s.select(now=5).seq_id == 0


def test_make_scheduler_resolution():
    assert isinstance(make_scheduler("fifo"), FIFOScheduler)
    assert isinstance(make_scheduler("spf"), ShortestPromptFirst)
    inst = PriorityAgingScheduler(aging_rate=0.5)
    assert make_scheduler(inst) is inst
    assert isinstance(make_scheduler(FIFOScheduler), FIFOScheduler)
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("lifo")


# -------------------------------------------------------- non-starvation

def _starvation_steps(low, high, rate, n_initial, max_steps):
    """Simulate the adversarial stream: one high-priority arrival per
    step, one admission per step (the tight-budget regime where each
    step's budget covers exactly one queued prompt's first chunk).
    Returns the step at which the low-priority victim is admitted, or
    None if it starved past ``max_steps``."""
    sched = PriorityAgingScheduler(aging_rate=rate)
    victim = _req(10_000, blocks=8, priority=low)
    sched.add(victim, 0)
    for i in range(n_initial):
        sched.add(_req(20_000 + i, priority=high), 0)
    for now in range(1, max_steps + 1):
        sched.add(_req(now, priority=high), now)
        chosen = sched.select(now)
        sched.pop(chosen)
        if chosen is victim:
            return now
    return None


def test_priority_aging_never_starves_deterministic():
    admitted_at = _starvation_steps(low=0, high=8, rate=0.5,
                                    n_initial=3, max_steps=40)
    assert admitted_at is not None
    # sanity: zero aging DOES starve under the same stream
    assert _starvation_steps(low=0, high=8, rate=0.0, n_initial=3,
                             max_steps=40) is None


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                        # optional dev dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=50)
    @given(low=st.integers(0, 3), high=st.integers(4, 10),
           rate=st.floats(0.05, 2.0), n_initial=st.integers(0, 5))
    def test_priority_aging_never_starves_property(low, high, rate,
                                                   n_initial):
        """effective = priority + rate*wait grows without bound, so the
        victim must be admitted within (high-low)/rate + queue slack
        steps whatever the priorities / rate / initial backlog."""
        bound = int((high - low) / rate) + 2 * (n_initial + 1) + 10
        assert _starvation_steps(low, high, rate, n_initial,
                                 bound) is not None
else:
    def test_priority_aging_never_starves_property():
        pytest.skip("hypothesis not installed")


# ---------------------------------------------------------- engine level

@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    return cfg, params


def _drain(eng):
    steps = 0
    while eng.has_unfinished():
        eng.step()
        steps += 1
        assert steps < 300, "engine failed to drain"
    return steps


def _pr2_admission_log(prompt_tokens, budget, bs):
    """Reference simulation of the PR-2 hard-coded admission deque (the
    exact loop the old Engine._admit ran, in the no-slot-contention
    regime): FIFO head, chunked at block granularity, partial chunk
    stays at the head."""
    waiting = deque(prompt_tokens.items())
    prefilling = {}
    log = []
    while waiting:
        b = budget
        while waiting and b >= bs:
            sid, total = waiting[0]
            start = prefilling.get(sid, 0)
            take = min(total - start, b // bs * bs)
            if take <= 0:
                break
            end = start + take
            b -= take
            prefilling[sid] = end
            log.append((sid, start, end))
            if end == total:
                waiting.popleft()
    return log


def test_engine_fifo_matches_pr2_admission_bit_for_bit(setup):
    """The default (FIFO) scheduler's chunk-by-chunk admission trace is
    identical to the PR-2 deque algorithm: same chunks, same order, same
    boundaries."""
    cfg, params = setup
    bs = cfg.kv_block_size
    blocks = {0: 2, 1: 5, 2: 1, 3: 3}
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_seq_len=8 * bs, prefill_budget=2 * bs))
    rng = np.random.RandomState(0)
    for sid, nb in blocks.items():
        eng.submit(Request(seq_id=sid,
                           prompt=rng.randint(0, cfg.vocab_size, nb * bs),
                           max_new_tokens=2))
    _drain(eng)
    want = _pr2_admission_log({s: nb * bs for s, nb in blocks.items()},
                              budget=2 * bs, bs=bs)
    # admission_log records carry path/fwd_tokens too (prefix-KV PR);
    # the PR-2 pin is on the chunk boundaries and their order
    assert [(r.seq_id, r.start, r.end) for r in eng.admission_log] == want


def test_engine_spf_admits_short_prompts_first(setup):
    cfg, params = setup
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_seq_len=8 * bs, prefill_budget=bs,
        scheduler="spf"))
    rng = np.random.RandomState(1)
    for sid, nb in ((0, 3), (1, 1), (2, 2), (3, 1)):
        eng.submit(Request(seq_id=sid,
                           prompt=rng.randint(0, cfg.vocab_size, nb * bs),
                           max_new_tokens=2))
    _drain(eng)
    first_chunk_order = [r.seq_id for r in eng.admission_log
                         if r.start == 0]
    assert first_chunk_order == [1, 3, 2, 0]


def test_engine_priority_scheduler_orders_admission(setup):
    cfg, params = setup
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_seq_len=6 * bs, prefill_budget=bs,
        scheduler=PriorityAgingScheduler(aging_rate=0.0)))
    rng = np.random.RandomState(2)
    for sid, pri in ((0, 0), (1, 5), (2, 1)):
        eng.submit(Request(seq_id=sid,
                           prompt=rng.randint(0, cfg.vocab_size, bs),
                           max_new_tokens=2, priority=pri))
    _drain(eng)
    first_chunk_order = [r.seq_id for r in eng.admission_log
                         if r.start == 0]
    assert first_chunk_order == [1, 2, 0]


def test_scheduler_choice_does_not_change_tokens(setup):
    """Admission ORDER is policy; token CONTENT is not: the same request
    set generates identical tokens under FIFO and SPF (greedy decode is
    deterministic and schedule-independent)."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(8)
    prompts = {0: rng.randint(0, cfg.vocab_size, 3 * bs),
               1: rng.randint(0, cfg.vocab_size, bs)}

    def run(policy):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=2, max_seq_len=6 * bs, prefill_budget=bs,
            scheduler=policy))
        reqs = [Request(seq_id=s, prompt=p, max_new_tokens=4)
                for s, p in prompts.items()]
        for r in reqs:
            eng.submit(r)
        _drain(eng)
        return {r.seq_id: list(r.generated) for r in reqs}

    assert run("fifo") == run("spf")
