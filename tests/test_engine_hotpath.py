"""Hot-path regression tests for the translate-once decode step.

Guards the PR-1 invariants (DESIGN.md §translate-once):

* the hybrid translation primitive is dispatched EXACTLY once per
  serve_step trace — not once per attention layer, not once on host;
* the in-graph translation telemetry (slots / in_rest / accesses /
  mapped) is bit-identical to the host-side ``translate()`` oracle;
* the engine's dirty-delta TAR/SF/flex sync reproduces the full
  re-upload bit-for-bit under a randomized alloc/evict/share/promote
  workload;
* batched slot-migration copies match sequential per-copy application,
  including chained copies within one drain;
* ``Engine.step()`` performs one device->host fetch per step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import (HybridConfig, HybridKVManager, translate, REST,
                        FLEX, SWAP)
from repro.models import model_dims, init_params
from repro.serve import Engine, Request
from repro.serve import decode as decode_mod
from repro.serve.decode import (DecodeSpec, init_decode_state,
                                make_serve_step, translate_step)


def _small_spec(m: HybridKVManager) -> DecodeSpec:
    cfg = m.cfg
    return DecodeSpec(block_size=cfg.block_size,
                      max_blocks_per_seq=cfg.max_blocks_per_seq,
                      slots_per_group=cfg.total_slots,
                      n_sets=cfg.num_sets, assoc=cfg.assoc,
                      hash_name=cfg.hash_name)


def _random_workload(seed: int, n_ops: int = 80) -> HybridKVManager:
    """Drive a manager through a random alloc/free/share/promote history."""
    rng = np.random.RandomState(seed)
    cfg = HybridConfig(total_slots=48, restseg_fraction=0.5, assoc=4,
                       max_seqs=4, max_blocks_per_seq=8,
                       promote_freq_threshold=2, promote_cost_threshold=4)
    m = HybridKVManager(cfg)
    live = []
    for _ in range(n_ops):
        op = rng.randint(6)
        if op == 0 and len(live) < cfg.max_seqs:
            sid = int(rng.randint(1000))
            if sid not in live:
                m.register_sequence(sid)
                live.append(sid)
        elif op in (1, 2) and live:
            m.allocate_block(live[rng.randint(len(live))],
                             int(rng.randint(cfg.max_blocks_per_seq)))
        elif op == 3 and len(live) >= 2:
            src, dst = rng.choice(len(live), 2, replace=False)
            m.share_prefix(live[src], live[dst],
                           1 + int(rng.randint(3)))
        elif op == 4 and live and len(live) > 2 and rng.rand() < 0.3:
            sid = live.pop(rng.randint(len(live)))
            m.free_sequence(sid)
        elif op == 5 and m.blocks:
            vpns = np.array([v for v, i in m.blocks.items()
                             if i.seg != SWAP], np.int64)
            if vpns.size:
                m.record_device_stats(
                    vpns, rng.rand(vpns.size) < 0.5,
                    np.full(vpns.size, 3))
                m.run_promotions()
        m.check_invariants()
    return m


# ------------------------------------------------- translate-once invariant

def test_translation_runs_once_per_step(monkeypatch):
    """The hybrid lookup is dispatched exactly once per step trace.

    The pre-PR decode called the RSW twice at trace time (block-read +
    current-block write) *inside* the layer scan body; the hoisted path
    batches both into one `_hybrid_lookup` call before the scan.  Count
    calls during tracing: must be exactly 1 for a multi-attention-layer
    model.
    """
    cfg = reduced(ARCHS["granite-8b"])
    assert cfg.num_layers >= 2                   # multi-layer, all attention
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    spec = DecodeSpec(block_size=cfg.kv_block_size, max_blocks_per_seq=4,
                      slots_per_group=16, n_sets=2, assoc=4)
    calls = []
    orig = decode_mod._hybrid_lookup

    def counting(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(decode_mod, "_hybrid_lookup", counting)
    step = make_serve_step(cfg, dims, spec, mesh=None, dtype=jnp.float32)
    dstate = init_decode_state(cfg, dims, spec, 2, 1)
    jax.make_jaxpr(step)(params, dstate, jnp.zeros((2,), jnp.int32))
    assert len(calls) == 1


# ---------------------------------------------- telemetry vs. host oracle

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_step_translation_matches_host_oracle(seed):
    """translate_step (the in-graph telemetry source) == core.translate."""
    m = _random_workload(seed)
    spec = _small_spec(m)
    ts = m.device_state()
    rng = np.random.RandomState(seed + 100)
    B = m.cfg.max_seqs
    positions = jnp.asarray(
        rng.randint(0, m.cfg.max_blocks_per_seq * m.cfg.block_size, B),
        jnp.int32)
    tar = jnp.asarray(m.tar)[None]
    sf = jnp.asarray(m.sf)[None]
    flex = jnp.asarray(m.flex_table.reshape(-1))[None]
    tr = translate_step(tar, sf, flex, positions, spec)

    vpns = np.asarray(tr.vpns).reshape(-1)
    oracle = translate(ts, jnp.asarray(vpns, jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(tr.slots[0]).reshape(-1), np.asarray(oracle.slot))
    np.testing.assert_array_equal(
        np.asarray(tr.in_rest[0]).reshape(-1), np.asarray(oracle.in_rest))
    np.testing.assert_array_equal(
        np.asarray(tr.mapped[0]).reshape(-1), np.asarray(oracle.mapped))
    np.testing.assert_array_equal(
        np.asarray(tr.accesses[0]).reshape(-1), np.asarray(oracle.accesses))
    # the write-slot lookup agrees with the oracle on the current blocks
    cur_vpn = (np.arange(B) * m.cfg.max_blocks_per_seq
               + np.asarray(positions) // m.cfg.block_size)
    w_oracle = translate(ts, jnp.asarray(cur_vpn, jnp.int32))
    np.testing.assert_array_equal(np.asarray(tr.w_valid[0]),
                                  np.asarray(w_oracle.mapped))
    got = np.asarray(tr.w_slot[0])[np.asarray(w_oracle.mapped)]
    np.testing.assert_array_equal(
        got, np.asarray(w_oracle.slot)[np.asarray(w_oracle.mapped)])


def test_stale_slot_write_is_masked():
    """An idle/released slot whose ctx_len ran past its vpn range must
    never produce a valid write slot (its cur_vpn would otherwise alias
    another sequence's blocks and corrupt a live block)."""
    m = _random_workload(0)
    spec = _small_spec(m)
    nblk, bs = m.cfg.max_blocks_per_seq, m.cfg.block_size
    B = m.cfg.max_seqs
    positions = jnp.full((B,), nblk * bs + 3, jnp.int32)   # out of range
    tr = translate_step(jnp.asarray(m.tar)[None], jnp.asarray(m.sf)[None],
                        jnp.asarray(m.flex_table.reshape(-1))[None],
                        positions, spec)
    assert not bool(np.asarray(tr.w_valid).any())


# -------------------------------------------------- engine-level contracts

@pytest.fixture(scope="module")
def small_engine_factory():
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)

    def make(**kw):
        kw.setdefault("max_batch", 4)
        kw.setdefault("max_seq_len", 4 * cfg.kv_block_size)
        return Engine(cfg, params, **kw), cfg

    return make


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_delta_sync_bit_identical_to_full_reupload(small_engine_factory,
                                                   seed):
    """Randomized alloc/evict/share/promote; after every delta sync the
    device TAR/SF/flex must equal the manager's host mirrors exactly."""
    eng, cfg = small_engine_factory()
    eng._sync_translation(full=True)             # baseline upload
    m = eng.manager
    rng = np.random.RandomState(seed)
    live = []
    for step in range(60):
        op = rng.randint(6)
        if op == 0 and len(live) < m.cfg.max_seqs:
            sid = int(rng.randint(1000))
            if sid not in live:
                m.register_sequence(sid)
                live.append(sid)
        elif op in (1, 2) and live:
            m.allocate_block(live[rng.randint(len(live))],
                             int(rng.randint(m.cfg.max_blocks_per_seq)))
        elif op == 3 and len(live) >= 2:
            s, d = rng.choice(len(live), 2, replace=False)
            m.share_prefix(live[s], live[d], 1 + int(rng.randint(2)))
        elif op == 4 and len(live) > 1 and rng.rand() < 0.3:
            m.free_sequence(live.pop(rng.randint(len(live))))
        elif op == 5 and m.blocks:
            vpns = np.array([v for v, i in m.blocks.items()
                             if i.seg != SWAP], np.int64)
            if vpns.size:
                m.record_device_stats(vpns,
                                      rng.rand(vpns.size) < 0.4,
                                      np.full(vpns.size, 4))
                m.run_promotions()
        m.take_pending_copies()                  # copies irrelevant here
        if rng.rand() < 0.5:                     # sync at random points
            eng._sync_translation()
            np.testing.assert_array_equal(
                np.asarray(eng.dstate["tar"][0]), m.tar)
            np.testing.assert_array_equal(
                np.asarray(eng.dstate["sf"][0]), m.sf)
            np.testing.assert_array_equal(
                np.asarray(eng.dstate["flex"][0]),
                m.flex_table.reshape(-1))
    eng._sync_translation()
    np.testing.assert_array_equal(np.asarray(eng.dstate["tar"][0]), m.tar)
    np.testing.assert_array_equal(np.asarray(eng.dstate["sf"][0]), m.sf)
    np.testing.assert_array_equal(np.asarray(eng.dstate["flex"][0]),
                                  m.flex_table.reshape(-1))


def test_batched_copies_match_sequential(small_engine_factory):
    """One gather/scatter == sequential per-copy application (chains too)."""
    eng, _ = small_engine_factory()
    shape = eng.dstate["k_pool"].shape
    rng = np.random.RandomState(7)
    kp = rng.randn(*shape).astype(np.float32)
    vp = rng.randn(*shape).astype(np.float32)
    eng.dstate["k_pool"] = jnp.asarray(kp)
    eng.dstate["v_pool"] = jnp.asarray(vp)
    # includes a chain 3->5->9 and an overwrite of dst 11
    copies = [(3, 5), (5, 9), (2, 11), (4, 11), (0, 1)]
    ref_k, ref_v = kp.copy(), vp.copy()
    for s, d in copies:
        ref_k[:, d] = ref_k[:, s]
        ref_v[:, d] = ref_v[:, s]
    eng.manager.pending_copies = list(copies)
    eng._apply_copies()
    np.testing.assert_array_equal(np.asarray(eng.dstate["k_pool"]), ref_k)
    np.testing.assert_array_equal(np.asarray(eng.dstate["v_pool"]), ref_v)


def test_engine_step_single_fetch(small_engine_factory, monkeypatch):
    """The steady-state step performs exactly ONE device->host fetch,
    independent of batch size (two live sequences here)."""
    eng, cfg = small_engine_factory()
    bs = cfg.kv_block_size
    rng = np.random.RandomState(3)
    for sid in (1, 2):
        eng.add_request(Request(seq_id=sid,
                                prompt=rng.randint(0, cfg.vocab_size, bs),
                                max_new_tokens=8))
    fetches = []
    orig = jax.device_get

    def counting(x):
        fetches.append(1)
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting)
    import repro.serve.engine as engine_mod
    monkeypatch.setattr(engine_mod.jax, "device_get", counting)
    for _ in range(3):
        fetches.clear()
        out = eng.step()
        assert len(out) == 2
        assert len(fetches) == 1


def test_spec_decode_step_single_fetch(small_engine_factory, monkeypatch):
    """Speculative decoding preserves the single-fetch contract: one
    device_get per step fetches the whole (B, K+1) accepted window plus
    per-slot emitted counts and telemetry — K+1 tokens per fetch instead
    of one."""
    eng, cfg = small_engine_factory(spec_decode="ngram",
                                    num_draft_tokens=4, max_seq_len=128)
    bs = cfg.kv_block_size
    rng = np.random.RandomState(3)
    for sid in (1, 2):
        eng.add_request(Request(seq_id=sid,
                                prompt=rng.randint(0, cfg.vocab_size, bs),
                                max_new_tokens=64))
    fetches = []
    orig = jax.device_get

    def counting(x):
        fetches.append(1)
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting)
    import repro.serve.engine as engine_mod
    monkeypatch.setattr(engine_mod.jax, "device_get", counting)
    for _ in range(4):
        fetches.clear()
        out = eng.step()
        assert len(out) == 2
        assert len(fetches) == 1


def test_spec_translation_runs_once_per_step(monkeypatch):
    """The speculative verify step dispatches the hybrid lookup exactly
    once: the K+1 per-position write slots are GATHERED from the one
    translation, never re-looked-up."""
    from repro.serve.spec_decode import make_spec_decode_step
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    spec = DecodeSpec(block_size=cfg.kv_block_size, max_blocks_per_seq=4,
                      slots_per_group=16, n_sets=2, assoc=4)
    calls = []
    orig = decode_mod._hybrid_lookup

    def counting(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(decode_mod, "_hybrid_lookup", counting)
    step = make_spec_decode_step(cfg, dims, spec, num_draft_tokens=4,
                                 mesh=None, dtype=jnp.float32)
    dstate = init_decode_state(cfg, dims, spec, 2, 1)
    dstate["hist"] = jnp.full((2, 4 * cfg.kv_block_size), -1, jnp.int32)
    jax.make_jaxpr(step)(params, dstate, jnp.zeros((2,), jnp.int32))
    assert len(calls) == 1
