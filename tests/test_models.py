"""Per-architecture smoke tests (assignment: reduced config, one
forward/train step on CPU, shape + finiteness assertions) and model-level
numerics (flash vs dense, SSD vs naive recurrence, decode vs train)."""
import jax
import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest

from repro.configs import ARCHS, reduced, list_archs, resolve
from repro.models import (model_dims, init_params, forward, loss_fn,
                          FwdOptions, dense_attention, flash_attention_jax)
from repro.models.ssm import (mamba_dims, init_mamba, mamba_forward,
                              ssd_chunked, init_mamba_cache,
                              mamba_decode_step)
from repro.optim import make_optimizer, clip_by_global_norm


def _batch(cfg, B=2, S=32):
    b = {"tokens": jnp.ones((B, S), jnp.int32) * 3,
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend != "none":
        b["frontend"] = jnp.full((B, cfg.frontend_tokens, cfg.d_model), 0.1,
                                 jnp.float32)
    return b


@pytest.mark.parametrize("arch", list_archs())
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch):
        cfg = reduced(ARCHS[arch])
        dims = model_dims(cfg, tp=1)
        params = init_params(jax.random.PRNGKey(0), cfg, dims)
        batch = _batch(cfg)
        logits, aux, _ = forward(params, batch, cfg, dims)
        assert logits.shape == (2, 32, dims.vocab)
        assert np.isfinite(np.asarray(logits)).all()

    def test_one_train_step(self, arch):
        cfg = reduced(ARCHS[arch])
        dims = model_dims(cfg, tp=1)
        params = init_params(jax.random.PRNGKey(0), cfg, dims)
        opt = make_optimizer(cfg.optimizer)
        ostate = opt.init(params)
        batch = _batch(cfg)

        @jax.jit
        def step(params, ostate):
            (loss, m), g = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg, dims), has_aux=True)(params)
            g, _ = clip_by_global_norm(g, 1.0)
            params, ostate = opt.update(g, ostate, params,
                                        jnp.zeros((), jnp.int32), 1e-3)
            return params, ostate, loss

        p1, o1, l1 = step(params, ostate)
        p2, o2, l2 = step(p1, o1)
        assert np.isfinite(float(l1)) and np.isfinite(float(l2))
        assert float(l2) < float(l1)  # one step on a fixed batch must help

    def test_remat_matches_no_remat(self, arch):
        cfg = reduced(ARCHS[arch])
        dims = model_dims(cfg, tp=1)
        params = init_params(jax.random.PRNGKey(1), cfg, dims)
        batch = _batch(cfg)
        l1, _ = loss_fn(params, batch, cfg, dims, FwdOptions(remat=False))
        l2, _ = loss_fn(params, batch, cfg, dims, FwdOptions(remat=True))
        npt.assert_allclose(float(l1), float(l2), rtol=1e-5)


class TestPadding:
    def test_head_vocab_padding_resolution(self):
        cfg = ARCHS["qwen2.5-14b"]              # 40 heads, tp 16 -> pad 48
        r = resolve(cfg, 16)
        assert r.num_heads == 48 and r.pad_heads == 8
        assert r.vocab_size % 16 == 0 and r.vocab_size % 128 == 0
        r1 = resolve(cfg, 1)
        assert r1.num_heads == 40

    def test_padded_vocab_masked_in_logits(self):
        cfg = reduced(ARCHS["granite-8b"])
        dims = model_dims(cfg, tp=1)._replace(vocab=512, logical_vocab=256)
        params = init_params(jax.random.PRNGKey(0), cfg, dims)
        logits, _, _ = forward(params, _batch(cfg), cfg, dims)
        assert float(logits[..., 256:].max()) <= -1e8


class TestAttentionNumerics:
    @pytest.mark.parametrize("shape", [(2, 64, 8, 2, 16), (1, 96, 6, 3, 8)])
    def test_flash_vs_dense(self, shape):
        B, S, H, KV, D = shape
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, KV, D))
        v = jax.random.normal(ks[2], (B, S, KV, D))
        for causal in (True, False):
            ref = dense_attention(q, k, v, causal=causal)
            out = flash_attention_jax(q, k, v, causal=causal, q_chunk=32,
                                      kv_chunk=32)
            npt.assert_allclose(np.asarray(out), np.asarray(ref),
                                rtol=2e-5, atol=2e-5)
            if causal:
                tri = flash_attention_jax(q, k, v, causal=True, q_chunk=32,
                                          kv_chunk=32,
                                          triangular_schedule=True)
                npt.assert_allclose(np.asarray(tri), np.asarray(ref),
                                    rtol=2e-5, atol=2e-5)

    def test_flash_grad_matches_dense(self):
        B, S, H, KV, D = 1, 64, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, KV, D))
        v = jax.random.normal(ks[2], (B, S, KV, D))
        g1 = jax.grad(lambda q: dense_attention(q, k, v).sum())(q)
        g2 = jax.grad(lambda q: flash_attention_jax(
            q, k, v, q_chunk=16, kv_chunk=16).sum())(q)
        npt.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=5e-4,
                            atol=5e-4)

    def test_odd_lengths_autochunk(self):
        # 17 chunks of 256 etc: pick_chunk must keep things working
        B, S, H, KV, D = 1, 68, 4, 4, 8
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, KV, D))
        v = jax.random.normal(ks[2], (B, S, KV, D))
        out = flash_attention_jax(q, k, v, q_chunk=32, kv_chunk=32)
        ref = dense_attention(q, k, v)
        npt.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                            atol=2e-5)


class TestSSDNumerics:
    def test_chunked_vs_naive(self):
        b, l, h, p, n = 2, 64, 3, 4, 8
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        xd = np.asarray(jax.random.normal(ks[0], (b, l, h, p)))
        dtA = -np.abs(np.asarray(jax.random.normal(ks[1], (b, l, h)))) * 0.1
        B_ = np.asarray(jax.random.normal(ks[2], (b, l, n)))
        C_ = np.asarray(jax.random.normal(ks[3], (b, l, n)))
        s = np.zeros((b, h, p, n))
        ys = []
        for t in range(l):
            s = s * np.exp(dtA[:, t])[:, :, None, None] + np.einsum(
                "bn,bhp->bhpn", B_[:, t], xd[:, t])
            ys.append(np.einsum("bn,bhpn->bhp", C_[:, t], s))
        y_ref = np.stack(ys, 1)
        for chunk in (8, 16, 32):
            y, s_out = ssd_chunked(jnp.asarray(xd), jnp.asarray(dtA),
                                   jnp.asarray(B_), jnp.asarray(C_),
                                   chunk=chunk)
            npt.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
            npt.assert_allclose(np.asarray(s_out), s, rtol=1e-4, atol=1e-4)

    def test_decode_chain_matches_forward(self):
        dims = mamba_dims(32, 16, 8, 2, 4)
        p = init_mamba(jax.random.PRNGKey(3), dims)
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 32)) * 0.5
        y_train, _ = mamba_forward(p, x, dims, chunk=8)
        cache = init_mamba_cache(2, dims)
        ys = []
        for t in range(16):
            y_t, cache = mamba_decode_step(p, x[:, t], cache, dims)
            ys.append(y_t)
        npt.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                            np.asarray(y_train), rtol=1e-3, atol=1e-3)

    def test_prefill_state_continues_exactly(self):
        dims = mamba_dims(32, 16, 8, 2, 4)
        p = init_mamba(jax.random.PRNGKey(5), dims)
        x = jax.random.normal(jax.random.PRNGKey(6), (1, 24, 32)) * 0.5
        y_full, _ = mamba_forward(p, x, dims, chunk=8)
        _, cache = mamba_forward(p, x[:, :16], dims, chunk=8,
                                 return_state=True)
        y_t, _ = mamba_decode_step(p, x[:, 16], cache, dims)
        npt.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, 16]),
                            rtol=1e-3, atol=1e-3)
