"""Distribution-layer tests: sharding rules, pins, pipeline parallelism."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.sharding import ShardingRules, _guard, _logical_param_spec
from repro.dist.pipeline import gpipe_reference, bubble_fraction

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class TestShardingRules:
    def test_divisibility_guard(self):
        mesh = jax.make_mesh((1,), ("model",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        spec = _guard(("model", None), (40, 16), mesh)
        assert spec == jax.sharding.PartitionSpec("model", None)

    def test_logical_specs_cover_param_tree(self):
        rules = ShardingRules()
        # attention / mlp / moe / mamba / embed all resolve
        assert _logical_param_spec(("layers", "attn", "q", "w"), rules) \
            == (("data",), "model")
        assert _logical_param_spec(("layers", "mlp", "down", "w"), rules) \
            == ("model", ("data",))
        assert _logical_param_spec(("layers", "moe", "gate"), rules) \
            == ("model", ("data",), None)
        assert _logical_param_spec(("layers", "mamba", "in_x"), rules) \
            == (("data",), "model")
        assert _logical_param_spec(("layers", "mamba", "norm1"), rules) \
            is None
        assert _logical_param_spec(("embed", "table"), rules) \
            == ("model", ("data",))

    def test_zero_off_replicates_non_model_dims(self):
        rules = ShardingRules(zero_params=False)
        assert _logical_param_spec(("layers", "attn", "q", "w"), rules) \
            == (None, "model")


class TestPipelineParallel:
    def test_bubble_fraction(self):
        assert bubble_fraction(8, 4) == pytest.approx(3 / 11)
        assert bubble_fraction(1, 1) == 0.0

    def test_gpipe_matches_sequential(self):
        """4-stage pipeline on 4 fake devices == sequential stage chain."""
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import AxisType
            from repro.dist.pipeline import gpipe_spmd, gpipe_reference

            S, n_micro, mb, d = 4, 6, 2, 8
            key = jax.random.PRNGKey(0)
            params = {"w": jax.random.normal(key, (S, d, d)) * 0.3,
                      "b": jnp.linspace(-1, 1, S * d).reshape(S, d)}
            x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

            def stage_fn(p, x):
                return jnp.tanh(x @ p["w"] + p["b"])

            mesh = jax.make_mesh((S,), ("stage",),
                                 axis_types=(AxisType.Auto,))
            out = gpipe_spmd(stage_fn, params, x, mesh)
            ref = gpipe_reference(stage_fn, params, x)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
            print("GPIPE_MATCHES")
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=600)
        assert "GPIPE_MATCHES" in out.stdout, (out.stdout[-1500:],
                                               out.stderr[-3000:])


class TestMegatronExplicit:
    def test_matches_gspmd_forward(self):
        """Hand-scheduled Megatron-SP layers == the GSPMD model forward
        (same params), on a 2x2 mesh, for GQA and MQA head counts."""
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count=4"
            import dataclasses
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import AxisType
            from repro.configs import ARCHS, reduced
            from repro.models import (model_dims, init_params, forward,
                                      FwdOptions)
            from repro.dist.megatron import make_megatron_forward

            mesh = jax.make_mesh((2, 2), ("data", "model"),
                                 axis_types=(AxisType.Auto,) * 2)
            for nkv in (4, 1):   # sharded-kv and replicated-kv paths
                cfg = dataclasses.replace(
                    reduced(ARCHS["granite-8b"]), num_kv_heads=nkv)
                dims = model_dims(cfg, tp=2)
                params = init_params(jax.random.PRNGKey(0), cfg, dims)
                batch = {"tokens": jnp.ones((4, 32), jnp.int32) * 7,
                         "labels": jnp.ones((4, 32), jnp.int32)}
                ref, _, _ = forward(params, batch, cfg, dims,
                                    FwdOptions(attn_impl="dense"))
                mfwd = make_megatron_forward(
                    cfg, dims, mesh, ("data",), attn_impl="dense",
                    remat=False)
                with mesh:
                    got, _, _ = jax.jit(mfwd)(
                        jax.tree.map(lambda a: a.astype(jnp.float32),
                                     params), batch)
                np.testing.assert_allclose(
                    np.asarray(got, np.float32),
                    np.asarray(ref, np.float32), rtol=3e-2, atol=3e-2)
                print(f"MEGATRON_MATCHES nkv={nkv}")
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=900)
        assert out.stdout.count("MEGATRON_MATCHES") == 2, (
            out.stdout[-1500:], out.stderr[-3000:])
