"""Benchmark-harness smoke: every paper-table module runs and emits rows."""
import os
import sys

import pytest

BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks")
sys.path.insert(0, BENCH)


@pytest.mark.parametrize("mod_name", [
    "bench_structure_size", "bench_restrictive_only",
    "bench_tar_sf_locality", "bench_hash_functions",
    "bench_roofline_summary",
])
def test_bench_module_runs(mod_name):
    mod = __import__(mod_name)
    rows = mod.run()
    assert rows
    for r in rows:
        assert set(r) >= {"name", "us", "derived"}


def test_structure_size_always_saves_vs_radix():
    mod = __import__("bench_structure_size")
    for r in mod.run():
        if "saving" in r:
            assert r["saving"] > 0.2, r["derived"]
