"""Graceful degradation under overload (ISSUE 6).

Tentpole contract: with a pool sized for ~4 concurrent sequences and 12
submitted, EVERY request completes and every token stream is bit-identical
to an uncontended run — the engine preempts victim sequences to the host
KV tier and resumes them instead of failing.  ``PoolExhausted`` survives
only for requests that can NEVER run, and carries structured occupancy
diagnostics.  A chaos injector (``ServeFaultInjector``) forces allocation
denials and preemptions at adversarial step points; the same differential
oracle must hold under any injection schedule.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import HybridConfig, HybridKVManager, PoolExhausted, FLEX
from repro.models import model_dims, init_params
from repro.runtime import (ServeFaultInjector, InjectedFault,
                           InjectedAllocFault, InjectedStepFault)
from repro.serve import Engine, Request, EngineConfig
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import (default_victim, FIFOScheduler,
                                   ShortestPromptFirst,
                                   PriorityAgingScheduler)

try:
    from hypothesis import given, settings, strategies as st, HealthCheck
    HAVE_HYPOTHESIS = True
except ImportError:                        # optional dev dependency
    HAVE_HYPOTHESIS = False


_SETUP_CACHE = {}


def _setup(arch="granite-8b"):
    """2-layer reduced model: the suite runs many engine pairs, so keep
    per-engine compile cost minimal (bucket shapes recur across runs and
    hit the jit cache)."""
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(reduced(ARCHS[arch]), num_layers=2)
        dims = model_dims(cfg, tp=1)
        params = init_params(jax.random.PRNGKey(2), cfg, dims)
        _SETUP_CACHE[arch] = (cfg, params)
    return _SETUP_CACHE[arch]


def _drain(eng, max_steps=900, invariants=True):
    """Poll to completion, asserting pool consistency after every step.
    Returns {seq_id: [token, ...]} per-request streams."""
    outs = {}
    for _ in range(max_steps):
        for ro in eng.poll():
            outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
        if invariants:
            eng.manager.check_invariants()
        if not eng.has_unfinished():
            return outs
    raise AssertionError("engine failed to drain")


def _overload_run(cfg, params, headroom, *, n_req=12, max_new=20,
                  sampling=None, **ekw):
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_seq_len=8 * bs, pool_headroom=headroom,
        auto_release=True, **ekw))
    rng = np.random.RandomState(7)
    for i in range(n_req):
        eng.submit(Request(
            seq_id=i, prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
            max_new_tokens=max_new,
            sampling=sampling if sampling is not None else SamplingParams()))
    outs = _drain(eng)
    assert set(outs) == set(range(n_req))
    return outs, eng


# --------------------------------------------------- the overload oracle

SAMPLED = SamplingParams(temperature=0.8, top_k=40, seed=123)


@pytest.mark.parametrize("spec,sampling", [
    (None, None), (None, SAMPLED), ("ngram", None), ("ngram", SAMPLED),
], ids=["greedy", "sampled", "spec-greedy", "spec-sampled"])
def test_overload_streams_bit_identical(spec, sampling):
    """Pool sized for 4 sequences (16 slots), 12 submitted: every request
    finishes, zero PoolExhausted, and each stream equals the uncontended
    (4x pool) run token for token — through real preempt/resume cycles."""
    cfg, params = _setup()
    oracle, ref = _overload_run(cfg, params, 2.0, sampling=sampling,
                                spec_decode=spec)
    tight, eng = _overload_run(cfg, params, 0.5, sampling=sampling,
                               spec_decode=spec)
    assert ref.hybrid_cfg.total_slots == 4 * eng.hybrid_cfg.total_slots
    for sid in oracle:
        assert tight[sid] == oracle[sid], f"seq {sid} diverged"
        assert len(tight[sid]) == 20
    ov = eng.stats()["overload"]
    assert ov["preempted_seqs"] > 0, "overload never exercised the tier"
    assert ov["resumed_seqs"] == ov["preempted_seqs"]
    assert ov["host_tier_seqs"] == 0          # everyone came back
    assert ov["swap_bytes_in"] == ov["swap_bytes_out"] > 0
    # drained pool is leak-free: no mapped blocks, no registered seqs
    assert not eng.manager.blocks
    assert not eng.manager.seq_lengths
    m = eng.manager
    assert m.stats["swap_out_preempt"] == m.stats["swap_in_resume"] > 0


def test_overload_fail_policy_is_fail_fast():
    """``overload_policy="fail"`` reproduces the pre-ISSUE-6 ladder:
    admission is footprint-gated (serve only what provably fits), nothing
    is ever preempted, and the streams still match the oracle — the cost
    is concurrency, not correctness."""
    cfg, params = _setup()
    oracle, _ = _overload_run(cfg, params, 2.0)
    tight, eng = _overload_run(cfg, params, 0.5, overload_policy="fail")
    for sid in oracle:
        assert tight[sid] == oracle[sid]
    assert eng.stats()["overload"]["preempted_seqs"] == 0


def test_overload_with_priority_scheduler_and_shared_release():
    """The ladder composes with a non-FIFO policy: priority+aging picks
    victims by effective priority and still drains bit-identically."""
    cfg, params = _setup()
    oracle, _ = _overload_run(cfg, params, 2.0, scheduler="priority")
    tight, eng = _overload_run(cfg, params, 0.5, scheduler="priority")
    for sid in oracle:
        assert tight[sid] == oracle[sid]
    assert eng.stats()["overload"]["preempted_seqs"] > 0


# ------------------------------------------- un-admittable diagnostics

def test_unadmittable_prompt_raises_with_diagnostics():
    """A prompt whose blocks alone exceed the whole pool can never be
    admitted — preemption cannot help, so PoolExhausted survives and
    carries structured occupancy diagnostics."""
    cfg, params = _setup()
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_seq_len=24 * bs, pool_headroom=0.4))
    assert eng.hybrid_cfg.total_slots < 20
    big = Request(seq_id=0, max_new_tokens=2,
                  prompt=np.arange(20 * bs) % cfg.vocab_size)
    eng.submit(big)
    with pytest.raises(PoolExhausted, match="cannot be admitted") as ei:
        for _ in range(10):
            eng.poll()
    d = ei.value.diag
    for key in ("pool_blocks", "mapped_blocks", "free_flex", "queued",
                "live", "finished_unreleased", "preempted"):
        assert key in d, key
    assert d["pool_blocks"] < 20
    # the diagnostics ride the message too (the operator-visible half)
    assert "pool_blocks=" in str(ei.value)


def test_pool_exhausted_diag_construction():
    e = PoolExhausted("no room", live=3, queued=2)
    assert e.diag == {"live": 3, "queued": 2}
    assert str(e) == "no room [live=3 queued=2]"
    assert str(PoolExhausted("plain")) == "plain"


def test_finished_unreleased_still_raises():
    """auto_release=False with every slot parked on finished sequences is
    a genuine deadlock (the caller must release) — preemption of FINISHED
    sequences is never attempted, so poll() still raises."""
    cfg, params = _setup()
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_seq_len=4 * bs, auto_release=False))
    rng = np.random.RandomState(3)
    for i in range(3):
        eng.submit(Request(seq_id=i, max_new_tokens=2,
                           prompt=rng.randint(0, cfg.vocab_size, bs)))
    with pytest.raises(PoolExhausted, match="finished") as ei:
        for _ in range(40):
            eng.poll()
    assert ei.value.diag["finished_unreleased"] == 2


# ----------------------------------------------- chaos: forced schedules

def _chaos_replay(preempt_at=(), alloc_fail_at=(), seed=None,
                  preempt_rate=0.0, alloc_fail_rate=0.0, spec=None,
                  n_req=6, headroom=2.0):
    """Differential chaos harness (fixed replays AND the fuzzer drive
    this): run a clean engine and an injected engine on the same
    workload; every request's stream must match bit-for-bit."""
    cfg, params = _setup()

    def run(inj):
        outs, eng = _overload_run(cfg, params, headroom, n_req=n_req,
                                  max_new=12, spec_decode=spec,
                                  fault_injector=inj)
        return outs, eng

    clean, _ = run(None)
    inj = ServeFaultInjector(preempt_at=preempt_at,
                             alloc_fail_at=alloc_fail_at, seed=seed,
                             preempt_rate=preempt_rate,
                             alloc_fail_rate=alloc_fail_rate)
    chaos, eng = run(inj)
    for sid in clean:
        assert chaos[sid] == clean[sid], f"seq {sid} diverged under chaos"
    assert not eng.manager.blocks and not eng.manager.seq_lengths
    return inj, eng


def test_forced_preempt_pre_and_post():
    """Preemptions forced at both safe points — before admission (tears a
    victim out between prefill chunks) and after the commit (between a
    spec window's verify/commit and the next dispatch) — plus injected
    admission/decode allocation denials, all stream-invisible."""
    inj, eng = _chaos_replay(
        preempt_at=[(3, "pre", "auto"), (6, "post", 1), (9, "pre", "auto")],
        alloc_fail_at=[(4, "admit"), (7, "decode")])
    fired = [ev for ev in inj.log if ev[0] == "preempt"]
    assert len(fired) == 3
    assert eng.stats()["overload"]["request_preempts"] >= 3


def test_forced_preempt_mid_spec_window():
    """Under speculation the post-commit point sits exactly between a
    verify/commit and the next draft dispatch; preempting there must not
    perturb the lossless acceptance stream."""
    inj, _ = _chaos_replay(
        preempt_at=[(4, "post", "auto"), (7, "pre", 2)], spec="ngram")
    assert inj.faults()["preempt"] == 2


def test_forced_preempt_mid_chunk_prefill():
    """A victim preempted while its prompt is mid-chunk resumes as the
    engine-owned chunk request and finishes prefill via the normal
    prefix-KV path.  A tiny prefill budget keeps prompts mid-chunk for
    several steps so the early-step schedule reliably catches one."""
    cfg, params = _setup()
    bs = cfg.kv_block_size

    def run(inj):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=4, max_seq_len=8 * bs, pool_headroom=2.0,
            auto_release=True, prefill_budget=bs, fault_injector=inj))
        rng = np.random.RandomState(11)
        for i in range(3):
            eng.submit(Request(
                seq_id=i, prompt=rng.randint(0, cfg.vocab_size, 4 * bs),
                max_new_tokens=6))
        return _drain(eng), eng

    clean, _ = run(None)
    inj = ServeFaultInjector(preempt_at=[(2, "pre", 0), (5, "pre", "auto")])
    chaos, eng = run(inj)
    assert chaos == clean
    assert eng.stats()["overload"]["request_preempts"] >= 1


def test_injector_schedule_validation_and_log():
    with pytest.raises(ValueError, match="phase"):
        ServeFaultInjector(preempt_at=[(1, "mid", "auto")])
    inj = ServeFaultInjector(alloc_fail_at=[(2, "admit")])
    assert inj.alloc_unavailable(1, "admit") is False
    assert inj.alloc_unavailable(2, "admit") is True
    assert inj.alloc_unavailable(2, "admit") is False      # fires once
    assert inj.faults() == {"alloc": 1, "preempt": 0, "step": 0}
    assert issubclass(InjectedAllocFault, InjectedFault)
    assert issubclass(InjectedStepFault, InjectedFault)
    assert InjectedAllocFault.kind == "alloc"


def test_fixed_chaos_schedules():
    """Deterministic instances of the chaos-replay harness (the same
    helper the hypothesis fuzzer drives), so the replay logic itself is
    exercised even where hypothesis is not installed."""
    _chaos_replay(preempt_at=[(2, "pre", "auto")], seed=5,
                  preempt_rate=0.15, headroom=1.0)
    _chaos_replay(alloc_fail_at=[(3, "decode"), (5, "resume")],
                  preempt_at=[(4, "post", 0)], headroom=0.75)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=6,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_chaos_schedule_fuzz(data):
        """Random (injection schedule x pool pressure x spec) chaos: the
        differential oracle holds for ANY schedule, generalizing the
        fixed replays above."""
        n_pre = data.draw(st.integers(0, 3), label="n_preempts")
        preempts = [(data.draw(st.integers(1, 10), label=f"pstep{i}"),
                     data.draw(st.sampled_from(["pre", "post"]),
                               label=f"pphase{i}"),
                     data.draw(st.sampled_from(["auto", 0, 1, 2]),
                               label=f"ptarget{i}"))
                    for i in range(n_pre)]
        n_alloc = data.draw(st.integers(0, 2), label="n_allocs")
        allocs = [(data.draw(st.integers(1, 10), label=f"astep{i}"),
                   data.draw(st.sampled_from(["admit", "decode", "resume"]),
                             label=f"apoint{i}"))
                  for i in range(n_alloc)]
        headroom = data.draw(st.sampled_from([0.75, 1.0, 2.0]),
                             label="headroom")
        spec = data.draw(st.sampled_from([None, "ngram"]), label="spec")
        _chaos_replay(preempt_at=preempts, alloc_fail_at=allocs,
                      headroom=headroom, spec=spec)
else:
    def test_chaos_schedule_fuzz():
        pytest.skip("hypothesis not installed")


# ---------------------------------------------- recurrent-family preempt

def test_recurrent_family_preempt_resume():
    """mamba2 has no KV blocks — the host tier carries the ssm/conv rows
    only — and the same stream-invisibility contract holds."""
    cfg, params = _setup("mamba2-130m")
    bs = cfg.kv_block_size

    def run(inj):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=4, max_seq_len=8 * bs, auto_release=True,
            fault_injector=inj))
        rng = np.random.RandomState(5)
        for i in range(4):
            eng.submit(Request(
                seq_id=i, prompt=rng.randint(0, cfg.vocab_size, bs),
                max_new_tokens=8))
        return _drain(eng, invariants=False), eng

    clean, _ = run(None)
    inj = ServeFaultInjector(preempt_at=[(3, "post", 1), (5, "pre", "auto")])
    chaos, eng = run(inj)
    assert chaos == clean
    ov = eng.stats()["overload"]
    assert ov["request_preempts"] == 2
    assert ov["swap_bytes_out"] == ov["swap_bytes_in"] > 0


# ------------------------------------------------ manager-level contract

def _mgr(**kw):
    kw.setdefault("total_slots", 32)
    kw.setdefault("assoc", 4)
    kw.setdefault("max_seqs", 4)
    kw.setdefault("max_blocks_per_seq", 8)
    return HybridKVManager(HybridConfig(**kw))


def test_manager_preempt_resume_roundtrip():
    m = _mgr()
    m.register_sequence(0)
    for b in range(5):
        assert m.allocate_block(0, b).slot >= 0
    before = m.stats["swap_out"]
    saved = m.preempt(0)
    assert [b for b, _ in saved] == list(range(5))
    assert all(w for _, w in saved)
    assert not m.blocks and 0 not in m._seq_ids
    assert m.stats["preempt_out"] == 1
    assert m.stats["swap_out_preempt"] == 5
    assert m.stats["swap_out"] == before + 5
    m.check_invariants()
    newmap = m.resume(0, saved)
    assert sorted(newmap) == list(range(5))
    for b in range(5):
        info = m.blocks[m.cfg.vpn(m.seq_slot(0), b)]
        assert info.slot >= 0 and info.writable
    assert m.stats["preempt_in"] == 1
    assert m.stats["swap_in_resume"] == 5
    m.check_invariants()


def test_manager_preempt_shared_prefix_coowner_safe():
    """Preempting a sharer only drops ITS reference: the co-owner's
    physical slots (and read-only marks) survive untouched, and the
    resumed sequence gets private writable state only where it had it."""
    m = _mgr()
    m.register_sequence(0)
    for b in range(4):
        m.allocate_block(0, b)
    m.register_sequence(1)
    m.share_prefix(0, 1, 2)                       # blocks 0,1 shared
    m.allocate_block(1, 2)                        # private tail
    owner_slots = {b: m.lookup(0, b)[0] for b in range(4)}
    saved = m.preempt(1)
    assert {b: m.lookup(0, b)[0] for b in range(4)} == owner_slots
    m.check_invariants()
    m.resume(1, saved)
    m.check_invariants()
    # shared blocks came back read-only (a prefix reference), private
    # tail came back writable

    def winfo(sid, b):
        return m.blocks[m.cfg.vpn(m.seq_slot(sid), b)].writable

    assert not winfo(1, 0) and not winfo(1, 1)
    assert winfo(1, 2)
    # and the resumed refs share or copy, but never steal: owner intact
    assert {b: m.lookup(0, b)[0] for b in range(4)} == owner_slots


def test_manager_preempt_restrictive_only_rejected():
    m = _mgr(mode="restrictive_only")
    m.register_sequence(0)
    with pytest.raises(ValueError, match="restorable"):
        m.preempt(0)


def test_alloc_ledger_exact_dry_run():
    """The ledger's all-or-nothing reserve answers exactly what a real
    allocation round would: per-set empty ways first, then flex slots."""
    m = _mgr(total_slots=16, max_blocks_per_seq=4)   # 12 rest + 4 flex
    m.register_sequence(0)
    led = m.alloc_ledger()
    want = [m.cfg.vpn(m.seq_slot(0), b) for b in range(4)]
    assert led.reserve(want)
    for b in range(4):
        m.allocate_block(0, b)
    # a fresh ledger reflects the consumed capacity
    m.register_sequence(1)
    led2 = m.alloc_ledger()
    vpns = [m.cfg.vpn(m.seq_slot(1), b) for b in range(4)]
    ok = led2.reserve(vpns)
    # verify against ground truth: replay on the real manager
    slots = [m.allocate_block(1, b).slot for b in range(4)]
    assert ok == all(s >= 0 for s in slots)
    # reserve is all-or-nothing: a failing batch consumes nothing
    m2 = _mgr(total_slots=8, max_blocks_per_seq=8, restseg_fraction=0.0)
    m2.register_sequence(0)
    led3 = m2.alloc_ledger()
    vp = [m2.cfg.vpn(m2.seq_slot(0), b) for b in range(8)]
    assert led3.reserve(vp[:6])                 # 6 of 8 flex slots
    assert not led3.reserve(vp[6:] + [vp[7] + 8])   # 3 needed, 2 left
    assert led3.reserve(vp[6:])                 # the failure reserved 0


def test_swap_counter_unification_invariant():
    """stats["swap_out"/"swap_in"] totals are mutated only through the
    counting helpers, so they always equal the per-reason breakdown —
    and check_invariants cross-checks exactly that."""
    m = _mgr(total_slots=16, max_blocks_per_seq=8, restseg_fraction=0.0)
    m.register_sequence(0)
    for b in range(8):
        m.allocate_block(0, b)
    m.register_sequence(1)
    for b in range(8):
        m.allocate_block(1, b)                 # pool-pressure swap-outs
    assert m.stats["swap_out"] == sum(
        v for k, v in m.stats.items() if k.startswith("swap_out_"))
    m.check_invariants()
    m.stats["swap_out"] += 1                   # simulate a rogue bump
    with pytest.raises(AssertionError, match="swap_out"):
        m.check_invariants()


# -------------------------------------------------- victim-policy units

class _St:
    def __init__(self, seq_id, arrival, last_step, prompt_len=8,
                 priority=0):
        self.request = type("R", (), {
            "seq_id": seq_id, "priority": priority,
            "prompt": np.zeros(prompt_len)})()
        self.arrival = arrival
        self.last_step = last_step


def test_default_victim_lru_then_youngest():
    a = _St(0, arrival=0, last_step=5)
    b = _St(1, arrival=2, last_step=3)          # least recent commit
    c = _St(2, arrival=4, last_step=3)          # tie: younger arrival
    assert default_victim([a, b], now=9) is b
    assert default_victim([a, b, c], now=9) is c
    assert FIFOScheduler.victim([a, c], 9) is c
    assert FIFOScheduler().should_preempt(a.request, 0, b, 9) is False


def test_spf_victim_longest_prompt():
    a = _St(0, arrival=0, last_step=1, prompt_len=4)
    b = _St(1, arrival=1, last_step=9, prompt_len=32)
    assert ShortestPromptFirst.victim([a, b], now=9) is b
    assert ShortestPromptFirst().should_preempt(a.request, 0, b, 9) is False


def test_priority_victim_and_admission_gate():
    s = PriorityAgingScheduler(aging_rate=0.0)
    lo = _St(0, arrival=0, last_step=8, priority=1)
    hi = _St(1, arrival=0, last_step=2, priority=9)
    assert s.victim([lo, hi], now=10) is lo
    urgent = _St(2, arrival=10, last_step=0, priority=5).request
    assert s.should_preempt(urgent, 10, lo, 10) is True      # 5 > 1
    assert s.should_preempt(urgent, 10, hi, 10) is False     # 5 < 9
    equal = _St(3, arrival=10, last_step=0, priority=1).request
    assert s.should_preempt(equal, 10, lo, 10) is False      # strict >


# ------------------------------------------------- serving stats surface

def test_overload_stats_block_and_per_request_shape():
    """stats() carries the aggregate overload block; the pinned
    per-request row schema is unchanged (test_serving_api pins it)."""
    cfg, params = _setup()
    _, eng = _overload_run(cfg, params, 0.5, n_req=8, max_new=20)
    s = eng.stats()
    ov = s["overload"]
    assert set(ov) == {"preempted_seqs", "resumed_seqs", "host_tier_seqs",
                       "swap_bytes_out", "swap_bytes_in",
                       "request_preempts", "request_resumes",
                       "dropped_request_preempts",
                       "dropped_request_resumes"}
    assert ov["preempted_seqs"] > 0
    assert ov["request_resumes"] == ov["request_preempts"]
    for row in s["per_request"].values():
        assert set(row) == {"rsw_hits", "flex_walks", "swap_faults",
                            "drafted", "accepted", "cached_blocks",
                            "preempts", "resumes"}
    # no ids were reused in this run: the rows carry the whole account
    assert (sum(r["preempts"] for r in s["per_request"].values())
            == ov["request_preempts"])


def test_request_preempt_counts_survive_seq_id_reuse():
    """ISSUE 9 satellite 1: ``request_preempts`` used to be a sum of
    ``st.preempts`` over ``self._states`` — resubmitting a finished
    seq_id replaced its state and the preempt history silently
    vanished.  Now the globals are MONOTONE engine counters; submit()
    banks the dropped row's counts, and
    ``sum(per-request rows) + dropped == global`` holds across reuse
    (also asserted by ``Engine.check_invariants``)."""
    cfg, params = _setup()
    bs = cfg.kv_block_size
    inj = ServeFaultInjector(preempt_at=[(2, "post", 0), (4, "pre", 1)])
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_seq_len=8 * bs, pool_headroom=2.0,
        auto_release=True, fault_injector=inj))
    rng = np.random.RandomState(3)

    def submit_round():
        for i in range(3):
            eng.submit(Request(
                seq_id=i, prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                max_new_tokens=6))

    submit_round()
    _drain(eng)
    s = eng.stats()
    ov = s["overload"]
    assert ov["request_preempts"] == 2 == ov["request_resumes"]
    assert ov["dropped_request_preempts"] == 0
    assert sum(r["preempts"] for r in s["per_request"].values()) == 2

    # reuse EVERY seq_id: submit() drops the finished rows and banks
    # their counts — the pre-fix row sum reported 0 preempts here
    submit_round()
    _drain(eng)
    s = eng.stats()
    ov = s["overload"]
    assert ov["request_preempts"] == 2 == ov["request_resumes"]  # monotone
    assert ov["dropped_request_preempts"] == 2
    assert ov["dropped_request_resumes"] == 2
    rows = s["per_request"]
    assert sum(r["preempts"] for r in rows.values()) == 0   # fresh rows
    assert sum(r["resumes"] for r in rows.values()) == 0
    assert (sum(r["preempts"] for r in rows.values())
            + ov["dropped_request_preempts"] == ov["request_preempts"])
    eng.check_invariants()
