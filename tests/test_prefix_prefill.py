"""Differential oracle suite for prefix-KV chunked prefill (ISSUE 4).

The tentpole contract: a chunk k > 0 that forwards ONLY its own tokens —
attending over the prefix's installed pool blocks and continuing saved
SSM/conv state — must be OBSERVATIONALLY IDENTICAL to the full-recompute
chunk forward (the PR-2 path, kept behind ``prefill_mode="recompute"`` as
the oracle) and to blocking (unchunked) admission:

* installed KV blocks, SSM/conv states and ctx_len are BIT-identical
  between the prefix-KV and recompute paths (the engine keys prefix
  buckets so each row's padded KV extent matches what recompute would
  use — float reductions nest bitwise only across pow2 tails);
* token streams are identical across prefix-KV / recompute / blocking,
  for greedy and sampled requests, under any admission schedule (fixed
  cases here, a hypothesis schedule fuzzer below);
* per-chunk forward-token cost is CONSTANT in chunk index on the
  prefix-KV path (asserted from ``admission_log``), while the recompute
  path's grows linearly — the quadratic-to-linear claim, pinned on the
  log rather than wall time.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import ChunkRecord, Engine, EngineConfig, Request
from repro.serve import SamplingParams

ARCH_LIST = ["granite-8b", "mamba2-130m", "jamba-1.5-large-398b"]


@pytest.fixture(scope="module", params=ARCH_LIST)
def setup(request):
    cfg = reduced(ARCHS[request.param])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    return cfg, params


@pytest.fixture(scope="module")
def dense_setup():
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    return cfg, params


def _drain(eng):
    steps = 0
    while eng.has_unfinished():
        eng.step()
        steps += 1
        assert steps < 400, "engine failed to drain"
    return steps


def _engine(cfg, params, mode, budget, max_batch=2, blocks=16, **kw):
    bs = cfg.kv_block_size
    return Engine(cfg, params, EngineConfig(
        max_batch=max_batch, max_seq_len=blocks * bs, prefill_budget=budget,
        prefill_mode=mode, **kw))


def _seq_state(eng, seq_id, nblk):
    """Installed per-block KV + recurrent state for one sequence."""
    out = {}
    if "k_pool" in eng.dstate:
        slots = [eng.manager.lookup(seq_id, cb)[0] for cb in range(nblk)]
        assert all(s >= 0 for s in slots), slots
        out["slots"] = slots
        out["k"] = np.asarray(eng.dstate["k_pool"])[:, slots]
        out["v"] = np.asarray(eng.dstate["v_pool"])[:, slots]
    if "ssm" in eng.dstate:
        slot = eng._slot_of[seq_id]
        out["ssm"] = np.asarray(eng.dstate["ssm"])[:, slot]
        out["conv"] = np.asarray(eng.dstate["conv"])[:, slot]
    out["ctx"] = int(eng._ctx_host[eng._slot_of[seq_id]])
    return out


# --------------------------------------------------- differential oracle

@pytest.mark.parametrize("budget_blocks", [2, 3])
def test_prefix_kv_bit_identical_to_recompute_and_blocking(
        setup, budget_blocks):
    """Across attention / ssm / hybrid families, for chunk boundaries
    that divide the prompt evenly (budget 2 blocks on 8) and ones that
    leave a ragged final chunk (budget 3 -> chunks 3+3+2): identical
    installed blocks, states, ctx_len and token streams."""
    cfg, params = setup
    bs = cfg.kv_block_size
    nblk = 8
    rng = np.random.RandomState(11)
    prompt = rng.randint(0, cfg.vocab_size, nblk * bs)

    states = {}
    toks = {}
    for mode in ("prefix_kv", "recompute"):
        eng = _engine(cfg, params, mode, budget_blocks * bs)
        r = Request(seq_id=0, prompt=prompt, max_new_tokens=4)
        eng.submit(r)
        # drain the ADMISSION first so the captured pool state is purely
        # the prompt's (decode writes its own blocks afterwards)
        eng.step()
        while 0 in eng._prefilling:
            eng.step()
        states[mode] = _seq_state(eng, 0, nblk)
        _drain(eng)
        toks[mode] = list(r.generated)
        paths = [rec.path for rec in eng.admission_log]
        if mode == "prefix_kv":
            assert paths[0] == "recompute"          # chunk 0 has no prefix
            assert all(p == "prefix_kv" for p in paths[1:])
        else:
            assert all(p == "recompute" for p in paths)
        eng.manager.check_invariants()

    a, b = states["prefix_kv"], states["recompute"]
    assert a["ctx"] == b["ctx"] == nblk * bs
    if "k" in a:
        assert a["slots"] == b["slots"]
        np.testing.assert_array_equal(a["k"], b["k"])
        np.testing.assert_array_equal(a["v"], b["v"])
    if "ssm" in a:
        np.testing.assert_array_equal(a["ssm"], b["ssm"])
        np.testing.assert_array_equal(a["conv"], b["conv"])

    # blocking (unchunked) admission: same tokens
    eng = _engine(cfg, params, "prefix_kv", None)
    r = Request(seq_id=0, prompt=prompt, max_new_tokens=4)
    eng.add_request(r)
    _drain(eng)
    assert toks["prefix_kv"] == toks["recompute"] == list(r.generated)


def test_prefix_kv_mid_decode_admission_matches(setup):
    """A chunked prompt admitted WHILE another sequence decodes: both
    requests' streams match the recompute engine token for token, and the
    decoding neighbour is never perturbed."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(3)
    pa = rng.randint(0, cfg.vocab_size, 2 * bs)
    pb = rng.randint(0, cfg.vocab_size, 6 * bs)

    streams = {}
    for mode in ("prefix_kv", "recompute"):
        eng = _engine(cfg, params, mode, 2 * bs)
        ra = Request(seq_id=0, prompt=pa, max_new_tokens=8)
        rb = Request(seq_id=1, prompt=pb, max_new_tokens=4)
        eng.submit(ra)
        eng.step()                      # A admitted, starts decoding
        eng.submit(rb)                  # B chunks in while A decodes
        _drain(eng)
        streams[mode] = (list(ra.generated), list(rb.generated))
        eng.manager.check_invariants()
    assert streams["prefix_kv"] == streams["recompute"]


def test_prefix_kv_sampled_streams_match(dense_setup):
    """Sampled (non-greedy) requests: the in-graph sampler folds absolute
    positions, so prefix-KV chunking must reproduce the recompute path's
    sampled stream exactly (same seed, same PRNG folds)."""
    cfg, params = dense_setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, cfg.vocab_size, 6 * bs)
    sp = SamplingParams(temperature=0.8, top_k=7, seed=123)
    streams = {}
    for mode in ("prefix_kv", "recompute"):
        eng = _engine(cfg, params, mode, 2 * bs)
        r = Request(seq_id=0, prompt=prompt, max_new_tokens=6, sampling=sp)
        eng.submit(r)
        _drain(eng)
        streams[mode] = list(r.generated)
    assert streams["prefix_kv"] == streams["recompute"]


def test_prefix_kv_with_shared_prefix(dense_setup):
    """Prefix sharing composes with prefix-KV chunking: the sharer's
    later chunks read shared (refcounted) blocks through the same pool
    gather, producing the source's exact tokens for the common prompt."""
    cfg, params = dense_setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(9)
    prompt = rng.randint(0, cfg.vocab_size, 6 * bs)

    ref_eng = _engine(cfg, params, "recompute", 2 * bs)
    ref = Request(seq_id=0, prompt=prompt, max_new_tokens=4)
    ref_eng.submit(ref)
    _drain(ref_eng)

    eng = _engine(cfg, params, "prefix_kv", 2 * bs, max_batch=2)
    src = Request(seq_id=0, prompt=prompt, max_new_tokens=4)
    eng.submit(src)
    _drain(eng)
    dup = Request(seq_id=1, prompt=prompt, max_new_tokens=4)
    eng.submit(dup, share_prefix_from=0, shared_blocks=3)
    _drain(eng)
    assert list(src.generated) == list(ref.generated)
    assert list(dup.generated) == list(ref.generated)
    eng.manager.check_invariants()


@pytest.mark.parametrize("arch", ["paligemma-3b", "whisper-medium"])
def test_frontend_families_prefix_matches_recompute(arch):
    """vlm (frontend blocks live in the prefix; chunk positions offset by
    the frontend) and audio (cross-attention reads the per-layer cross
    K/V chunk 0 installed, instead of re-running the encoder): prefix-KV
    chunking reproduces the recompute streams."""
    cfg = reduced(ARCHS[arch])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    rng = np.random.RandomState(13)
    prompt = rng.randint(0, cfg.vocab_size, 6 * bs)
    frontend = rng.randn(cfg.frontend_tokens, cfg.d_model
                         ).astype(np.float32)
    toks = {}
    for mode in ("prefix_kv", "recompute"):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=2, max_seq_len=12 * bs, prefill_budget=2 * bs,
            prefill_mode=mode))
        r = Request(seq_id=0, prompt=prompt, frontend=frontend,
                    max_new_tokens=4)
        eng.submit(r)
        _drain(eng)
        toks[mode] = list(r.generated)
        if mode == "prefix_kv":
            assert [rec.path for rec in eng.admission_log] == \
                ["recompute", "prefix_kv", "prefix_kv"]
    assert toks["prefix_kv"] == toks["recompute"]


# ------------------------------------------------------- cost linearity

def test_prefix_chunk_cost_is_constant_in_chunk_index(setup):
    """The acceptance pin: on the prefix-KV path every chunk k > 0
    forwards exactly its own tokens (admission_log.fwd_tokens constant in
    chunk index for a fixed budget), while the recompute path's
    per-chunk forward tokens grow with the prefix."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, 16 * bs)

    logs = {}
    for mode in ("prefix_kv", "recompute"):
        eng = _engine(cfg, params, mode, 2 * bs, blocks=20)
        eng.submit(Request(seq_id=0, prompt=prompt, max_new_tokens=1))
        _drain(eng)
        logs[mode] = [rec for rec in eng.admission_log if rec.seq_id == 0]

    pre = logs["prefix_kv"]
    assert isinstance(pre[0], ChunkRecord)
    assert len(pre) == 8                          # 16 blocks / 2 per step
    # every chunk (the first included) forwards exactly the budget
    assert [rec.fwd_tokens for rec in pre] == [2 * bs] * 8
    assert [rec.path for rec in pre] == ["recompute"] + ["prefix_kv"] * 7
    rec_log = logs["recompute"]
    assert [rec.fwd_tokens for rec in rec_log] == [
        2 * bs * (i + 1) for i in range(8)]       # linear growth per chunk
    # totals: linear vs quadratic in the number of chunks
    assert sum(r.fwd_tokens for r in pre) == 16 * bs
    assert sum(r.fwd_tokens for r in rec_log) == 2 * bs * 36


# ------------------------------------------------- paged gather variant

def test_paged_gather_matches_exact_tokens(dense_setup):
    """The Q>1 paged-attention pool read (online-softmax merged with the
    chunk-causal part) produces the same greedy tokens as the exact
    gather — same math up to float associativity, same argmax."""
    cfg, params = dense_setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(21)
    prompt = rng.randint(0, cfg.vocab_size, 6 * bs)
    streams = {}
    for gather in ("exact", "paged"):
        eng = _engine(cfg, params, "prefix_kv", 2 * bs,
                      prefix_gather=gather)
        r = Request(seq_id=0, prompt=prompt, max_new_tokens=5)
        eng.submit(r)
        _drain(eng)
        streams[gather] = list(r.generated)
    assert streams["paged"] == streams["exact"]


def test_unknown_prefill_mode_rejected(dense_setup):
    cfg, params = dense_setup
    with pytest.raises(ValueError, match="prefill_mode"):
        Engine(cfg, params, EngineConfig(prefill_mode="speculative"))


def test_non_dense_attn_impl_falls_back_to_recompute(dense_setup):
    """The prefix chunk forward implements the dense softmax; a
    flash-attention engine must not mix summation orders between chunk 0
    and later chunks, so prefix_kv falls back to recompute (warned)."""
    cfg, params = dense_setup
    with pytest.warns(UserWarning, match="falling back"):
        eng = Engine(cfg, params, EngineConfig(attn_impl="flash_jax",
                                               prefill_mode="prefix_kv"))
    assert eng.prefill_mode == "recompute"


# ------------------------------------------------ schedule fuzzer (PR 2+)

try:
    from hypothesis import given, settings, strategies as st, HealthCheck
    HAVE_HYPOTHESIS = True
except ImportError:                        # optional dev dependency
    HAVE_HYPOTHESIS = False


_FUZZ_CACHE = {}


def _fuzz_setup():
    """Tiny 2-layer dense model: the fuzzer replays many engine pairs, so
    keep per-engine compile cost minimal (bucket shapes recur across
    examples and hit the jit cache)."""
    if "v" not in _FUZZ_CACHE:
        cfg = dataclasses.replace(reduced(ARCHS["granite-8b"]),
                                  num_layers=2)
        dims = model_dims(cfg, tp=1)
        params = init_params(jax.random.PRNGKey(2), cfg, dims)
        _FUZZ_CACHE["v"] = (cfg, params)
    return _FUZZ_CACHE["v"]


def _replay(blocks, submit_at, budget_blocks, sched, sampled):
    """Run one schedule on BOTH engines; assert per-request streams match.

    ``blocks``/``submit_at`` are per-request prompt block counts and the
    engine step each request is submitted before; ``sampled`` gives
    request 0 a non-greedy SamplingParams.
    """
    cfg, params = _fuzz_setup()
    bs = cfg.kv_block_size
    n_req = len(blocks)
    budget = bs * budget_blocks
    rng = np.random.RandomState(sum(blocks) + 7 * budget_blocks)
    prompts = [rng.randint(0, cfg.vocab_size, nb * bs) for nb in blocks]

    def run(mode):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=4, max_seq_len=8 * bs, prefill_budget=budget,
            prefill_mode=mode, scheduler=sched))
        reqs = [Request(
            seq_id=i, prompt=prompts[i], max_new_tokens=3,
            sampling=(SamplingParams(temperature=0.7, top_k=5, seed=i)
                      if sampled and i == 0 else SamplingParams()))
            for i in range(n_req)]
        step = 0
        while (any(eng._states.get(i) is None for i in range(n_req))
               or eng.has_unfinished()):
            for i, at in enumerate(submit_at):
                if at == step:
                    eng.submit(reqs[i])
            eng.step()
            step += 1
            assert step < 200
        return [list(r.generated) for r in reqs]

    assert run("prefix_kv") == run("recompute")


def test_fixed_schedules_prefix_equals_recompute():
    """Deterministic instances of the schedule-replay harness (the same
    helper the hypothesis fuzzer drives), so the replay logic itself is
    exercised even where hypothesis is not installed."""
    _replay([5, 2], [0, 1], 2, "fifo", False)
    _replay([6, 1, 3], [0, 0, 2], 1, "spf", True)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=8,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_schedule_fuzz_prefix_equals_recompute(data):
        """Random (prompt lengths x budget x scheduler x submit step x
        sampled/greedy) schedules: the prefix-KV engine's per-request
        streams equal the recompute engine's, generalizing the fixed
        interleaving pins above into a schedule fuzzer."""
        n_req = data.draw(st.integers(1, 3), label="n_req")
        blocks = [data.draw(st.integers(1, 6), label=f"blocks{i}")
                  for i in range(n_req)]
        submit_at = [data.draw(st.integers(0, 2), label=f"at{i}")
                     for i in range(n_req)]
        budget_blocks = data.draw(st.integers(1, 3), label="budget_blocks")
        sched = data.draw(st.sampled_from(["fifo", "spf"]), label="sched")
        sampled = data.draw(st.booleans(), label="sampled")
        _replay(blocks, submit_at, budget_blocks, sched, sampled)
else:
    def test_schedule_fuzz_prefix_equals_recompute():
        pytest.skip("hypothesis not installed")
