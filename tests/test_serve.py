"""Serving-stack tests: the golden decode-vs-forward consistency check per
family, engine policies (sharing, eviction, promotion feedback), and an
SPMD equivalence test (sharded serve_step on 8 fake devices == the
single-device reference) run in a subprocess so the device-count flag does
not leak into this process."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params, forward, FwdOptions
from repro.serve import Engine, Request

GOLDEN_ARCHS = ["granite-8b", "qwen2.5-14b", "paligemma-3b",
                "qwen3-moe-30b-a3b", "mamba2-130m",
                "jamba-1.5-large-398b", "whisper-medium"]


def _greedy_reference(params, cfg, dims, prompt, frontend, n):
    toks = list(prompt)
    out = []
    for _ in range(n):
        batch = {"tokens": jnp.asarray(toks)[None]}
        if frontend is not None:
            batch["frontend"] = jnp.asarray(frontend)[None]
        logits, _, _ = forward(params, batch, cfg, dims, FwdOptions())
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


@pytest.mark.parametrize("arch", GOLDEN_ARCHS)
def test_engine_matches_full_forward(arch):
    """Prefill + hybrid-translated paged decode == re-forwarding the full
    sequence each step (greedy)."""
    cfg = reduced(ARCHS[arch])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    S = 2 * bs
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, cfg.vocab_size, S)
    frontend = (rng.randn(cfg.frontend_tokens, cfg.d_model)
                .astype(np.float32) if cfg.frontend != "none" else None)
    n_decode = 4
    eng = Engine(cfg, params, max_batch=2,
                 max_seq_len=S + cfg.frontend_tokens + 64)
    req = Request(seq_id=7, prompt=prompt, frontend=frontend,
                  max_new_tokens=n_decode + 1)
    eng.add_request(req)
    for _ in range(n_decode):
        eng.step()
    ref = _greedy_reference(params, cfg, dims, prompt, frontend,
                            n_decode + 1)
    assert list(req.generated) == ref


def test_engine_two_sequences_with_prefix_sharing():
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    prompt = np.random.RandomState(1).randint(0, cfg.vocab_size, 2 * bs)
    eng = Engine(cfg, params, max_batch=4, max_seq_len=2 * bs + 64)
    r1 = Request(seq_id=1, prompt=prompt, max_new_tokens=4)
    r2 = Request(seq_id=2, prompt=prompt, max_new_tokens=4)
    eng.add_request(r1)
    eng.add_request(r2, share_prefix_from=1, shared_blocks=1)
    for _ in range(3):
        eng.step()
    # identical prompts must produce identical generations
    assert r1.generated == r2.generated
    assert eng.manager.stats["shared_blocks"] >= 1
    eng.release(1)
    eng.release(2)
    eng.manager.check_invariants()


def test_engine_translation_stats_flow():
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    prompt = np.random.RandomState(2).randint(0, cfg.vocab_size, 2 * bs)
    eng = Engine(cfg, params, max_batch=2, max_seq_len=2 * bs + 64)
    eng.add_request(Request(seq_id=1, prompt=prompt, max_new_tokens=6))
    for _ in range(5):
        eng.step()
    st = eng.stats()
    assert st["rsw_hits"] > 0          # RestSeg serving translations
    assert st["faults"] >= 2           # block allocations happened


SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import AxisType
    from repro.configs import ARCHS, reduced
    from repro.models import model_dims, init_params
    from repro.serve.decode import (DecodeSpec, make_serve_step,
                                    init_decode_state,
                                    decode_state_shardings)
    from repro.dist.sharding import ShardingRules, make_pins, param_shardings

    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    G, TP = 2, 4
    B = 4
    spec1 = DecodeSpec(block_size=bs, max_blocks_per_seq=4,
                       slots_per_group=16, n_sets=2, assoc=4, mode="batch")
    # single-device reference
    st1 = init_decode_state(cfg, dims, spec1, B, 1)
    # install two blocks/seq host-side: identical content per seq slot
    rng = np.random.RandomState(0)
    kv_shape = st1["k_pool"].shape
    kpool = rng.randn(*kv_shape).astype(np.float32)
    vpool = rng.randn(*kv_shape).astype(np.float32)

    # reference: single group, flat flex table maps vpn->slot identity-ish
    flex1 = -np.ones((1, B * 4), np.int32)
    for s in range(B):
        for b in range(2):
            flex1[0, s * 4 + b] = s * 4 + b
    st1["k_pool"] = jnp.asarray(kpool)
    st1["v_pool"] = jnp.asarray(vpool)
    st1["flex"] = jnp.asarray(flex1)
    st1["ctx_len"] = jnp.full((B,), 2 * bs - 1, jnp.int32)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, B), jnp.int32)
    step1 = jax.jit(make_serve_step(cfg, dims, spec1, mesh=None,
                                    dtype=jnp.float32))
    logits_ref, _, _ = step1(params, st1, tokens)

    # sharded: 2x4 mesh; same logical state rearranged into 2 groups
    mesh = jax.make_mesh((G, TP), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    spec2 = DecodeSpec(block_size=bs, max_blocks_per_seq=4,
                       slots_per_group=16, n_sets=2, assoc=4, mode="batch")
    st2 = init_decode_state(cfg, dims, spec2, B, G)
    # group g holds seqs [g*2, g*2+2); its local slots replicate ref layout
    L = kv_shape[0]
    kp2 = np.zeros((L, G * 16) + kv_shape[2:], np.float32)
    vp2 = np.zeros_like(kp2)
    flex2 = -np.ones((G, 2 * 4), np.int32)
    for s in range(B):
        g, sl = divmod(s, 2)
        for b in range(2):
            src = flex1[0, s * 4 + b]
            dst_local = sl * 4 + b
            kp2[:, g * 16 + dst_local] = kpool[:, src]
            vp2[:, g * 16 + dst_local] = vpool[:, src]
            flex2[g, sl * 4 + b] = dst_local
    st2["k_pool"] = jnp.asarray(kp2)
    st2["v_pool"] = jnp.asarray(vp2)
    st2["flex"] = jnp.asarray(flex2)
    st2["ctx_len"] = jnp.full((B,), 2 * bs - 1, jnp.int32)
    rules = ShardingRules(data_axes=("data",), zero_params=False)
    pins = make_pins(mesh, rules)
    step2 = make_serve_step(cfg, dims, spec2, mesh=mesh, pins=pins,
                            dtype=jnp.float32)
    with mesh:
        p_sh = param_shardings(jax.eval_shape(lambda: params), rules, mesh)
        d_sh = decode_state_shardings(
            jax.eval_shape(lambda: st2), mesh, spec2)
        logits_spmd, _, _ = jax.jit(step2)(params, st2, tokens)
    np.testing.assert_allclose(np.asarray(logits_spmd),
                               np.asarray(logits_ref), rtol=2e-3, atol=2e-3)
    print("SPMD_DECODE_MATCHES")
""")


def test_spmd_decode_matches_reference():
    """8 fake devices (2 data groups x 4-way TP token striping) must
    reproduce the single-device decode logits."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SPMD_DECODE_MATCHES" in out.stdout, (out.stdout[-2000:],
                                                 out.stderr[-4000:])
