"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

# hypothesis is an optional dev dependency: skip (not error) when absent
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import (HybridConfig, HybridKVManager, get_hash, HASHES,
                        translate, REST, FLEX, SWAP)
from repro.core.policies import SRRIP
from repro.dist import compression
from repro.kernels.utopia_rsw.ref import rsw_ref
from repro.kernels.utopia_rsw.ops import utopia_rsw

SETTINGS = dict(deadline=None, max_examples=25,
                suppress_health_check=[HealthCheck.too_slow])


@st.composite
def manager_and_ops(draw):
    assoc = draw(st.sampled_from([2, 4, 8]))
    n_sets = draw(st.sampled_from([2, 4, 8]))
    flex = draw(st.integers(4, 32))
    total = n_sets * assoc + flex
    max_seqs = draw(st.integers(2, 6))
    max_blocks = draw(st.sampled_from([8, 16]))
    hash_name = draw(st.sampled_from(sorted(HASHES)))
    cfg = HybridConfig(total_slots=total,
                       restseg_fraction=n_sets * assoc / total,
                       assoc=assoc, max_seqs=max_seqs,
                       max_blocks_per_seq=max_blocks, hash_name=hash_name)
    n_ops = draw(st.integers(5, 60))
    ops = [draw(st.tuples(st.sampled_from(["reg", "alloc", "free", "share",
                                           "stats"]),
                          st.integers(0, max_seqs - 1),
                          st.integers(0, max_blocks - 1)))
           for _ in range(n_ops)]
    return cfg, ops


@given(manager_and_ops())
@settings(**SETTINGS)
def test_manager_invariants_hold_under_any_op_sequence(case):
    """SF == TAR occupancy; TAR tags match block registry; no slot is both
    mapped and free — after any sequence of operations."""
    cfg, ops = case
    m = HybridKVManager(cfg)
    live = set()
    for op, s, b in ops:
        try:
            if op == "reg" and len(live) < cfg.max_seqs:
                m.register_sequence(s)
                live.add(s)
            elif op == "alloc" and s in live:
                m.allocate_block(s, b)
            elif op == "free" and s in live:
                m.free_sequence(s)
                live.discard(s)
            elif op == "share" and s in live and ((s + 1) % cfg.max_seqs) in live:
                m.share_prefix(s, (s + 1) % cfg.max_seqs, 1 + b % 4)
            elif op == "stats" and s in live:
                vpns = np.array([m.cfg.vpn(m.seq_slot(s), bb)
                                 for bb in range(4)])
                vpns = np.array([v for v in vpns if v in m.blocks])
                if vpns.size:
                    m.record_device_stats(
                        vpns, np.zeros(len(vpns), bool),
                        np.full(len(vpns), 4))
                    m.run_promotions()
        except Exception as e:  # only PoolExhausted-ish errors are legal
            from repro.core import PoolExhausted
            assert isinstance(e, (PoolExhausted, KeyError, ValueError)), e
        m.check_invariants()


@given(manager_and_ops())
@settings(**SETTINGS)
def test_translation_total_and_exclusive(case):
    """Every allocated block translates to exactly one segment, and device
    translation agrees with the host registry."""
    cfg, ops = case
    m = HybridKVManager(cfg)
    live = set()
    for op, s, b in ops:
        if op == "reg" and len(live) < cfg.max_seqs:
            m.register_sequence(s)
            live.add(s)
        elif op == "alloc" and s in live:
            m.allocate_block(s, b)
    ts = m.device_state()
    for vpn, info in m.blocks.items():
        res = translate(ts, jnp.array([vpn], jnp.int32))
        if info.seg == SWAP:
            assert not bool(res.mapped[0])
        else:
            assert bool(res.mapped[0])
            assert int(res.slot[0]) == info.slot
            assert bool(res.in_rest[0]) == (info.seg == REST)


@given(st.integers(0, 2**27), st.sampled_from(sorted(HASHES)),
       st.sampled_from([4, 8, 96, 128, 480]))
@settings(**SETTINGS)
def test_hash_domain_consistency(vpn, name, n_sets):
    h = get_hash(name)
    a = h(vpn, n_sets)
    b = int(np.asarray(h(np.array([vpn], np.int32), n_sets))[0])
    c = int(np.asarray(h(jnp.array([vpn], jnp.int32), n_sets))[0])
    assert a == b == c
    assert 0 <= a < n_sets


@given(st.lists(st.integers(0, 511), min_size=1, max_size=64, unique=True))
@settings(**SETTINGS)
def test_rsw_kernel_equals_ref_on_random_tables(vpns):
    rng = np.random.RandomState(sum(vpns) % 2**31)
    n_sets, assoc = 16, 4
    tar = np.zeros((n_sets, assoc), np.int32)
    # install a random subset at their correct sets
    for v in rng.choice(512, size=40, replace=False):
        s = v % n_sets
        ways = np.nonzero(tar[s] == 0)[0]
        if ways.size:
            tar[s, ways[0]] = v + 1
    sf = (tar != 0).sum(axis=1).astype(np.int32)
    flex = rng.randint(-1, 64, size=512).astype(np.int32)
    out_k = utopia_rsw(jnp.asarray(vpns, jnp.int32), jnp.asarray(tar),
                       jnp.asarray(sf), jnp.asarray(flex))
    out_r = rsw_ref(jnp.asarray(vpns, jnp.int32), jnp.asarray(tar),
                    jnp.asarray(sf), jnp.asarray(flex))
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(1, 7), st.integers(2, 4))
@settings(**SETTINGS)
def test_srrip_victim_always_valid(seed, assoc):
    rng = np.random.RandomState(seed)
    srrip = SRRIP(4, assoc)
    valid = rng.rand(assoc) > 0.3
    if not valid.any():
        valid[0] = True
    v = srrip.victim(0, valid)
    assert valid[v]


@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=4,
                max_size=64))
@settings(**SETTINGS)
def test_ef_compression_residual_bound(xs):
    """Quantization error never exceeds half a quantization step, and the
    error-feedback identity sum(g_hat) + residual == sum(g) holds."""
    g = jnp.asarray(np.array(xs, np.float32))
    ef = compression.EFState(residual=jnp.zeros_like(g))
    g_hat, ef2 = compression.compress_with_ef(g, ef)
    np.testing.assert_allclose(np.asarray(g_hat + ef2.residual),
                               np.asarray(g), rtol=1e-5, atol=1e-5)
