"""In-graph sampling tests (ISSUE 3 tentpole).

Pins the sampling contract of the request-centric API:

* top-k / top-p masking matches a numpy oracle implementing the same
  threshold semantics;
* temperature sampling's empirical distribution matches the numpy
  softmax of the scaled logits (gumbel-max correctness);
* a sampled token always lies inside the top-k/top-p support;
* engine-level seeded determinism: identical runs, identical tokens;
* schedule independence: a sampled request's tokens do not depend on
  chunking/admission interleaving (the PRNG key folds the absolute
  position, not the step index);
* greedy rows in a mixed batch are bit-identical to an all-greedy run;
* sampled decode still performs exactly ONE device fetch per step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, EngineConfig, Request, SamplingParams
from repro.serve.sampling import (apply_top_k_top_p, prng_key_data,
                                  sample_tokens)


# ------------------------------------------------------------ numpy oracle

def _np_softmax(x):
    x = x - np.max(x)
    e = np.exp(x)
    return e / e.sum()


def _np_mask(row, k, p):
    """Numpy mirror of apply_top_k_top_p's threshold semantics."""
    V = row.size
    keff = V if k <= 0 else min(max(k, 1), V)
    desc = np.sort(row)[::-1]
    desc_k = np.where(np.arange(V) < keff, desc, -np.inf)
    pr = _np_softmax(desc_k)
    cum = np.cumsum(pr)
    keep = ((cum - pr) < p) & (np.arange(V) < keff)
    last = max(int(keep.sum()) - 1, 0)
    thr = desc_k[last]
    return np.where(row >= thr, row, -np.inf)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_top_k_top_p_mask_matches_numpy_oracle(seed):
    rng = np.random.RandomState(seed)
    B, V = 8, 32
    logits = rng.randn(B, V).astype(np.float32) * 2
    ks = rng.choice([0, 1, 3, 7, V], B).astype(np.int32)
    ps = rng.choice([0.25, 0.55, 0.9, 1.0], B).astype(np.float32)
    got = np.asarray(apply_top_k_top_p(
        jnp.asarray(logits), jnp.asarray(ks), jnp.asarray(ps)))
    for b in range(B):
        want = _np_mask(logits[b], int(ks[b]), float(ps[b]))
        np.testing.assert_array_equal(
            np.isfinite(got[b]), np.isfinite(want),
            err_msg=f"row {b}: k={ks[b]} p={ps[b]}")
        np.testing.assert_allclose(got[b][np.isfinite(got[b])],
                                   want[np.isfinite(want)])


def test_top_k_one_and_tiny_top_p_keep_exactly_argmax():
    rng = np.random.RandomState(3)
    logits = jnp.asarray(rng.randn(4, 16).astype(np.float32))
    for ks, ps in (((1, 1, 1, 1), (1.0,) * 4), ((0,) * 4, (1e-6,) * 4)):
        m = np.asarray(apply_top_k_top_p(
            logits, jnp.asarray(ks, jnp.int32),
            jnp.asarray(ps, jnp.float32)))
        assert (np.isfinite(m).sum(axis=1) == 1).all()
        assert (np.argmax(m, axis=1) == np.argmax(logits, axis=1)).all()


def test_temperature_matches_numpy_softmax_oracle():
    """Empirical frequency of gumbel-max draws == softmax(logits/T).

    Deterministic (fixed key, fold steps 0..N-1), so no flake: the draw
    set never changes across runs.
    """
    V, N, temp = 12, 4096, 0.7
    rng = np.random.RandomState(0)
    base = rng.randn(V).astype(np.float32) * 1.5
    logits = jnp.asarray(np.tile(base, (N, 1)))
    key = prng_key_data(SamplingParams(seed=42), 0)
    toks = np.asarray(sample_tokens(
        logits, jnp.full((N,), temp, jnp.float32),
        jnp.zeros((N,), jnp.int32), jnp.ones((N,), jnp.float32),
        jnp.asarray(np.tile(key, (N, 1))),
        jnp.arange(N, dtype=jnp.int32)))
    freq = np.bincount(toks, minlength=V) / N
    probs = _np_softmax(base / temp)
    assert np.abs(freq - probs).max() < 0.03, (freq, probs)


def test_sampled_token_always_inside_support():
    rng = np.random.RandomState(7)
    B, V = 16, 24
    logits = rng.randn(B, V).astype(np.float32) * 3
    ks = rng.choice([0, 2, 5], B).astype(np.int32)
    ps = rng.choice([0.4, 0.8, 1.0], B).astype(np.float32)
    temps = rng.choice([0.5, 1.0, 2.0], B).astype(np.float32)
    keys = np.stack([prng_key_data(SamplingParams(seed=b), b)
                     for b in range(B)])
    for step in range(20):
        toks = np.asarray(sample_tokens(
            jnp.asarray(logits), jnp.asarray(temps), jnp.asarray(ks),
            jnp.asarray(ps), jnp.asarray(keys),
            jnp.full((B,), step, jnp.int32)))
        masked = np.asarray(apply_top_k_top_p(
            jnp.asarray(logits / temps[:, None]), jnp.asarray(ks),
            jnp.asarray(ps)))
        assert np.isfinite(masked[np.arange(B), toks]).all()


def test_greedy_rows_ignore_sampling_fields():
    """temperature == 0 returns the exact argmax whatever top-k/top-p/key
    say — the greedy fast path is bit-identical to pre-sampling."""
    rng = np.random.RandomState(1)
    logits = jnp.asarray(rng.randn(6, 20).astype(np.float32))
    toks = np.asarray(sample_tokens(
        logits, jnp.zeros((6,), jnp.float32),
        jnp.asarray(rng.randint(0, 5, 6), jnp.int32),
        jnp.asarray(rng.rand(6).clip(0.1, 1.0), jnp.float32),
        jnp.asarray(rng.randint(0, 2**31, (6, 2)), jnp.uint32),
        jnp.arange(6, dtype=jnp.int32)))
    np.testing.assert_array_equal(toks, np.argmax(np.asarray(logits), -1))


# ---------------------------------------------------------- engine-level

@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    return cfg, params


def _drain(eng):
    steps = 0
    while eng.has_unfinished():
        eng.step()
        steps += 1
        assert steps < 200, "engine failed to drain"
    return steps


SAMPLED = SamplingParams(temperature=0.9, top_k=8, top_p=0.95, seed=123)


def test_engine_sampled_decode_is_seed_reproducible(setup):
    cfg, params = setup
    bs = cfg.kv_block_size
    prompt = np.random.RandomState(2).randint(0, cfg.vocab_size, 2 * bs)

    def run():
        eng = Engine(cfg, params, EngineConfig(max_batch=2,
                                               max_seq_len=6 * bs))
        r = Request(seq_id=0, prompt=prompt, max_new_tokens=6,
                    sampling=SAMPLED)
        eng.submit(r)
        _drain(eng)
        return list(r.generated)

    a, b = run(), run()
    assert a == b
    assert len(a) == 6


def test_engine_sampled_schedule_independent(setup):
    """A sampled request's tokens are identical whether it is served
    alone (blocking admission) or admitted mid-decode, chunked under a
    tight budget, next to another request — the PRNG key folds the
    absolute position, not the engine step."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(4)
    p_long = rng.randint(0, cfg.vocab_size, 4 * bs)
    p_other = rng.randint(0, cfg.vocab_size, 2 * bs)
    sp = SamplingParams(temperature=1.1, top_k=16, seed=55)

    solo = Engine(cfg, params, EngineConfig(max_batch=2,
                                            max_seq_len=8 * bs))
    r_solo = Request(seq_id=0, prompt=p_long, max_new_tokens=5, sampling=sp)
    solo.add_request(r_solo)
    _drain(solo)

    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq_len=8 * bs,
                                           prefill_budget=bs))
    other = Request(seq_id=7, prompt=p_other, max_new_tokens=8,
                    sampling=SamplingParams(temperature=0.6, seed=9))
    eng.submit(other)
    eng.step()
    eng.step()
    r = Request(seq_id=3, prompt=p_long, max_new_tokens=5, sampling=sp)
    eng.submit(r)                      # mid-decode, chunked at 1 block/step
    _drain(eng)
    assert list(r.generated) == list(r_solo.generated)


def test_mixed_batch_greedy_row_bit_identical(setup):
    """A greedy request decodes the same tokens whether its batch
    neighbour samples at high temperature or not."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(6)
    p_greedy = rng.randint(0, cfg.vocab_size, 2 * bs)
    p_other = rng.randint(0, cfg.vocab_size, 2 * bs)

    def run(sampled_neighbour):
        eng = Engine(cfg, params, EngineConfig(max_batch=2,
                                               max_seq_len=6 * bs))
        g = Request(seq_id=0, prompt=p_greedy, max_new_tokens=6)
        eng.submit(g)
        sp = (SamplingParams(temperature=2.0, seed=1)
              if sampled_neighbour else SamplingParams())
        eng.submit(Request(seq_id=1, prompt=p_other, max_new_tokens=6,
                           sampling=sp))
        _drain(eng)
        return list(g.generated)

    assert run(True) == run(False)


def test_sampled_engine_step_single_fetch(setup, monkeypatch):
    """Sampled decode keeps the translate-once contract: exactly ONE
    device->host fetch per steady-state step."""
    cfg, params = setup
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(max_batch=4,
                                           max_seq_len=4 * bs))
    rng = np.random.RandomState(3)
    for sid in (1, 2):
        eng.add_request(Request(
            seq_id=sid, prompt=rng.randint(0, cfg.vocab_size, bs),
            max_new_tokens=8,
            sampling=SamplingParams(temperature=0.8, top_k=10, seed=sid)))
    fetches = []
    orig = jax.device_get

    def counting(x):
        fetches.append(1)
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting)
    import repro.serve.engine as engine_mod
    monkeypatch.setattr(engine_mod.jax, "device_get", counting)
    for _ in range(3):
        fetches.clear()
        out = eng.step()
        assert len(out) == 2
        assert len(fetches) == 1
