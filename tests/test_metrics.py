"""Live metrics stream (ISSUE 9 tentpole).

Contracts pinned here:

* ``RollingWindow`` medians/percentiles equal a numpy oracle computed
  over the same trailing window, through ring-buffer wraparound;
* attaching a ``MetricsLogger`` is stream-invisible: token streams are
  bit-identical logger-on vs logger-off across greedy/sampled x spec
  on/off, and on a real (1, 2) mesh (the logger is host-side
  arithmetic — no device op, no PRNG draw);
* the JSONL sink round-trips: ``read_jsonl(path)`` equals the
  ``MemorySink`` event list from the same run;
* the logger's re-integrated ``totals`` agree with ``Engine.stats()``
  counters at EVERY step of an overload run (preempt/resume, swap
  bytes, spec, prefix-cache — the deltas it emits sum back to the
  engine's monotone truth);
* per-request submit-to-finish latencies come from the injected
  monotonic clock.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import (Engine, EngineConfig, JsonlSink, MemorySink,
                         MetricsLogger, Request, RollingWindow)
from repro.serve.metrics import STEP_COUNTER_KEYS, read_jsonl
from repro.serve.sampling import SamplingParams

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")

_SETUP_CACHE = {}


def _setup(arch="granite-8b"):
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(reduced(ARCHS[arch]), num_layers=2)
        dims = model_dims(cfg, tp=1)
        params = init_params(jax.random.PRNGKey(2), cfg, dims)
        _SETUP_CACHE[arch] = (cfg, params)
    return _SETUP_CACHE[arch]


def _drain(eng, max_steps=900):
    outs = {}
    for _ in range(max_steps):
        for ro in eng.poll():
            outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
        if not eng.has_unfinished():
            return outs
    raise AssertionError("engine failed to drain")


def _run(cfg, params, *, metrics=None, headroom=0.5, n_req=8, max_new=10,
         sampling=None, **ekw):
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_seq_len=8 * bs, pool_headroom=headroom,
        auto_release=True, metrics=metrics, **ekw))
    rng = np.random.RandomState(7)
    for i in range(n_req):
        eng.submit(Request(
            seq_id=i, prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
            max_new_tokens=max_new,
            sampling=sampling if sampling is not None
            else SamplingParams()))
    return _drain(eng), eng


# ------------------------------------------------------- rolling window

def test_rolling_window_matches_numpy_oracle():
    """Median/p99 of the window equal numpy over the same trailing
    slice, at every push — including after the ring wraps."""
    rng = np.random.RandomState(0)
    feed = rng.exponential(3.0, 300)
    w = RollingWindow(64)
    for i, x in enumerate(feed):
        w.push(x)
        ref = feed[max(0, i + 1 - 64):i + 1]
        assert len(w) == len(ref)
        np.testing.assert_allclose(w.values(), ref)
        assert w.median() == pytest.approx(float(np.median(ref)))
        assert w.percentile(99) == pytest.approx(
            float(np.percentile(ref, 99)))
        assert w.sum() == pytest.approx(float(ref.sum()))


def test_rolling_window_edge_cases():
    w = RollingWindow(4)
    assert len(w) == 0 and w.median() == 0.0 and w.percentile(99) == 0.0
    w.push(5.0)
    assert w.median() == 5.0
    with pytest.raises(ValueError):
        RollingWindow(0)


# --------------------------------------------------------- sink plumbing

def test_jsonl_sink_round_trips_memory_sink(tmp_path):
    """The JSONL file replays to exactly the event list an in-memory
    sink captured from the same logger."""
    path = str(tmp_path / "events.jsonl")
    mem = MemorySink()
    log = MetricsLogger([mem, JsonlSink(path)])
    cfg, params = _setup()
    _run(cfg, params, metrics=log, n_req=4, max_new=6)
    log.close()
    replay = read_jsonl(path)
    assert replay == mem.events
    kinds = [e["kind"] for e in replay]
    assert kinds.count("submit") == 4 and kinds.count("finish") == 4
    assert kinds.count("step") == log.n_steps > 0
    # step events carry every declared counter delta + the gauges
    step0 = next(e for e in replay if e["kind"] == "step")
    for k in STEP_COUNTER_KEYS:
        assert k in step0
    for k in ("occupancy", "mapped_blocks", "pool_blocks", "live",
              "queued", "host_tier_seqs", "wall_s"):
        assert k in step0


def test_logger_context_manager_closes_sinks(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with MetricsLogger([JsonlSink(path)]) as log:
        log.on_submit(0, 0)
    assert log.sinks[0]._f.closed
    assert [e["kind"] for e in read_jsonl(path)] == ["submit"]


# ------------------------------------------------- stream invisibility

@pytest.mark.parametrize("spec,sampling", [
    (None, None),
    (None, SamplingParams(temperature=0.8, top_k=40, seed=123)),
    ("ngram", None),
    ("ngram", SamplingParams(temperature=0.8, top_k=40, seed=123)),
], ids=["greedy", "sampled", "spec-greedy", "spec-sampled"])
def test_streams_bit_identical_logger_on_vs_off(spec, sampling):
    """The tentpole's safety contract: the logger observes, never
    perturbs.  Same overloaded workload (preempt/resume cycles
    included), token streams must match exactly with and without it."""
    cfg, params = _setup()
    off, _ = _run(cfg, params, metrics=None, sampling=sampling,
                  spec_decode=spec)
    log = MetricsLogger([MemorySink()])
    on, eng = _run(cfg, params, metrics=log, sampling=sampling,
                   spec_decode=spec)
    assert on == off
    assert log.n_steps == eng.step_count > 0
    eng.check_invariants()


def test_streams_bit_identical_on_mesh():
    """(1, 2)-sharded engine with the logger attached streams
    identically to the single-device logger-off run; per-shard swap
    deltas in the events sum to the global swap counters.  Subprocess
    pins 8 host devices before importing jax (test_sharded_serve
    recipe)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import numpy as np, jax
        from repro.configs import ARCHS, reduced
        from repro.models import model_dims, init_params
        from repro.serve import (Engine, EngineConfig, MemorySink,
                                 MetricsLogger, Request)
        cfg = dataclasses.replace(reduced(ARCHS["granite-8b"]),
                                  num_layers=2)
        dims = model_dims(cfg, tp=1)
        params = init_params(jax.random.PRNGKey(2), cfg, dims)
        bs = cfg.kv_block_size

        def run(mesh, log):
            eng = Engine(cfg, params, EngineConfig(
                max_batch=4, max_seq_len=8 * bs, pool_headroom=0.5,
                auto_release=True, mesh_shape=mesh, metrics=log))
            rng = np.random.RandomState(7)
            for i in range(12):
                eng.submit(Request(
                    seq_id=i,
                    prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                    max_new_tokens=20))
            outs = {}
            for _ in range(900):
                for ro in eng.poll():
                    outs.setdefault(ro.seq_id, []).extend(
                        ro.new_token_ids)
                if not eng.has_unfinished():
                    break
            eng.check_invariants()
            return outs, eng

        base, _ = run(None, None)
        mem = MemorySink()
        log = MetricsLogger([mem])
        got, eng = run((1, 2), log)
        assert got == base, "sharded logger-on stream diverged"
        steps = [e for e in mem.events if e["kind"] == "step"]
        assert steps and all("shard_swap_bytes_out" in e for e in steps)
        ov = eng.stats()["overload"]
        tot_out = sum(sum(e["shard_swap_bytes_out"]) for e in steps)
        tot_in = sum(sum(e["shard_swap_bytes_in"]) for e in steps)
        assert tot_out == ov["swap_bytes_out"] > 0
        assert tot_in == ov["swap_bytes_in"] > 0
        print("ALL_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0 and "ALL_OK" in out.stdout, (
        out.stdout[-2000:], out.stderr[-4000:])


# -------------------------------------------- stats() <-> logger oracle

def test_logger_totals_agree_with_stats_every_step():
    """Drive an overloaded spec-decode run one ``step()`` at a time and
    cross-check the logger's re-integrated ``totals`` against
    ``Engine.stats()`` after EVERY step — the deltas it emitted sum
    back to the engine's monotone counters with no drift, through
    preempt/resume and swap traffic."""
    cfg, params = _setup()
    bs = cfg.kv_block_size
    mem = MemorySink()
    log = MetricsLogger([mem])
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_seq_len=8 * bs, pool_headroom=0.5,
        auto_release=True, spec_decode="ngram", metrics=log))
    rng = np.random.RandomState(7)
    for i in range(12):
        eng.submit(Request(
            seq_id=i, prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
            max_new_tokens=20))
    for _ in range(900):
        eng.step()
        # step() returns only the LAST token per sequence (spec commits
        # several); the emitted-token truth is the generated streams
        emitted = sum(len(s.generated) for s in eng._states.values())
        st = eng.stats()
        ov = st["overload"]
        pc = st["prefix_cache"]
        expect = {
            "tokens": emitted,
            "rsw_hits": st.get("rsw_hits", 0),
            "flex_walks": st.get("flex_walks", 0),
            "swap_faults": st.get("faults", 0),
            "spec_drafted": st["spec_drafted"],
            "spec_accepted": st["spec_accepted"],
            "request_preempts": ov["request_preempts"],
            "request_resumes": ov["request_resumes"],
            "swap_bytes_out": ov["swap_bytes_out"],
            "swap_bytes_in": ov["swap_bytes_in"],
            "prefix_lookups": pc["lookups"],
            "prefix_hits": pc["hits"],
            "cancelled": st["lifecycle"]["cancelled"],
            "deadline_expired": st["lifecycle"]["deadline_expired"],
        }
        assert log.totals == expect, f"drift at step {eng.step_count}"
        if not eng.has_unfinished():
            break
    assert not eng.has_unfinished()
    assert log.totals["request_preempts"] > 0, "overload never hit"
    # the per-step deltas in the event stream re-integrate to totals
    steps = [e for e in mem.events if e["kind"] == "step"]
    for k in STEP_COUNTER_KEYS:
        assert sum(e[k] for e in steps) == log.totals[k]
    # deltas are per-step accounts of monotone counters: never negative
    assert all(e[k] >= 0 for e in steps for k in STEP_COUNTER_KEYS)
    eng.check_invariants()


# ------------------------------------------------- rollups + lifecycle

def test_rolling_and_dashboard_and_latency():
    """``rolling()`` exposes the headline rates, the dashboard line
    renders them, and every finished request has a latency from the
    injected clock (here: a fake monotone counter, so values are exact
    and NTP-immune by construction)."""
    t = [0.0]

    def fake_clock():
        t[0] += 1.0
        return t[0]

    cfg, params = _setup()
    log = MetricsLogger([MemorySink()], window=8, clock=fake_clock)
    outs, eng = _run(cfg, params, metrics=log, n_req=6, max_new=8)
    r = log.rolling()
    assert r["steps"] == eng.step_count
    assert r["window_steps"] == min(8, eng.step_count)
    assert r["tokens_per_s"] > 0
    assert 0.0 <= r["rsw_hit_rate"] <= 1.0
    assert 0.0 <= r["occupancy"] <= 1.0
    assert r["step_ms_p99"] >= r["step_ms_p50"] > 0
    line = log.dashboard_line()
    assert "tok/s" in line and "p99" in line and "occ" in line
    # submit-to-finish latency recorded for every request, strictly
    # positive on the fake monotone clock
    assert set(log.request_latencies) == set(outs)
    assert all(v > 0 for v in log.request_latencies.values())


def test_rsw_hit_rate_reflects_translation_mode():
    """restrictive_only serves every decode-step translation from the
    RestSeg walker: the rolling RestSeg hit rate must be 1.0; a
    flexible_only run must be 0.0 (pure flex walks)."""
    cfg, params = _setup()
    log = MetricsLogger()
    _run(cfg, params, metrics=log, headroom=2.0, n_req=4, max_new=8,
         mode="restrictive_only")
    assert log.rolling()["rsw_hit_rate"] == 1.0
    log2 = MetricsLogger()
    _run(cfg, params, metrics=log2, headroom=2.0, n_req=4, max_new=8,
         mode="flexible_only")
    assert log2.rolling()["rsw_hit_rate"] == 0.0
