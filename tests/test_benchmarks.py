"""Benchmark harness robustness + the perf-regression gate (ISSUE 9).

``benchmarks/`` is not a package; ``run.py`` and ``common.py`` are
loaded by file path.  Pinned here:

* a malformed/truncated ``BENCH_*.json`` is skipped with a warning and
  recorded under ``"skipped"`` — it must not wedge the aggregation (or
  the --diff gate) on an unrelated file (ISSUE 9 satellite 3);
* ``diff_summaries`` is direction-aware: a 20% step-latency regression
  on a "lower is better" metric trips the gate, the same-magnitude
  IMPROVEMENT passes, in-band drift passes, and an identical summary
  diffs clean;
* every gated metric family in ``KEY_METRICS`` has a ``NOISE_BANDS``
  direction (a new headline metric without a declared direction would
  silently escape the gate);
* ``summarize_times`` under a coarse timer: a zero median must not
  classify every nonzero sample as a compile spike (ISSUE 9
  satellite 2 — the timer-granularity floor).
"""
import importlib.util
import json
import os
import sys

import numpy as np
import pytest

BENCH_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks")


def _load(name):
    spec = importlib.util.spec_from_file_location(
        f"bench_{name}", os.path.join(BENCH_DIR, f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


run_mod = _load("run")
common = _load("common")


# ------------------------------------------------- robust aggregation

def _write(path, rec):
    with open(path, "w") as f:
        if isinstance(rec, str):
            f.write(rec)
        else:
            json.dump(rec, f)


def test_summarize_skips_malformed_bench_files(tmp_path, capsys):
    """One valid record, one truncated write, one non-object top level:
    the valid rows survive, the bad files land in ``skipped`` (both in
    the return value and the written summary), and a warning names
    each."""
    _write(tmp_path / "BENCH_sampling.json",
           {"benchmark": "sampling",
            "sampled_over_greedy_step_ratio": 1.4})
    _write(tmp_path / "BENCH_truncated.json",
           '{"benchmark": "overload", "goodput')
    _write(tmp_path / "BENCH_notdict.json", [1, 2, 3])
    out = tmp_path / "BENCH_summary.json"
    rows, skipped = run_mod.summarize_bench_jsons(str(tmp_path), str(out))
    assert rows == [{"benchmark": "sampling",
                     "metric": "sampled_over_greedy_step_ratio",
                     "value": 1.4}]
    assert sorted(s["file"] for s in skipped) == [
        "BENCH_notdict.json", "BENCH_truncated.json"]
    err = capsys.readouterr().err
    assert "BENCH_truncated.json" in err and "BENCH_notdict.json" in err
    rec = json.load(open(out))
    assert rec["summary"] == rows
    assert [s["file"] for s in rec["skipped"]] == \
        [s["file"] for s in skipped]
    # the summary itself is never re-ingested as an input file
    rows2, skipped2 = run_mod.summarize_bench_jsons(str(tmp_path), None)
    assert rows2 == rows and len(skipped2) == 2


def test_summarize_expands_dict_metrics(tmp_path):
    """Dict-valued headline metrics expand to one dotted row per key —
    the shape the NOISE_BANDS prefix matching relies on."""
    _write(tmp_path / "BENCH_engine_step.json",
           {"benchmark": "engine_step",
            "speedup_vs_pre_pr": {"hybrid_b2": 3.1},
            "steady_step_ms": {"hybrid_b2": 2.7, "hybrid_b4": 3.9}})
    rows, _ = run_mod.summarize_bench_jsons(str(tmp_path), None)
    assert {(r["metric"], r["value"]) for r in rows} == {
        ("speedup_vs_pre_pr.hybrid_b2", 3.1),
        ("steady_step_ms.hybrid_b2", 2.7),
        ("steady_step_ms.hybrid_b4", 3.9)}


# --------------------------------------------------- perf-regression gate

def _rows(**metrics):
    return [{"benchmark": "engine_step", "metric": m, "value": v}
            for m, v in metrics.items()]


def test_diff_identical_summaries_pass():
    rows = _rows(**{"steady_step_ms.hybrid_b2": 2.7,
                    "speedup_vs_pre_pr.hybrid_b2": 3.0})
    regs, notes = run_mod.diff_summaries(rows, rows)
    assert regs == [] and notes == []


def test_diff_catches_synthetic_20pct_latency_regression():
    """The acceptance-criteria scenario: steady step latency 20% worse
    than baseline on a 15% band -> gate trips, and the offending row
    carries enough to print (baseline, current, change, band)."""
    old = _rows(**{"steady_step_ms.hybrid_b2": 2.7})
    new = _rows(**{"steady_step_ms.hybrid_b2": 2.7 * 1.2})
    regs, _ = run_mod.diff_summaries(old, new)
    assert len(regs) == 1
    r = regs[0]
    assert r["metric"] == "steady_step_ms.hybrid_b2"
    assert r["better"] == "lower" and r["band"] == 0.15
    assert r["change"] == pytest.approx(0.2)


def test_diff_is_direction_aware():
    """A 20% IMPROVEMENT on the same 'lower' metric passes; a 'higher'
    metric (speedup) regresses by SHRINKING, not growing."""
    old = _rows(**{"steady_step_ms.hybrid_b2": 2.7,
                   "speedup_vs_pre_pr.hybrid_b2": 3.0})
    faster = _rows(**{"steady_step_ms.hybrid_b2": 2.7 / 1.2,
                      "speedup_vs_pre_pr.hybrid_b2": 3.0 * 1.2})
    regs, _ = run_mod.diff_summaries(old, faster)
    assert regs == []
    slower = _rows(**{"steady_step_ms.hybrid_b2": 2.7,
                      "speedup_vs_pre_pr.hybrid_b2": 3.0 * 0.5})
    regs, _ = run_mod.diff_summaries(old, slower)
    assert [r["metric"] for r in regs] == ["speedup_vs_pre_pr.hybrid_b2"]


def test_diff_in_band_drift_and_unknown_metrics_pass():
    old = _rows(**{"steady_step_ms.hybrid_b2": 2.7,
                   "some_informational_metric": 10.0})
    new = _rows(**{"steady_step_ms.hybrid_b2": 2.7 * 1.10,   # in band
                   "some_informational_metric": 99.0})       # ungated
    regs, _ = run_mod.diff_summaries(old, new)
    assert regs == []


def test_diff_surfaces_one_sided_metrics_as_notes():
    old = _rows(**{"steady_step_ms.hybrid_b2": 2.7})
    new = _rows(**{"steady_step_ms.hybrid_b4": 3.9})
    regs, notes = run_mod.diff_summaries(old, new)
    assert regs == []
    assert any("in baseline only" in n for n in notes)
    assert any("no baseline" in n for n in notes)


def test_every_key_metric_has_a_noise_band():
    """Gate coverage: each headline metric family declared in
    KEY_METRICS must carry a NOISE_BANDS direction, or a regression in
    it would silently pass."""
    for bench, metrics in run_mod.KEY_METRICS.items():
        for m in metrics:
            band = run_mod.band_for(m)
            assert band is not None, f"{bench}/{m} has no noise band"
            better, rel = band
            assert better in ("higher", "lower") and 0 < rel < 1


def test_gate_end_to_end_against_committed_summary(tmp_path):
    """The CI step, in miniature: the committed BENCH files diff clean
    against their own committed summary, and an injected 20% latency
    regression (baseline rewritten 1.2x faster) exits nonzero."""
    root = os.path.dirname(BENCH_DIR)
    committed = os.path.join(root, "BENCH_summary.json")
    if not os.path.exists(committed):
        pytest.skip("no committed BENCH_summary.json")
    assert run_mod.run_diff_gate(committed, root) == 0
    rows = run_mod.load_summary_rows(committed)
    n = 0
    for r in rows:
        if r["metric"].startswith("steady_step_ms"):
            r["value"] = r["value"] / 1.2
            n += 1
    if n == 0:
        pytest.skip("committed summary predates steady_step_ms")
    inj = tmp_path / "injected.json"
    _write(inj, {"summary": rows, "skipped": []})
    assert run_mod.run_diff_gate(str(inj), root) == 1


# ------------------------------------------- summarize_times timer floor

def test_summarize_times_zero_median_coarse_clock():
    """ISSUE 9 satellite 2: on a coarse clock most steps record as
    exactly 0.0 and the median is zero; the old ``3 * median``
    threshold classified EVERY nonzero step as a compile spike.  With
    the timer-granularity floor the nonzero ticks stay in the steady
    set."""
    times = [0.0] * 6 + [0.001] * 4
    out = common.summarize_times(times)
    assert out["n_compile_spikes"] == 0
    assert out["n_steady_steps"] == 10
    assert out["step_ms_mean"] == pytest.approx(0.4)
    assert out["step_ms"] == 0.0          # the median is honestly zero


def test_summarize_times_still_flags_real_spikes():
    """The floor is inert on well-resolved series: a genuine compile
    spike is still excluded from the steady mean and reported."""
    times = [0.002] * 10 + [0.250]
    out = common.summarize_times(times)
    assert out["n_compile_spikes"] == 1
    assert out["compile_spike_ms"] == pytest.approx(250.0)
    assert out["step_ms_mean"] == pytest.approx(2.0)
    assert out["n_steady_steps"] == 10
    # all-zero pathological input: no crash, nothing flagged
    z = common.summarize_times([0.0] * 5)
    assert z["n_compile_spikes"] == 0 and z["step_ms"] == 0.0
