"""Admission-scheduler tests (ISSUE 2).

The tentpole contract: batched, chunked, budget-bounded admission must be
OBSERVATIONALLY IDENTICAL to the old one-request-at-a-time serving — same
greedy tokens, no cross-sequence interference — while prefill never
touches the state of non-participating slots (the ``jnp.full_like``
ctx_len stomp this PR fixes) and finished sequences recycle their slots
under sustained load.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, Request
from repro.serve.decode import DecodeSpec, init_decode_state
from repro.serve.prefill import make_prefill_step


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    return cfg, dims, params


def _drain(eng):
    steps = 0
    while eng.waiting or any(not r.done for r in eng.requests.values()):
        eng.step()
        steps += 1
        assert steps < 200, "engine failed to drain"
    return steps


# ------------------------------------------------ prefill ctx_len regression

def test_prefill_never_mutates_nonparticipating_ctx(setup):
    """The multi-sequence prefill scatters ctx_len to participating slots
    ONLY.  The pre-fix code did ``jnp.full_like(ctx_len, ctx)``, stomping
    every live sequence's context length."""
    cfg, dims, params = setup
    bs = cfg.kv_block_size
    spec = DecodeSpec(block_size=bs, max_blocks_per_seq=4,
                      slots_per_group=16, n_sets=2, assoc=4)
    dstate = init_decode_state(cfg, dims, spec, 4, 1)
    before = np.asarray([5, 7, 0, 9], np.int32)
    dstate["ctx_len"] = jnp.asarray(before)
    kp_before = np.asarray(dstate["k_pool"])
    pf = make_prefill_step(cfg, dims, spec, mesh=None)
    _, ns, stats = jax.jit(pf)(
        params, dstate,
        {"tokens": jnp.zeros((2, 2 * bs), jnp.int32)},
        jnp.asarray([[2, 3], [-1, -1]], jnp.int32),   # row 1: pad row
        jnp.asarray([2, -1], jnp.int32),              # participant slot 2
        jnp.asarray([2 * bs, 0], jnp.int32),
        jnp.asarray([2 * bs - 1, 0], jnp.int32))
    got = np.asarray(ns["ctx_len"])
    assert got[2] == 2 * bs                      # participant updated
    np.testing.assert_array_equal(got[[0, 1, 3]], before[[0, 1, 3]])
    # the -1 pad row must be dropped, not clamped onto pool slot 0
    np.testing.assert_array_equal(np.asarray(ns["k_pool"])[:, 0],
                                  kp_before[:, 0])
    assert stats["next_token"].shape == (2,)


def test_engine_prefill_leaves_live_slots_alone(setup):
    """Admitting (and chunking) a new prompt mid-decode must not disturb a
    live sequence's context length or generation."""
    cfg, _, params = setup
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, max_batch=4, max_seq_len=8 * bs,
                 prefill_budget=bs)             # 1 block/step: forces chunks
    rng = np.random.RandomState(0)
    a = Request(seq_id=0, prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                max_new_tokens=12)
    eng.add_request(a)
    slot_a = eng._slot_of[0]
    ctx_a = int(eng._ctx_host[slot_a])
    eng.submit(Request(seq_id=1,
                       prompt=rng.randint(0, cfg.vocab_size, 4 * bs),
                       max_new_tokens=4))
    for k in range(1, 4):                       # B is mid-prefill throughout
        eng.step()
        assert eng._prefilling.get(1, 4 * bs) < 4 * bs
        # A decoded exactly once per step; B's chunks never touched it
        assert int(eng._ctx_host[slot_a]) == ctx_a + k
        np.testing.assert_array_equal(np.asarray(eng.dstate["ctx_len"]),
                                      eng._ctx_host)


# -------------------------------------------------- sequential equivalence

def test_interleaved_admission_matches_sequential(setup):
    """Admitting prompts through the batched/chunked scheduler mid-decode
    produces token-for-token the same generations as serving each request
    alone (same engine geometry, one at a time)."""
    cfg, _, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(42)
    prompts = [rng.randint(0, cfg.vocab_size, n * bs)
               for n in (2, 4, 2, 3)]
    n_new = [6, 5, 6, 4]

    def engine():
        return Engine(cfg, params, max_batch=4, max_seq_len=8 * bs)

    # sequential one-at-a-time reference (fresh pool per request)
    ref = []
    for p, n in zip(prompts, n_new):
        eng = engine()
        r = Request(seq_id=0, prompt=p, max_new_tokens=n)
        eng.add_request(r)
        _drain(eng)
        ref.append(list(r.generated))

    # interleaved: two up front, the rest submitted mid-decode; a small
    # budget chunks the 4-block prompt across steps
    eng = engine()
    eng.prefill_budget = 2 * bs
    reqs = [Request(seq_id=i, prompt=p, max_new_tokens=n)
            for i, (p, n) in enumerate(zip(prompts, n_new))]
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    eng.step()
    eng.step()
    eng.submit(reqs[2])                          # mid-decode admission
    eng.submit(reqs[3])
    _drain(eng)
    for i, r in enumerate(reqs):
        assert list(r.generated) == ref[i], f"request {i} diverged"
    eng.manager.check_invariants()


@pytest.mark.parametrize("arch", ["mamba2-130m", "jamba-1.5-large-398b"])
def test_recurrent_family_nonpow2_prompt_matches_full_forward(arch):
    """Recurrent (SSM/conv) state must not integrate the bucket's pad
    tail: ssm/hybrid rows ride the pow2 buckets with a per-row
    ``seq_len`` mask that zeroes dt past the real length, making every
    pad position an exact identity transition (PR 4; PR 2 used exact
    lengths instead).  The oracle is a full re-forward per step (NOT
    another engine path — both engine paths share the bucketized
    prefill, so comparing them would miss this)."""
    from repro.models import forward, FwdOptions
    cfg = reduced(ARCHS[arch])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    prompt = np.random.RandomState(5).randint(0, cfg.vocab_size, 3 * bs)
    n_new = 4

    toks, ref = list(prompt), []
    for _ in range(n_new):
        logits, _, _ = forward(params, {"tokens": jnp.asarray(toks)[None]},
                               cfg, dims, FwdOptions())
        ref.append(int(jnp.argmax(logits[0, -1])))
        toks.append(ref[-1])

    eng = Engine(cfg, params, max_batch=2, max_seq_len=8 * bs)
    r = Request(seq_id=0, prompt=prompt, max_new_tokens=n_new)
    eng.submit(r)
    _drain(eng)
    assert list(r.generated) == ref


def test_share_source_released_before_sharer_admitted(setup):
    """Prefix sharing degrades to plain prefill (same tokens, no crash)
    when the source finished and auto-released while the sharer queued."""
    cfg, _, params = setup
    bs = cfg.kv_block_size
    prompt = np.random.RandomState(11).randint(0, cfg.vocab_size, 2 * bs)

    solo = Request(seq_id=9, prompt=prompt, max_new_tokens=3)
    eng0 = Engine(cfg, params, max_batch=1, max_seq_len=6 * bs)
    eng0.add_request(solo)
    _drain(eng0)

    eng = Engine(cfg, params, max_batch=1, max_seq_len=6 * bs,
                 auto_release=True)
    src = Request(seq_id=0, prompt=prompt, max_new_tokens=3)
    eng.add_request(src)
    # max_batch=1: the sharer cannot register until src releases — by
    # which time its share source is gone
    dup = Request(seq_id=1, prompt=prompt, max_new_tokens=3)
    eng.submit(dup, share_prefix_from=0, shared_blocks=2)
    _drain(eng)
    assert list(src.generated) == list(solo.generated)
    assert list(dup.generated) == list(solo.generated)
    eng.manager.check_invariants()


def test_empty_prompt_rejected(setup):
    cfg, _, params = setup
    eng = Engine(cfg, params, max_batch=2,
                 max_seq_len=4 * cfg.kv_block_size)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(seq_id=0, prompt=np.zeros(0, np.int64)))


# ------------------------------------------------------ EOS + slot recycle

def test_eos_terminates_early_and_releases(setup):
    cfg, _, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, cfg.vocab_size, 2 * bs)
    probe = Request(seq_id=0, prompt=prompt, max_new_tokens=8)
    eng = Engine(cfg, params, max_batch=2, max_seq_len=6 * bs)
    eng.add_request(probe)
    _drain(eng)
    assert len(probe.generated) == 8

    eng2 = Engine(cfg, params, max_batch=2, max_seq_len=6 * bs,
                  auto_release=True)
    r = Request(seq_id=0, prompt=prompt, max_new_tokens=8,
                eos_token=probe.generated[2])
    eng2.add_request(r)
    _drain(eng2)
    assert r.done
    assert list(r.generated) == probe.generated[:3]   # stopped ON the eos
    assert 0 in eng2.finished and 0 not in eng2.requests
    assert not eng2._slot_of                          # slot freed
    eng2.manager.check_invariants()


def test_sustained_load_recycles_slots(setup):
    """More requests than batch slots: finished sequences auto-release and
    the queue drains through the recycled slots."""
    cfg, _, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(9)
    eng = Engine(cfg, params, max_batch=2, max_seq_len=6 * bs,
                 auto_release=True)
    n_req = 5
    for sid in range(n_req):
        eng.submit(Request(seq_id=sid,
                           prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                           max_new_tokens=3))
    _drain(eng)
    assert len(eng.finished) == n_req
    assert all(len(r.generated) == 3 for r in eng.finished.values())
    assert len(eng.manager._free_seq_slots) == 2      # all slots recycled
    assert not eng.requests and not eng.waiting
    eng.manager.check_invariants()
