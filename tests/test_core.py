"""Core hybrid-translation unit tests (paper mechanics)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (HybridConfig, HybridKVManager, RestSegConfig,
                        FlexSegConfig, translate, rsw, init_restseg, insert,
                        remove, ElasticCuckooTable, POMTLB, RadixBuilder,
                        translate_radix, translate_ech, translate_pom,
                        get_hash, HASHES, REST, FLEX, SWAP)


def make_manager(**kw):
    cfg = HybridConfig(total_slots=kw.pop("total_slots", 128),
                       restseg_fraction=kw.pop("restseg_fraction", 0.75),
                       assoc=kw.pop("assoc", 4),
                       max_seqs=kw.pop("max_seqs", 8),
                       max_blocks_per_seq=kw.pop("max_blocks_per_seq", 32),
                       **kw)
    return HybridKVManager(cfg)


class TestSegments:
    def test_geometry(self):
        cfg = HybridConfig(total_slots=128, restseg_fraction=0.75, assoc=8)
        assert cfg.rest_slots % cfg.assoc == 0
        assert cfg.rest_slots + cfg.flex_slots == 128
        assert cfg.num_sets == cfg.rest_slots // 8

    def test_structure_sizes_scale(self):
        """Fig. 13: TAR+SF should be far smaller than the radix table."""
        for num_blocks in (1 << 10, 1 << 14, 1 << 18):
            rs = RestSegConfig(num_slots=num_blocks, assoc=8)
            fx = FlexSegConfig(num_slots=num_blocks)
            compact = rs.tar_bytes() + rs.sf_bytes()
            radix = fx.table_bytes(num_blocks)
            assert compact < radix, (num_blocks, compact, radix)

    def test_mode_validation(self):
        with pytest.raises(ValueError):
            HybridConfig(mode="bogus")


class TestHashes:
    @pytest.mark.parametrize("name", sorted(HASHES))
    def test_domains_agree(self, name):
        """python ints, numpy arrays and jnp arrays must agree bit-for-bit."""
        h = get_hash(name)
        n_sets = 96
        vpns = np.arange(0, 20000, 7, dtype=np.int32)
        a = np.array([h(int(v), n_sets) for v in vpns])
        b = np.asarray(h(vpns, n_sets))
        c = np.asarray(h(jnp.asarray(vpns), n_sets))
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(b, c)
        assert (a >= 0).all() and (a < n_sets).all()


class TestTarSf:
    def test_insert_rsw_remove(self):
        st = init_restseg(8, 2)
        st = insert(st, 5, 0)
        st = insert(st, 13, 1)     # 13 % 8 == 5: same set, way 1
        res = rsw(st, jnp.array([5, 13, 21], jnp.int32))
        assert list(np.asarray(res.hit)) == [True, True, False]
        assert int(res.slot[0]) == 5 * 2 and int(res.slot[1]) == 5 * 2 + 1
        assert int(st.sf[5]) == 2
        st = remove(st, 5)
        res = rsw(st, jnp.array([5, 13], jnp.int32))
        assert list(np.asarray(res.hit)) == [False, True]

    def test_sf_skips_empty_sets(self):
        st = init_restseg(8, 2)
        res = rsw(st, jnp.arange(8, dtype=jnp.int32))
        assert bool(res.sf_skipped.all())
        assert int(res.tar_touched.sum()) == 0


class TestManager:
    def test_fault_based_alloc_prefers_restseg(self):
        m = make_manager()
        m.register_sequence(0)
        infos = [m.allocate_block(0, b) for b in range(16)]
        assert all(i.seg == REST for i in infos)
        m.check_invariants()

    def test_eviction_migrates_to_flex_not_swap(self):
        m = make_manager(total_slots=32, restseg_fraction=0.5, assoc=2,
                         max_seqs=8, max_blocks_per_seq=64)
        m.register_sequence(0)
        for b in range(24):
            m.allocate_block(0, b)
        m.check_invariants()
        assert m.stats["migrations_rest_to_flex"] > 0 or \
            m.stats["flex_allocs"] > 0
        assert m.stats["swap_out"] == 0   # flexible space absorbed conflicts

    def test_restrictive_only_swaps(self):
        """Fig. 9: without a FlexSeg, conflicts hit the swap space."""
        m = make_manager(total_slots=16, restseg_fraction=1.0, assoc=2,
                         max_seqs=8, max_blocks_per_seq=64,
                         mode="restrictive_only")
        m.register_sequence(0)
        for b in range(40):
            m.allocate_block(0, b)
        assert m.stats["swap_out"] > 0
        m.check_invariants()

    def test_flexible_only_never_uses_rest(self):
        m = make_manager(mode="flexible_only")
        m.register_sequence(0)
        infos = [m.allocate_block(0, b) for b in range(16)]
        assert all(i.seg == FLEX for i in infos)

    def test_sharing_requires_flex_and_refcounts(self):
        m = make_manager()
        for s in (0, 1):
            m.register_sequence(s)
        for b in range(8):
            m.allocate_block(0, b)
        shared = m.share_prefix(0, 1, 4)
        assert shared == 4
        for b in range(4):
            s0, seg0 = m.lookup(0, b)
            s1, seg1 = m.lookup(1, b)
            assert s0 == s1 and seg0 == FLEX == seg1  # migrated out of rest
        m.free_sequence(0)
        m.check_invariants()
        for b in range(4):
            assert m.lookup(1, b)[0] >= 0   # survivor keeps the slot
        m.free_sequence(1)
        m.check_invariants()
        assert not m.blocks

    def test_promotion_via_cost_tracking(self):
        m = make_manager(total_slots=64, restseg_fraction=0.125, assoc=2,
                         max_seqs=4, max_blocks_per_seq=16,
                         alloc_evicts=False)
        m.register_sequence(0)
        # the 8-slot restseg fills; later blocks land in flex
        infos = [m.allocate_block(0, b) for b in range(16)]
        flex_vpns = [i.vpn for i in infos if i.seg == FLEX]
        assert flex_vpns, "expected some flex blocks"
        vpn = flex_vpns[0]
        for _ in range(6):
            m.record_device_stats(np.array([vpn]), np.array([False]),
                                  np.array([4]))
        n = m.run_promotions()
        assert n >= 1
        assert m.blocks[vpn].seg == REST
        assert m.stats["migrations_flex_to_rest"] >= 1
        m.check_invariants()

    def test_device_host_agreement(self):
        m = make_manager()
        for s in range(4):
            m.register_sequence(s)
            for b in range(20):
                m.allocate_block(s, b)
        ts = m.device_state()
        for s in range(4):
            for b in range(20):
                vpn = m.cfg.vpn(m.seq_slot(s), b)
                res = translate(ts, jnp.array([vpn], jnp.int32))
                host_slot, _ = m.lookup(s, b)
                assert int(res.slot[0]) == host_slot

    def test_swap_in_roundtrip(self):
        m = make_manager(total_slots=8, restseg_fraction=1.0, assoc=2,
                         max_seqs=4, max_blocks_per_seq=32,
                         mode="restrictive_only")
        m.register_sequence(0)
        for b in range(16):
            m.allocate_block(0, b)
        swapped = [vpn for vpn, i in m.blocks.items() if i.seg == SWAP]
        assert swapped
        b = swapped[0] % 32
        info = m.swap_in(0, b)
        assert info.seg != SWAP
        assert m.stats["swap_in"] == 1

    def test_swap_in_does_not_count_a_fresh_fault(self):
        """Fig. 9 accounting: bringing a swapped block back is a swap_in,
        not a new page fault (the re-entered allocate_block previously
        double-counted)."""
        m = make_manager(total_slots=8, restseg_fraction=1.0, assoc=2,
                         max_seqs=4, max_blocks_per_seq=32,
                         mode="restrictive_only")
        m.register_sequence(0)
        for b in range(16):
            m.allocate_block(0, b)
        faults_before = m.stats["faults"]
        b = next(vpn for vpn, i in m.blocks.items() if i.seg == SWAP) % 32
        m.swap_in(0, b)
        assert m.stats["swap_in"] == 1
        assert m.stats["faults"] == faults_before

    def test_third_sharer_updates_all_refcounts(self):
        """A third sequence joining a shared slot must refresh refcount on
        EVERY sharer's BlockInfo, not just the src (stale-refcount bug)."""
        m = make_manager()
        for s in (0, 1, 2):
            m.register_sequence(s)
        for b in range(4):
            m.allocate_block(0, b)
        m.share_prefix(0, 1, 2)
        m.share_prefix(0, 2, 2)
        for b in range(2):
            infos = [m.blocks[m.cfg.vpn(m.seq_slot(s), b)] for s in range(3)]
            assert [i.refcount for i in infos] == [3, 3, 3]
            assert all(i.slot == infos[0].slot for i in infos)
        m.check_invariants()
        # releases propagate the decrement to the survivors too
        m.free_sequence(1)
        for b in range(2):
            assert m.blocks[m.cfg.vpn(m.seq_slot(0), b)].refcount == 2
            assert m.blocks[m.cfg.vpn(m.seq_slot(2), b)].refcount == 2
        m.check_invariants()
        m.free_sequence(0)
        m.free_sequence(2)
        m.check_invariants()

    def test_promotion_clears_stale_flex_refcount(self):
        """A flex->rest promotion frees the flex slot; its refcount entry
        must go with it (caught by the slot_refcount/occupancy
        cross-check in check_invariants)."""
        m = make_manager(total_slots=64, restseg_fraction=0.125, assoc=2,
                         max_seqs=4, max_blocks_per_seq=16,
                         alloc_evicts=False)
        m.register_sequence(0)
        infos = [m.allocate_block(0, b) for b in range(16)]
        vpn = next(i.vpn for i in infos if i.seg == FLEX)
        old_slot = m.blocks[vpn].slot
        for _ in range(6):
            m.record_device_stats(np.array([vpn]), np.array([False]),
                                  np.array([4]))
        assert m.run_promotions() >= 1
        assert m.blocks[vpn].seg == REST
        assert old_slot not in m.slot_refcount
        m.check_invariants()


class TestBaselines:
    def test_radix_walk(self):
        rb = RadixBuilder(num_levels=4, fanout=8)
        pairs = [(i * 37 % 4000, i) for i in range(200)]
        for vpn, slot in pairs:
            rb.map(vpn, slot)
        tab = rb.device_table()
        vpns = jnp.array([p[0] for p in pairs], jnp.int32)
        slot, ok, acc = tab.walk(vpns)
        assert bool(ok.all())
        np.testing.assert_array_equal(np.asarray(slot),
                                      [p[1] for p in pairs])
        assert int(acc[0]) == 4          # four serial accesses
        slot, ok, _ = tab.walk(jnp.array([3999], jnp.int32))
        assert not bool(ok[0]) or int(slot[0]) == dict(pairs).get(3999, -1)

    def test_ech_insert_lookup_resize(self):
        t = ElasticCuckooTable(capacity=16, n_tables=4)
        for vpn in range(100):
            t.insert(vpn, vpn * 2)
        assert t.resizes >= 1
        st = t.device_state()
        slot, hit, acc = st.lookup(jnp.arange(100, dtype=jnp.int32))
        assert bool(hit.all())
        np.testing.assert_array_equal(np.asarray(slot),
                                      np.arange(100) * 2)
        assert int(acc[0]) == 4          # n parallel probes (paper Fig. 5)

    def test_pom_tlb_hit_path(self):
        pom = POMTLB(entries=64, ways=4)
        for vpn in range(32):
            pom.lookup_fill(vpn, vpn + 100)
        st = pom.device_state()
        slot, hit, acc = st.lookup(jnp.arange(32, dtype=jnp.int32))
        assert bool(hit.all())
        assert pom.misses == 32 and pom.hits == 0
        pom.lookup_fill(5, -1)
        assert pom.hits == 1
