"""Request-centric serving API tests (ISSUE 3).

* EngineConfig replaces the kwarg pile; the legacy kwargs still work
  through a shim that warns exactly once per process;
* Request is an immutable submission (frozen dataclass, read-only
  prompt array) whose runtime state lives in the engine;
* Engine.poll() / stream() surface RequestOutput snapshots whose
  concatenated deltas reconstruct each request's full generation;
* stats()["per_request"] attributes RestSeg hits / flexible walks /
  swap faults per seq_id, and the per-request rows sum to the global
  counters fed back from decode telemetry.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import (Engine, EngineConfig, Request, RequestOutput,
                         SamplingParams)
import repro.serve.engine as engine_mod


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    return cfg, params


# ------------------------------------------------------- config + shim

def test_legacy_kwargs_warn_exactly_once(setup, monkeypatch):
    cfg, params = setup
    bs = cfg.kv_block_size
    monkeypatch.setattr(engine_mod, "_LEGACY_KWARGS_WARNED", False)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        e1 = Engine(cfg, params, max_batch=2, max_seq_len=4 * bs)
        e2 = Engine(cfg, params, max_batch=2, max_seq_len=4 * bs,
                    mode="flexible_only")
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)
           and "EngineConfig" in str(x.message)]
    assert len(dep) == 1
    # the shim still configures faithfully
    assert e1.max_batch == 2 and e2.hybrid_cfg.mode == "flexible_only"


def test_config_and_kwargs_are_exclusive(setup):
    cfg, params = setup
    with pytest.raises(TypeError, match="not both"):
        Engine(cfg, params, EngineConfig(max_batch=2), max_seq_len=64)
    with pytest.raises(TypeError, match="unknown Engine kwargs"):
        Engine(cfg, params, batch_size=2)


# -------------------------------------------------------- immutability

def test_request_is_immutable():
    req = Request(seq_id=0, prompt=np.arange(4, dtype=np.int64),
                  sampling=SamplingParams(temperature=0.5))
    with pytest.raises(dataclasses.FrozenInstanceError):
        req.max_new_tokens = 3
    with pytest.raises(dataclasses.FrozenInstanceError):
        req.prompt = np.zeros(4, np.int64)
    with pytest.raises(ValueError):
        req.prompt[0] = 7                  # defensive read-only copy
    src = np.arange(4, dtype=np.int64)
    r2 = Request(seq_id=1, prompt=src)
    src[0] = 99                            # caller mutation is invisible
    assert r2.prompt[0] == 0


def test_sampling_params_validated():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    assert SamplingParams().is_greedy
    assert not SamplingParams(temperature=0.7).is_greedy


def test_duplicate_seq_id_rejected(setup):
    cfg, params = setup
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(max_batch=2,
                                           max_seq_len=4 * bs))
    prompt = np.zeros(bs, np.int64)
    eng.submit(Request(seq_id=0, prompt=prompt))
    with pytest.raises(ValueError, match="already queued"):
        eng.submit(Request(seq_id=0, prompt=prompt))


# -------------------------------------------------- poll / stream output

def test_stream_outputs_reconstruct_generations(setup):
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(5)
    eng = Engine(cfg, params, EngineConfig(max_batch=2,
                                           max_seq_len=6 * bs,
                                           auto_release=True))
    reqs = [Request(seq_id=s,
                    prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                    max_new_tokens=n)
            for s, n in ((0, 5), (1, 3))]
    for r in reqs:
        eng.submit(r)
    deltas = {0: [], 1: []}
    finals = {}
    for out in eng.stream():
        assert isinstance(out, RequestOutput)
        deltas[out.seq_id].extend(out.new_token_ids)
        assert tuple(deltas[out.seq_id]) == out.token_ids
        if out.finished:
            assert out.seq_id not in finals     # reported exactly once
            finals[out.seq_id] = out
    for r in reqs:
        assert deltas[r.seq_id] == list(r.generated)
        assert len(deltas[r.seq_id]) == r.max_new_tokens
        assert finals[r.seq_id].finish_reason == "length"
    assert not eng.has_unfinished()


def test_eos_finish_reason_is_stop(setup):
    cfg, params = setup
    bs = cfg.kv_block_size
    prompt = np.random.RandomState(3).randint(0, cfg.vocab_size, bs)
    probe = Request(seq_id=0, prompt=prompt, max_new_tokens=4)
    eng = Engine(cfg, params, EngineConfig(max_batch=1,
                                           max_seq_len=4 * bs))
    eng.submit(probe)
    outs = [o for o in eng.stream() if o.finished]
    assert outs[0].finish_reason == "length"

    eng2 = Engine(cfg, params, EngineConfig(max_batch=1,
                                            max_seq_len=4 * bs))
    r = Request(seq_id=0, prompt=prompt, max_new_tokens=4,
                eos_token=probe.generated[1])
    eng2.submit(r)
    fin = [o for o in eng2.stream() if o.finished][0]
    assert fin.finish_reason == "stop"
    assert fin.token_ids == tuple(probe.generated[:2])


def test_poll_without_work_returns_empty(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(
        max_batch=1, max_seq_len=4 * cfg.kv_block_size))
    assert eng.poll() == []


def test_stream_raises_instead_of_spinning_when_stuck(setup):
    """auto_release=False + more requests than slots: once every slot is
    held by a finished sequence, iteration must raise (release or
    auto_release would unstick it), not busy-loop forever."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(13)
    eng = Engine(cfg, params, EngineConfig(max_batch=1,
                                           max_seq_len=4 * bs))
    for sid in (0, 1):
        eng.submit(Request(seq_id=sid,
                           prompt=rng.randint(0, cfg.vocab_size, bs),
                           max_new_tokens=2))
    from repro.core import PoolExhausted
    with pytest.raises(PoolExhausted, match="cannot be admitted"):
        for _ in eng.stream():
            pass
    eng.release(0)                      # unstick manually and finish
    for _ in eng.stream():
        pass
    assert len(eng._states[1].generated) == 2


def test_seq_id_reuse_after_finish_forgets_old_incarnation(setup):
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(17)
    eng = Engine(cfg, params, EngineConfig(max_batch=1,
                                           max_seq_len=4 * bs,
                                           auto_release=True))
    first = Request(seq_id=0, prompt=rng.randint(0, cfg.vocab_size, bs),
                    max_new_tokens=2)
    eng.submit(first)
    while eng.has_unfinished():
        eng.step()
    assert 0 in eng.finished
    second = Request(seq_id=0, prompt=rng.randint(0, cfg.vocab_size, bs),
                     max_new_tokens=3)
    eng.submit(second)                  # reuse after finish is allowed
    assert 0 not in eng.finished        # old incarnation forgotten
    while eng.has_unfinished():
        eng.step()
    assert len(second.generated) == 3
    assert list(eng.stats()["per_request"]) == [0]


def test_seq_id_reuse_with_held_slot_raises(setup):
    """auto_release=False: a finished request still holds its slot, so
    reusing its id must raise with guidance, not inherit the old slot
    (or crash mid-step)."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(19)
    eng = Engine(cfg, params, EngineConfig(max_batch=2,
                                           max_seq_len=4 * bs))
    eng.submit(Request(seq_id=0, prompt=rng.randint(0, cfg.vocab_size, bs),
                       max_new_tokens=2))
    while eng.has_unfinished():
        eng.step()
    with pytest.raises(ValueError, match="still holds its"):
        eng.submit(Request(seq_id=0,
                           prompt=rng.randint(0, cfg.vocab_size, bs)))
    eng.release(0)
    eng.submit(Request(seq_id=0,                 # fine after release
                       prompt=rng.randint(0, cfg.vocab_size, bs),
                       max_new_tokens=2))


def test_prefill_budget_below_block_size_rejected(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="prefill_budget"):
        Engine(cfg, params, EngineConfig(
            max_batch=2, max_seq_len=4 * cfg.kv_block_size,
            prefill_budget=cfg.kv_block_size - 1))


def test_scheduler_instance_cannot_be_shared_across_engines(setup):
    cfg, params = setup
    from repro.serve import PriorityAgingScheduler
    config = EngineConfig(max_batch=1,
                          max_seq_len=4 * cfg.kv_block_size,
                          scheduler=PriorityAgingScheduler(0.5))
    Engine(cfg, params, config)
    with pytest.raises(ValueError, match="already bound"):
        Engine(cfg, params, config)


def test_request_requires_prompt():
    with pytest.raises(TypeError):
        Request(seq_id=0)


# -------------------------------------------------- per-request telemetry

def test_stats_attributes_translation_per_request(setup):
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(11)
    eng = Engine(cfg, params, EngineConfig(max_batch=2,
                                           max_seq_len=6 * bs))
    reqs = [Request(seq_id=s,
                    prompt=rng.randint(0, cfg.vocab_size, (s + 1) * bs),
                    max_new_tokens=6)
            for s in (0, 1)]
    for r in reqs:
        eng.submit(r)
    while eng.has_unfinished():
        eng.step()
    st = eng.stats()
    per = st["per_request"]
    assert set(per) == {0, 1}
    for row in per.values():
        assert set(row) == {"rsw_hits", "flex_walks", "swap_faults",
                            "drafted", "accepted", "cached_blocks",
                            "preempts", "resumes"}
        # spec decode is off: no drafts were ever proposed
        assert row["drafted"] == row["accepted"] == 0
    # decode telemetry is attributed exhaustively: per-request rows sum
    # to the global counters record_device_stats accumulated
    assert sum(r["rsw_hits"] for r in per.values()) == st["rsw_hits"]
    assert sum(r["flex_walks"] for r in per.values()) == st["flex_walks"]
    total = st["rsw_hits"] + st["flex_walks"]
    assert total > 0
    # the longer prompt owns more blocks, so it must account for more
    # translations overall
    t0 = per[0]["rsw_hits"] + per[0]["flex_walks"]
    t1 = per[1]["rsw_hits"] + per[1]["flex_walks"]
    assert t1 > t0
