"""End-to-end system behaviour tests.

* SPMD train equivalence: sharded (2 data x 2 model on 4 fake devices)
  train loss == single-device loss (subprocess to isolate the device-count
  flag).
* Dry-run machinery on a tiny mesh: lower + compile + roofline terms.
* Elastic checkpoint restore: save under one topology, restore under
  another (global shapes preserved, shardings reapplied).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, timeout=900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return out.stdout


SPMD_TRAIN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
    from repro.configs import ARCHS, reduced
    from repro.models import model_dims, FwdOptions
    from repro.train import (TrainConfig, make_train_step, init_state,
                             state_shardings)
    from repro.dist.sharding import ShardingRules
    from repro.data import DataConfig, SyntheticLM

    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    tc = TrainConfig(lr=1e-3, dtype=jnp.float32)
    fwd = FwdOptions(dtype=jnp.float32)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8, seed=5))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

    # single device
    state1 = init_state(jax.random.PRNGKey(0), cfg, dims, tc)
    step1 = jax.jit(make_train_step(cfg, dims, tc, fwd))
    losses1 = []
    for i in range(3):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state1, m1 = step1(state1, b)
        losses1.append(float(m1["loss"]))

    # 2x2 sharded
    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    rules = ShardingRules(data_axes=("data",), zero_params=True)
    state2 = init_state(jax.random.PRNGKey(0), cfg, dims, tc)
    sh = state_shardings(jax.eval_shape(lambda: state2), mesh, rules)
    state2 = jax.device_put(state2, sh)
    step2 = jax.jit(make_train_step(cfg, dims, tc, fwd, mesh, rules),
                    in_shardings=(sh, {k: NamedSharding(mesh, P("data"))
                                       for k in batch}),
                    out_shardings=(sh, None))
    losses2 = []
    with mesh:
        for i in range(3):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state2, m2 = step2(state2, b)
            losses2.append(float(m2["loss"]))
    print("L1", losses1)
    print("L2", losses2)
    np.testing.assert_allclose(losses1, losses2, rtol=2e-4, atol=2e-4)
    print("SPMD_TRAIN_MATCHES")
""")


def test_spmd_train_matches_single_device():
    out = _run(SPMD_TRAIN)
    assert "SPMD_TRAIN_MATCHES" in out


TINY_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    import jax
    # tiny-mesh analogue of the production dry-run: same code path
    from repro.launch.mesh import make_local_mesh
    import repro.launch.dryrun as dr
    # monkeypatch the production mesh to the tiny one for this test
    import repro.launch.mesh as meshmod
    meshmod.make_production_mesh = lambda multi_pod=False: \
        jax.make_mesh((2, 4), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
    from repro.configs import ARCHS, reduced
    import repro.configs as configs
    small = reduced(ARCHS["granite-8b"])
    import dataclasses
    small = dataclasses.replace(small, num_layers=2)
    configs.ARCHS = dict(configs.ARCHS)
    configs.get_config = lambda name: small
    import repro.configs
    repro.configs.get_config = configs.get_config
    import repro.configs.base as base
    base.SHAPES = tuple(dataclasses.replace(s, seq_len=64, global_batch=8)
                        for s in base.SHAPES)
    sc = {s.name: s for s in base.SHAPES}
    repro.configs.shape_cell = lambda n: sc[n]
    import importlib
    dr.run_cell.__globals__["build_cell"]  # force resolution
    res = dr.run_cell("granite-8b", "train_4k", False)
    assert res["ok"]
    assert res["memory"]["temp_bytes"] > 0
    assert res["flops_per_device_raw"] > 0
    res2 = dr.run_cell("granite-8b", "decode_32k", False)
    assert res2["ok"]
    print("TINY_DRYRUN_OK")
""")


def test_dryrun_machinery_on_tiny_mesh():
    out = _run(TINY_DRYRUN)
    assert "TINY_DRYRUN_OK" in out


ELASTIC = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
    from repro.ckpt import CheckpointManager

    state = {"w": jnp.arange(64.0).reshape(8, 8), "step": jnp.asarray(5)}
    mesh1 = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
    state1 = jax.device_put(state, {"w": NamedSharding(mesh1, P("data")),
                                    "step": NamedSharding(mesh1, P())})
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(5, state1, blocking=True)
        # restore onto a DIFFERENT topology (2-way instead of 4-way)
        mesh2 = jax.make_mesh((2,), ("data",), axis_types=(AxisType.Auto,))
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            state)
        restored, step = mgr.restore(like)
        state2 = jax.device_put(restored,
                                {"w": NamedSharding(mesh2, P("data")),
                                 "step": NamedSharding(mesh2, P())})
        np.testing.assert_array_equal(np.asarray(state2["w"]),
                                      np.asarray(state["w"]))
        assert step == 5
    print("ELASTIC_OK")
""")


def test_elastic_restore_across_topologies():
    out = _run(ELASTIC)
    assert "ELASTIC_OK" in out
