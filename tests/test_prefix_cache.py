"""Utopia-native global prefix cache (ISSUE 8).

Tentpole contract: the content-addressed prefix cache
(``core/prefix_cache.py``) is INVISIBLE in the token streams.  For any
workload, every request's stream with the cache on is bit-identical to
the cache-off run — across greedy+sampled x spec on/off x chunked
admission x preempt/resume x recompute prefill x sharded meshes.  The
cache only changes how much prefill compute runs and how many physical
slots the shared blocks occupy.

Also pinned here:

* hash-chain semantics (``block_hash_chain``): prefix property, block
  order sensitivity, trailing-partial-block truncation;
* directory mechanics at the manager level: insert/dedup/match,
  refcount-guarded eviction, ``evict_one`` as the degradation ladder's
  cheapest rung (engine capacity-reclaim test);
* the cache-ownership invariant (satellite 6): ``slot_refcount[s] ==
  flex occupancy + (s in cached_slots)`` — a rogue release of the
  cache's reference trips ``check_invariants``;
* telemetry cross-checks: per-request ``cached_blocks`` rows sum to the
  global ``dedup_blocks``; ``bytes_saved`` scales with the KV block;
* the legacy ``submit(share_prefix_from=...)`` kwargs parse, warn
  exactly once, and the cache delivers the equivalent dedup.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import (CHAIN_SEED, HybridConfig, HybridKVManager,
                        PrefixCache, block_hash_chain)
from repro.models import model_dims, init_params
from repro.runtime import ServeFaultInjector
from repro.serve import Engine, EngineConfig, Request
from repro.serve.sampling import SamplingParams

try:
    from hypothesis import given, settings, strategies as st, HealthCheck
    HAVE_HYPOTHESIS = True
except ImportError:                        # optional dev dependency
    HAVE_HYPOTHESIS = False


_SETUP_CACHE = {}

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _setup(arch="granite-8b"):
    """2-layer reduced model (the test_overload recipe): many engine
    pairs run here, and recurring bucket shapes hit the jit cache."""
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(reduced(ARCHS[arch]), num_layers=2)
        dims = model_dims(cfg, tp=1)
        params = init_params(jax.random.PRNGKey(2), cfg, dims)
        _SETUP_CACHE[arch] = (cfg, params)
    return _SETUP_CACHE[arch]


def _drain(eng, max_steps=900):
    """Poll to completion, asserting pool AND cache-directory
    consistency after every step."""
    outs = {}
    for _ in range(max_steps):
        for ro in eng.poll():
            outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
        eng.manager.check_invariants()
        if eng.prefix_cache is not None:
            eng.prefix_cache.check_invariants()
        if not eng.has_unfinished():
            return outs
    raise AssertionError("engine failed to drain")


def _fanout(cfg, params, cache, *, n_req=6, shared_blocks=3,
            tail_blocks=1, max_new=8, sampling=None, spec=None,
            budget_blocks="prompt", headroom=2.0, inj=None,
            prefill_mode="prefix_kv", seed=13):
    """Shared-system-prompt fan-out: ``n_req`` requests share a
    ``shared_blocks`` prefix and differ in a random tail.  With
    ``budget_blocks="prompt"`` one full prompt admits per round, so
    request 0 publishes the shared blocks before anyone else admits
    (cache entries are matchable from the NEXT round)."""
    bs = cfg.kv_block_size
    nblk = shared_blocks + tail_blocks
    budget = nblk if budget_blocks == "prompt" else budget_blocks
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_seq_len=(nblk + 3) * bs,
        pool_headroom=headroom, auto_release=True,
        prefill_budget=None if budget is None else budget * bs,
        prefill_mode=prefill_mode, spec_decode=spec,
        fault_injector=inj,
        prefix_cache="auto" if cache else False))
    rng = np.random.RandomState(seed)
    shared = rng.randint(0, cfg.vocab_size, shared_blocks * bs)
    for i in range(n_req):
        eng.submit(Request(
            seq_id=i,
            prompt=np.concatenate(
                [shared, rng.randint(0, cfg.vocab_size,
                                     tail_blocks * bs)]),
            max_new_tokens=max_new,
            sampling=sampling if sampling is not None
            else SamplingParams()))
    outs = _drain(eng)
    assert set(outs) == set(range(n_req))
    return outs, eng


# ------------------------------------------------- the differential oracle

SAMPLED = SamplingParams(temperature=0.8, top_k=40, seed=123)


@pytest.mark.parametrize("spec,sampling", [
    (None, None), (None, SAMPLED), ("ngram", None), ("ngram", SAMPLED),
], ids=["greedy", "sampled", "spec-greedy", "spec-sampled"])
def test_cache_streams_bit_identical(spec, sampling):
    """6 requests, 3 shared + 1 unique block each: the cache dedupes the
    shared prefix (hits for everyone admitted after request 0) and every
    stream still equals the cache-off run token for token.  Under
    speculation the attached prefix also seeds the drafter's ``hist``."""
    cfg, params = _setup()
    off, _ = _fanout(cfg, params, False, spec=spec, sampling=sampling)
    on, eng = _fanout(cfg, params, True, spec=spec, sampling=sampling)
    for sid in off:
        assert on[sid] == off[sid], f"seq {sid} diverged with cache on"
    pcs = eng.stats()["prefix_cache"]
    assert pcs["hits"] == 5                 # everyone after request 0
    assert pcs["dedup_blocks"] == 5 * 3
    # drained requests released; the cache's references keep the shared
    # slots resident (that is the point) — no sequence leaks though
    assert not eng.manager.blocks
    assert not eng.manager.seq_lengths
    assert eng.manager.cached_slots


def test_cache_chunked_admission_identical():
    """prefill_budget = 1 block: prompts chunk across steps, the matched
    prefix skips straight to the tail chunks, and the streams still
    match the cache-off chunked run."""
    cfg, params = _setup()
    off, _ = _fanout(cfg, params, False, budget_blocks=1, tail_blocks=2)
    on, eng = _fanout(cfg, params, True, budget_blocks=1, tail_blocks=2)
    assert on == off
    assert eng.stats()["prefix_cache"]["hits"] > 0


def test_cache_recompute_prefill_identical():
    """prefill_mode="recompute" (the full-prefix oracle path) composes
    with cache hits: already-mapped blocks are skipped at allocation and
    writes to their -1 slots are dropped."""
    cfg, params = _setup()
    off, _ = _fanout(cfg, params, False, budget_blocks=2,
                     prefill_mode="recompute")
    on, eng = _fanout(cfg, params, True, budget_blocks=2,
                      prefill_mode="recompute")
    assert on == off
    assert eng.stats()["prefix_cache"]["hits"] > 0


def test_cache_preempt_resume_identical():
    """Forced preemptions (the ISSUE-6 injector) tear sequences holding
    cache-attached read-only blocks out mid-flight; resume gives them
    private copies and the streams stay equal to the clean cache-off
    run.  Chain of equality: off_clean == on_clean == on_chaos."""
    cfg, params = _setup()
    off, _ = _fanout(cfg, params, False, n_req=8, max_new=12)
    on, _ = _fanout(cfg, params, True, n_req=8, max_new=12)
    assert on == off
    inj = ServeFaultInjector(preempt_at=[(3, "pre", "auto"),
                                         (6, "post", "auto"),
                                         (9, "pre", "auto")])
    chaos, eng = _fanout(cfg, params, True, n_req=8, max_new=12, inj=inj)
    assert chaos == off
    assert eng.stats()["overload"]["request_preempts"] >= 1
    assert eng.stats()["prefix_cache"]["hits"] > 0


def test_cache_tight_pool_reclaims_before_preempt():
    """The capacity gate's cheapest rung: a pool too small to hold the
    cache residue plus new admissions reclaims unreferenced cache
    entries (evict_one) before ever preempting — sequential distinct
    prompts keep publishing blocks nobody references again."""
    cfg, params = _setup()
    bs = cfg.kv_block_size

    def run(cache):
        # all-flex pool: every published block is cache-pinnable, so the
        # residue grows until ONLY eviction can admit the next request
        eng = Engine(cfg, params, EngineConfig(
            max_batch=2, max_seq_len=6 * bs, pool_headroom=1.0,
            restseg_fraction=0.0, auto_release=True,
            prefix_cache="auto" if cache else False))
        rng = np.random.RandomState(3)
        outs = {}
        for i in range(8):                 # sequential: drain each fully
            eng.submit(Request(
                seq_id=i, prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                max_new_tokens=6))
            outs.update(_drain(eng))
        return outs, eng

    off, _ = run(False)
    on, eng = run(True)
    assert on == off
    pcs = eng.stats()["prefix_cache"]
    assert pcs["evictions"] > 0, "pool pressure never exercised eviction"
    assert eng.stats()["overload"]["preempted_seqs"] == 0


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=5,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_cache_differential_fuzz(data):
        """Random (fan-out shape x budget x spec x sampling x pressure):
        the cache-on run equals cache-off for ANY draw, with pool and
        directory invariants green after every step (``_drain``)."""
        cfg, params = _setup()
        kw = dict(
            n_req=data.draw(st.integers(2, 5), label="n_req"),
            shared_blocks=data.draw(st.integers(0, 3), label="shared"),
            tail_blocks=data.draw(st.integers(1, 2), label="tail"),
            budget_blocks=data.draw(st.sampled_from([1, "prompt", None]),
                                    label="budget"),
            spec=data.draw(st.sampled_from([None, "ngram"]), label="spec"),
            sampling=data.draw(st.sampled_from([None, SAMPLED]),
                               label="sampling"),
            headroom=data.draw(st.sampled_from([0.75, 2.0]),
                               label="headroom"),
            max_new=6,
            seed=data.draw(st.integers(0, 3), label="seed"))
        off, _ = _fanout(cfg, params, False, **kw)
        on, _ = _fanout(cfg, params, True, **kw)
        assert on == off
else:
    def test_cache_differential_fuzz():
        pytest.skip("hypothesis not installed")


# ---------------------------------------------------- sharded differential

def test_cache_sharded_mesh_identical():
    """mesh_shape=(1, 2): the cache mutates only host-side flex tables
    and refcounts, the dirty-row sync carries the attachments to the
    sharded mirrors, and the streams equal the single-device cache-off
    run.  Subprocess pins 8 host devices before importing jax (the
    test_sharded_serve recipe)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import numpy as np, jax
        from repro.configs import ARCHS, reduced
        from repro.models import model_dims, init_params
        from repro.serve import Engine, EngineConfig, Request
        cfg = dataclasses.replace(reduced(ARCHS["granite-8b"]),
                                  num_layers=2)
        dims = model_dims(cfg, tp=1)
        params = init_params(jax.random.PRNGKey(2), cfg, dims)
        bs = cfg.kv_block_size

        def run(mesh, cache):
            eng = Engine(cfg, params, EngineConfig(
                max_batch=4, max_seq_len=7 * bs, auto_release=True,
                prefill_budget=4 * bs, mesh_shape=mesh,
                prefix_cache="auto" if cache else False))
            rng = np.random.RandomState(13)
            shared = rng.randint(0, cfg.vocab_size, 3 * bs)
            for i in range(5):
                eng.submit(Request(seq_id=i, prompt=np.concatenate(
                    [shared, rng.randint(0, cfg.vocab_size, bs)]),
                    max_new_tokens=6))
            outs = {}
            for _ in range(600):
                for ro in eng.poll():
                    outs.setdefault(ro.seq_id, []).extend(
                        ro.new_token_ids)
                if not eng.has_unfinished():
                    break
            eng.check_invariants()
            return outs, eng

        base, _ = run(None, False)
        got, eng = run((1, 2), True)
        assert got == base, "sharded cache-on stream diverged"
        assert eng.stats()["prefix_cache"]["hits"] > 0
        print("ALL_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0 and "ALL_OK" in out.stdout, (
        out.stdout[-2000:], out.stderr[-4000:])


# ------------------------------------------------------ hash-chain algebra

def test_block_hash_chain_properties():
    bs = 8
    t = np.arange(32, dtype=np.int64)
    ch = block_hash_chain(t, bs)
    assert len(ch) == 4
    # deterministic
    assert list(block_hash_chain(t, bs)) == list(ch)
    # trailing partial block is ignored (it cannot be content-complete)
    assert list(block_hash_chain(t[:20], bs)) == list(ch[:2])
    assert len(block_hash_chain(t[:7], bs)) == 0
    # prefix property: a different tail preserves the shared prefix
    # chains and changes every chain from the divergence point on
    t2 = np.concatenate([t[:16], t[16:] + 1])
    ch2 = block_hash_chain(t2, bs)
    assert list(ch2[:2]) == list(ch[:2])
    assert ch2[2] != ch[2] and ch2[3] != ch[3]
    # block content is position-mixed: permuting tokens WITHIN a block
    # changes its digest
    t3 = t.copy()
    t3[0], t3[1] = t3[1], t3[0]
    assert block_hash_chain(t3, bs)[0] != ch[0]
    # the chain threads the parent: changing block 0 perturbs chain 1
    # even though block 1's tokens are untouched
    assert block_hash_chain(t3, bs)[1] != ch[1]


# ----------------------------------------- directory mechanics (manager)

def _mgr(**kw):
    kw.setdefault("total_slots", 32)
    kw.setdefault("assoc", 4)
    kw.setdefault("max_seqs", 4)
    kw.setdefault("max_blocks_per_seq", 8)
    # 4-token KV blocks: PrefixCache.match slices tokens with the
    # manager's cfg.block_size, so the unit workloads hash at the same
    # granularity
    kw.setdefault("block_size", 4)
    return HybridKVManager(HybridConfig(**kw))


def _insert_seq(m, pc, seq_id, tokens, tbs):
    chains = block_hash_chain(tokens, tbs)
    parents = [CHAIN_SEED] + [int(c) for c in chains[:-1]]
    ok = []
    for b in range(len(chains)):
        ok.append(pc.insert(int(chains[b]), parents[b],
                            tokens[b * tbs:(b + 1) * tbs], seq_id, b))
    return chains, ok


def test_cache_insert_match_dedup_and_evict():
    m = _mgr()
    pc = PrefixCache(m)
    m.register_sequence(0)
    for b in range(4):
        m.allocate_block(0, b)
    tbs = 4
    tokens = np.arange(16, dtype=np.int64)
    chains, ok = _insert_seq(m, pc, 0, tokens, tbs)
    assert ok == [True] * 4 and pc.n_entries == 4
    pc.check_invariants()
    m.check_invariants()
    # longest-prefix match walks the chain and stops at the first miss
    entries = pc.match(tokens, chains)
    assert [e.chain for e in entries] == [int(c) for c in chains]
    t2 = np.concatenate([tokens[:8], tokens[8:] + 1])
    assert len(pc.match(t2, block_hash_chain(t2, tbs))) == 2
    assert pc.match(tokens + 1, block_hash_chain(tokens + 1, tbs)) == []
    # re-inserting identical content dedups (no second slot pinned)
    _, again = _insert_seq(m, pc, 0, tokens, tbs)
    assert again == [False] * 4 and pc.n_entries == 4
    # every cached slot is still referenced by the live sequence
    # (refcount 2 = flex occupancy + cache), so nothing is evictable
    assert pc.evictable_count() == 0
    assert pc.evict_one() is False
    # release the sequence: the cache's references keep the slots alive
    m.free_sequence(0)
    m.check_invariants()
    assert len(m.cached_slots) == 4
    assert pc.evictable_count() == 4
    for _ in range(4):
        assert pc.evict_one() is True
        pc.check_invariants()
        m.check_invariants()
    assert pc.evict_one() is False and pc.n_entries == 0
    assert not m.cached_slots and not m.slot_refcount


def test_cache_exact_verification_guards_set_collisions():
    """Two different blocks forced into the same directory set (tiny
    num_sets) never alias: match verifies chain, parent AND the raw
    tokens, so a hash-set collision is a miss, not a wrong slot."""
    m = _mgr()
    pc = PrefixCache(m, num_sets=1, assoc=4)   # everything collides
    m.register_sequence(0)
    m.allocate_block(0, 0)
    m.allocate_block(0, 1)
    tbs = 4
    tokens = np.arange(8, dtype=np.int64)
    chains, ok = _insert_seq(m, pc, 0, tokens, tbs)
    assert ok == [True, True]
    other = tokens[:4] + 7
    assert pc.match(other, block_hash_chain(other, tbs)) == []
    e = pc.match(tokens, chains)
    assert len(e) == 2 and e[0].parent == CHAIN_SEED
    assert e[1].parent == int(chains[0])


def test_cache_ownership_invariant_trips_on_rogue_release():
    """Satellite 6: ``slot_refcount[s] == flex occupancy + (s in
    cached_slots)``.  Dropping the cache's reference out-of-band (or
    inventing a cached slot) must trip check_invariants, not corrupt the
    pool silently."""
    m = _mgr()
    pc = PrefixCache(m)
    m.register_sequence(0)
    m.allocate_block(0, 0)
    tbs = 4
    tokens = np.arange(4, dtype=np.int64)
    _insert_seq(m, pc, 0, tokens, tbs)
    slot = next(iter(m.cached_slots))
    m.check_invariants()
    # rogue release of the cache's reference
    m.slot_refcount[slot] -= 1
    with pytest.raises(AssertionError):
        m.check_invariants()
    m.slot_refcount[slot] += 1
    m.check_invariants()
    # a "cached" slot the directory never pinned is just as illegal
    free = m.flex_free[-1]
    m.cached_slots.add(free)
    with pytest.raises(AssertionError):
        m.check_invariants()
    m.cached_slots.discard(free)
    m.check_invariants()


def test_cache_pin_refuses_swap_and_double_pin():
    m = _mgr()
    m.register_sequence(0)
    m.allocate_block(0, 0)
    s = m.cache_pin_block(0, 0)
    assert s is not None and s in m.cached_slots
    assert m.cache_pin_block(0, 0) is None       # already cached
    assert m.cache_pin_block(0, 3) is None       # never allocated
    m.check_invariants()
    m.cache_unpin_slot(s)
    assert s not in m.cached_slots
    m.check_invariants()


# -------------------------------------------------- telemetry cross-checks

def test_cache_telemetry_rows_sum_to_globals():
    cfg, params = _setup()
    _, eng = _fanout(cfg, params, True)
    s = eng.stats()
    pcs = s["prefix_cache"]
    assert pcs["enabled"] is True
    assert sum(r["cached_blocks"] for r in s["per_request"].values()) \
        == pcs["dedup_blocks"] > 0
    assert 0 < pcs["hits"] <= pcs["lookups"] == 6
    assert pcs["inserts"] >= pcs["cached_blocks"] - pcs["evictions"]
    # bytes_saved is dedup_blocks KV blocks' worth of pool bytes
    assert pcs["bytes_saved"] > 0
    assert pcs["bytes_saved"] % pcs["dedup_blocks"] == 0


def test_cache_disabled_telemetry_and_modes():
    cfg, params = _setup()
    bs = cfg.kv_block_size
    _, eng = _fanout(cfg, params, False, n_req=2, max_new=2)
    pcs = eng.stats()["prefix_cache"]
    assert pcs["enabled"] is False
    assert pcs["lookups"] == pcs["hits"] == pcs["dedup_blocks"] == 0
    # "auto" silently disables where content sharing cannot work...
    ro = Engine(cfg, params, EngineConfig(
        max_batch=2, max_seq_len=4 * bs, mode="restrictive_only"))
    assert ro.prefix_cache is None
    # ...demanding it there raises with the reason
    with pytest.raises(ValueError, match="flexible segment"):
        Engine(cfg, params, EngineConfig(
            max_batch=2, max_seq_len=4 * bs, mode="restrictive_only",
            prefix_cache=True))


# ----------------------------------------------------- legacy kwarg shim

def test_share_prefix_kwargs_warn_once_and_cache_covers(monkeypatch):
    import repro.serve.engine as engine_mod
    monkeypatch.setattr(engine_mod, "_SHARE_KWARG_WARNED", False)
    cfg, params = _setup()
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_seq_len=6 * bs, prefill_budget=3 * bs,
        pool_headroom=2.0, auto_release=True))
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, cfg.vocab_size, 3 * bs)
    eng.submit(Request(seq_id=0, prompt=prompt, max_new_tokens=6))
    with pytest.warns(DeprecationWarning, match="share_prefix_from"):
        eng.submit(Request(seq_id=1, prompt=prompt, max_new_tokens=6),
                   share_prefix_from=0, shared_blocks=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng.submit(Request(seq_id=2, prompt=prompt, max_new_tokens=6),
                   share_prefix_from=0, shared_blocks=2)
    assert not w, "legacy kwargs must warn exactly once"
    outs = _drain(eng)
    # identical greedy prompts: all three streams identical, and the
    # kwarg requests got the dedup through the cache (pinned equivalent
    # to a cache hit)
    assert outs[1] == outs[0] and outs[2] == outs[0]
    per = eng.stats()["per_request"]
    assert per[1]["cached_blocks"] > 0
    assert per[2]["cached_blocks"] > 0
