"""Speculative decoding (ISSUE 5 tentpole): in-graph draft → verify →
accept, pinned by a LOSSLESS differential oracle.

The contract (DESIGN.md §speculative-decoding):

* spec-off is untouched — the engine state carries no history buffer and
  the decode step is the PR-4 step;
* spec-on GREEDY streams are token-identical to spec-off, across K,
  ragged acceptance patterns, mid-decode admission under a chunked
  prefill budget, shared prefixes, and eos / max-token truncation
  mid-window;
* spec-on SEEDED-SAMPLED streams are ALSO token-identical to spec-off
  (the position-folded PRNG draw is a maximal coupling of the rejection
  sampler — see serve/sampling.py), and the coupled sampler's emitted
  marginal matches the numpy softmax oracle;
* recurrent (ssm/hybrid) families fall back to non-speculative decode
  with a warn-once;
* a rejected tail that crossed a block boundary deallocates the blocks
  it faulted in (manager invariants hold throughout);
* per-request drafted/accepted counters sum exactly to the globals.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, EngineConfig, Request, SamplingParams
from repro.serve.sampling import (prng_key_data, sample_tokens_q,
                                  verify_draft_tokens)
from repro.serve.spec_decode import propose_ngram_drafts


# --------------------------------------------------------------- drafter

def _hist(rows, H=32):
    h = -np.ones((len(rows), H), np.int32)
    for i, r in enumerate(rows):
        h[i, :len(r)] = r
    return jnp.asarray(h)


def test_ngram_drafter_proposes_continuation_of_latest_match():
    # row 0: ... [5 6] 7 8 9 ... [5 6] -> propose 7 8 9
    # row 1: two occurrences of [3 4]; the LATEST one (followed by 9 9 9)
    #        must win over the earlier one (followed by 1 1 1)
    rows = [[1, 5, 6, 7, 8, 9, 2, 5, 6],
            [3, 4, 1, 1, 1, 3, 4, 9, 9, 9, 2, 3, 4]]
    hist = _hist(rows)
    ctx = jnp.asarray([len(r) - 1 for r in rows], jnp.int32)
    drafts = np.asarray(propose_ngram_drafts(hist, ctx, K=3, ngram=2))
    np.testing.assert_array_equal(drafts[0], [7, 8, 9])
    np.testing.assert_array_equal(drafts[1], [9, 9, 9])


def test_ngram_drafter_no_match_repeats_current_token():
    rows = [[1, 2, 3, 4, 5, 6]]
    hist = _hist(rows)
    ctx = jnp.asarray([5], jnp.int32)
    drafts = np.asarray(propose_ngram_drafts(hist, ctx, K=4, ngram=2))
    np.testing.assert_array_equal(drafts[0], [6, 6, 6, 6])


def test_ngram_drafter_match_running_off_history_falls_back():
    # [7 8] recurs right before the end: continuation runs past the
    # known history, so the unknown tail falls back to the current token
    rows = [[7, 8, 1, 7, 8]]
    hist = _hist(rows)
    ctx = jnp.asarray([4], jnp.int32)
    drafts = np.asarray(propose_ngram_drafts(hist, ctx, K=4, ngram=2))
    # j*=1 -> known continuation [1, 7, 8], then fallback 8
    np.testing.assert_array_equal(drafts[0], [1, 7, 8, 8])


def test_ngram_drafter_never_proposes_negative_tokens():
    rows = [[-1, -1, 2, 3]]          # frontend-style unknown prefix
    hist = _hist(rows)
    drafts = np.asarray(propose_ngram_drafts(
        hist, jnp.asarray([3], jnp.int32), K=4, ngram=2))
    assert (drafts >= 0).all()


# ---------------------------------------------------------- verification

def test_verify_accept_counts_leading_matches_only():
    tgt = jnp.asarray([[5, 6, 7, 8],      # all drafts match
                       [5, 9, 7, 8],      # diverges at draft 2
                       [1, 6, 7, 8]])     # diverges at draft 1
    drafts = jnp.asarray([[5, 6, 7],
                          [5, 6, 7],
                          [5, 6, 7]])
    toks, n_emit = verify_draft_tokens(tgt, drafts)
    np.testing.assert_array_equal(np.asarray(n_emit), [4, 2, 1])
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(tgt))


def test_coupled_rejection_sampler_matches_softmax_oracle():
    """The emitted token's marginal at every window position is the
    target softmax — the losslessness property of rejection sampling —
    and the draft acceptance rate is p_target(draft), the min(1, p/q)
    rule for a point-mass drafter.  Deterministic: fixed keys, fold
    steps 0..N-1."""
    V, N, Q, temp = 12, 4096, 2, 0.7
    rng = np.random.RandomState(0)
    base = rng.randn(V).astype(np.float32) * 1.5
    logits = jnp.asarray(np.tile(base, (N, Q, 1)))
    key = prng_key_data(SamplingParams(seed=42), 0)
    steps = (jnp.arange(N, dtype=jnp.int32)[:, None] * Q
             + jnp.arange(Q, dtype=jnp.int32)[None, :])
    tgt = np.asarray(sample_tokens_q(
        logits, jnp.full((N,), temp, jnp.float32),
        jnp.zeros((N,), jnp.int32), jnp.ones((N,), jnp.float32),
        jnp.asarray(np.tile(key, (N, 1))), steps))
    probs = np.exp(base / temp - np.max(base / temp))
    probs /= probs.sum()
    for q in range(Q):
        freq = np.bincount(tgt[:, q], minlength=V) / N
        assert np.abs(freq - probs).max() < 0.03
    # acceptance of a fixed draft d == p(d); the emitted token GIVEN
    # rejection is the renormalized residual (support excludes d)
    d = int(np.argsort(base)[-2])            # a likely-but-not-top token
    drafts = jnp.full((N, Q - 1), d, jnp.int32)
    toks, n_emit = verify_draft_tokens(jnp.asarray(tgt), drafts)
    acc_rate = float((np.asarray(n_emit) - 1).mean()) / (Q - 1)
    assert abs(acc_rate - probs[d]) < 0.03
    rejected_first = tgt[:, 0][tgt[:, 0] != d]
    resid = probs.copy()
    resid[d] = 0.0
    resid /= resid.sum()
    freq = np.bincount(rejected_first, minlength=V) / rejected_first.size
    assert np.abs(freq - resid).max() < 0.04


# ------------------------------------------------- engine differential

@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    return cfg, params


def _drain(eng, limit=400):
    steps = 0
    while eng.has_unfinished():
        eng.step()
        steps += 1
        assert steps < limit, "engine failed to drain"
    return steps


def _repetitive_prompt(cfg, blocks):
    """A prompt that is one n-gram pattern repeated: the prompt-lookup
    drafter finds matches immediately, driving acceptance up."""
    bs = cfg.kv_block_size
    pat = np.asarray([11, 23, 42, 7], np.int64)
    return np.tile(pat, blocks * bs // pat.size)[:blocks * bs]


@pytest.mark.parametrize("K", [1, 3, 4])
def test_greedy_stream_token_identical(setup, K):
    """The headline oracle: greedy spec-on == spec-off, for small and
    large windows, random (mostly-rejected) and repetitive
    (mostly-accepted) prompts sharing one batch."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(K)
    prompts = {0: rng.randint(0, cfg.vocab_size, 2 * bs),
               1: _repetitive_prompt(cfg, 2),
               2: rng.randint(0, cfg.vocab_size, bs)}

    def run(spec):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=4, max_seq_len=16 * bs, spec_decode=spec,
            num_draft_tokens=K))
        reqs = [Request(seq_id=s, prompt=p, max_new_tokens=20)
                for s, p in prompts.items()]
        for r in reqs:
            eng.submit(r)
        steps = _drain(eng)
        eng.manager.check_invariants()
        return [list(r.generated) for r in reqs], steps, eng.stats()

    off, steps_off, _ = run(None)
    on, steps_on, st = run("ngram")
    assert on == off
    assert st["spec_drafted"] > 0
    # the repetitive prompt must actually accept drafts — otherwise this
    # test exercises nothing but the K=0-equivalent path
    assert st["per_request"][1]["accepted"] > 0
    assert steps_on <= steps_off


def test_greedy_mid_decode_admission_and_shared_prefix(setup):
    """Spec-on composes with the chunked admission scheduler: a request
    admitted mid-decode under a tight budget, plus a prefix-sharing
    request, still produce spec-off's exact streams."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(7)
    p_long = rng.randint(0, cfg.vocab_size, 4 * bs)
    p_sys = _repetitive_prompt(cfg, 2)

    def run(spec):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=3, max_seq_len=16 * bs, prefill_budget=bs,
            spec_decode=spec, num_draft_tokens=4))
        r0 = Request(seq_id=0, prompt=p_sys, max_new_tokens=14)
        eng.submit(r0)
        eng.step()
        eng.step()
        r1 = Request(seq_id=1, prompt=p_long, max_new_tokens=10)
        eng.submit(r1)                 # mid-decode, chunked at 1 block/step
        r2 = Request(seq_id=2, prompt=p_sys, max_new_tokens=14)
        eng.submit(r2, share_prefix_from=0, shared_blocks=1)
        _drain(eng)
        eng.manager.check_invariants()
        return [list(r.generated) for r in (r0, r1, r2)]

    off, on = run(None), run("ngram")
    assert on == off
    # shared-prefix + identical prompt + greedy => identical streams
    assert on[0] == on[2]


def test_sampled_stream_token_identical(setup):
    """Seeded-sampled spec-on == spec-off: the rejection sampler's
    gumbel coupling reuses the position-folded keys, so the realized
    stream is the non-speculative one, not merely the same
    distribution."""
    cfg, params = setup
    bs = cfg.kv_block_size
    sp = SamplingParams(temperature=0.9, top_k=8, top_p=0.95, seed=123)

    def run(spec):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=2, max_seq_len=16 * bs, spec_decode=spec,
            num_draft_tokens=3))
        r0 = Request(seq_id=0, prompt=_repetitive_prompt(cfg, 2),
                     max_new_tokens=14, sampling=sp)
        r1 = Request(seq_id=1, prompt=_repetitive_prompt(cfg, 2),
                     max_new_tokens=14)          # greedy row, mixed batch
        eng.submit(r0)
        eng.submit(r1)
        _drain(eng)
        return list(r0.generated), list(r1.generated)

    assert run("ngram") == run(None)


def test_eos_and_max_tokens_truncate_mid_window(setup):
    """A window that overshoots eos or max_new_tokens commits exactly
    spec-off's stream: the engine truncates, rewinds ctx_len and frees
    overshoot blocks."""
    cfg, params = setup
    bs = cfg.kv_block_size
    prompt = _repetitive_prompt(cfg, 2)

    # learn the greedy continuation, then make its 3rd token the eos
    eng = Engine(cfg, params, EngineConfig(max_batch=1,
                                           max_seq_len=16 * bs))
    probe = Request(seq_id=0, prompt=prompt, max_new_tokens=8)
    eng.submit(probe)
    _drain(eng)
    eos = probe.generated[2]
    first_eos = probe.generated.index(eos)

    def run(spec, **req_kw):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=1, max_seq_len=16 * bs, spec_decode=spec,
            num_draft_tokens=4))
        r = Request(seq_id=0, prompt=prompt, **req_kw)
        eng.submit(r)
        _drain(eng)
        eng.manager.check_invariants()
        st = eng._states[0]
        # the committed context and the host mirror agree after rewinds
        slot = eng._slot_of[0]
        assert int(np.asarray(eng.dstate["ctx_len"])[slot]) \
            == int(eng._ctx_host[slot])
        return list(r.generated), st.finish_reason

    for kw in (dict(max_new_tokens=8, eos_token=eos),
               dict(max_new_tokens=5),
               dict(max_new_tokens=first_eos + 1, eos_token=eos)):
        off = run(None, **kw)
        on = run("ngram", **kw)
        assert on == off, kw


def test_rejected_tail_blocks_are_deallocated(setup):
    """Blocks a rejected/truncated tail faulted in past the committed
    context must be freed.  A live row may retain exactly one block past
    its committed ctx — the one containing its next write position (fed
    the committed bonus token on the very next step); a finished row may
    retain nothing uncommitted."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(3)
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_seq_len=16 * bs, spec_decode="ngram",
        num_draft_tokens=7))           # window K+1 = bs: crosses every step
    reqs = [Request(seq_id=s,
                    prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                    max_new_tokens=12) for s in (0, 1)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.has_unfinished():
        eng.step()
        steps += 1
        assert steps < 100
        m = eng.manager
        m.check_invariants()
        for sid in (0, 1):
            if sid not in eng._slot_of:
                continue
            ctx = int(eng._ctx_host[eng._slot_of[sid]])
            done = eng._states[sid].done
            threshold = ctx if done else ctx + 1
            first_free = (threshold + bs - 1) // bs
            for b in range(first_free, eng.spec.max_blocks_per_seq):
                assert m.lookup(sid, b)[0] < 0, (sid, b, ctx, done)
    # both rows finished un-released: the strict rule applied to them
    for sid in (0, 1):
        ctx = int(eng._ctx_host[eng._slot_of[sid]])
        for b in range((ctx + bs - 1) // bs, eng.spec.max_blocks_per_seq):
            assert eng.manager.lookup(sid, b)[0] < 0
    assert [list(r.generated) for r in reqs]


def test_window_overrunning_seq_capacity_stays_lossless(setup):
    """A verify window that runs past the last KV block must not commit
    tokens from range-masked query positions: with the CONVENTIONAL
    max_seq_len sizing (prompt + max_new + one block — no speculative
    headroom), spec-on streams stay identical to spec-off right up to
    the capacity edge."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(13)
    prompt = rng.randint(0, cfg.vocab_size, 2 * bs)
    max_new = 14
    seq_len = len(prompt) + max_new + bs       # nblk*bs = 32 < ctx+K tail

    def run(spec, K=4):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=1, max_seq_len=seq_len, spec_decode=spec,
            num_draft_tokens=K))
        r = Request(seq_id=0, prompt=prompt, max_new_tokens=max_new)
        eng.submit(r)
        _drain(eng)
        eng.manager.check_invariants()
        return list(r.generated)

    off = run(None)
    for K in (3, 4, 7):
        assert run("ngram", K) == off, K
    """The device-side history equals prompt + generated at every
    committed position (the drafter's ground truth)."""
    cfg, params = setup
    bs = cfg.kv_block_size
    prompt = _repetitive_prompt(cfg, 2)
    eng = Engine(cfg, params, EngineConfig(
        max_batch=1, max_seq_len=16 * bs, spec_decode="ngram",
        num_draft_tokens=3))
    r = Request(seq_id=0, prompt=prompt, max_new_tokens=10)
    eng.submit(r)
    _drain(eng)
    slot = eng._slot_of[0]
    ctx = int(eng._ctx_host[slot])
    hist = np.asarray(eng.dstate["hist"])[slot]
    want = np.concatenate([prompt, np.asarray(r.generated)])
    np.testing.assert_array_equal(hist[:ctx], want[:ctx])
    assert ctx >= len(prompt)


def test_kv_capacity_exhaustion_stops_with_length(setup):
    """A row whose ``max_new_tokens`` overruns its KV block table must
    commit only exact tokens and then STOP: the stream equals a spec-off
    run sized to the capacity edge, the device ``ctx_len`` and the host
    mirror agree after every step (the clamp-without-finish rewind), and
    the row finishes with a "length" stop at exactly ``nblk*bs``
    committed positions instead of committing range-masked (inexact)
    tokens forever."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(21)
    prompt = rng.randint(0, cfg.vocab_size, 2 * bs)
    seq_len = 4 * bs              # capacity: 2*bs generated tokens

    # the longest exact stream: the final token is emitted from query
    # position nblk*bs - 1 (its K/V write is in range) and never needs
    # a write of its own — capacity - prompt + 1 tokens
    eng_off = Engine(cfg, params, EngineConfig(max_batch=1,
                                               max_seq_len=seq_len))
    r_off = Request(seq_id=0, prompt=prompt, max_new_tokens=2 * bs + 1)
    eng_off.submit(r_off)
    _drain(eng_off)

    for K in (3, 4, 7):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=1, max_seq_len=seq_len, spec_decode="ngram",
            num_draft_tokens=K))
        r = Request(seq_id=0, prompt=prompt, max_new_tokens=100)
        eng.submit(r)
        steps = 0
        while eng.has_unfinished():
            eng.step()
            steps += 1
            assert steps < 100
            slot = eng._slot_of[0]
            assert int(np.asarray(eng.dstate["ctx_len"])[slot]) \
                == int(eng._ctx_host[slot]), (K, steps)
        eng.manager.check_invariants()
        st = eng._states[0]
        assert st.finish_reason == "length"
        cap_tokens = eng.spec.max_blocks_per_seq * bs - len(prompt) + 1
        assert len(r.generated) == cap_tokens
        assert list(r.generated) == list(r_off.generated), K
        # invariant discipline survives the zero-commit final window
        stats = eng.stats()
        per = stats["per_request"][0]
        assert per["drafted"] == stats["spec_drafted"]
        assert per["accepted"] == stats["spec_accepted"]
        assert 0 <= per["accepted"] <= per["drafted"]


def test_capacity_stop_frees_slot_for_waiting_request(setup):
    """A zero-token capacity finish that auto-releases its slot counts
    as progress: ``poll()`` must admit the queued request on the next
    step instead of raising PoolExhausted."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(5)
    eng = Engine(cfg, params, EngineConfig(
        max_batch=1, max_seq_len=4 * bs, spec_decode="ngram",
        num_draft_tokens=4, auto_release=True))
    eng.submit(Request(seq_id=0,
                       prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                       max_new_tokens=100))     # overruns KV capacity
    r1 = Request(seq_id=1, prompt=rng.randint(0, cfg.vocab_size, bs),
                 max_new_tokens=4)
    eng.submit(r1)
    outs, polls = [], 0
    while eng.has_unfinished():
        outs.extend(eng.poll())
        polls += 1
        assert polls < 200
    fins = {o.seq_id: o.finish_reason for o in outs if o.finished}
    assert fins == {0: "length", 1: "length"}
    assert len(r1.generated) == 4


def test_spec_counters_sum_to_global_and_bound(setup):
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(9)
    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_seq_len=16 * bs, spec_decode="ngram",
        num_draft_tokens=4))
    for s in range(3):
        prompt = (_repetitive_prompt(cfg, 2) if s == 0
                  else rng.randint(0, cfg.vocab_size, 2 * bs))
        eng.submit(Request(seq_id=s, prompt=prompt,
                           max_new_tokens=24 if s == 0 else 10 + 3 * s))
    _drain(eng)
    st = eng.stats()
    per = st["per_request"]
    assert sum(r["drafted"] for r in per.values()) == st["spec_drafted"]
    assert sum(r["accepted"] for r in per.values()) == st["spec_accepted"]
    assert st["spec_drafted"] > 0
    for r in per.values():
        assert 0 <= r["accepted"] <= r["drafted"]
    # the repetitive request must realize accepted drafts (the drafter
    # matches its pattern from the very first window)
    assert per[0]["accepted"] > 0


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "paligemma-3b",
                                  "whisper-medium"])
def test_greedy_stream_identical_other_attention_families(arch):
    """moe / vlm / audio run the same verify step (audio adds per-query
    cross attention); greedy spec-on == spec-off for each."""
    cfg = reduced(ARCHS[arch])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, cfg.vocab_size, 2 * bs)
    frontend = (rng.randn(cfg.frontend_tokens,
                          cfg.d_model).astype(np.float32)
                if cfg.frontend != "none" else None)

    def run(spec):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=2, max_seq_len=12 * bs, spec_decode=spec,
            num_draft_tokens=3))
        r = Request(seq_id=0, prompt=prompt, frontend=frontend,
                    max_new_tokens=10)
        eng.submit(r)
        _drain(eng)
        eng.manager.check_invariants()
        return list(r.generated)

    assert run("ngram") == run(None)


def test_recurrent_family_falls_back_with_single_warning():
    import repro.serve.engine as engine_mod
    cfg = reduced(ARCHS["mamba2-130m"])
    assert cfg.family == "ssm"
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    engine_mod._SPEC_FALLBACK_WARNED = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        e1 = Engine(cfg, params, EngineConfig(
            max_batch=1, max_seq_len=8 * bs, spec_decode="ngram"))
        e2 = Engine(cfg, params, EngineConfig(
            max_batch=1, max_seq_len=8 * bs, spec_decode="ngram"))
    spec_warnings = [x for x in w
                     if "speculative" in str(x.message).lower()]
    assert len(spec_warnings) == 1          # warn-once
    assert e1.spec_K == 0 and e2.spec_K == 0
    assert "hist" not in e1.dstate          # no spec state installed
    # ... and it decodes exactly like a spec-off engine
    prompt = np.random.RandomState(1).randint(0, cfg.vocab_size, bs)
    r1 = Request(seq_id=0, prompt=prompt, max_new_tokens=6)
    e1.submit(r1)
    _drain(e1)
    e_off = Engine(cfg, params, EngineConfig(max_batch=1,
                                             max_seq_len=8 * bs))
    r_off = Request(seq_id=0, prompt=prompt, max_new_tokens=6)
    e_off.submit(r_off)
    _drain(e_off)
    assert list(r1.generated) == list(r_off.generated)


def test_spec_off_state_is_unchanged(setup):
    """spec_decode=None must not grow the decode state: spec-off stays
    the PR-4 pytree bit for bit."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_batch=2,
                                           max_seq_len=64))
    assert "hist" not in eng.dstate
    assert eng.spec_K == 0


def test_spec_config_validation(setup):
    """Non-positive K or n-gram order raise loudly at construction —
    spec_ngram < 1 would otherwise silently degrade the drafter to
    repeat-current-token (the all-rejected worst case)."""
    cfg, params = setup
    for kw in (dict(num_draft_tokens=0), dict(spec_ngram=0),
               dict(spec_ngram=-1)):
        with pytest.raises(ValueError):
            Engine(cfg, params, EngineConfig(
                max_batch=1, max_seq_len=64, spec_decode="ngram", **kw))


def test_slot_recycling_clears_history(setup):
    """Under auto_release a recycled slot must not draft from the
    previous occupant's tokens (the history row resets to -1)."""
    cfg, params = setup
    bs = cfg.kv_block_size
    rng = np.random.RandomState(5)
    eng = Engine(cfg, params, EngineConfig(
        max_batch=1, max_seq_len=16 * bs, spec_decode="ngram",
        num_draft_tokens=3, auto_release=True))
    eng.submit(Request(seq_id=0, prompt=_repetitive_prompt(cfg, 2),
                       max_new_tokens=6))
    _drain(eng)
    hist = np.asarray(eng.dstate["hist"])[0]
    assert (hist == -1).all()               # released -> cleared
    # second occupant decodes spec-off-identically
    p2 = rng.randint(0, cfg.vocab_size, 2 * bs)
    r2 = Request(seq_id=1, prompt=p2, max_new_tokens=8)
    eng.submit(r2)
    _drain(eng)
    off = Engine(cfg, params, EngineConfig(max_batch=1,
                                           max_seq_len=16 * bs))
    r_off = Request(seq_id=1, prompt=p2, max_new_tokens=8)
    off.submit(r_off)
    _drain(off)
    assert list(r2.generated) == list(r_off.generated)
