"""Crash-safe serving (ISSUE 10): snapshot/restore, supervisor, lifecycle.

THE differential oracle: a ``ServeFaultInjector``-driven crash at ANY
step boundary ("pre": before the step mutated anything; "post": after
its full commit), followed by ``ResilientServe`` restoring the latest
snapshot and replaying, must produce token streams BIT-IDENTICAL to an
uncrashed run — across greedy+sampled × spec on/off × prefix-cache
on/off × chunked prefill × preempt/resume overload × a (1, 2) mesh,
with ``Engine.check_invariants()`` green after every restore.

Also pinned here:

* snapshot round-trip is bytes-equal through the npz array encoding;
* restore onto a FRESH engine of the same config replays identically;
* snapshot while a sequence is parked on the host KV tier;
* seq_id reuse across a restore;
* cancel/deadline release every block, pin and ledger claim (zero
  leaks), and surface ``finish_reason="cancelled"/"deadline"`` through
  ``RequestOutput``, ``stats()`` and the metrics event stream;
* ``ckpt.CheckpointManager`` durability: atomic manifest commit and
  corrupt/truncated-shard fallback to the previous committed step;
* a hypothesis fuzzer over random crash schedules (PR-6 gating idiom).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.runtime import (InjectedStepFault, ReplayDivergence,
                           ResilientServe, ServeFaultInjector,
                           StepWatchdog)
from repro.serve import (Engine, EngineConfig, EngineSnapshot, Request,
                         MetricsLogger, MemorySink)
from repro.serve.metrics import STEP_COUNTER_KEYS
from repro.serve.sampling import SamplingParams

try:
    from hypothesis import given, settings, strategies as st, HealthCheck
    HAVE_HYPOTHESIS = True
except ImportError:                        # optional dev dependency
    HAVE_HYPOTHESIS = False

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SETUP_CACHE = {}


def _setup(arch="granite-8b"):
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(reduced(ARCHS[arch]), num_layers=2)
        dims = model_dims(cfg, tp=1)
        params = init_params(jax.random.PRNGKey(2), cfg, dims)
        _SETUP_CACHE[arch] = (cfg, params)
    return _SETUP_CACHE[arch]


SAMPLED = SamplingParams(temperature=0.8, top_p=0.9, seed=11)

# the oracle matrix: greedy+sampled × spec on/off × prefix-cache on/off
# (collapsed to the four informative corners — spec and the prefix cache
# are both exercised against both sampling modes via these)
VARIANTS = {
    "greedy": (SamplingParams(), {}),
    "sampled": (SAMPLED, {}),
    "spec_greedy": (SamplingParams(), {"spec_decode": "ngram",
                                       "num_draft_tokens": 3}),
    "prefix_sampled": (SAMPLED, {"prefix_cache": True}),
}


def _mkeng(cfg, params, injector=None, **ekw):
    bs = cfg.kv_block_size
    kw = dict(max_batch=4, max_seq_len=8 * bs, auto_release=True,
              prefill_budget=bs,      # chunked prefill: every prompt
                                      # crosses multiple step boundaries
              fault_injector=injector)
    kw.update(ekw)
    return Engine(cfg, params, EngineConfig(**kw))


def _reqs(cfg, sampling, n=4, max_new=8, shared_prefix=False):
    bs = cfg.kv_block_size
    rng = np.random.RandomState(7)
    prefix = rng.randint(0, cfg.vocab_size, bs)
    out = []
    for i in range(n):
        tail = rng.randint(0, cfg.vocab_size, bs)
        prompt = (np.concatenate([prefix, tail]) if shared_prefix
                  else rng.randint(0, cfg.vocab_size, 2 * bs))
        out.append(Request(seq_id=i, prompt=prompt, max_new_tokens=max_new,
                           sampling=sampling))
    return out


def _drain(poller, has_unfinished, outs=None, max_steps=900):
    outs = {} if outs is None else outs
    for _ in range(max_steps):
        for ro in poller():
            outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
        if not has_unfinished():
            return outs
    raise AssertionError("failed to drain")


def _reference(cfg, params, sampling, ekw, *, shared_prefix=False,
               n=4, max_new=8):
    """Uncrashed run: streams + the step count (the crash-step domain)."""
    eng = _mkeng(cfg, params, **ekw)
    for r in _reqs(cfg, sampling, n=n, max_new=max_new,
                   shared_prefix=shared_prefix):
        eng.submit(r)
    outs = _drain(eng.poll, eng.has_unfinished)
    return outs, eng._step_count


def _crashed_run(cfg, params, sampling, ekw, crash_at, *,
                 snapshot_every=5, shared_prefix=False, n=4, max_new=8,
                 max_restarts=None, injector_kw=None):
    inj = ServeFaultInjector(crash_at=crash_at, **(injector_kw or {}))
    eng = _mkeng(cfg, params, injector=inj, **ekw)
    sup = ResilientServe(eng, snapshot_every=snapshot_every,
                         max_restarts=(max_restarts if max_restarts
                                       is not None else len(crash_at) + 1))
    for r in _reqs(cfg, sampling, n=n, max_new=max_new,
                   shared_prefix=shared_prefix):
        sup.submit(r)
    outs = _drain(sup.poll, sup.has_unfinished)
    eng.check_invariants()
    return outs, sup


# ------------------------------------------------- THE crash oracle

@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_crash_at_every_step_boundary_bit_identical(variant):
    """Crash at EVERY boundary of the run (phases alternating pre/post
    so both legal crash points are swept), restore, replay: the
    externally observed streams equal the uncrashed run's exactly."""
    cfg, params = _setup()
    sampling, ekw = VARIANTS[variant]
    shared = "prefix" in variant
    ref, total = _reference(cfg, params, sampling, ekw,
                            shared_prefix=shared)
    assert total >= 8, "reference run too short to sweep boundaries"
    for s in range(1, total + 1):
        phase = "pre" if s % 2 else "post"
        outs, sup = _crashed_run(cfg, params, sampling, ekw,
                                 [(s, phase)], shared_prefix=shared)
        assert outs == ref, (
            f"[{variant}] crash at step {s} ({phase}) diverged")
        assert sup.restarts == 1


def test_crash_under_preempt_resume_overload():
    """Crashes landing mid-preempt/resume traffic (tight pool + forced
    preemptions) still replay bit-identically, and the host-tier
    sequences inside the snapshot survive the round-trip."""
    cfg, params = _setup()
    ekw = dict(pool_headroom=0.40, max_batch=4)
    ref, total = _reference(cfg, params, SamplingParams(), ekw,
                            n=6, max_new=10)
    forced = [(4, "post", "auto"), (6, "pre", "auto")]
    for s in (5, 7, max(8, total - 2)):
        for phase in ("pre", "post"):
            outs, sup = _crashed_run(
                cfg, params, SamplingParams(), ekw, [(s, phase)],
                n=6, max_new=10, snapshot_every=3,
                injector_kw={"preempt_at": list(forced)})
            assert outs == ref, f"overload crash at {s}/{phase} diverged"


def test_multi_crash_and_restart_budget():
    cfg, params = _setup()
    ref, total = _reference(cfg, params, SamplingParams(), {})
    crash = [(3, "pre"), (6, "post"), (9, "pre")]
    outs, sup = _crashed_run(cfg, params, SamplingParams(), {}, crash,
                             snapshot_every=4)
    assert outs == ref
    assert sup.restarts == 3
    assert sup.stats()["recovery"]["replayed_steps"] > 0
    # budget exhausted: the fault escapes instead of spinning
    with pytest.raises(InjectedStepFault):
        _crashed_run(cfg, params, SamplingParams(), {}, crash,
                     snapshot_every=4, max_restarts=2)


# ------------------------------------------------- snapshot round-trip

def test_snapshot_roundtrip_bytes_equal():
    """snapshot → to_arrays → from_arrays reproduces the snapshot
    exactly, and restoring it leaves the engine in a state whose OWN
    snapshot has byte-identical device arrays and an equal host blob."""
    cfg, params = _setup()
    eng = _mkeng(cfg, params)
    for r in _reqs(cfg, SamplingParams()):
        eng.submit(r)
    for _ in range(5):
        eng.poll()
    snap = eng.snapshot()
    rt = EngineSnapshot.from_arrays(snap.to_arrays())
    assert rt.version == snap.version and rt.step == snap.step
    assert rt.host_blob == snap.host_blob
    assert set(rt.dstate) == set(snap.dstate)
    for k in snap.dstate:
        assert np.array_equal(rt.dstate[k], snap.dstate[k]), k
    fresh = _mkeng(cfg, params)
    fresh.restore(rt)
    fresh.check_invariants()
    again = fresh.snapshot()
    assert again.step == snap.step
    for k in snap.dstate:
        assert np.array_equal(again.dstate[k], snap.dstate[k]), (
            f"device array {k} changed across restore")


def test_restore_fresh_engine_replays_identically():
    cfg, params = _setup()
    ref, _ = _reference(cfg, params, SAMPLED, {})
    eng = _mkeng(cfg, params)
    for r in _reqs(cfg, SAMPLED):
        eng.submit(r)
    outs = {}
    for _ in range(6):
        for ro in eng.poll():
            outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
    snap = eng.snapshot()
    fresh = _mkeng(cfg, params)
    fresh.restore(snap)
    fresh.check_invariants()
    _drain(fresh.poll, fresh.has_unfinished, outs)
    assert outs == ref


def test_snapshot_while_preempted():
    """A sequence parked on the host KV tier rides the snapshot: after
    restore it resumes and finishes with the uncontended stream."""
    cfg, params = _setup()
    ref, _ = _reference(cfg, params, SamplingParams(), {})
    inj = ServeFaultInjector(preempt_at=[(3, "post", "auto")])
    eng = _mkeng(cfg, params, injector=inj)
    for r in _reqs(cfg, SamplingParams()):
        eng.submit(r)
    outs = {}
    for _ in range(4):
        for ro in eng.poll():
            outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
    assert eng._preempted, "forced preempt did not land"
    snap = eng.snapshot()
    fresh = _mkeng(cfg, params)
    fresh.restore(snap)
    assert fresh._preempted.keys() == eng._preempted.keys()
    fresh.check_invariants()
    _drain(fresh.poll, fresh.has_unfinished, outs)
    assert outs == ref


def test_seq_id_reuse_across_restore():
    cfg, params = _setup()
    eng = _mkeng(cfg, params)
    reqs = _reqs(cfg, SamplingParams(), n=2, max_new=4)
    for r in reqs:
        eng.submit(r)
    _drain(eng.poll, eng.has_unfinished)
    snap = eng.snapshot()
    fresh = _mkeng(cfg, params)
    fresh.restore(snap)
    # both ids finished inside the snapshot: reusing them must work
    rng = np.random.RandomState(3)
    bs = cfg.kv_block_size
    for i in range(2):
        fresh.submit(Request(
            seq_id=i, prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
            max_new_tokens=4, sampling=SamplingParams()))
    outs = _drain(fresh.poll, fresh.has_unfinished)
    assert set(outs) == {0, 1}
    fresh.check_invariants()


def test_restore_rejects_mismatched_config():
    cfg, params = _setup()
    eng = _mkeng(cfg, params)
    snap = eng.snapshot()
    other = _mkeng(cfg, params, spec_decode="ngram", num_draft_tokens=2)
    with pytest.raises(ValueError, match="does not match"):
        other.restore(snap)      # snapshot lacks the spec 'hist' array
    bad = dataclasses.replace(snap, version=snap.version + 1)
    with pytest.raises(ValueError, match="version"):
        eng.restore(bad)


# ------------------------------------------------- cancel / deadline

def test_cancel_releases_everything():
    """Cancel in every lifecycle stage — queued, live, preempted — then
    drain: no leaked blocks, pins or ledger claims."""
    cfg, params = _setup()
    inj = ServeFaultInjector(preempt_at=[(3, "post", "auto")])
    eng = _mkeng(cfg, params, injector=inj, prefix_cache=False)
    for r in _reqs(cfg, SamplingParams(), n=6, max_new=10):
        eng.submit(r)
    for _ in range(4):
        eng.poll()
    assert eng._preempted, "forced preempt did not land"
    parked = next(iter(eng._preempted))
    live = next(sid for sid in eng.requests
                if not eng._states[sid].done and sid != parked)
    queued = [r.seq_id for r in eng.waiting
              if r.seq_id not in eng._prefilling
              and r.seq_id != parked]
    assert eng.cancel(parked) and eng.cancel(live)
    if queued:
        assert eng.cancel(queued[-1])
    eng.check_invariants()
    assert eng.cancel(live) is False            # idempotent
    for sid in (parked, live):
        assert eng._states[sid].finish_reason == "cancelled"
        assert sid not in eng._slot_of and sid not in eng._preempted
    _drain(eng.poll, eng.has_unfinished)
    eng.check_invariants()
    # zero leaks: every sequence gone from the manager, no refcounts
    assert not eng.manager.blocks, "leaked KV blocks after cancel"
    assert not any(eng.manager.slot_refcount.values()), "leaked refcounts"
    assert not eng.manager.seq_lengths, "leaked sequence slots"
    n = 2 + (1 if queued else 0)
    assert eng.stats()["lifecycle"]["cancelled"] == n


def test_cancelled_outputs_and_metrics_events():
    cfg, params = _setup()
    sink = MemorySink()
    eng = _mkeng(cfg, params, metrics=MetricsLogger([sink]))
    for r in _reqs(cfg, SamplingParams(), n=3, max_new=12):
        eng.submit(r)
    for _ in range(3):
        eng.poll()
    assert eng.cancel(1)
    outs = {}
    reasons = {}
    for _ in range(200):
        for ro in eng.poll():
            outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
            if ro.finished:
                reasons[ro.seq_id] = ro.finish_reason
        if not eng.has_unfinished():
            break
    assert reasons[1] == "cancelled"
    fin = [e for e in sink.events if e["kind"] == "finish"]
    assert any(e["seq_id"] == 1 and e["finish_reason"] == "cancelled"
               for e in fin)
    tot = eng.metrics.totals
    assert tot["cancelled"] == 1 and tot["deadline_expired"] == 0


def test_deadline_expiry():
    cfg, params = _setup()
    bs = cfg.kv_block_size
    eng = _mkeng(cfg, params)
    rng = np.random.RandomState(5)
    eng.submit(Request(seq_id=0,
                       prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                       max_new_tokens=12, sampling=SamplingParams(),
                       deadline_ms=0.0))       # expires immediately
    eng.submit(Request(seq_id=1,
                       prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                       max_new_tokens=6, sampling=SamplingParams()))
    outs = _drain(eng.poll, eng.has_unfinished)
    assert eng._states[0].finish_reason == "deadline"
    assert eng._states[1].finish_reason in ("stop", "length")
    assert len(outs.get(1, [])) > 0
    assert eng.stats()["lifecycle"]["deadline_expired"] == 1
    eng.check_invariants()
    with pytest.raises(ValueError, match="deadline_ms"):
        eng.submit(Request(seq_id=2,
                           prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                           max_new_tokens=4, sampling=SamplingParams(),
                           deadline_ms=-1.0))


def test_deadline_rebases_across_restore():
    """The remaining budget — not the absolute clock — rides the
    snapshot: a generous deadline survives restore into a new engine."""
    cfg, params = _setup()
    bs = cfg.kv_block_size
    eng = _mkeng(cfg, params)
    rng = np.random.RandomState(5)
    eng.submit(Request(seq_id=0,
                       prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                       max_new_tokens=6, sampling=SamplingParams(),
                       deadline_ms=600000.0))
    eng.poll()
    snap = eng.snapshot()
    fresh = _mkeng(cfg, params)
    fresh.restore(snap)
    st = fresh._states[0]
    assert st.deadline_at is not None
    import time as _t
    remaining = st.deadline_at - _t.perf_counter()
    assert 0 < remaining <= 600.0
    outs = _drain(fresh.poll, fresh.has_unfinished)
    assert fresh._states[0].finish_reason in ("stop", "length")


# ------------------------------------------------- metrics across restore

def test_metrics_rebase_no_negative_deltas():
    cfg, params = _setup()
    sink = MemorySink()
    eng = _mkeng(cfg, params, metrics=MetricsLogger([sink]))
    sup = ResilientServe(eng, snapshot_every=3, max_restarts=3)
    inj = ServeFaultInjector(crash_at=[(5, "post")])
    eng._injector = inj
    for r in _reqs(cfg, SamplingParams()):
        sup.submit(r)
    _drain(sup.poll, sup.has_unfinished)
    steps = [e for e in sink.events if e["kind"] == "step"]
    assert steps, "no step events"
    for e in steps:
        for k in STEP_COUNTER_KEYS:
            assert e[k] >= 0, (
                f"negative delta {k}={e[k]} at step {e['step']}: the "
                "restore rewound counters without a rebase")
    assert eng.metrics.totals["tokens"] == eng._tokens_emitted


# ------------------------------------------------- checkpoint durability

def test_ckpt_atomic_manifest_and_commit(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep_last=2)
    state = {"w": np.arange(6, dtype=np.float32)}
    ck.save(3, state, blocking=True)
    step_dir = tmp_path / "step_3"
    assert (step_dir / "COMMIT").exists()
    assert not list(tmp_path.glob(".tmp_step_*")), "temp dir leaked"
    assert not list(step_dir.glob("*.tmp")), "non-atomic marker write"
    restored, step = ck.restore(state)
    assert step == 3 and np.array_equal(restored["w"], state["w"])


def test_ckpt_corrupt_shard_falls_back(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep_last=5)
    state = {"w": np.arange(4, dtype=np.float32)}
    ck.save(1, state, blocking=True)
    state2 = {"w": np.arange(4, dtype=np.float32) * 2}
    ck.save(2, state2, blocking=True)
    # truncate the latest shard UNDER its COMMIT marker (torn write)
    shard = tmp_path / "step_2" / "shard_0.npz"
    shard.write_bytes(shard.read_bytes()[:20])
    with pytest.warns(UserWarning, match="corrupt or truncated"):
        restored, step = ck.restore(state)
    assert step == 1 and np.array_equal(restored["w"], state["w"])
    # every step corrupt -> loud failure, not silence
    (tmp_path / "step_1" / "shard_0.npz").write_bytes(b"junk")
    with pytest.warns(UserWarning):
        with pytest.raises(FileNotFoundError, match="corrupt"):
            ck.restore(state)


def test_ckpt_save_named_variable_shapes(tmp_path):
    """Named checkpoints carry shape-changing entries between steps —
    the engine's pickled host blob grows/shrinks — which the positional
    API's shape check forbids."""
    ck = CheckpointManager(str(tmp_path), keep_last=3)
    ck.save_named(1, {"host": np.frombuffer(b"abc", np.uint8),
                      "meta": np.asarray([1, 1])}, blocking=True)
    ck.save_named(2, {"host": np.frombuffer(b"abcdef", np.uint8),
                      "meta": np.asarray([1, 2])}, blocking=True)
    arrays, step = ck.restore_named()
    assert step == 2 and arrays["host"].tobytes() == b"abcdef"
    arrays, step = ck.restore_named(step=1)
    assert step == 1 and arrays["host"].tobytes() == b"abc"


def test_persisted_snapshot_resume_with_corruption(tmp_path):
    """Kill-and-recover across processes WITH a torn latest snapshot:
    ``from_checkpoint`` skips the corrupt step (warning) and resumes
    from the previous one; the resumed tail matches the reference."""
    cfg, params = _setup()
    ck = CheckpointManager(str(tmp_path), keep_last=10)
    ref, _ = _reference(cfg, params, SamplingParams(), {})
    eng = _mkeng(cfg, params)
    sup = ResilientServe(eng, ck, snapshot_every=3)
    for r in _reqs(cfg, SamplingParams()):
        sup.submit(r)
    for _ in range(8):
        sup.poll()
    ck.wait()
    steps = ck.all_steps()
    assert len(steps) >= 2, "cadence produced too few snapshots"
    shard = tmp_path / f"step_{steps[-1]}" / "shard_0.npz"
    shard.write_bytes(shard.read_bytes()[:50])
    fresh = _mkeng(cfg, params)
    with pytest.warns(UserWarning, match="corrupt or truncated"):
        sup2 = ResilientServe.from_checkpoint(fresh, ck)
    fresh.check_invariants()
    tail = _drain(sup2.poll, sup2.has_unfinished)
    ck.wait()
    for sid, toks in tail.items():
        assert ref[sid][-len(toks):] == toks, f"resumed tail diverges {sid}"


# ------------------------------------------------- watchdog

def test_step_watchdog_flags_hung_steps():
    wd = StepWatchdog(threshold=5.0, warmup=3)
    for _ in range(6):
        assert wd.record(0.01) is False
    assert wd.record(0.5) is True
    assert len(wd.flags) == 1
    sup_like = wd.record(0.011)
    assert sup_like is False, "EMA poisoned by the outlier spike"


# ------------------------------------------------- (1, 2) mesh restore

def _run(script: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0 and "ALL_OK" in out.stdout, (
        out.stdout[-2000:], out.stderr[-4000:])


_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np, jax
    from repro.configs import ARCHS, reduced
    from repro.models import model_dims, init_params
    from repro.runtime import ResilientServe, ServeFaultInjector
    from repro.serve import Engine, EngineConfig, Request
    from repro.serve.sampling import SamplingParams
    cfg = dataclasses.replace(reduced(ARCHS["granite-8b"]), num_layers=2)
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(2), cfg, dims)
    bs = cfg.kv_block_size
""")


def test_mesh_crash_restore_bit_identical():
    """(1, 2) mesh: crash + restore replays bit-identically (restore
    re-places every device array with the mesh shardings and rebuilds
    the padded translation mirrors), and a snapshot taken on the mesh
    restores onto a FRESH mesh engine."""
    _run(_PRELUDE + textwrap.dedent("""
        def mkeng(injector=None):
            return Engine(cfg, params, EngineConfig(
                max_batch=4, max_seq_len=8 * bs, auto_release=True,
                prefill_budget=bs, mesh_shape=(1, 2),
                fault_injector=injector))
        def reqs():
            rng = np.random.RandomState(7)
            return [Request(seq_id=i,
                            prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                            max_new_tokens=8, sampling=SamplingParams())
                    for i in range(4)]
        def drain(poller, unfinished, outs):
            for _ in range(500):
                for ro in poller():
                    outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
                if not unfinished():
                    return outs
            raise AssertionError("no drain")
        ref_eng = mkeng()
        for r in reqs(): ref_eng.submit(r)
        ref = drain(ref_eng.poll, ref_eng.has_unfinished, {})
        total = ref_eng._step_count
        for s in (2, total // 2, total - 1):
            for phase in ("pre", "post"):
                inj = ServeFaultInjector(crash_at=[(s, phase)])
                eng = mkeng(inj)
                sup = ResilientServe(eng, snapshot_every=4,
                                     max_restarts=2)
                for r in reqs(): sup.submit(r)
                outs = drain(sup.poll, sup.has_unfinished, {})
                assert outs == ref, f"mesh crash {s}/{phase} diverged"
                eng.check_invariants()
        # snapshot -> fresh mesh engine restore
        eng = mkeng()
        for r in reqs(): eng.submit(r)
        outs = {}
        for _ in range(5):
            for ro in eng.poll():
                outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
        snap = eng.snapshot()
        fresh = mkeng()
        fresh.restore(snap)
        fresh.check_invariants()
        drain(fresh.poll, fresh.has_unfinished, outs)
        assert outs == ref, "fresh mesh restore diverged"
        print("ALL_OK")
    """))


# ------------------------------------------------- hypothesis fuzzer

if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(crashes=st.lists(
               st.tuples(st.integers(min_value=1, max_value=24),
                         st.sampled_from(["pre", "post"])),
               min_size=1, max_size=3, unique=True),
           every=st.integers(min_value=2, max_value=8))
    def test_fuzz_crash_schedules_bit_identical(crashes, every):
        """Any crash schedule × any snapshot cadence: the supervised
        stream equals the uncrashed reference."""
        cfg, params = _setup()
        key = ("fuzz_ref",)
        if key not in _SETUP_CACHE:
            _SETUP_CACHE[key] = _reference(cfg, params, SamplingParams(),
                                           {})
        ref, _total = _SETUP_CACHE[key]
        outs, sup = _crashed_run(cfg, params, SamplingParams(), {},
                                 crashes, snapshot_every=every,
                                 max_restarts=len(crashes) + 1)
        assert outs == ref
