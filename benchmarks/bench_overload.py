"""Overload benchmark: goodput and tail latency under oversubscription.

A pool sized for ~4 concurrent sequences receives ``oversub`` x that many
requests at t=0.  Two degradation policies serve the identical workload:

* ``preempt`` (ISSUE 6, default) — the engine preempts victim sequences
  to the host KV tier when a block allocation misses and resumes them
  through the scheduler queue: admission stays aggressive, capacity is
  time-shared.
* ``fail`` — the fail-fast baseline (the pre-tier ladder): admission is
  footprint-gated, a sequence only starts once its WHOLE worst-case
  footprint provably fits, so the pool is never oversubscribed and
  nothing is ever preempted.

Both complete every request (the tests pin bit-identical streams); the
benchmark measures what the tier buys and what it costs:

* ``goodput_tok_s``  — completed tokens / wall time;
* ``ttft_ms``        — time to first token, p50/p99 across requests
  (footprint gating makes LATE requests wait for whole-sequence
  reservations, stretching the tail);
* ``preemptions`` / ``swap_out_mb`` — how hard the tier worked.

``--smoke`` runs a tiny configuration for CI (keeps the script from
bit-rotting; timings are not meaningful there).

Run:  PYTHONPATH=src python benchmarks/bench_overload.py
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, EngineConfig, Request

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_one(cfg, params, policy: str, n_req: int, max_batch: int,
            max_new: int, headroom: float, warm: bool) -> dict:
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(
        max_batch=max_batch, max_seq_len=8 * bs, pool_headroom=headroom,
        auto_release=True, overload_policy=policy))
    rng = np.random.RandomState(7)
    reqs = [Request(seq_id=i,
                    prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                    max_new_tokens=max_new) for i in range(n_req)]
    if warm:
        # compile the bucket shapes outside the timed region
        eng.submit(dataclasses.replace(reqs[0], seq_id=n_req + 1,
                                       max_new_tokens=2))
        while eng.has_unfinished():
            eng.poll()
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    ttft, tokens, steps = {}, 0, 0
    while eng.has_unfinished():
        for ro in eng.poll():
            if ro.new_token_ids and ro.seq_id < n_req:
                ttft.setdefault(ro.seq_id,
                                time.perf_counter() - t0)
                tokens += len(ro.new_token_ids)
        steps += 1
        assert steps < 400 * n_req, "engine failed to drain"
    wall = time.perf_counter() - t0
    ov = eng.stats()["overload"]
    lat = np.asarray(sorted(ttft.values())) * 1e3
    return {
        "policy": policy,
        "n_req": n_req,
        "oversub": round(n_req / max_batch, 2),
        "pool_blocks": eng.hybrid_cfg.total_slots,
        "completed": sum(1 for i in range(n_req)
                         if eng._states[i].done),
        "steps": steps,
        "wall_s": round(wall, 3),
        "goodput_tok_s": round(tokens / wall, 1),
        "ttft_ms_p50": round(float(np.percentile(lat, 50)), 1),
        "ttft_ms_p99": round(float(np.percentile(lat, 99)), 1),
        "preemptions": ov["request_preempts"],
        "swap_out_mb": round(ov["swap_bytes_out"] / 2**20, 3),
        "swap_in_mb": round(ov["swap_bytes_in"] / 2**20, 3),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--oversub", default="2,4",
                    help="comma list of oversubscription factors "
                         "(requests = factor x max_batch)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--headroom", type=float, default=0.5)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (keeps the script from "
                         "bit-rotting; timings not meaningful)")
    ap.add_argument("--out", default=os.path.join(
        ROOT, "BENCH_overload.json"))
    args = ap.parse_args()
    if args.smoke:
        args.oversub, args.max_new = "2", 12
    factors = [int(x) for x in args.oversub.split(",")]

    cfg = dataclasses.replace(reduced(ARCHS[args.arch]), num_layers=2)
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)

    results, goodput_ratio, p99_ratio, rate = [], {}, {}, {}
    for f in factors:
        n_req = f * args.max_batch
        pair = {}
        for policy in ("fail", "preempt"):
            r = run_one(cfg, params, policy, n_req, args.max_batch,
                        args.max_new, args.headroom, warm=(f == factors[0]
                                                           and policy == "fail"))
            assert r["completed"] == n_req, (policy, r)
            pair[policy] = r
            results.append(r)
            print(f"x{f} {policy:7s}: {r['goodput_tok_s']:8.1f} tok/s  "
                  f"ttft p50 {r['ttft_ms_p50']:7.1f} ms  "
                  f"p99 {r['ttft_ms_p99']:7.1f} ms  "
                  f"preempts {r['preemptions']:3d}  "
                  f"swap {r['swap_out_mb']:.2f} MB")
        key = f"oversub_{f}x"
        goodput_ratio[key] = round(pair["preempt"]["goodput_tok_s"]
                                   / pair["fail"]["goodput_tok_s"], 3)
        p99_ratio[key] = round(pair["preempt"]["ttft_ms_p99"]
                               / max(pair["fail"]["ttft_ms_p99"], 1e-9), 3)
        rate[key] = round(pair["preempt"]["preemptions"] / n_req, 3)

    record = {
        "benchmark": "overload",
        "arch": f"{args.arch} (reduced, 2 layers)",
        "platform": jax.devices()[0].platform,
        "jax": jax.__version__,
        "smoke": bool(args.smoke),
        "max_batch": args.max_batch,
        "pool_headroom": args.headroom,
        "max_new_tokens": args.max_new,
        "results": results,
        "goodput_ratio_preempt_over_fail": goodput_ratio,
        "ttft_p99_ratio_preempt_over_fail": p99_ratio,
        "preemptions_per_request": rate,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
