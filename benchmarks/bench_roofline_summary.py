"""Roofline headline rows for the benchmark CSV (reads dry-run JSONs).

Full tables come from ``python benchmarks/roofline.py``; this emits the
hillclimb cells' baseline vs optimized bounds so `benchmarks.run` output is
self-contained.  Silently skipped when the dry-run has not been executed.
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import csv_row  # noqa: E402

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "results", "dryrun")

CELLS = [
    ("qwen2-72b__train_4k__16x16", "baseline"),
    ("qwen2-72b__train_4k__16x16__nosp_mb8_triangular", "optimized:layout"),
    ("qwen2-72b__train_4k__16x16__megatron", "optimized:explicit-schedule"),
    ("qwen3-moe-30b-a3b__prefill_32k__16x16", "baseline"),
    ("qwen3-moe-30b-a3b__prefill_32k__16x16__nosp_mb8", "optimized"),
    ("qwen2-72b__decode_32k__16x16", "baseline"),
    ("qwen2-72b__decode_32k__16x16__kv_int8_no_zero", "optimized"),
]


def run() -> list:
    rows = []
    from cost_model import PEAK_FLOPS, ICI_BW
    for tag, label in CELLS:
        path = os.path.join(RESULTS, tag + ".json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            r = json.load(f)
        coll = r["collectives"]["collective_bytes_per_device"]
        rows.append({
            "name": f"roofline/{tag}[{label}]",
            "us": 0.0,
            "derived": (f"compute_s={r.get('flops_per_device', 0)/PEAK_FLOPS:.3f} "
                        f"collective_s={coll/ICI_BW:.3f} "
                        f"mem_gib={(r['memory']['argument_bytes']+r['memory']['temp_bytes'])/2**30:.1f}"),
        })
    if not rows:
        rows.append({"name": "roofline/dryrun_not_run", "us": 0.0,
                     "derived": "run `python -m repro.launch.dryrun --all` first"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(csv_row(r["name"], r["us"], r["derived"]))
