"""Paper Fig. 27: sensitivity to RestSeg size.

End-to-end serving (tiny model, real engine) across RestSeg fractions of a
fixed, pressured pool: RSW hit rate, evictions and swaps.  The paper finds
a mid-size RestSeg captures ~all of the benefit while a tiny one
degenerates toward the flexible baseline."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, Request
from common import csv_row, time_us


def _serve(frac, n_steps=6):
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, max_batch=8, max_seq_len=8 * bs,
                 pool_headroom=1.1, restseg_fraction=frac)
    rng = np.random.RandomState(0)
    for sid in range(6):
        prompt = rng.randint(0, cfg.vocab_size, 4 * bs)
        eng.add_request(Request(seq_id=sid, prompt=prompt,
                                max_new_tokens=n_steps + 1))
    for _ in range(n_steps):
        eng.step()
    st = eng.stats()
    total = st.get("rsw_hits", 0) + st.get("flex_walks", 0)
    return st.get("rsw_hits", 0) / max(total, 1), st


def run() -> list:
    rows = []
    for frac in (0.1, 0.25, 0.5, 0.75, 0.95):
        hit, st = _serve(frac)
        rows.append({
            "name": f"restseg_size/frac={frac}", "us": 0.0,
            "derived": (f"rsw_hit_rate={hit:.2%} "
                        f"rest_allocs={st.get('rest_allocs', 0)} "
                        f"flex_allocs={st.get('flex_allocs', 0)} "
                        f"evictions={st.get('rest_evictions', 0)} "
                        f"swaps={st.get('swap_out', 0)}"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(csv_row(r["name"], r["us"], r["derived"]))
