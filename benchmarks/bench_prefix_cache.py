"""Prefix-cache benchmark: shared-system-prompt fan-out vs cache-off.

The headline workload is the ISSUE-8 acceptance shape: ``n_req``
requests that all share one ``shared_blocks``-block system prompt and
differ only in a 1-block unique tail.  Cache-off, every request
prefills its whole prompt; cache-on, request 0 publishes the shared
blocks and everyone admitted after it attaches them read-only and
forwards ONLY its tail.  Both runs complete the identical workload with
bit-identical token streams (asserted here; the differential suite in
tests/test_prefix_cache.py pins it across every engine mode), so the
comparison is pure mechanism cost/benefit:

* ``prefill_fwd_tokens`` — prompt tokens actually fed through prefill
  dispatches (summed from the admission log), the compute the cache
  skips;
* ``ttft_ms`` — time to first token per request (mean/p50/p99): fewer
  forwarded tokens admit later requests sooner;
* ``peak_pool_occupancy`` — peak distinct mapped pool slots: dedup'd
  blocks occupy ONE slot however many requests read them (note the
  cache also KEEPS published blocks resident after their sequences
  release, so under a slow-admission workload where cache-off frees
  early finishers before late arrivals allocate, cache-on peak can be
  higher — resident reuse capacity, not a leak);
* the HONEST cold-miss cost: the same fan-out with all-distinct prompts
  (every lookup misses, every insert pays hash+pin) — wall-clock ratio
  cache-on / cache-off shows what the machinery costs when it never
  helps.

``--smoke`` runs a tiny configuration for CI (keeps the script from
bit-rotting; timings are not meaningful there).

Run:  PYTHONPATH=src python benchmarks/bench_prefix_cache.py
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, EngineConfig, Request

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_prompts(cfg, n_req: int, shared_blocks: int,
                 shared: bool) -> list:
    bs = cfg.kv_block_size
    rng = np.random.RandomState(11)
    sys_prompt = rng.randint(0, cfg.vocab_size, shared_blocks * bs)
    out = []
    for _ in range(n_req):
        head = (sys_prompt if shared
                else rng.randint(0, cfg.vocab_size, shared_blocks * bs))
        out.append(np.concatenate(
            [head, rng.randint(0, cfg.vocab_size, bs)]))
    return out


def run_one(cfg, params, prompts, cache: bool, max_new: int,
            warm: bool) -> dict:
    bs = cfg.kv_block_size
    n_req = len(prompts)
    nblk = len(prompts[0]) // bs
    eng = Engine(cfg, params, EngineConfig(
        max_batch=n_req, max_seq_len=(nblk + 3) * bs,
        # a bounded per-round admission budget, the real serving
        # constraint the cache relieves: cache-off must spend it
        # re-forwarding the shared prompt for every request (delaying
        # every later admission), cache-on spends it only on unique
        # tails.  Request 0 still publishes before anyone else admits —
        # followers cannot register while it consumes the budget.
        prefill_budget=2 * bs,
        auto_release=True, prefix_cache="auto" if cache else False))
    if warm:
        # compile every shape the timed wave will hit — the cache-on
        # run admits many 1-block tails per round, so the pow2-padded
        # multi-row prefix buckets (B_pad 8/16) must be compiled too,
        # not just the single-row shapes.  A warm fan-out with DISTINCT
        # content but the workload's exact shape, budget and max_batch
        # reproduces the same admission dynamics (and the same bucket
        # keys) without polluting the workload's content.  It runs
        # TWICE: the mass release at the end of a wave dirties a large
        # batch of translation entries whose delta-scatter pad size is
        # only dispatched (and jitted) once the NEXT wave starts, so
        # only a second wave — running in exactly the post-release
        # state the timed wave will see — compiles those shapes.  The
        # stats snapshot below excludes all of it.
        for wave, seed in enumerate((99, 101)):
            wrng = np.random.RandomState(seed)
            whead = wrng.randint(0, cfg.vocab_size, (nblk - 1) * bs)
            for k in range(n_req):
                eng.submit(Request(
                    seq_id=(wave + 1) * n_req + 1 + k,
                    prompt=np.concatenate(
                        [whead, wrng.randint(0, cfg.vocab_size, bs)]),
                    max_new_tokens=2))
            while eng.has_unfinished():
                eng.poll()
        base_log = len(eng.admission_log)
    else:
        base_log = 0
    pcs0 = eng.stats()["prefix_cache"]   # exclude warm-up from the stats
    for i, p in enumerate(prompts):
        eng.submit(Request(seq_id=i, prompt=p, max_new_tokens=max_new))
    t0 = time.perf_counter()
    ttft, streams, peak_occ, steps = {}, {}, 0, 0
    while eng.has_unfinished():
        for ro in eng.poll():
            if ro.new_token_ids and ro.seq_id < n_req:
                ttft.setdefault(ro.seq_id, time.perf_counter() - t0)
            if ro.seq_id < n_req:
                streams[ro.seq_id] = list(ro.token_ids)
        peak_occ = max(peak_occ, len({
            i.slot for i in eng.manager.blocks.values() if i.slot >= 0}))
        steps += 1
        assert steps < 200 * n_req, "engine failed to drain"
    wall = time.perf_counter() - t0
    fwd = sum(c.fwd_tokens for c in eng.admission_log[base_log:])
    lat = np.asarray(sorted(ttft.values())) * 1e3
    pcs = eng.stats()["prefix_cache"]
    eng.check_invariants()
    return {
        "cache": cache,
        "n_req": n_req,
        "prompt_blocks": nblk,
        "steps": steps,
        "wall_s": round(wall, 3),
        "prefill_fwd_tokens": int(fwd),
        "ttft_ms_mean": round(float(lat.mean()), 1),
        "ttft_ms_p50": round(float(np.percentile(lat, 50)), 1),
        "ttft_ms_p99": round(float(np.percentile(lat, 99)), 1),
        "peak_pool_occupancy": int(peak_occ),
        "cache_hits": pcs["hits"] - pcs0["hits"],
        "dedup_blocks": pcs["dedup_blocks"] - pcs0["dedup_blocks"],
        "bytes_saved": pcs["bytes_saved"] - pcs0["bytes_saved"],
        "streams": streams,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--shared-blocks", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (keeps the script from "
                         "bit-rotting; timings not meaningful)")
    ap.add_argument("--out", default=os.path.join(
        ROOT, "BENCH_prefix_cache.json"))
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.shared_blocks, args.max_new = 6, 4, 4

    cfg = dataclasses.replace(reduced(ARCHS[args.arch]), num_layers=2)
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)

    results = {}
    shared = make_prompts(cfg, args.requests, args.shared_blocks, True)
    for cache in (False, True):
        r = run_one(cfg, params, shared, cache, args.max_new, warm=True)
        results["shared_on" if cache else "shared_off"] = r
        print(f"shared  cache={'on ' if cache else 'off'}: "
              f"fwd_tokens={r['prefill_fwd_tokens']:5d}  "
              f"ttft mean {r['ttft_ms_mean']:7.1f} ms  "
              f"p99 {r['ttft_ms_p99']:7.1f} ms  "
              f"peak_occ={r['peak_pool_occupancy']:3d}  "
              f"dedup={r['dedup_blocks']}")
    # the differential contract, re-checked where the numbers are made
    assert results["shared_on"]["streams"] \
        == results["shared_off"]["streams"], \
        "cache-on streams diverged from cache-off"
    # honest cold-miss: all-distinct prompts — every lookup misses,
    # every insert still pays hashing + pinning
    distinct = make_prompts(cfg, args.requests, args.shared_blocks, False)
    for cache in (False, True):
        r = run_one(cfg, params, distinct, cache, args.max_new,
                    warm=False)
        results["distinct_on" if cache else "distinct_off"] = r
        print(f"distinct cache={'on ' if cache else 'off'}: "
              f"fwd_tokens={r['prefill_fwd_tokens']:5d}  "
              f"wall {r['wall_s']:6.3f} s  hits={r['cache_hits']}")
    assert results["distinct_on"]["streams"] \
        == results["distinct_off"]["streams"], \
        "cache-on streams diverged from cache-off (distinct prompts)"
    for r in results.values():
        del r["streams"]

    on, off = results["shared_on"], results["shared_off"]
    don, doff = results["distinct_on"], results["distinct_off"]
    record = {
        "benchmark": "prefix_cache",
        "arch": f"{args.arch} (reduced, 2 layers)",
        "platform": jax.devices()[0].platform,
        "jax": jax.__version__,
        "smoke": bool(args.smoke),
        "n_requests": args.requests,
        "shared_blocks": args.shared_blocks,
        "max_new_tokens": args.max_new,
        "results": results,
        "prefill_fwd_token_ratio_off_over_on": round(
            off["prefill_fwd_tokens"] / max(on["prefill_fwd_tokens"], 1),
            3),
        "ttft_mean_ratio_on_over_off": round(
            on["ttft_ms_mean"] / max(off["ttft_ms_mean"], 1e-9), 3),
        "peak_occupancy_ratio_on_over_off": round(
            on["peak_pool_occupancy"]
            / max(off["peak_pool_occupancy"], 1), 3),
        "cold_miss_wall_ratio_on_over_off": round(
            don["wall_s"] / max(doff["wall_s"], 1e-9), 3),
        "dedup_blocks": on["dedup_blocks"],
        "bytes_saved": on["bytes_saved"],
    }
    print(f"fwd-token reduction {record['prefill_fwd_token_ratio_off_over_on']}x, "
          f"ttft mean ratio {record['ttft_mean_ratio_on_over_off']}, "
          f"cold-miss wall ratio "
          f"{record['cold_miss_wall_ratio_on_over_off']}")
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
