"""Steady-state decode step benchmark: current vs pre-PR engine hot path.

Measures per-step latency and tokens/step-second of ``Engine.step()`` at
several batch sizes, in hybrid and flexible_only translation modes, and
records the speedup of the translate-once hot path (PR 1) over a faithful
emulation of the pre-PR engine:

* pre-PR: the hybrid RSW ran inside the per-layer scan body (L
  translations per step), the engine re-translated on host for stats
  (``translate()`` + ``device_state()`` per live request), re-uploaded the
  FULL TAR/SF/flex every step, applied slot copies one ``.at[].set`` at a
  time, and paid one ``int(ctx_len[slot])`` + one
  ``int(argmax(logits[slot]))`` device sync per request per step;
* current: one translation dispatch per step, telemetry in-graph, dirty-
  delta sync, one batched copy dispatch, ONE device fetch per step.

Emits a JSON record (default: BENCH_engine_step.json at the repo root) so
the decode-step perf trajectory is tracked from this PR onward.

Run:  PYTHONPATH=src python benchmarks/bench_engine_step.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import summarize_times  # noqa: E402

from repro.configs import ARCHS, reduced
from repro.core import translate
from repro.models import model_dims, init_params
from repro.serve import Engine, Request
from repro.serve.decode import make_serve_step, translate_step

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class LegacyEngine(Engine):
    """Emulates the pre-PR hot path on top of the current engine.

    Every removed overhead is reinstated; the per-layer in-scan
    translation is emulated by forcing one extra translation dispatch per
    attention layer into the jitted step (their results are returned as
    live outputs so XLA cannot dead-code them).
    """

    def __init__(self, *args, dtype=jnp.float32, **kwargs):
        super().__init__(*args, dtype=dtype, **kwargs)
        base = make_serve_step(self.cfg, self.dims, self.spec, mesh=None,
                               dtype=dtype)
        n_extra = max(0, self._n_attn_layers - 1)
        spec = self.spec

        def legacy_step(params, dstate, tokens):
            logits, nd, st = base(params, dstate, tokens)
            # pre-PR: every attention layer re-translated all block vpns
            for i in range(n_extra):
                tr = translate_step(dstate["tar"], dstate["sf"],
                                    dstate["flex"], dstate["ctx_len"], spec)
                st[f"_layer_translation_{i}"] = tr.slots   # keep it live
            return logits, nd, st

        self._serve_step = jax.jit(legacy_step)

    def _sync_translation(self, full: bool = False) -> None:
        m = self.manager
        m.take_dirty()
        self.dstate["tar"] = jnp.asarray(m.tar)[None]
        self.dstate["sf"] = jnp.asarray(m.sf)[None]
        self.dstate["flex"] = jnp.asarray(m.flex_table.reshape(-1))[None]
        self._synced_full = True

    def _apply_copies(self) -> None:
        for src, dst in self.manager.take_pending_copies():
            self.dstate["k_pool"] = self.dstate["k_pool"].at[:, dst].set(
                self.dstate["k_pool"][:, src])
            self.dstate["v_pool"] = self.dstate["v_pool"].at[:, dst].set(
                self.dstate["v_pool"][:, src])

    def step(self):
        live = [r for r in self.requests.values() if not r.done]
        if not live:
            return {}
        m = self.manager
        bs = self.cfg.kv_block_size
        tokens = np.zeros(self.max_batch, np.int64)
        for r in live:
            slot = self._slot_of[r.seq_id]
            pos = int(self.dstate["ctx_len"][slot])     # device sync / req
            if self._n_attn_layers and pos % bs == 0:
                info = m.allocate_block(r.seq_id, pos // bs)
                if info.seg == 2:
                    info = m.swap_in(r.seq_id, pos // bs)
            tokens[slot] = r.generated[-1]
        self._apply_copies()
        self._sync_translation()

        logits, self.dstate, _ = self._serve_step(
            self.params, self.dstate, jnp.asarray(tokens))

        # host-side re-translation for stats (the pre-PR third translation)
        if self._n_attn_layers and self.track_stats:
            ts = m.device_state()
            for r in live:
                slot = self._slot_of[r.seq_id]
                pos = int(self.dstate["ctx_len"][slot])
                nblk = (pos + bs - 1) // bs
                vpns = np.array([m.cfg.vpn(slot, b) for b in range(nblk)])
                res = translate(ts, jnp.asarray(vpns, jnp.int32))
                m.record_device_stats(vpns, np.asarray(res.in_rest),
                                      np.asarray(res.accesses))
            m.run_promotions()
            self._apply_copies()

        out = {}
        for r in live:
            slot = self._slot_of[r.seq_id]
            nxt = int(jnp.argmax(logits[slot]))         # device sync / req
            r.generated.append(nxt)
            out[r.seq_id] = nxt
            if len(r.generated) >= r.max_new_tokens:
                self._states[r.seq_id].done = True
        self._ctx_host[:] = np.asarray(self.dstate["ctx_len"])
        return out


def run_one(engine_cls, cfg, params, mode: str, max_batch: int,
            warmup: int = 6, steps: int = 32) -> dict:
    bs = cfg.kv_block_size
    eng = engine_cls(cfg, params, max_batch=max_batch,
                     max_seq_len=2 * bs + (warmup + steps + bs),
                     mode=mode)
    rng = np.random.RandomState(0)
    horizon = warmup + steps + 2
    for sid in range(max_batch):
        eng.add_request(Request(seq_id=sid,
                                prompt=rng.randint(0, cfg.vocab_size,
                                                   2 * bs),
                                max_new_tokens=horizon + 2))
    t_compile = time.perf_counter()
    for _ in range(warmup):
        eng.step()
    t_compile = time.perf_counter() - t_compile
    times = []
    for _ in range(steps):
        t0 = time.perf_counter()
        out = eng.step()
        times.append(time.perf_counter() - t0)
        assert len(out) == max_batch
    # median + warmup-excluded steady mean; a timed step that still hit a
    # one-time XLA compile (a fresh scatter-bucket shape) is reported
    # separately as a compile spike instead of polluting the mean
    r = {
        "engine": "legacy_emulated" if engine_cls is LegacyEngine
                  else "current",
        "mode": mode,
        "max_batch": max_batch,
        "steps": steps,
    }
    r.update(summarize_times(times, compile_s=t_compile))
    r["tokens_per_step_s"] = round(max_batch / (r["step_ms"] / 1e3), 1)
    return r


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batches", default="2,4")
    ap.add_argument("--modes", default="hybrid,flexible_only")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--out", default=os.path.join(
        ROOT, "BENCH_engine_step.json"))
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)

    results = []
    for mode in args.modes.split(","):
        for mb in (int(b) for b in args.batches.split(",")):
            for cls in (Engine, LegacyEngine):
                r = run_one(cls, cfg, params, mode, mb, steps=args.steps)
                results.append(r)
                print(f"{r['engine']:16s} mode={mode:14s} B={mb}: "
                      f"{r['step_ms']:8.2f} ms/step  "
                      f"{r['tokens_per_step_s']:8.1f} tok/s")

    speedups = {}
    steady = {}
    for mode in args.modes.split(","):
        for mb in (int(b) for b in args.batches.split(",")):
            cur = next(r for r in results if r["engine"] == "current"
                       and r["mode"] == mode and r["max_batch"] == mb)
            leg = next(r for r in results
                       if r["engine"] == "legacy_emulated"
                       and r["mode"] == mode and r["max_batch"] == mb)
            speedups[f"{mode}_b{mb}"] = round(
                leg["step_ms"] / cur["step_ms"], 2)
            # the absolute steady-state latency headline, lifted to the
            # top level so run.py's KEY_METRICS/--diff gate can track it
            # PR-over-PR (direction: lower is better)
            steady[f"{mode}_b{mb}"] = cur["step_ms"]

    record = {
        "benchmark": "engine_step",
        "arch": f"{args.arch} (reduced)",
        "platform": jax.devices()[0].platform,
        "jax": jax.__version__,
        "results": results,
        "speedup_vs_pre_pr": speedups,
        "steady_step_ms": steady,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"\nspeedup vs pre-PR hot path: {speedups}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
