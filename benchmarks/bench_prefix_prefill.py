"""Prefix-KV chunked prefill benchmark: linear vs quadratic chunk cost.

Admits one long prompt under a tight per-step prefill budget on both
paths and records, PER CHUNK, the forward-token count (from
``Engine.admission_log`` — the ground truth the tests also pin) and the
wall time of the engine step that ran the chunk:

* ``prefix_kv`` — chunks k > 0 forward only their own tokens and read
  the installed prefix from the pool: fwd_tokens is CONSTANT in chunk
  index;
* ``recompute`` — the PR-2 oracle path re-forwards the whole prefix
  every chunk: fwd_tokens grows linearly per chunk (quadratic total).

Each engine is warmed with a full admission pass first so the measured
pass reuses compiled executables (the pow2 bucket shapes are bounded by
design).

Emits a JSON record (default: BENCH_prefix_prefill.json at the repo
root).

Run:  PYTHONPATH=src python benchmarks/bench_prefix_prefill.py
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, EngineConfig, Request

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def admit_one(cfg, params, mode: str, prompt_blocks: int,
              budget_blocks: int) -> dict:
    bs = cfg.kv_block_size
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, cfg.vocab_size, prompt_blocks * bs)
    # ONE engine for warmup and measurement: the jitted step caches live
    # on the Engine's closures, so a fresh engine would re-compile every
    # bucket shape and the "measured" pass would time XLA, not admission
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_seq_len=(prompt_blocks + 2) * bs,
        prefill_budget=budget_blocks * bs, prefill_mode=mode,
        auto_release=True))

    def admit(sid):
        eng.submit(Request(seq_id=sid, prompt=prompt, max_new_tokens=1))
        steps = []
        while True:
            t0 = time.perf_counter()
            eng.step()
            steps.append(time.perf_counter() - t0)
            if sid not in eng._prefilling:
                break
        while eng.has_unfinished():    # finish + auto-release the slot
            eng.step()
        return steps

    t0 = time.perf_counter()
    admit(0)                           # warmup: compile every bucket shape
    compile_s = time.perf_counter() - t0
    steps = admit(1)
    chunks = [rec for rec in eng.admission_log if rec.seq_id == 1]
    assert len(chunks) == len(steps)
    per_chunk = [{
        "chunk": i,
        "start": rec.start,
        "end": rec.end,
        "path": rec.path,
        "fwd_tokens": rec.fwd_tokens,
        "step_wall_s": round(steps[i], 5),
    } for i, rec in enumerate(chunks)]
    return {
        "mode": mode,
        "prompt_tokens": prompt_blocks * bs,
        "budget_tokens": budget_blocks * bs,
        "chunks": per_chunk,
        "total_fwd_tokens": sum(r.fwd_tokens for r in chunks),
        "admission_wall_s": round(sum(steps), 5),
        # warmup-pass wall (all XLA compiles), separated from the
        # measured admission wall (ISSUE 5 reporting fix)
        "compile_wall_s": round(compile_s, 5),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    # 1024-token prompt, 32-token chunks: long enough that the recompute
    # path's quadratic forward dominates its dispatch overhead even on
    # the tiny reduced model (CPU); short prompts are overhead-bound and
    # understate the win
    ap.add_argument("--prompt-blocks", type=int, default=128)
    ap.add_argument("--budget-blocks", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (8-block prompt)")
    ap.add_argument("--out", default=os.path.join(
        ROOT, "BENCH_prefix_prefill.json"))
    args = ap.parse_args()
    if args.smoke:
        args.prompt_blocks, args.budget_blocks = 8, 2

    cfg = reduced(ARCHS[args.arch])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)

    results = []
    for mode in ("prefix_kv", "recompute"):
        r = admit_one(cfg, params, mode, args.prompt_blocks,
                      args.budget_blocks)
        results.append(r)
        fts = [c["fwd_tokens"] for c in r["chunks"]]
        print(f"{mode:10s}: {len(fts)} chunks, fwd_tokens/chunk {fts[:6]}"
              f"{'...' if len(fts) > 6 else ''}  "
              f"total {r['total_fwd_tokens']}  "
              f"admission {r['admission_wall_s']:.3f}s")

    pre, rec = results
    # linearity: every prefix chunk forwards exactly its own tokens; only
    # the final chunk may be ragged (prompt not a budget multiple)
    for c in pre["chunks"]:
        assert c["fwd_tokens"] == c["end"] - c["start"], c
    body = {c["fwd_tokens"] for c in pre["chunks"][:-1]}
    assert len(body) <= 1, f"prefix path not linear: {body}"
    record = {
        "benchmark": "prefix_prefill",
        "arch": f"{args.arch} (reduced)",
        "platform": jax.devices()[0].platform,
        "jax": jax.__version__,
        "results": results,
        "fwd_token_ratio_recompute_over_prefix": round(
            rec["total_fwd_tokens"] / pre["total_fwd_tokens"], 2),
        "admission_speedup_prefix_over_recompute": round(
            rec["admission_wall_s"] / pre["admission_wall_s"], 2),
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"\nfwd-token ratio (recompute/prefix): "
          f"{record['fwd_token_ratio_recompute_over_prefix']}  "
          f"admission speedup: "
          f"{record['admission_speedup_prefix_over_recompute']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
