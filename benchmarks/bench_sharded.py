"""Sharded serving benchmark: step latency / throughput vs mesh shape.

The SPMD engine (ISSUE 7, DESIGN.md §sharded-serving) shards the KV
pool and the TAR/SF/flex translation structures over the mesh's
``model`` axis and translates once per step per shard.  This benchmark
drives the identical decode workload on ``mesh_shape=None`` (the
single-device baseline) and on ``(1, 2)`` / ``(2, 2)`` meshes, checks
the streams stay bit-identical, and records per-mesh step latency and
throughput.

HONEST CPU CAVEAT: on host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) all "devices"
share the same cores, the transformer compute is fully REPLICATED
across the model axis (that is what buys bit-identical streams — no
float reductions), and every psum is real inter-"device" traffic.  So
sharding on CPU is expected to be SLOWER than the baseline; the numbers
here pin the overhead trend and the wiring, not a speedup.  The win on
real accelerators is KV/table MEMORY per device: each shard holds
``1/M`` of the pool and translation structures (``kv_bytes_per_shard``
below), which is what lets a pool too big for one device serve at all.

``--smoke`` runs a tiny configuration for CI (keeps the script from
bit-rotting; timings are not meaningful there).

Run:  PYTHONPATH=src python benchmarks/bench_sharded.py
"""
from __future__ import annotations

import os

# must precede the jax import: the mesh shapes below need 4 devices
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import json
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, EngineConfig, Request
from repro.serve.sampling import SamplingParams

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_one(cfg, params, mesh_shape, n_req: int, max_batch: int,
            max_new: int) -> dict:
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(
        max_batch=max_batch, max_seq_len=8 * bs, auto_release=True,
        mesh_shape=mesh_shape))
    rng = np.random.RandomState(7)
    reqs = [Request(seq_id=i,
                    prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                    max_new_tokens=max_new,
                    sampling=SamplingParams()) for i in range(n_req)]
    # compile the bucket shapes outside the timed region
    eng.submit(dataclasses.replace(reqs[0], seq_id=n_req + 1,
                                   max_new_tokens=2))
    while eng.has_unfinished():
        eng.poll()
    for r in reqs:
        eng.submit(r)
    outs = {}
    steps, step_s = 0, []
    t0 = time.perf_counter()
    while eng.has_unfinished():
        ts = time.perf_counter()
        for ro in eng.poll():
            if ro.seq_id <= n_req:
                outs.setdefault(ro.seq_id, []).extend(ro.new_token_ids)
        step_s.append(time.perf_counter() - ts)
        steps += 1
        assert steps < 400 * n_req, "engine failed to drain"
    wall = time.perf_counter() - t0
    eng.check_invariants()
    tokens = sum(len(v) for v in outs.values())
    kv_bytes = (np.asarray(eng.dstate["k_pool"]).nbytes
                + np.asarray(eng.dstate["v_pool"]).nbytes)
    shards = 1 if mesh_shape is None else mesh_shape[1]
    lat = np.asarray(step_s) * 1e3
    return {
        "mesh": "none" if mesh_shape is None else
                f"{mesh_shape[0]}x{mesh_shape[1]}",
        "kv_shards": shards,
        "steps": steps,
        "wall_s": round(wall, 3),
        "tok_s": round(tokens / wall, 1),
        "step_ms_p50": round(float(np.percentile(lat, 50)), 2),
        "step_ms_p99": round(float(np.percentile(lat, 99)), 2),
        "kv_bytes_per_shard": kv_bytes // shards,
        "_streams": outs,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (keeps the script from "
                         "bit-rotting; timings not meaningful)")
    ap.add_argument("--out", default=os.path.join(
        ROOT, "BENCH_sharded.json"))
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.max_new = 4, 10

    cfg = dataclasses.replace(reduced(ARCHS[args.arch]), num_layers=2)
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)

    results, ratios = [], {}
    base = None
    for ms in (None, (1, 2), (2, 2)):
        r = run_one(cfg, params, ms, args.requests, args.max_batch,
                    args.max_new)
        streams = r.pop("_streams")
        if base is None:
            base = (r, streams)
        else:
            assert streams == base[1], f"streams diverged on mesh {ms}"
            ratios[f"mesh_{r['mesh']}"] = round(
                r["step_ms_p50"] / max(base[0]["step_ms_p50"], 1e-9), 3)
        results.append(r)
        print(f"mesh {r['mesh']:4s}: {r['tok_s']:8.1f} tok/s  "
              f"step p50 {r['step_ms_p50']:7.2f} ms  "
              f"p99 {r['step_ms_p99']:7.2f} ms  "
              f"kv/shard {r['kv_bytes_per_shard'] / 2**20:.2f} MB")
    print("streams bit-identical across meshes: OK")

    record = {
        "benchmark": "sharded",
        "arch": f"{args.arch} (reduced, 2 layers)",
        "platform": jax.devices()[0].platform,
        "devices": jax.device_count(),
        "jax": jax.__version__,
        "smoke": bool(args.smoke),
        "max_batch": args.max_batch,
        "n_requests": args.requests,
        "max_new_tokens": args.max_new,
        "caveat": ("CPU host devices share cores and compute is "
                   "replicated across the model axis for bit-identical "
                   "streams; expect slowdown here, not speedup — the "
                   "accelerator win is 1/M KV+table memory per shard "
                   "(kv_bytes_per_shard)"),
        "results": results,
        "step_latency_ratio_vs_single_device": ratios,
        "kv_bytes_per_shard": {f"mesh_{r['mesh']}": r["kv_bytes_per_shard"]
                               for r in results},
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
