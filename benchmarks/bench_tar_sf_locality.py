"""Paper Fig. 23: TAR/SF cache hit rates.

The paper adds 2 KB SRAM caches for TAR and SF and measures 81%/98% hit
rates.  Our TPU adaptation holds TAR/SF wholly in VMEM, so the analogue is
(i) whether they FIT in a VMEM budget, and (ii) the hit rate a 2 KB
direct-mapped cache would see on the RSW access stream (temporal locality
of set indices) — measured by replaying the stream through a simulated
cache, as the paper does in Sniper."""
from __future__ import annotations

import numpy as np

from repro.core import HybridConfig, HybridKVManager, get_hash
from common import csv_row, zipf_block_stream


def _cache_hit_rate(line_ids: np.ndarray, n_lines: int,
                    ways: int = 2) -> float:
    """``ways``-assoc LRU cache of n_lines lines over a line-id stream."""
    n_sets = max(1, n_lines // ways)
    tags = -np.ones((n_sets, ways), np.int64)
    stamp = np.zeros((n_sets, ways), np.int64)
    hits = 0
    for t, lid in enumerate(line_ids):
        idx = lid % n_sets
        w = np.nonzero(tags[idx] == lid)[0]
        if w.size:
            hits += 1
            stamp[idx, w[0]] = t
        else:
            victim = int(np.argmin(stamp[idx]))
            tags[idx, victim] = lid
            stamp[idx, victim] = t
    return hits / len(line_ids)


def run() -> list:
    cfg = HybridConfig(total_slots=4096, restseg_fraction=0.75, assoc=8,
                       max_seqs=32, max_blocks_per_seq=128)
    m = HybridKVManager(cfg)
    for s in range(32):
        m.register_sequence(s)
        for b in range(96):
            m.allocate_block(s, b)
    stream = zipf_block_stream(32, 96, 20000, a=1.6, seed=7)
    vpns = stream[:, 0] * 128 + stream[:, 1]
    h = get_hash(cfg.hash_name)
    sets = np.asarray([h(int(v), cfg.num_sets) for v in vpns])

    # TAR: one 64B line covers 64/ (tag 6B) ~10 ways -> line = set (assoc 8)
    tar_line_bytes = cfg.assoc * 6
    sf_entries_per_line = 64  # 1B counters
    tar_lines_2kb = max(1, 2048 // tar_line_bytes)
    sf_lines_2kb = max(1, 2048 // 64)
    tar_hit = _cache_hit_rate(sets, tar_lines_2kb)
    sf_hit = _cache_hit_rate(sets // sf_entries_per_line, sf_lines_2kb)

    tar_bytes = cfg.restseg().tar_bytes()
    sf_bytes = cfg.restseg().sf_bytes()
    vmem_budget = 64 * 2**20  # conservative VMEM share for translation
    rows = [
        {"name": "tar_sf/cache_hit_rates", "us": 0.0,
         "derived": (f"tar_2kb_hit={tar_hit:.2%} (paper 81%) "
                     f"sf_2kb_hit={sf_hit:.2%} (paper 98%)")},
        {"name": "tar_sf/vmem_residency", "us": 0.0,
         "derived": (f"tar={tar_bytes}B sf={sf_bytes}B "
                     f"fits_vmem={'yes' if tar_bytes + sf_bytes < vmem_budget else 'no'} "
                     f"(TPU adaptation: fully VMEM-resident)")},
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(csv_row(r["name"], r["us"], r["derived"]))
