"""Speculative-decode benchmark: tokens/s and acceptance vs spec-off.

Three decode workloads, each run by a spec-off engine and a spec-on
engine (same params, same prompts, token-identical streams — the tests
pin that; this script measures the speed side):

* ``repetitive``   — a repetitive-continuation workload: a small-vocab
  reduced config whose greedy continuation genuinely collapses into a
  short loop (with 32 logical tokens the argmax map reaches a fixed
  point within a few dozen tokens — measured, not assumed), decoding a
  repeated-pattern prompt.  The prompt-lookup drafter catches the loop,
  acceptance approaches 1, and one dispatch commits up to K+1 tokens.
  The headline: steady-state tokens/s must clearly beat spec-off
  (ISSUE 5 acceptance: >= 1.5x).
* ``random``       — random prompts on the standard reduced config,
  greedy: whatever acceptance the model's natural quasi-loops produce.
* ``all_rejected`` — random prompts sampled at temperature 2.0: the
  target draw almost never equals the point-mass draft, so nearly every
  window commits exactly 1 token.  This is the WORST case — the
  K+1-wide verify forward buys nothing — and pins the overhead: the
  spec-on step latency vs spec-off (K=1 keeps it near 1x even on CPU,
  where — unlike a memory-bound accelerator decode — the K+1x attention
  arithmetic of a wide window is not free).

Each workload runs at every K in ``--num-draft-tokens`` (comma list):
K is the operator's knob, small for rejection-heavy traffic, wide for
input-grounded traffic.  Timings use the warmup-excluded steady-state
summary (benchmarks/common ``summarize_times``) so
BENCH_spec_decode.json trajectories are comparable PR-over-PR.
``--smoke`` runs a tiny configuration for CI (keeps the script from
bit-rotting; ratios are printed, not asserted — CI machines are noisy).

Run:  PYTHONPATH=src python benchmarks/bench_spec_decode.py
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import summarize_times  # noqa: E402

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, EngineConfig, Request, SamplingParams

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg_for(workload: str, arch: str):
    cfg = reduced(ARCHS[arch])
    if workload == "repetitive":
        # 32 logical tokens: the greedy next-token map collapses to a
        # short cycle the drafter can ride (the honest stand-in for an
        # input-grounded production workload, where the model re-emits
        # spans of its context)
        cfg = dataclasses.replace(cfg, vocab_size=32)
    return cfg


def _prompts(cfg, workload: str, n: int, blocks: int):
    bs = cfg.kv_block_size
    rng = np.random.RandomState(0)
    if workload == "repetitive":
        pat = np.asarray([3, 9, 4, 1], np.int64) % cfg.vocab_size
        p = np.tile(pat, blocks * bs // pat.size)[:blocks * bs]
        return [p.copy() for _ in range(n)]
    return [rng.randint(0, cfg.vocab_size, blocks * bs) for _ in range(n)]


def _sampling(workload: str, sid: int) -> SamplingParams:
    if workload == "all_rejected":
        # high temperature: the seeded target draw ~ uniform-ish over the
        # vocab, so a point-mass draft is accepted with probability ~1/V
        return SamplingParams(temperature=2.0, seed=sid)
    return SamplingParams()


def run_one(cfg, params, workload: str, spec: bool, K: int, max_batch: int,
            warmup: int, steps: int) -> dict:
    bs = cfg.kv_block_size
    horizon = (warmup + steps + 2) * (K + 1)
    eng = Engine(cfg, params, EngineConfig(
        max_batch=max_batch,
        max_seq_len=2 * bs + ((horizon + 2 * bs) // bs + 2) * bs,
        spec_decode="ngram" if spec else None, num_draft_tokens=K))
    for sid, prompt in enumerate(_prompts(cfg, workload, max_batch, 2)):
        eng.submit(Request(seq_id=sid, prompt=prompt,
                           max_new_tokens=horizon + 2,
                           sampling=_sampling(workload, sid)))
    t0 = time.perf_counter()
    for _ in range(warmup):
        eng.step()
    compile_s = time.perf_counter() - t0

    def n_generated():
        return sum(len(st.generated) for st in eng._states.values())

    times = []
    tok0 = n_generated()
    for _ in range(steps):
        t0 = time.perf_counter()
        out = eng.step()
        times.append(time.perf_counter() - t0)
        assert len(out) == max_batch
    tokens = n_generated() - tok0

    st = eng.stats()
    r = {
        "workload": workload,
        "engine": "spec_on" if spec else "spec_off",
        "num_draft_tokens": K if spec else 0,
        "max_batch": max_batch,
        "steps": steps,
        "tokens": tokens,
    }
    r.update(summarize_times(times, compile_s=compile_s))
    # tokens/s over EXACTLY the steady subset step_ms_mean describes
    # (compile spikes excluded; token counts are per-step uniform enough
    # at steady state)
    r["tokens_per_s"] = round(
        tokens / steps * r["n_steady_steps"] / max(r["steady_wall_s"],
                                                   1e-9), 1)
    if spec:
        r["acceptance_rate"] = round(
            st["spec_accepted"] / max(st["spec_drafted"], 1), 4)
        r["tokens_per_step"] = round(tokens / (steps * max_batch), 3)
    return r


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--num-draft-tokens", default="1,4",
                    help="comma list of window widths to sweep")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=48)
    ap.add_argument("--warmup", type=int, default=12)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (keeps the script from "
                         "bit-rotting; timings not meaningful)")
    ap.add_argument("--out", default=os.path.join(
        ROOT, "BENCH_spec_decode.json"))
    args = ap.parse_args()
    if args.smoke:
        args.max_batch, args.steps, args.warmup = 2, 6, 3
        args.num_draft_tokens = "2"
    Ks = [int(k) for k in args.num_draft_tokens.split(",")]

    results = []
    speedups, latency_ratios, acceptance = {}, {}, {}
    for workload in ("repetitive", "random", "all_rejected"):
        cfg = _cfg_for(workload, args.arch)
        dims = model_dims(cfg, tp=1)
        params = init_params(jax.random.PRNGKey(0), cfg, dims)
        off = run_one(cfg, params, workload, False, max(Ks),
                      args.max_batch, args.warmup, args.steps)
        off["workload"] = workload
        results.append(off)
        print(f"{workload:13s} spec_off  : {off['step_ms']:7.2f} ms/step"
              f"  {off['tokens_per_s']:8.1f} tok/s")
        for K in Ks:
            r = run_one(cfg, params, workload, True, K, args.max_batch,
                        args.warmup, args.steps)
            results.append(r)
            key = f"{workload}_k{K}"
            speedups[key] = round(r["tokens_per_s"]
                                  / off["tokens_per_s"], 2)
            latency_ratios[key] = round(r["step_ms_mean"]
                                        / off["step_ms_mean"], 2)
            acceptance[key] = r["acceptance_rate"]
            print(f"{workload:13s} spec_on K={K}: {r['step_ms']:7.2f} "
                  f"ms/step  {r['tokens_per_s']:8.1f} tok/s  "
                  f"acc={r['acceptance_rate']:.2%}  "
                  f"speedup={speedups[key]:.2f}x  "
                  f"latency x{latency_ratios[key]:.2f}")

    record = {
        "benchmark": "spec_decode",
        "arch": f"{args.arch} (reduced; repetitive uses vocab=32)",
        "platform": jax.devices()[0].platform,
        "jax": jax.__version__,
        "smoke": bool(args.smoke),
        "num_draft_tokens": Ks,
        "results": results,
        "tokens_per_s_speedup_spec_on_over_off": speedups,
        "step_latency_ratio_spec_on_over_off": latency_ratios,
        "acceptance_rate": acceptance,
        # the two ISSUE-5 headline numbers
        "best_repetitive_speedup": max(
            v for k, v in speedups.items() if k.startswith("repetitive")),
        "worst_case_latency_ratio_k1_all_rejected": latency_ratios.get(
            "all_rejected_k1"),
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"\ntokens/s speedup spec-on/off: {speedups}")
    print(f"step-latency ratio spec-on/off (worst case = all_rejected): "
          f"{latency_ratios}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
