"""HLO post-processing for the roofline: trip-count-corrected FLOPs/bytes
and per-device collective traffic.

Why this exists: ``compiled.cost_analysis()`` counts a while-loop body
ONCE (verified empirically: an 80-layer scanned model reports 1/80th of
analytic FLOPs).  The roofline must therefore re-weight per-computation
costs by loop trip counts.  Collective bytes are not in cost_analysis at
all — they are summed from the HLO text, weighted by the enclosing
computation's multiplier and the op's replica group size.

Per-device moved-bytes model (ring algorithms):
    all-reduce(S)          2 * S * (g-1)/g
    all-gather(R)          R * (g-1)/g         (R = gathered result)
    reduce-scatter(R)      R * (g-1)           (R = scattered result)
    all-to-all(S)          S * (g-1)/g
    collective-permute(S)  S
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\w+\[[\d,]*\](?:\{[^}]*\})?|\((?:[^()]|\([^()]*\))*\)))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[=\{":\s]+n["\s:]*"?(\d+)')
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of 'bf16[1,2,3]' or a tuple '(f32[2], s32[])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """Top-level computation blocks.  HLO text nests braces only in
    attribute lists within a line, so a computation starts at an unindented
    ``name (args) -> type {`` line and ends at a lone ``}``."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            if (line and not line[0].isspace() and line.rstrip().endswith("{")
                    and "=" not in line.split("(")[0]):
                m = _COMP_NAME_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        comps[cur].append(line.strip())
    return comps


def _trip_count(while_line: str, cond_lines: List[str]) -> int:
    """Loop bound: XLA's known_trip_count backend_config, else the largest
    positive constant in the condition computation."""
    m = _TRIP_RE.search(while_line)
    if m:
        return int(m.group(1))
    consts = []
    for ln in cond_lines:
        if "compare" in ln or "constant" in ln:
            consts += [int(c) for c in _CONST_RE.findall(ln)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


def analyze_collectives(hlo: str) -> Dict:
    """Trip-count-weighted per-device collective bytes from HLO text."""
    comps = _split_computations(hlo)

    # call graph: comp -> [(child, multiplier)]
    edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            wm = _WHILE_RE.search(ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(ln, comps.get(cond, []))
                edges[name].append((body, trips))
                edges[name].append((cond, trips))
                continue
            for cm in _CALL_RE.finditer(ln):
                edges[name].append((cm.group(1), 1))

    # multipliers via DFS from entry (last computation = ENTRY by convention;
    # find the one nobody calls)
    called = {c for outs in edges.values() for c, _ in outs}
    roots = [c for c in comps if c not in called] or list(comps)[-1:]
    mult: Dict[str, int] = defaultdict(int)

    def visit(name: str, m: int, depth=0):
        if depth > 50:
            return
        mult[name] += m
        for child, k in edges.get(name, []):
            if child in comps:
                visit(child, m * k, depth + 1)

    for r in roots:
        visit(r, 1)

    per_kind_bytes: Dict[str, float] = defaultdict(float)
    per_kind_count: Dict[str, int] = defaultdict(int)
    for name, lines in comps.items():
        m = max(mult.get(name, 1), 1)
        for ln in lines:
            cm = _COLL_RE.search(ln)
            if not cm:
                continue
            size = _shape_bytes(cm.group(1))
            kind = cm.group(2)
            g = None
            gm = _GROUPS_RE.search(ln)
            if gm:
                g = len(gm.group(1).split(","))
            else:
                gi = _GROUPS_IOTA_RE.search(ln)
                if gi:
                    g = int(gi.group(2))
            if not g or g <= 1:
                g = 2  # conservative default
            frac = (g - 1) / g
            if kind == "all-reduce":
                moved = 2 * size * frac
            elif kind == "all-gather":
                moved = size * frac
            elif kind == "reduce-scatter":
                moved = size * (g - 1)
            elif kind == "all-to-all":
                moved = size * frac
            else:  # collective-permute
                moved = size
            per_kind_bytes[kind] += moved * m
            per_kind_count[kind] += m

    return {
        "collective_bytes_per_device": sum(per_kind_bytes.values()),
        "per_kind_bytes": dict(per_kind_bytes),
        "per_kind_count": dict(per_kind_count),
        "n_computations": len(comps),
    }


_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                    r"((?:\w+\[[\d,]*\](?:\{[^}]*\})?|\((?:[^()]|\([^()]*\))*\)))\s*"
                    r"([\w\-]+)\(([^)]*)\)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BDIMS_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_WINDOW_RE = re.compile(r"window=\{[^}]*size=([\dx]+)")
_FGC_RE = re.compile(r"feature_group_count=(\d+)")


def _dims_of(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def analyze_costs(hlo: str) -> Dict:
    """Trip-count-weighted FLOPs and HBM bytes from the post-opt HLO.

    * FLOPs: dot ops (2*result*K, K from lhs shape + contracting dims) and
      convolutions (2*result*window*Cin/groups).  Element-wise flops are
      ignored (dots dominate every assigned arch by >100x).
    * Bytes: per top-level op, operands + result sizes — post-optimization
      HLO is fusion-granular, so this approximates kernel-level HBM
      traffic the same way XLA's own bytes-accessed does.
    Each computation's contribution is multiplied by its loop/call
    multiplier (the correction cost_analysis lacks).
    """
    comps = _split_computations(hlo)

    edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            wm = _WHILE_RE.search(ln)
            if wm:
                trips = _trip_count(ln, comps.get(wm.group(1), []))
                edges[name].append((wm.group(2), trips))
                edges[name].append((wm.group(1), trips))
                continue
            for cm in _CALL_RE.finditer(ln):
                edges[name].append((cm.group(1), 1))

    called = {c for outs in edges.values() for c, _ in outs}
    roots = [c for c in comps if c not in called] or list(comps)[-1:]
    mult: Dict[str, int] = defaultdict(int)

    def visit(name: str, m: int, depth=0):
        if depth > 50:
            return
        mult[name] += m
        for child, k in edges.get(name, []):
            if child in comps:
                visit(child, m * k, depth + 1)

    for r in roots:
        visit(r, 1)

    total_flops = 0.0
    total_bytes = 0.0
    per_comp_flops: Dict[str, float] = defaultdict(float)
    for name, lines in comps.items():
        m = max(mult.get(name, 1), 1)
        symbols: Dict[str, str] = {}
        cflops = 0.0
        cbytes = 0.0
        for ln in lines:
            om = _OP_RE.match(ln)
            if not om:
                continue
            oname, oshape, okind, oargs = om.groups()
            symbols[oname] = oshape
            result_bytes = _shape_bytes(oshape)
            operand_bytes = 0
            for a in oargs.split(","):
                a = a.strip().lstrip("%")
                a = a.split(" ")[0]
                if a in symbols:
                    operand_bytes += _shape_bytes(symbols[a])
            # HBM-traffic ops only: on TPU the element-wise/convert/copy
            # chains fuse into their consumers, so counting them (as the
            # unfused CPU HLO would suggest) overstates traffic ~10x.
            if okind in ("fusion", "dot", "convolution", "custom-call",
                         "all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute",
                         "dynamic-update-slice", "scatter", "gather",
                         "reduce", "sort", "dynamic-slice",
                         "select-and-scatter"):
                cbytes += result_bytes + operand_bytes
            if okind == "dot":
                rdims = _dims_of(oshape) or []
                lhs = oargs.split(",")[0].strip().lstrip("%").split(" ")[0]
                ldims = _dims_of(symbols.get(lhs, "")) or []
                cd = _CDIMS_RE.search(ln)
                k = 1
                if cd and cd.group(1):
                    for d in cd.group(1).split(","):
                        di = int(d)
                        if di < len(ldims):
                            k *= ldims[di]
                n = 1
                for d in rdims:
                    n *= d
                cflops += 2.0 * n * k
            elif okind == "convolution":
                rdims = _dims_of(oshape) or []
                n = 1
                for d in rdims:
                    n *= d
                w = _WINDOW_RE.search(ln)
                win = 1
                if w:
                    for d in w.group(1).split("x"):
                        win *= int(d)
                cflops += 2.0 * n * win
        total_flops += cflops * m
        total_bytes += cbytes * m
        if cflops:
            per_comp_flops[name] = cflops * m

    top = sorted(per_comp_flops.items(), key=lambda kv: -kv[1])[:10]
    return {"flops_weighted": total_flops, "bytes_weighted": total_bytes,
            "top_computations": top}


def normalize_cost_analysis(ca) -> Dict:
    """compiled.cost_analysis() returns a dict on current jax but a
    one-element list of dicts on older releases; normalize to a dict."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def loop_corrected_costs(compiled, hlo: Optional[str] = None) -> Dict:
    """cost_analysis with while-loop bodies re-weighted by trip count.

    XLA attributes body costs to the entry once; we approximate the
    correction by multiplying the whole-program flops/bytes by the
    dominant loop weight when a single top-level scan dominates.  The
    robust path (used by the roofline) is analytic-per-layer x L,
    cross-checked against this.
    """
    ca = normalize_cost_analysis(compiled.cost_analysis())
    if hlo is None:
        hlo = compiled.as_text()
    comps = _split_computations(hlo)
    # find top-level while trip counts (in ENTRY or main computations)
    trips = []
    for name, lines in comps.items():
        for ln in lines:
            wm = _WHILE_RE.search(ln)
            if wm:
                trips.append(_trip_count(ln, comps.get(wm.group(1), [])))
    return {
        "raw_flops": float(ca.get("flops", 0.0)),
        "raw_bytes": float(ca.get("bytes accessed", 0.0)),
        "loop_trip_counts": sorted(trips, reverse=True)[:8],
    }
