"""Sampled vs greedy decode-step latency (ISSUE 3 satellite).

In-graph sampling (temperature / top-k / top-p with per-slot PRNG keys,
serve/sampling.py) rides inside the same jitted serve_step as greedy
argmax: the sampling math is O(B·V) element-wise work plus one sort,
dwarfed by the layer stack, so a sampled step must cost the same as a
greedy step to within noise.  This benchmark measures both (plus a
mixed greedy/sampled batch — the branch-free design means ONE trace
serves all three) and records the ratio so a regression that puts
sampling on the hot path (extra dispatch, host round-trip, per-request
python) is caught.

Emits a JSON record (default: BENCH_sampling.json at the repo root).
``--smoke`` runs a tiny configuration for CI (scripts must stay
runnable; the ratio is not asserted there — CI machines are noisy).

Run:  PYTHONPATH=src python benchmarks/bench_sampling.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import summarize_times  # noqa: E402

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, EngineConfig, Request, SamplingParams

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

VARIANTS = {
    "greedy": lambda sid: SamplingParams(),
    "sampled": lambda sid: SamplingParams(temperature=0.8, top_k=40,
                                          top_p=0.95, seed=sid),
    "mixed": lambda sid: (SamplingParams() if sid % 2 == 0 else
                          SamplingParams(temperature=0.8, top_k=40,
                                         seed=sid)),
}


def _build(cfg, params, variant: str, max_batch: int, horizon: int):
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, EngineConfig(
        max_batch=max_batch, max_seq_len=2 * bs + horizon + bs))
    rng = np.random.RandomState(0)
    for sid in range(max_batch):
        eng.add_request(Request(
            seq_id=sid, prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
            max_new_tokens=horizon + 2, sampling=VARIANTS[variant](sid)))
    return eng


def run_batch(cfg, params, max_batch: int, warmup: int,
              steps: int) -> list:
    """Measure every variant at one batch size with INTERLEAVED timed
    steps (greedy, sampled, mixed, greedy, ...): slow machine-load drift
    then hits all variants equally instead of whichever ran last."""
    horizon = warmup + steps + 2
    engines = {v: _build(cfg, params, v, max_batch, horizon)
               for v in VARIANTS}
    compile_s = {}
    for v, eng in engines.items():
        t0 = time.perf_counter()
        for _ in range(warmup):
            eng.step()
        compile_s[v] = time.perf_counter() - t0
    times = {v: [] for v in VARIANTS}
    for _ in range(steps):
        for v, eng in engines.items():
            t0 = time.perf_counter()
            out = eng.step()
            times[v].append(time.perf_counter() - t0)
            assert len(out) == max_batch
    results = []
    for v in VARIANTS:
        r = {"variant": v, "max_batch": max_batch, "steps": steps}
        r.update(summarize_times(times[v], compile_s=compile_s[v]))
        r["tokens_per_step_s"] = round(max_batch / (r["step_ms"] / 1e3), 1)
        results.append(r)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batches", default="2,4")
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--warmup", type=int, default=6)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (keeps the script from "
                         "bit-rotting; timings not meaningful)")
    ap.add_argument("--out", default=os.path.join(
        ROOT, "BENCH_sampling.json"))
    args = ap.parse_args()
    if args.smoke:
        args.batches, args.steps, args.warmup = "2", 4, 2

    cfg = reduced(ARCHS[args.arch])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)

    results = []
    ratios = {}
    for mb in (int(b) for b in args.batches.split(",")):
        batch_results = run_batch(cfg, params, mb, args.warmup,
                                  args.steps)
        results.extend(batch_results)
        for r in batch_results:
            print(f"{r['variant']:8s} B={mb}: {r['step_ms']:8.2f} ms/step"
                  f"  {r['tokens_per_step_s']:8.1f} tok/s")
        by = {r["variant"]: r for r in batch_results}
        ratios[f"b{mb}"] = round(by["sampled"]["step_ms"]
                                 / by["greedy"]["step_ms"], 3)

    record = {
        "benchmark": "sampling",
        "arch": f"{args.arch} (reduced)",
        "platform": jax.devices()[0].platform,
        "jax": jax.__version__,
        "smoke": bool(args.smoke),
        "results": results,
        "sampled_over_greedy_step_ratio": ratios,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"\nsampled/greedy step ratio: {ratios} (must stay ~1.0)")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
