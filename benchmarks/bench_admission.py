"""Admission-throughput benchmark: batched/chunked scheduler vs the old
per-request blocking path.

Two ways to push the same request stream through the engine:

* ``per_request`` — the pre-PR admission: ``add_request`` per request,
  i.e. one blocking full-prompt prefill dispatch per request (bucket of
  batch 1), decode steps in between;
* ``batched`` — ``submit`` everything, let ``step()`` admit under the
  prefill token budget: same-length prompts share one padded-bucket
  prefill dispatch, long prompts chunk across steps, finished sequences
  auto-release so slots recycle under sustained load.

Both paths run on the SAME engine implementation and produce identical
tokens (tests/test_admission.py pins that); the benchmark isolates the
admission machinery.  Each engine is warmed with a full pass first so the
measured pass reuses compiled executables (the pow2 bucket shapes are
bounded by design).

Emits a JSON record (default: BENCH_admission.json at the repo root).

Run:  PYTHONPATH=src python benchmarks/bench_admission.py
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, Request

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _requests(cfg, rng, n, blocks, sid0):
    bs = cfg.kv_block_size
    return [Request(seq_id=sid0 + i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       blocks[i % len(blocks)] * bs),
                    max_new_tokens=1)
            for i in range(n)]


def _drain(eng):
    steps = 0
    while eng.waiting or any(not r.done for r in eng.requests.values()):
        eng.step()
        steps += 1
        assert steps < 10_000
    return steps


def run_one(cfg, params, path: str, n_req: int, blocks, max_batch: int,
            budget) -> dict:
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, max_batch=max_batch,
                 max_seq_len=(max(blocks) + 2) * bs,
                 prefill_budget=budget, auto_release=True)
    rng = np.random.RandomState(0)

    def one_pass(sid0):
        reqs = _requests(cfg, rng, n_req, blocks, sid0)
        t0 = time.perf_counter()
        if path == "per_request":
            for r in reqs:
                eng.add_request(r)
                _drain(eng)          # blocking semantics: finish, recycle
            steps = 0
        else:
            for r in reqs:
                eng.submit(r)
            steps = _drain(eng)
        dt = time.perf_counter() - t0
        assert len(eng.finished) == n_req + sid0
        assert all(r.done for r in reqs)
        return dt, steps

    t0 = time.perf_counter()
    one_pass(0)                      # warmup: compile every bucket shape
    compile_s = time.perf_counter() - t0
    dt, steps = one_pass(n_req)
    tokens = int(sum(len(r.prompt) for r in
                     _requests(cfg, np.random.RandomState(0), n_req,
                               blocks, 0)))
    return {
        "path": path,
        "requests": n_req,
        "prompt_blocks": list(blocks),
        "max_batch": max_batch,
        "prefill_budget": eng.prefill_budget,
        "engine_steps": steps,
        "wall_s": round(dt, 4),
        # warmup-pass wall (XLA compiles + first-shape scatters), kept
        # OUT of the measured pass so wall_s trajectories compare
        # PR-over-PR (ISSUE 5 reporting fix)
        "compile_wall_s": round(compile_s, 4),
        "admitted_tokens_per_s": round(tokens / dt, 1),
        "requests_per_s": round(n_req / dt, 2),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--out", default=os.path.join(
        ROOT, "BENCH_admission.json"))
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size

    scenarios = {
        # same-length prompts: pure bucket-batching win
        "uniform_2blk": dict(blocks=(2,), budget=None),
        # mixed lengths, ample budget: batching across length buckets
        "mixed_2_4_8blk": dict(blocks=(2, 4, 8), budget=None),
        # tight budget: long prompts CHUNK across steps — buys decode
        # interleaving at the cost of prefix recompute, so this row is
        # expected to trade some admission throughput away
        "mixed_chunked_b4": dict(blocks=(2, 4, 8), budget=4 * bs),
    }
    results = []
    speedups = {}
    for name, sc in scenarios.items():
        per = {}
        for path in ("per_request", "batched"):
            r = run_one(cfg, params, path, args.requests, sc["blocks"],
                        args.max_batch, sc["budget"])
            r["scenario"] = name
            results.append(r)
            per[path] = r
            print(f"{name:16s} {path:12s}: {r['wall_s']:7.3f}s  "
                  f"{r['admitted_tokens_per_s']:9.1f} prompt tok/s  "
                  f"{r['requests_per_s']:6.2f} req/s")
        speedups[name] = round(per["per_request"]["wall_s"]
                               / per["batched"]["wall_s"], 2)

    record = {
        "benchmark": "admission",
        "arch": f"{args.arch} (reduced)",
        "platform": jax.devices()[0].platform,
        "jax": jax.__version__,
        "results": results,
        "speedup_batched_vs_per_request": speedups,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"\nspeedup batched vs per-request: {speedups}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
