"""Paper Fig. 30 (§8.3.8): sensitivity to the RestSeg hash function.

Allocation conflict behaviour (evictions + spill-to-flex) and device
translation latency per hash, on sequential and strided vpn workloads.
The paper finds modulo performs on par with fancier hashes at minimal
hardware cost."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HASHES, HybridConfig, HybridKVManager, translate
from common import csv_row, time_us


def run() -> list:
    rows = []
    for name in sorted(HASHES):
        cfg = HybridConfig(total_slots=320, restseg_fraction=0.8, assoc=8,
                           max_seqs=16, max_blocks_per_seq=64,
                           hash_name=name)
        m = HybridKVManager(cfg)
        for s in range(12):
            m.register_sequence(s)
            # strided pattern stresses weak hashes
            for b in range(0, 40, 2):
                m.allocate_block(s, b)
        ts = m.device_state()
        vpns = jnp.asarray([m.cfg.vpn(m.seq_slot(s), b)
                            for s in range(12) for b in range(0, 40, 2)],
                           jnp.int32)
        fn = jax.jit(lambda v, ts=ts: translate(ts, v))
        us = time_us(fn, vpns)
        res = fn(vpns)
        rows.append({
            "name": f"hash/{name}", "us": us,
            "derived": (f"rsw_hit={float(res.in_rest.mean()):.2%} "
                        f"evictions={m.stats['rest_evictions']} "
                        f"spilled_to_flex={m.stats['flex_allocs']}"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(csv_row(r["name"], r["us"], r["derived"]))
