"""Paper Fig. 9: restrictive-only mapping inflates swap traffic.

Allocate identical multi-sequence workloads under (i) restrictive-only,
(ii) hybrid, (iii) flexible-only managers at ~90% pool pressure and count
swap-space accesses.  The paper measures 2.2x swap traffic for
restrictive-only over the flexible baseline."""
from __future__ import annotations

import numpy as np

from repro.core import HybridConfig, HybridKVManager
from common import csv_row


def _workload(mode: str, n_seqs: int = 12, blocks: int = 26,
              total_slots: int = 224, seed: int = 1):
    cfg = HybridConfig(total_slots=total_slots, restseg_fraction=0.75,
                       assoc=8, max_seqs=n_seqs, max_blocks_per_seq=64,
                       mode=mode)
    m = HybridKVManager(cfg)
    rng = np.random.Generator(np.random.Philox(seed))
    for s in range(n_seqs):
        m.register_sequence(s)
    # demand ~125% of pool capacity with sequence churn: even the
    # flexible baseline must swap, as in the paper's pressured setup
    import itertools
    for rnd in range(3):
        for s in range(n_seqs):
            if rnd and s % 4 == 0:
                m.free_sequence(s)
                m.register_sequence(s)
            n = blocks if s % 3 else blocks // 2
            for b in range(n):
                info = m.allocate_block(s, b)
                if info.seg == 2:  # touch swapped blocks again -> swap_in
                    try:
                        m.swap_in(s, b)
                    except Exception:
                        pass
    return m


def run() -> list:
    rows = []
    results = {}
    for mode in ("flexible_only", "hybrid", "restrictive_only"):
        m = _workload(mode)
        swaps = m.stats["swap_out"] + m.stats["swap_in"]
        results[mode] = swaps
        rows.append({
            "name": f"restrictive_only/swaps[{mode}]",
            "us": 0.0,
            "derived": (f"swap_accesses={swaps} "
                        f"rest_allocs={m.stats['rest_allocs']} "
                        f"flex_allocs={m.stats['flex_allocs']} "
                        f"evictions={m.stats['rest_evictions']}"),
        })
    base = max(results["flexible_only"], 1)
    ratio = results["restrictive_only"] / base
    hybrid_ratio = results["hybrid"] / base
    rows.append({
        "name": "restrictive_only/ratio_vs_flexible",
        "us": 0.0,
        "derived": (f"restrictive_only={ratio:.2f}x (paper: 2.2x) "
                    f"hybrid={hybrid_ratio:.2f}x (paper claim: ~1x)"),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(csv_row(r["name"], r["us"], r["derived"]))
