"""Recovery benchmark: what crash-safety costs and what it buys (ISSUE 10).

Three questions, one workload (oversubscribed serve drain, the
bench_overload shape):

* **Snapshot overhead** — the direct cost of one ``Engine.snapshot()``
  call (``snapshot_ms_mean``, one batched device_get + one pickle)
  amortized over snapshot cadence N ∈ {10, 50}
  (``snapshot_overhead_ratio.every_N`` = 1 + snap_ms / (N · step_ms);
  gated analytically because the true cost is far below run-to-run
  wall noise). End-to-end ``ResilientServe``-supervised drains at each
  cadence are also run and reported (``supervised_wall_s``) as an
  ungated sanity reference.
* **Restore latency** — ``Engine.restore`` onto a fresh engine
  (``restore_ms``): unpickle, device_put, full translation re-sync.
* **Replay vs cold re-prefill** — after a crash near the end of the
  run, finishing from the last snapshot (restore + replay the tail)
  vs restarting the whole workload from scratch
  (``recovery_speedup_replay_over_cold``, the reason snapshots exist:
  replay re-runs a bounded tail, cold recovery re-prefills every
  prompt).

``--smoke`` runs a tiny configuration for CI (keeps the script from
bit-rotting; timings are not meaningful there).

Run:  PYTHONPATH=src python benchmarks/bench_recovery.py
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.runtime import ResilientServe, ServeFaultInjector
from repro.serve import Engine, EngineConfig, Request

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mkeng(cfg, params, max_batch, injector=None):
    bs = cfg.kv_block_size
    return Engine(cfg, params, EngineConfig(
        max_batch=max_batch, max_seq_len=8 * bs, pool_headroom=0.75,
        auto_release=True, fault_injector=injector))


def _reqs(cfg, n_req, max_new):
    bs = cfg.kv_block_size
    rng = np.random.RandomState(7)
    return [Request(seq_id=i,
                    prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                    max_new_tokens=max_new) for i in range(n_req)]


def _drain(poll, unfinished, budget=20000):
    steps = 0
    while unfinished():
        poll()
        steps += 1
        assert steps < budget, "failed to drain"
    return steps


def run_baseline(cfg, params, n_req, max_batch, max_new, warm):
    # warm with the FULL workload shape so the timed drains (this one
    # and every supervised run after it) compare compile-free walls
    if warm:
        weng = _mkeng(cfg, params, max_batch)
        for r in _reqs(cfg, n_req, max_new):
            weng.submit(r)
        _drain(weng.poll, weng.has_unfinished)
    eng = _mkeng(cfg, params, max_batch)
    for r in _reqs(cfg, n_req, max_new):
        eng.submit(r)
    t0 = time.perf_counter()
    steps = _drain(eng.poll, eng.has_unfinished)
    return time.perf_counter() - t0, steps, eng


def run_supervised(cfg, params, n_req, max_batch, max_new, every):
    eng = _mkeng(cfg, params, max_batch)
    sup = ResilientServe(eng, snapshot_every=every)
    for r in _reqs(cfg, n_req, max_new):
        sup.submit(r)
    t0 = time.perf_counter()
    _drain(sup.poll, sup.has_unfinished)
    return time.perf_counter() - t0, sup


def measure_snapshot_restore(cfg, params, n_req, max_batch, max_new,
                             reps=5):
    """Direct per-call costs mid-workload (live KV + queue state)."""
    eng = _mkeng(cfg, params, max_batch)
    for r in _reqs(cfg, n_req, max_new):
        eng.submit(r)
    for _ in range(6):
        eng.poll()
    snap_ms = []
    for _ in range(reps):
        t0 = time.perf_counter()
        snap = eng.snapshot()
        snap_ms.append((time.perf_counter() - t0) * 1e3)
    nbytes = (len(snap.host_blob)
              + sum(a.nbytes for a in snap.dstate.values()))
    fresh = _mkeng(cfg, params, max_batch)
    restore_ms = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fresh.restore(snap)
        restore_ms.append((time.perf_counter() - t0) * 1e3)
    return (round(float(np.mean(snap_ms)), 3),
            round(float(np.mean(restore_ms)), 3),
            nbytes)


def measure_replay_vs_cold(cfg, params, n_req, max_batch, max_new,
                           total_steps):
    """Crash near the end of the drain: finish via restore+replay vs
    restart the whole workload cold."""
    crash_step = max(4, int(total_steps * 0.75))
    inj = ServeFaultInjector(crash_at=[(crash_step, "pre")])
    eng = _mkeng(cfg, params, max_batch, injector=inj)
    sup = ResilientServe(eng, snapshot_every=10, max_restarts=2)
    for r in _reqs(cfg, n_req, max_new):
        sup.submit(r)
    # run up to one step before the crash outside the timed region;
    # the supervisor recovers *inside* poll(), so the next poll pays
    # restore + replay and the timed region must start here
    while eng._step_count < crash_step - 1 and sup.has_unfinished():
        sup.poll()
    t_rec = time.perf_counter()
    _drain(sup.poll, sup.has_unfinished)
    replay_s = time.perf_counter() - t_rec
    assert sup.restarts == 1, "crash did not land where expected"
    # cold recovery: a new engine re-prefills EVERY prompt from scratch
    cold = _mkeng(cfg, params, max_batch)
    for r in _reqs(cfg, n_req, max_new):
        cold.submit(r)
    t0 = time.perf_counter()
    _drain(cold.poll, cold.has_unfinished)
    cold_s = time.perf_counter() - t0
    return replay_s, cold_s, crash_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--n-req", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--every", default="10,50",
                    help="comma list of snapshot cadences to sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (keeps the script from "
                         "bit-rotting; timings not meaningful)")
    ap.add_argument("--out", default=os.path.join(
        ROOT, "BENCH_recovery.json"))
    args = ap.parse_args()
    if args.smoke:
        args.n_req, args.max_new = 4, 8

    cfg = dataclasses.replace(reduced(ARCHS[args.arch]), num_layers=2)
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    cadences = [int(x) for x in args.every.split(",")]

    reps = 1 if args.smoke else 3
    walls = []
    for i in range(reps):
        w, total_steps, _ = run_baseline(cfg, params, args.n_req,
                                         args.max_batch, args.max_new,
                                         warm=(i == 0))
        walls.append(w)
    base_s = float(np.median(walls))
    print(f"baseline drain: {base_s:.3f} s over {total_steps} steps")

    snap_ms, restore_ms, snap_bytes = measure_snapshot_restore(
        cfg, params, args.n_req, args.max_batch, args.max_new)
    print(f"snapshot {snap_ms:.2f} ms  restore {restore_ms:.2f} ms  "
          f"({snap_bytes / 2**20:.2f} MB)")

    # end-to-end supervised walls are reported for reference, but the
    # gated overhead ratio is amortized from the per-call snapshot
    # cost: the true cost (~snap_ms every N steps) is far below
    # run-to-run wall noise, so a wall/wall ratio would gate on noise
    step_ms = base_s * 1e3 / max(total_steps, 1)
    overhead, supervised_wall = {}, {}
    for every in cadences:
        sup_s, sup = run_supervised(cfg, params, args.n_req,
                                    args.max_batch, args.max_new, every)
        supervised_wall[f"every_{every}"] = round(sup_s, 3)
        overhead[f"every_{every}"] = round(
            1.0 + snap_ms / (every * step_ms), 5)
        print(f"supervised N={every:3d}: {sup_s:.3f} s end-to-end "
              f"({sup.snapshots} snapshots, amortized overhead "
              f"x{overhead[f'every_{every}']})")

    replay_s, cold_s, crash_step = measure_replay_vs_cold(
        cfg, params, args.n_req, args.max_batch, args.max_new,
        total_steps)
    speedup = round(cold_s / max(replay_s, 1e-9), 3)
    print(f"crash at step {crash_step}: replay {replay_s:.3f} s vs "
          f"cold {cold_s:.3f} s (x{speedup})")

    record = {
        "benchmark": "recovery",
        "arch": f"{args.arch} (reduced, 2 layers)",
        "platform": jax.devices()[0].platform,
        "jax": jax.__version__,
        "smoke": bool(args.smoke),
        "n_req": args.n_req,
        "max_new_tokens": args.max_new,
        "baseline_wall_s": round(base_s, 3),
        "baseline_steps": total_steps,
        "supervised_wall_s": supervised_wall,
        "snapshot_overhead_ratio": overhead,
        "snapshot_ms_mean": snap_ms,
        "restore_ms": restore_ms,
        "snapshot_bytes": snap_bytes,
        "crash_step": crash_step,
        "replay_wall_s": round(replay_s, 3),
        "cold_wall_s": round(cold_s, 3),
        "recovery_speedup_replay_over_cold": speedup,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
