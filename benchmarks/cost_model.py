"""Analytic FLOPs/bytes model per (arch x shape x mesh) for the roofline.

MODEL_FLOPS follows the assignment: 6·N·D_tokens (train, dense) /
6·N_active·D (MoE); forward-only kinds use the 2·N·D forward factor plus
attention terms.  Attention FLOPs are added explicitly (they are not in
N-based estimates).  These analytic numbers cross-check the
trip-count-corrected HLO costs (hlo_analysis.py).

Hardware constants (TPU v5e class, per assignment):
    peak 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, ShapeCell

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DTYPE_BYTES = 2  # bf16


def attention_flops(cfg: ArchConfig, seq: int, batch: int,
                    kind: str, causal_half: bool = False) -> float:
    """q@k + p@v matmul flops for self-attention over the whole model."""
    n_attn = sum(cfg.attn_on_layer(l) for l in range(cfg.num_layers))
    if n_attn == 0:
        return 0.0
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    if kind == "decode":
        # one query token against `seq` cached tokens
        per_layer = 2 * 2 * batch * H * hd * seq
        return per_layer * n_attn
    per_layer = 2 * 2 * batch * H * hd * seq * seq
    if causal_half:
        per_layer /= 2
    total = per_layer * n_attn
    if kind == "train":
        total *= 3  # fwd + bwd(2x)
    return total


def model_flops(cfg: ArchConfig, shape: ShapeCell) -> Dict[str, float]:
    """Returns MODEL_FLOPS (6ND / 2ND style) and attention extras."""
    N = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        base = 6.0 * N * tokens
        attn = attention_flops(cfg, S, B, "train", causal_half=True)
    elif shape.kind == "prefill":
        tokens = B * S
        base = 2.0 * N * tokens
        attn = attention_flops(cfg, S, B, "prefill", causal_half=True)
    else:  # decode: one token per sequence
        tokens = B
        base = 2.0 * N * tokens
        attn = attention_flops(cfg, S, B, "decode")
    return {"model_flops": base, "attention_flops": attn,
            "total_flops": base + attn, "tokens": tokens}


def hbm_bytes(cfg: ArchConfig, shape: ShapeCell) -> float:
    """Dominant per-step HBM traffic (global): weights + KV reads."""
    N = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    n_attn = sum(cfg.attn_on_layer(l) for l in range(cfg.num_layers))
    kv_per_token = n_attn * cfg.num_kv_heads * cfg.resolved_head_dim * 2
    if shape.kind == "decode":
        # weights once + the whole KV cache once per decode step
        return (N * DTYPE_BYTES
                + B * S * kv_per_token * DTYPE_BYTES)
    # train/prefill: weights (+grad/opt traffic for train) + activations
    act = B * S * cfg.d_model * DTYPE_BYTES * cfg.num_layers
    w_passes = 3 if shape.kind == "train" else 1
    return N * DTYPE_BYTES * w_passes + act


def roofline_terms(cfg: ArchConfig, shape: ShapeCell, n_chips: int,
                   hlo_flops_per_dev: float, hlo_bytes_per_dev: float,
                   collective_bytes_per_dev: float) -> Dict[str, float]:
    """Three roofline terms in seconds + bottleneck + useful-flops ratio.

    The memory term is reported twice: ``memory_s_ub`` from the HLO op-level
    operand/result bytes (an upper bound: a value re-read by k consumers is
    charged k times, as in XLA's own bytes-accessed) and ``memory_s`` from
    the analytic traffic model (weights + KV + activations once — the lower
    bound a perfectly-fused TPU program approaches).  The bottleneck is
    picked with the analytic term; both appear in the table.
    """
    compute_s = hlo_flops_per_dev / PEAK_FLOPS
    memory_ub_s = hlo_bytes_per_dev / HBM_BW
    memory_s = hbm_bytes(cfg, shape) / n_chips / HBM_BW
    collective_s = collective_bytes_per_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    terms["memory_s_ub"] = memory_ub_s
    mf = model_flops(cfg, shape)
    useful = mf["total_flops"] / n_chips
    return {
        **terms,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "model_flops_global": mf["total_flops"],
        "model_flops_per_dev": useful,
        "hlo_flops_per_dev": hlo_flops_per_dev,
        "useful_flops_ratio": (useful / hlo_flops_per_dev
                               if hlo_flops_per_dev else 0.0),
        "roofline_fraction": (useful / PEAK_FLOPS) / max(
            terms[dominant], 1e-30),
    }
