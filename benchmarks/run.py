"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Roofline terms come from
``benchmarks/roofline.py`` (reads the dry-run JSONs); everything here runs
live on CPU with the real mechanisms at reduced scale.

``--all`` additionally aggregates every ``BENCH_*.json`` at the repo
root into ONE ``BENCH_summary.json`` trajectory table — (benchmark, key
metric, value) rows — and prints it, so a CI log shows the perf
trajectory of the serving stack at a glance without opening each file.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import traceback

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import csv_row  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MODULES = [
    "bench_structure_size",     # Fig. 13
    "bench_restrictive_only",   # Fig. 9
    "bench_translation",        # Figs. 18/19/20
    "bench_tar_sf_locality",    # Fig. 23
    "bench_reuse",              # Figs. 24/26
    "bench_restseg_size",       # Fig. 27
    "bench_hash_functions",     # Fig. 30
    "bench_non_bound",          # §8.3.7
    "bench_roofline_summary",   # §Roofline headline (from dry-run JSONs)
]

# the headline metric(s) to lift out of each engine benchmark's JSON:
# dotted paths into (possibly nested) dicts; every leaf of a matched
# dict becomes one summary row
KEY_METRICS = {
    "engine_step": ["speedup_vs_pre_pr"],
    "admission": ["speedup_batched_vs_per_request"],
    "sampling": ["sampled_over_greedy_step_ratio"],
    "prefix_prefill": ["fwd_token_ratio_recompute_over_prefix",
                       "admission_speedup_prefix_over_recompute"],
    "spec_decode": ["tokens_per_s_speedup_spec_on_over_off",
                    "step_latency_ratio_spec_on_over_off",
                    "acceptance_rate"],
    "overload": ["goodput_ratio_preempt_over_fail",
                 "ttft_p99_ratio_preempt_over_fail",
                 "preemptions_per_request"],
    "sharded": ["step_latency_ratio_vs_single_device",
                "kv_bytes_per_shard"],
    "prefix_cache": ["prefill_fwd_token_ratio_off_over_on",
                     "ttft_mean_ratio_on_over_off",
                     "peak_occupancy_ratio_on_over_off",
                     "cold_miss_wall_ratio_on_over_off"],
}


def summarize_bench_jsons(root: str = ROOT,
                          out: str | None = None) -> list:
    """Aggregate BENCH_*.json records into a (benchmark, metric, value)
    trajectory table; write it to ``out`` and return the rows."""
    rows = []
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        if os.path.basename(path) == "BENCH_summary.json":
            continue
        try:
            with open(path) as f:
                rec = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            rows.append({"benchmark": os.path.basename(path),
                         "metric": "UNREADABLE", "value": str(e)})
            continue
        bench = rec.get("benchmark", os.path.basename(path))
        metrics = KEY_METRICS.get(bench)
        if metrics is None:
            # unknown benchmark: surface every scalar top-level field
            metrics = [k for k, v in rec.items()
                       if isinstance(v, (int, float))
                       and not isinstance(v, bool)]
        for name in metrics:
            val = rec.get(name)
            if isinstance(val, dict):
                for k, v in sorted(val.items()):
                    rows.append({"benchmark": bench,
                                 "metric": f"{name}.{k}", "value": v})
            elif val is not None:
                rows.append({"benchmark": bench, "metric": name,
                             "value": val})
    if out:
        with open(out, "w") as f:
            json.dump({"summary": rows}, f, indent=1)
    return rows


def print_summary(rows) -> None:
    w = max([len(r["benchmark"]) for r in rows] + [9])
    wm = max([len(r["metric"]) for r in rows] + [6])
    print(f"{'benchmark':{w}s}  {'metric':{wm}s}  value")
    for r in rows:
        print(f"{r['benchmark']:{w}s}  {r['metric']:{wm}s}  {r['value']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true",
                    help="also aggregate BENCH_*.json into "
                         "BENCH_summary.json and print the table")
    ap.add_argument("--summary-only", action="store_true",
                    help="skip the paper-figure CSV modules; only "
                         "aggregate the BENCH_*.json trajectory table")
    args = ap.parse_args()

    failures = []
    if not args.summary_only:
        print("name,us_per_call,derived")
        for mod_name in MODULES:
            try:
                mod = __import__(mod_name)
                for r in mod.run():
                    print(csv_row(r["name"], r["us"], r["derived"]),
                          flush=True)
            except Exception:
                failures.append(mod_name)
                traceback.print_exc()
    if args.all or args.summary_only:
        out = os.path.join(ROOT, "BENCH_summary.json")
        rows = summarize_bench_jsons(ROOT, out)
        print()
        print_summary(rows)
        print(f"\nwrote {out}")
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
