"""Benchmark harness: one module per paper table/figure, plus the
serving-stack trajectory tools.

Prints ``name,us_per_call,derived`` CSV.  Roofline terms come from
``benchmarks/roofline.py`` (reads the dry-run JSONs); everything here runs
live on CPU with the real mechanisms at reduced scale.

``--all`` additionally aggregates every ``BENCH_*.json`` at the repo
root into ONE ``BENCH_summary.json`` trajectory table — (benchmark, key
metric, value) rows — and prints it, so a CI log shows the perf
trajectory of the serving stack at a glance without opening each file.
A malformed or truncated ``BENCH_*.json`` is skipped with a warning and
recorded under ``"skipped"`` in the summary (it must not wedge the
gate below on an unrelated file).

``--smoke`` runs the engine benchmarks that support a smoke mode into a
scratch directory (CI keeps the scripts from bit-rotting without paying
full measurement cost).

``--diff OLD_SUMMARY.json`` is the perf-regression gate: it freshly
aggregates the ``BENCH_*.json`` files (same rows ``--all`` writes) and
compares them to the baseline summary per metric, with the
direction-aware noise bands declared in ``NOISE_BANDS`` below.  A
metric regressing beyond its band — slower where lower is better,
smaller where higher is better — prints an offending row and exits
nonzero; improvements and in-band drift pass.  CI diffs against the
committed summary from the parent commit, so a PR that lands worse
steady-state numbers fails loudly (DESIGN.md §observability).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
import traceback

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import csv_row  # noqa: E402

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(BENCH_DIR)

MODULES = [
    "bench_structure_size",     # Fig. 13
    "bench_restrictive_only",   # Fig. 9
    "bench_translation",        # Figs. 18/19/20
    "bench_tar_sf_locality",    # Fig. 23
    "bench_reuse",              # Figs. 24/26
    "bench_restseg_size",       # Fig. 27
    "bench_hash_functions",     # Fig. 30
    "bench_non_bound",          # §8.3.7
    "bench_roofline_summary",   # §Roofline headline (from dry-run JSONs)
]

# engine benchmarks with a --smoke mode (tiny configurations for CI);
# bench_sharded needs >= 4 forced host devices and runs in its own job
SMOKE_MODULES = [
    "bench_sampling",
    "bench_prefix_prefill",
    "bench_spec_decode",
    "bench_overload",
    "bench_prefix_cache",
    "bench_recovery",
]

# the headline metric(s) to lift out of each engine benchmark's JSON:
# dotted paths into (possibly nested) dicts; every leaf of a matched
# dict becomes one summary row
KEY_METRICS = {
    "engine_step": ["speedup_vs_pre_pr", "steady_step_ms"],
    "admission": ["speedup_batched_vs_per_request"],
    "sampling": ["sampled_over_greedy_step_ratio"],
    "prefix_prefill": ["fwd_token_ratio_recompute_over_prefix",
                       "admission_speedup_prefix_over_recompute"],
    "spec_decode": ["tokens_per_s_speedup_spec_on_over_off",
                    "step_latency_ratio_spec_on_over_off",
                    "acceptance_rate"],
    "overload": ["goodput_ratio_preempt_over_fail",
                 "ttft_p99_ratio_preempt_over_fail",
                 "preemptions_per_request"],
    "sharded": ["step_latency_ratio_vs_single_device",
                "kv_bytes_per_shard"],
    "prefix_cache": ["prefill_fwd_token_ratio_off_over_on",
                     "ttft_mean_ratio_on_over_off",
                     "peak_occupancy_ratio_on_over_off",
                     "cold_miss_wall_ratio_on_over_off"],
    "recovery": ["snapshot_overhead_ratio",
                 "snapshot_ms_mean",
                 "restore_ms",
                 "recovery_speedup_replay_over_cold"],
}

# Direction-aware noise bands for the --diff gate, declared alongside
# KEY_METRICS: metric name (the summary row's name, or its prefix
# before the first ".") -> (better, rel_band).
#
# * better="higher": new < old * (1 - band) is a regression
#   (speedups, hit/acceptance rates, dedup ratios);
# * better="lower":  new > old * (1 + band) is a regression
#   (latencies, latency ratios, byte footprints, preemption counts).
#
# Bands absorb run-to-run measurement noise on the machine that wrote
# the committed BENCH files; deterministic metrics (byte footprints,
# token-count ratios) get tight bands.  A metric with no entry here is
# informational: printed in the summary, never gated.
NOISE_BANDS = {
    "steady_step_ms": ("lower", 0.15),
    # ratio against the EMULATED legacy engine (a ~20x slower step
    # measured in the same process): its run-to-run spread is far wider
    # than the current engine's own latency, which steady_step_ms gates
    # tightly — so this band only catches wholesale collapses
    "speedup_vs_pre_pr": ("higher", 0.35),
    "speedup_batched_vs_per_request": ("higher", 0.15),
    "sampled_over_greedy_step_ratio": ("lower", 0.15),
    "fwd_token_ratio_recompute_over_prefix": ("higher", 0.05),
    "admission_speedup_prefix_over_recompute": ("higher", 0.25),
    "tokens_per_s_speedup_spec_on_over_off": ("higher", 0.15),
    "step_latency_ratio_spec_on_over_off": ("lower", 0.15),
    "acceptance_rate": ("higher", 0.10),
    "goodput_ratio_preempt_over_fail": ("higher", 0.15),
    "ttft_p99_ratio_preempt_over_fail": ("lower", 0.20),
    "preemptions_per_request": ("lower", 0.30),
    "step_latency_ratio_vs_single_device": ("lower", 0.25),
    "kv_bytes_per_shard": ("lower", 0.01),
    "prefill_fwd_token_ratio_off_over_on": ("higher", 0.05),
    "ttft_mean_ratio_on_over_off": ("lower", 0.15),
    "peak_occupancy_ratio_on_over_off": ("lower", 0.10),
    "cold_miss_wall_ratio_on_over_off": ("lower", 0.25),
    # amortized analytically from snapshot_ms (see bench_recovery
    # docstring), so the ratio itself is near-deterministic; the raw
    # per-call timings carry the usual CPU-timer noise
    "snapshot_overhead_ratio": ("lower", 0.02),
    "snapshot_ms_mean": ("lower", 0.50),
    "restore_ms": ("lower", 0.50),
    "recovery_speedup_replay_over_cold": ("higher", 0.30),
}


def band_for(metric: str):
    """Noise band for a summary metric name: exact match first, then
    the declared family prefix (``steady_step_ms.hybrid_b2`` matches
    ``steady_step_ms``).  None = informational, never gated."""
    if metric in NOISE_BANDS:
        return NOISE_BANDS[metric]
    return NOISE_BANDS.get(metric.split(".", 1)[0])


def summarize_bench_jsons(root: str = ROOT, out: str | None = None):
    """Aggregate BENCH_*.json records into a (benchmark, metric, value)
    trajectory table; write it to ``out`` and return
    ``(rows, skipped)``.

    A file that cannot be parsed — truncated write, malformed JSON, a
    non-object top level — is SKIPPED with a warning and recorded in
    ``skipped``, instead of wedging the aggregation (and the --diff
    gate downstream) on an unrelated file."""
    rows, skipped = [], []
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        if os.path.basename(path) == "BENCH_summary.json":
            continue
        try:
            with open(path) as f:
                rec = json.load(f)
            if not isinstance(rec, dict):
                raise ValueError(
                    f"top-level JSON is {type(rec).__name__}, not an "
                    "object")
        except Exception as e:   # noqa: BLE001 — any bad file: skip+warn
            print(f"WARNING: skipping {os.path.basename(path)}: {e}",
                  file=sys.stderr)
            skipped.append({"file": os.path.basename(path),
                            "error": str(e)})
            continue
        bench = rec.get("benchmark", os.path.basename(path))
        metrics = KEY_METRICS.get(bench)
        if metrics is None:
            # unknown benchmark: surface every scalar top-level field
            metrics = [k for k, v in rec.items()
                       if isinstance(v, (int, float))
                       and not isinstance(v, bool)]
        for name in metrics:
            val = rec.get(name)
            if isinstance(val, dict):
                for k, v in sorted(val.items()):
                    rows.append({"benchmark": bench,
                                 "metric": f"{name}.{k}", "value": v})
            elif val is not None:
                rows.append({"benchmark": bench, "metric": name,
                             "value": val})
    if out:
        with open(out, "w") as f:
            json.dump({"summary": rows, "skipped": skipped}, f, indent=1)
    return rows, skipped


def print_summary(rows) -> None:
    if not rows:
        print("(no BENCH_*.json rows)")
        return
    w = max([len(r["benchmark"]) for r in rows] + [9])
    wm = max([len(r["metric"]) for r in rows] + [6])
    print(f"{'benchmark':{w}s}  {'metric':{wm}s}  value")
    for r in rows:
        print(f"{r['benchmark']:{w}s}  {r['metric']:{wm}s}  {r['value']}")


# ------------------------------------------------- perf-regression gate

def load_summary_rows(path: str) -> list:
    """Rows of a BENCH_summary.json written by ``summarize_bench_jsons``
    (tolerates the pre-gate format without ``skipped``)."""
    with open(path) as f:
        rec = json.load(f)
    return rec["summary"] if isinstance(rec, dict) else rec


def diff_summaries(old_rows, new_rows):
    """Compare two summary-row lists per metric under NOISE_BANDS.

    Returns ``(regressions, notes)``: ``regressions`` is one dict per
    gated metric that moved beyond its band in the WORSE direction
    (direction-aware — an improvement can never regress), ``notes``
    records gated metrics present on only one side (a renamed or
    removed benchmark is surfaced, not silently dropped)."""
    def key(r):
        return (r["benchmark"], r["metric"])

    old = {key(r): r["value"] for r in old_rows}
    new = {key(r): r["value"] for r in new_rows}
    regressions, notes = [], []
    for k in sorted(set(old) | set(new)):
        bench, metric = k
        band = band_for(metric)
        if band is None:
            continue
        if k not in new:
            notes.append(f"{bench}/{metric}: in baseline only")
            continue
        if k not in old:
            notes.append(f"{bench}/{metric}: new metric (no baseline)")
            continue
        ov, nv = old[k], new[k]
        if not all(isinstance(v, (int, float)) and not isinstance(v, bool)
                   for v in (ov, nv)):
            notes.append(f"{bench}/{metric}: non-numeric value")
            continue
        better, rel = band
        if ov == 0:
            notes.append(f"{bench}/{metric}: zero baseline")
            continue
        change = nv / ov - 1.0
        bad = (change < -rel) if better == "higher" else (change > rel)
        if bad:
            regressions.append({
                "benchmark": bench, "metric": metric,
                "baseline": ov, "current": nv,
                "change": change, "band": rel, "better": better,
            })
    return regressions, notes


def run_diff_gate(baseline_path: str, root: str = ROOT) -> int:
    """Aggregate fresh rows from ``root`` and gate them against the
    baseline summary; print every offending metric row (not just a
    nonzero exit) and return the process exit code."""
    old_rows = load_summary_rows(baseline_path)
    new_rows, skipped = summarize_bench_jsons(root, out=None)
    regressions, notes = diff_summaries(old_rows, new_rows)
    for n in notes:
        print(f"note: {n}")
    if skipped:
        print(f"note: {len(skipped)} unreadable BENCH file(s) skipped: "
              + ", ".join(s["file"] for s in skipped))
    if not regressions:
        print(f"perf gate PASS: {len(new_rows)} metric rows vs "
              f"{os.path.basename(baseline_path)}, no regression beyond "
              "the declared noise bands")
        return 0
    w = max(len(r["benchmark"]) + len(r["metric"]) + 1
            for r in regressions)
    print(f"perf gate FAIL: {len(regressions)} metric(s) regressed "
          f"beyond their noise band vs {os.path.basename(baseline_path)}:")
    for r in regressions:
        name = f"{r['benchmark']}/{r['metric']}"
        print(f"  {name:{w}s}  baseline={r['baseline']:<10g} "
              f"current={r['current']:<10g} change={r['change']:+.1%} "
              f"band=±{r['band']:.0%} (better: {r['better']})")
    return 1


def run_smoke(smoke_dir: str) -> int:
    """Run every SMOKE_MODULES benchmark with ``--smoke`` into
    ``smoke_dir`` and print the aggregated table; returns nonzero if
    any script fails (CI's bit-rot canary)."""
    os.makedirs(smoke_dir, exist_ok=True)
    failures = []
    for mod in SMOKE_MODULES:
        out = os.path.join(smoke_dir, f"BENCH_{mod[len('bench_'):]}.json")
        cmd = [sys.executable, os.path.join(BENCH_DIR, f"{mod}.py"),
               "--smoke", "--out", out]
        print(f"--- {mod} --smoke", flush=True)
        res = subprocess.run(cmd)
        if res.returncode != 0:
            failures.append(mod)
    rows, _ = summarize_bench_jsons(smoke_dir, out=None)
    print()
    print_summary(rows)
    if failures:
        print(f"SMOKE FAILED: {failures}", file=sys.stderr)
        return 1
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true",
                    help="also aggregate BENCH_*.json into "
                         "BENCH_summary.json and print the table")
    ap.add_argument("--summary-only", action="store_true",
                    help="skip the paper-figure CSV modules; only "
                         "aggregate the BENCH_*.json trajectory table")
    ap.add_argument("--smoke", action="store_true",
                    help="run the engine benchmarks in smoke mode into "
                         "--smoke-dir (skips the CSV modules)")
    ap.add_argument("--smoke-dir", default="/tmp/bench_smoke",
                    help="where --smoke writes its BENCH_*.json files")
    ap.add_argument("--diff", metavar="OLD_SUMMARY.json", default=None,
                    help="perf-regression gate: aggregate fresh rows "
                         "from --bench-root and fail on any metric "
                         "beyond its declared noise band vs this "
                         "baseline summary")
    ap.add_argument("--bench-root", default=ROOT,
                    help="directory whose BENCH_*.json files feed the "
                         "aggregation / --diff gate (default: repo "
                         "root)")
    args = ap.parse_args()

    if args.smoke:
        rc = run_smoke(args.smoke_dir)
        if rc:
            sys.exit(rc)
    if args.diff is not None:
        sys.exit(run_diff_gate(args.diff, args.bench_root))

    failures = []
    if not (args.summary_only or args.smoke):
        print("name,us_per_call,derived")
        for mod_name in MODULES:
            try:
                mod = __import__(mod_name)
                for r in mod.run():
                    print(csv_row(r["name"], r["us"], r["derived"]),
                          flush=True)
            except Exception:
                failures.append(mod_name)
                traceback.print_exc()
    if args.all or args.summary_only:
        out = os.path.join(ROOT, "BENCH_summary.json")
        rows, skipped = summarize_bench_jsons(args.bench_root, out)
        print()
        print_summary(rows)
        if skipped:
            print(f"\nskipped {len(skipped)} unreadable BENCH file(s): "
                  + ", ".join(s["file"] for s in skipped))
        print(f"\nwrote {out}")
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
