"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Roofline terms come from
``benchmarks/roofline.py`` (reads the dry-run JSONs); everything here runs
live on CPU with the real mechanisms at reduced scale.
"""
from __future__ import annotations

import os
import sys
import traceback

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import csv_row  # noqa: E402

MODULES = [
    "bench_structure_size",     # Fig. 13
    "bench_restrictive_only",   # Fig. 9
    "bench_translation",        # Figs. 18/19/20
    "bench_tar_sf_locality",    # Fig. 23
    "bench_reuse",              # Figs. 24/26
    "bench_restseg_size",       # Fig. 27
    "bench_hash_functions",     # Fig. 30
    "bench_non_bound",          # §8.3.7
    "bench_roofline_summary",   # §Roofline headline (from dry-run JSONs)
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for mod_name in MODULES:
        try:
            mod = __import__(mod_name)
            for r in mod.run():
                print(csv_row(r["name"], r["us"], r["derived"]), flush=True)
        except Exception:
            failures.append(mod_name)
            traceback.print_exc()
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
