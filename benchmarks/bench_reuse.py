"""Paper Figs. 24/26: migration interference + reuse at eviction.

Replays a skewed serving access pattern with allocation-on-demand through
the manager (as the engine does), feeding RSW hit statistics back, and
reports (i) the reuse-level distribution of blocks when they are evicted
from the RestSeg (paper: ~0% evicted unused, >50% reused 5+) and (ii)
migration rates per kilo-access (paper: 0.8 migrations/kilo-instruction)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import HybridConfig, HybridKVManager, translate
from common import csv_row, zipf_block_stream


def run() -> list:
    cfg = HybridConfig(total_slots=96, restseg_fraction=0.5, assoc=4,
                       max_seqs=16, max_blocks_per_seq=32,
                       promote_freq_threshold=3, promote_cost_threshold=4)
    m = HybridKVManager(cfg)
    for s in range(16):
        m.register_sequence(s)
    stream = zipf_block_stream(16, 32, 12000, a=1.4, seed=3)
    n = 0
    for chunk in np.array_split(stream, 120):
        # allocation on demand (brings eviction pressure DURING serving)
        for s, b in chunk:
            if m.cfg.vpn(m.seq_slot(int(s)), int(b)) not in m.blocks:
                info = m.allocate_block(int(s), int(b))
                if info.seg == 2:
                    m.swap_in(int(s), int(b))
        m.take_pending_copies()
        ts = m.device_state()
        vpns = chunk[:, 0] * 32 + chunk[:, 1]
        res = translate(ts, jnp.asarray(vpns, jnp.int32))
        m.record_device_stats(vpns, np.asarray(res.in_rest),
                              np.asarray(res.accesses))
        m.run_promotions()
        n += len(chunk)

    hist = dict(sorted(m.reuse_histogram.items()))
    total_evicted = sum(hist.values()) or 1
    unused = hist.get(0, 0) / total_evicted
    reused5 = sum(v for k, v in hist.items() if k >= 5) / total_evicted
    migrations = (m.stats["migrations_rest_to_flex"]
                  + m.stats["migrations_flex_to_rest"])
    rows = [
        {"name": "reuse/eviction_histogram", "us": 0.0,
         "derived": (f"evicted_unused={unused:.2%} (paper ~0%) "
                     f"reused_5plus={reused5:.2%} (paper >50%) "
                     f"evictions={total_evicted}")},
        {"name": "reuse/migrations", "us": 0.0,
         "derived": (f"migrations_per_kilo_access="
                     f"{1000 * migrations / n:.2f} (paper 0.8/kI) "
                     f"copies={m.stats['copies_issued']} "
                     f"rsw_hits={m.stats['rsw_hits']} "
                     f"flex_walks={m.stats['flex_walks']} "
                     f"swaps={m.stats['swap_out']}")},
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(csv_row(r["name"], r["us"], r["derived"]))
