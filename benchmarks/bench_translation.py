"""Paper Figs. 18/19/20: translation latency & metadata traffic by scheme.

Same populated mapping, five translation backends:
  utopia (RSW ∥ flat-flex), flat block table, radix 4-level walk,
  ECH (4 parallel probes), POM-TLB (probe + radix fill path).

Reports per-translation structure accesses, metadata bytes and wall-clock
µs per batch of device translations (+ the Pallas RSW kernel path).
The paper's headline: Utopia issues ~88% fewer memory requests than radix
and RSWs are ~7.6x faster than PTWs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (HybridConfig, HybridKVManager, translate,
                        translate_radix, translate_ech, translate_pom,
                        RadixBuilder, ElasticCuckooTable, POMTLB)
from repro.kernels.utopia_rsw.ops import utopia_rsw
from common import csv_row, time_us, zipf_block_stream


def _setup(n_seqs=8, blocks=28, seed=0):
    cfg = HybridConfig(total_slots=512, restseg_fraction=0.75, assoc=8,
                       max_seqs=n_seqs, max_blocks_per_seq=32)
    m = HybridKVManager(cfg)
    radix = RadixBuilder(num_levels=4, fanout=8)
    ech = ElasticCuckooTable(capacity=256)
    pom = POMTLB(entries=128, ways=8)   # deliberately small: misses happen
    for s in range(n_seqs):
        m.register_sequence(s)
        for b in range(blocks):
            info = m.allocate_block(s, b)
            vpn = cfg.vpn(m.seq_slot(s), b)
            radix.map(vpn, info.slot)
            ech.insert(vpn, info.slot)
    stream = zipf_block_stream(n_seqs, blocks, 4096, seed=seed)
    vpns = jnp.asarray(stream[:, 0] * 32 + stream[:, 1], jnp.int32)
    return m, radix, ech, pom, vpns


def run() -> list:
    m, radix, ech, pom, vpns = _setup()
    ts = m.device_state()
    rtab = radix.device_table()
    est = ech.device_state()
    # fill POM with ~half the stream, then measure mixed hits/misses
    for v in np.asarray(vpns[:2048]):
        slot = m.blocks[int(v)].slot if int(v) in m.blocks else -1
        pom.lookup_fill(int(v), slot)
    pst = pom.device_state()
    ff = ts.flex.table.reshape(-1)

    backends = {
        "utopia": jax.jit(lambda v: translate(ts, v)),
        "flat": jax.jit(lambda v: ts.flex.lookup_vpn(v, 32)),
        "radix": jax.jit(lambda v: translate_radix(None, rtab, v)),
        "ech": jax.jit(lambda v: translate_ech(est, v)),
        "pom_tlb": jax.jit(lambda v: translate_pom(pst, rtab, v)),
        "utopia_rsw_kernel": lambda v: utopia_rsw(
            v, ts.rest.tar, ts.rest.sf, ff),
    }
    rows = []
    baseline_acc = None
    for name, fn in backends.items():
        us = time_us(fn, vpns)
        derived = f"batch={len(vpns)}"
        out = fn(vpns)
        if hasattr(out, "accesses"):
            acc = float(out.accesses.mean())
            byt = float(out.bytes_touched.mean())
            derived += f" accesses/req={acc:.2f} bytes/req={byt:.1f}"
            if name == "radix":
                baseline_acc = acc
            if name == "utopia":
                derived += f" rsw_hit={float(out.in_rest.mean()):.2%}"
        rows.append({"name": f"translation/{name}", "us": us,
                     "derived": derived})
    # headline ratio (paper: utopia issues far fewer requests than radix)
    ut = float(translate(ts, vpns).accesses.mean())
    rd = float(translate_radix(None, rtab, vpns).accesses.mean())
    rows.append({"name": "translation/access_reduction_vs_radix", "us": 0.0,
                 "derived": f"utopia={ut:.2f} radix={rd:.2f} "
                            f"reduction={1 - ut / rd:.2%} (paper: fewer "
                            f"serial accesses; 88% fewer mem requests)"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(csv_row(r["name"], r["us"], r["derived"]))
