"""Roofline report: three terms per (arch x shape x mesh) from the dry-run.

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun),
computes
    compute term    = flops_per_device / peak
    memory term     = bytes_per_device / HBM bw
    collective term = collective_bytes_per_device / ICI bw
plus MODEL_FLOPS (6·N_active·D), the useful-flops ratio, the dominant
bottleneck, and the roofline fraction (useful-compute-time / bound-time).

Usage:
    python benchmarks/roofline.py [--mesh 16x16] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from cost_model import roofline_terms, PEAK_FLOPS, HBM_BW, ICI_BW  # noqa
from repro.configs import get_config, shape_cell  # noqa

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "results", "dryrun")


def load_cells(mesh: str = None, variant: str = "baseline"):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("skipped"):
            rows.append(r)
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("perf_variant", "baseline") != variant:
            continue
        cfg = get_config(r["arch"])
        shape = shape_cell(r["shape"])
        n_chips = 512 if r["mesh"] == "2x16x16" else 256
        terms = roofline_terms(
            cfg, shape, n_chips,
            hlo_flops_per_dev=r.get("flops_per_device",
                                    r["flops_per_device_raw"]),
            hlo_bytes_per_dev=r.get("bytes_per_device",
                                    r["bytes_per_device_raw"]),
            collective_bytes_per_dev=r["collectives"][
                "collective_bytes_per_device"])
        r["roofline"] = terms
        rows.append(r)
    return rows


def fmt_table(rows, markdown=False):
    hdr = ["cell", "mesh", "compute_s", "memory_s", "memory_s_ub",
           "collective_s", "dominant", "useful_ratio", "roofline_frac",
           "hbm_fit"]
    out = []
    if markdown:
        out.append("| " + " | ".join(hdr) + " |")
        out.append("|" + "---|" * len(hdr))
    else:
        out.append(",".join(hdr))
    for r in rows:
        if r.get("skipped"):
            line = [f"{r['arch']}/{r['shape']}", r.get("mesh", "-"),
                    "SKIP", "", "", "",
                    r.get("skip_reason", "")[:40], "", "", ""]
        else:
            t = r["roofline"]
            mem = r["memory"]
            per_dev_gib = (mem["argument_bytes"] + mem["temp_bytes"]) / 2**30
            line = [f"{r['arch']}/{r['shape']}", r["mesh"],
                    f"{t['compute_s']:.3e}", f"{t['memory_s']:.3e}",
                    f"{t['memory_s_ub']:.3e}",
                    f"{t['collective_s']:.3e}", t["dominant"],
                    f"{t['useful_flops_ratio']:.2f}",
                    f"{t['roofline_fraction']:.3f}",
                    f"{per_dev_gib:.1f}GiB"]
        if markdown:
            out.append("| " + " | ".join(str(x) for x in line) + " |")
        else:
            out.append(",".join(str(x) for x in line))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load_cells(args.mesh, args.variant)
    print(fmt_table(rows, args.markdown))


if __name__ == "__main__":
    main()
