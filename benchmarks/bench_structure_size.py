"""Paper Fig. 13: translation-structure memory vs segment size.

TAR+SF (compact, restrictive) against the radix block table, the flat
table, ECH (4-way cuckoo at 0.6 occupancy) and POM-TLB, across
fully-allocated segments of increasing size.  The paper reports 81% less
memory than radix at the largest size."""
from __future__ import annotations

from repro.core import RestSegConfig, FlexSegConfig
from common import csv_row


def run() -> list:
    rows = []
    for num_blocks in (1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20):
        rs = RestSegConfig(num_slots=num_blocks, assoc=8)
        fx = FlexSegConfig(num_slots=num_blocks)
        tar_sf = rs.tar_bytes() + rs.sf_bytes()
        radix = fx.table_bytes(num_blocks)
        flat = num_blocks * 8
        ech = int(num_blocks / 0.6) * 8           # paper's 0.6 occupancy
        saving = 1 - tar_sf / radix
        rows.append({
            "name": f"structure_size/blocks={num_blocks}",
            "us": 0.0,
            "derived": (f"tar_sf={tar_sf}B radix={radix}B flat={flat}B "
                        f"ech={ech}B saving_vs_radix={saving:.2%}"),
            "saving": saving,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(csv_row(r["name"], r["us"], r["derived"]))
