"""Paper §8.3.7: overhead on non-translation-bound workloads.

A workload whose working set fits entirely in the RestSeg with zero
conflicts (the analogue of low-TLB-MPKI SPEC workloads): hybrid serving
must cost the same as flexible-only serving (paper: <0.05% loss)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, Request
from common import csv_row


def _steps_per_sec(mode: str, n_steps=8) -> float:
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, max_batch=2, max_seq_len=8 * bs, mode=mode,
                 pool_headroom=4.0,    # plenty of room: no conflicts
                 track_stats=False)    # measure the serve path, not the
                                       # host policy loop
    prompt = np.random.RandomState(0).randint(0, cfg.vocab_size, 2 * bs)
    eng.add_request(Request(seq_id=0, prompt=prompt,
                            max_new_tokens=n_steps + 1))
    eng.step()  # warm the jit
    t0 = time.perf_counter()
    for _ in range(n_steps - 1):
        eng.step()
    return (n_steps - 1) / (time.perf_counter() - t0)


def run() -> list:
    hybrid = _steps_per_sec("hybrid")
    flex = _steps_per_sec("flexible_only")
    overhead = 1 - hybrid / flex
    return [{
        "name": "non_bound/hybrid_vs_flexible", "us": 1e6 / hybrid,
        "derived": (f"hybrid={hybrid:.2f} steps/s flexible={flex:.2f} "
                    f"steps/s overhead={overhead:+.2%} (paper <0.05%)"),
    }]


if __name__ == "__main__":
    for r in run():
        print(csv_row(r["name"], r["us"], r["derived"]))
