"""Shared benchmark helpers: timing + workload generators."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List

import numpy as np


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        out = fn(*args)
    _block(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    _block(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _block(out):
    import jax
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def zipf_block_stream(n_seqs: int, blocks_per_seq: int, n_accesses: int,
                      a: float = 1.2, seed: int = 0) -> np.ndarray:
    """(seq, block) access stream with zipfian block popularity — the
    skewed reuse the paper's cost-tracking policy exploits."""
    rng = np.random.Generator(np.random.Philox(seed))
    seqs = rng.integers(0, n_seqs, n_accesses)
    blocks = (rng.zipf(a, n_accesses) - 1) % blocks_per_seq
    return np.stack([seqs, blocks], axis=1)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"
