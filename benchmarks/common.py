"""Shared benchmark helpers: timing + workload generators."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List

import numpy as np


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        out = fn(*args)
    _block(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    _block(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _block(out):
    import jax
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def zipf_block_stream(n_seqs: int, blocks_per_seq: int, n_accesses: int,
                      a: float = 1.2, seed: int = 0) -> np.ndarray:
    """(seq, block) access stream with zipfian block popularity — the
    skewed reuse the paper's cost-tracking policy exploits."""
    rng = np.random.Generator(np.random.Philox(seed))
    seqs = rng.integers(0, n_seqs, n_accesses)
    blocks = (rng.zipf(a, n_accesses) - 1) % blocks_per_seq
    return np.stack([seqs, blocks], axis=1)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"


def summarize_times(times_s, compile_s: float | None = None,
                    outlier_factor: float = 3.0) -> Dict[str, float]:
    """Separate steady state from compile events in per-step wall times.

    The engine benchmarks warm up before timing, but a timed step can
    still trigger a one-time XLA compile the warmup never reached (a
    fresh pow2 scatter-bucket shape first appearing mid-run).  A plain
    mean folds those multi-ms spikes into "steady state" — ISSUE 5's
    motivating example: BENCH_engine_step.json showed mean 50.6 ms
    against median 2.72 ms — which makes BENCH_*.json trajectories
    incomparable PR-over-PR.  This helper reports:

    * ``step_ms``        — median (the steady-state latency headline);
    * ``step_ms_mean``   — mean EXCLUDING steps slower than
      ``outlier_factor`` x median (warmup-excluded steady-state mean);
    * ``compile_spike_ms`` / ``n_compile_spikes`` — what was excluded,
      so the report stays honest about total wall time;
    * ``compile_ms``     — the measured warmup/compile phase wall, when
      the caller timed it (``compile_s``).

    The spike threshold has a timer-granularity floor (ISSUE 9 bugfix):
    under a coarse clock, sub-tick steps record as EXACTLY zero, and a
    zero median would classify every nonzero step as a compile spike —
    collapsing the "steady" set to the zero samples.  The smallest
    nonzero sample estimates one timer tick, and the threshold never
    drops below ``outlier_factor`` ticks.  When the median is positive
    the floor is inert (the smallest nonzero sample is <= the median),
    so well-resolved series summarize exactly as before.
    """
    t = np.asarray(list(times_s), np.float64)
    med = float(np.median(t))
    pos = t[t > 0]
    tick = float(pos.min()) if pos.size else 0.0
    spike = t > outlier_factor * max(med, tick)
    steady = t[~spike] if bool((~spike).any()) else t
    out = {
        "step_ms": round(med * 1e3, 3),
        "step_ms_mean": round(float(steady.mean()) * 1e3, 3),
        "compile_spike_ms": round(float(t[spike].sum()) * 1e3, 3),
        "n_compile_spikes": int(spike.sum()),
        # the steady subset itself, so derived rates (tokens/s etc.) can
        # be computed over EXACTLY the steps step_ms_mean describes
        # instead of re-deriving the filter from rounded fields
        "n_steady_steps": int(steady.size),
        "steady_wall_s": round(float(steady.sum()), 6),
    }
    if compile_s is not None:
        out["compile_ms"] = round(float(compile_s) * 1e3, 3)
    return out
