"""End-to-end driver: serve a small model with batched requests.

Continuous batching over the Utopia hybrid-translated KV pool: staggered
request admission, prefix sharing between related prompts, block
allocation/eviction/promotion live, and the manager's translation
statistics printed at the end (the serving analogue of the paper's §8
analysis).

Run:  PYTHONPATH=src python examples/serve_engine.py
"""
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, Request


def main() -> None:
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    eng = Engine(cfg, params, max_batch=4, max_seq_len=8 * bs)
    rng = np.random.RandomState(0)

    system_prompt = rng.randint(0, cfg.vocab_size, 2 * bs)
    eng.add_request(Request(seq_id=0, prompt=system_prompt,
                            max_new_tokens=12))
    # second request shares the system-prompt prefix (FlexSeg refcounts)
    eng.add_request(Request(seq_id=1, prompt=system_prompt,
                            max_new_tokens=12),
                    share_prefix_from=0, shared_blocks=1)

    t0 = time.time()
    step = 0
    admitted_third = False
    while any(not r.done for r in eng.requests.values()):
        out = eng.step()
        step += 1
        if step == 3 and not admitted_third:   # continuous batching
            prompt = rng.randint(0, cfg.vocab_size, 2 * bs)
            eng.add_request(Request(seq_id=2, prompt=prompt,
                                    max_new_tokens=8))
            admitted_third = True
        print(f"step {step:2d}: tokens={out}")
    dt = time.time() - t0

    print(f"\ngenerated in {dt:.2f}s:")
    for sid, r in sorted(eng.requests.items()):
        print(f"  seq {sid}: {r.generated}")
    st = eng.stats()
    total = st.get("rsw_hits", 0) + st.get("flex_walks", 0)
    print(f"\ntranslation stats: rsw_hits={st.get('rsw_hits', 0)} "
          f"({100 * st.get('rsw_hits', 0) / max(total, 1):.1f}%) "
          f"flex_walks={st.get('flex_walks', 0)} "
          f"shared_blocks={st.get('shared_blocks', 0)} "
          f"migrations={st.get('migrations_rest_to_flex', 0) + st.get('migrations_flex_to_rest', 0)} "
          f"swaps={st.get('swap_out', 0)}")
    for sid in list(eng.requests):
        eng.release(sid)
    eng.manager.check_invariants()
    print("released; invariants OK")


if __name__ == "__main__":
    main()
