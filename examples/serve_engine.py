"""End-to-end driver: the request-centric serving API.

Continuous batching over the Utopia hybrid-translated KV pool, driven
through the redesigned API: immutable ``Request`` submissions carry
``SamplingParams`` (greedy and sampled requests share one batch), a
pluggable Scheduler orders admission under a per-step prefill token
budget (a long prompt is CHUNKED across steps so it interleaves with
decode instead of stalling it), finished sequences auto-release so
their slots recycle, prefix sharing links related prompts (FlexSeg
refcounts), and generation is consumed as a stream of ``RequestOutput``
snapshots.  Translation statistics print at the end, both global and
attributed per request (the serving analogue of the paper's §8
analysis).

Run:  PYTHONPATH=src python examples/serve_engine.py
"""
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, EngineConfig, Request, SamplingParams


def main() -> None:
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    # budget = 2 blocks/step: the 6-block prompt below takes 3 admission
    # steps, decoding the already-live sequences in between
    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_seq_len=10 * bs, prefill_budget=2 * bs,
        auto_release=True, scheduler="fifo"))
    rng = np.random.RandomState(0)

    system_prompt = rng.randint(0, cfg.vocab_size, 2 * bs)
    eng.add_request(Request(seq_id=0, prompt=system_prompt,
                            max_new_tokens=10))
    # second request has the same prompt: the engine's automatic prefix
    # cache attaches seq 0's published blocks (FlexSeg refcounts), no
    # kwargs needed.  The legacy share_prefix_from kwargs still parse —
    # they warn once and the cache provides the equivalent dedup.  Both
    # greedy, so seq 0 and seq 1 MUST print identical token streams —
    # the quick correctness signal for this example
    eng.submit(Request(seq_id=1, prompt=system_prompt, max_new_tokens=10),
               share_prefix_from=0, shared_blocks=1)
    # long prompt: chunked over three steps under the 2-block budget
    eng.submit(Request(seq_id=2, prompt=rng.randint(0, cfg.vocab_size,
                                                    6 * bs),
                       max_new_tokens=6))
    # more requests than batch slots: admitted as soon as a slot recycles.
    # seq 4 SAMPLES at temperature 0.8 — per-slot sampling state means
    # the greedy requests sharing its batch are untouched
    eng.submit(Request(seq_id=3,
                       prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                       max_new_tokens=6))
    eng.submit(Request(seq_id=4,
                       prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                       max_new_tokens=6,
                       sampling=SamplingParams(temperature=0.8, top_k=40,
                                               seed=7)))

    t0 = time.perf_counter()   # monotonic: NTP-immune duration
    results = {}
    for out in eng.stream():
        queued = len(eng.waiting)
        tag = f" [{out.finish_reason}]" if out.finished else ""
        print(f"step {eng.step_count:2d}: seq {out.seq_id} "
              f"+{list(out.new_token_ids)}{tag} (queued={queued})")
        results[out.seq_id] = out
    dt = time.perf_counter() - t0

    print(f"\ngenerated in {dt:.2f}s over {eng.step_count} steps:")
    for sid, out in sorted(results.items()):
        print(f"  seq {sid}: {list(out.token_ids)} ({out.finish_reason})")
    st = eng.stats()
    total = st.get("rsw_hits", 0) + st.get("flex_walks", 0)
    mapped = sum(1 for i in eng.manager.blocks.values() if i.slot >= 0)
    print(f"\ntranslation stats: rsw_hits={st.get('rsw_hits', 0)} "
          f"({100 * st.get('rsw_hits', 0) / max(total, 1):.1f}%) "
          f"flex_walks={st.get('flex_walks', 0)} "
          f"shared_blocks={st.get('shared_blocks', 0)} "
          f"migrations={st.get('migrations_rest_to_flex', 0) + st.get('migrations_flex_to_rest', 0)} "
          f"swap_out={st.get('swap_out', 0)} "
          f"swap_in={st.get('swap_in', 0)} "
          f"faults={st.get('swap_in_fault', 0)} "
          f"occupancy={mapped}/{eng.hybrid_cfg.total_slots}")
    pcs = st["prefix_cache"]
    print(f"prefix cache: lookups={pcs['lookups']} hits={pcs['hits']} "
          f"dedup_blocks={pcs['dedup_blocks']} "
          f"bytes_saved={pcs['bytes_saved'] / 2**10:.0f}KiB")
    for sid, row in sorted(st["per_request"].items()):
        print(f"  seq {sid}: rsw_hits={row['rsw_hits']} "
              f"flex_walks={row['flex_walks']} "
              f"swap_faults={row['swap_faults']} "
              f"cached_blocks={row['cached_blocks']}")
    for sid in list(eng.requests):
        eng.release(sid)
    eng.manager.check_invariants()
    print("released; invariants OK")

    # ---- prefix cache: shared-system-prompt fan-out (ISSUE 8) ---------
    # N requests share one system prompt; only request 0's prefill
    # installs those blocks — everyone admitted after it attaches them
    # read-only from the content-addressed cache and forwards just its
    # own unique tail.  Fan-out streams are bit-identical to what each
    # request would produce alone (the differential suite pins this).
    print("\n--- prefix cache: 6-way shared-system-prompt fan-out ---")
    # budget = one prompt per step: request 0 publishes its blocks
    # before anyone else admits (entries are matchable from the NEXT
    # admission round), so requests 1-5 all hit
    fan = Engine(cfg, params, EngineConfig(
        max_batch=6, max_seq_len=8 * bs, pool_headroom=1.0,
        prefill_budget=4 * bs, auto_release=True))
    sys_prompt = rng.randint(0, cfg.vocab_size, 3 * bs)
    for i in range(6):
        fan.submit(Request(
            seq_id=i,
            prompt=np.concatenate(
                [sys_prompt, rng.randint(0, cfg.vocab_size, bs)]),
            max_new_tokens=6))
    for out in fan.stream():
        pass
    pcs = fan.stats()["prefix_cache"]
    fwd = sum(c.fwd_tokens for c in fan.admission_log)
    print(f"6 requests x 4-block prompts (3 shared): "
          f"hits={pcs['hits']}/{pcs['lookups']} "
          f"dedup_blocks={pcs['dedup_blocks']} "
          f"bytes_saved={pcs['bytes_saved'] / 2**10:.0f}KiB "
          f"prefill_fwd_tokens={fwd} (vs {6 * 4 * bs} cache-off)")

    # ---- speculative decoding: same API, K tokens per dispatch --------
    # A fresh engine with spec_decode="ngram": each decode dispatch
    # verifies K self-drafted tokens (prompt-lookup against the slot's
    # own history) and commits every leading match plus one bonus token.
    # LOSSLESS: the streams below are token-identical to the run above
    # whenever the request and params match — speculation only changes
    # how many steps it takes.
    print("\n--- speculative decoding (spec_decode='ngram', K=4) ---")
    spec = Engine(cfg, params, EngineConfig(
        max_batch=3, max_seq_len=10 * bs, auto_release=True,
        spec_decode="ngram", num_draft_tokens=4))
    spec.add_request(Request(seq_id=0, prompt=system_prompt,
                             max_new_tokens=10))
    for out in spec.stream():
        pass
    st = spec.stats()
    print(f"seq 0 (spec): {list(spec.finished[0].generated)}")
    print(f"steps {spec.step_count}, drafted={st['spec_drafted']} "
          f"accepted={st['spec_accepted']} (acceptance "
          f"{st['spec_accepted'] / max(st['spec_drafted'], 1):.0%})")
    assert list(spec.finished[0].generated) \
        == list(results[0].token_ids), "lossless contract violated"
    print("spec-on stream identical to spec-off: OK")

    # ---- graceful degradation under overload (ISSUE 6) ----------------
    # A pool sized for ~half the submitted work: instead of failing,
    # the engine preempts victim sequences to the host KV tier (one
    # batched swap-out of their blocks + rows) and resumes them through
    # the scheduler queue.  Streams stay bit-identical to an uncontended
    # run — the tests pin that; this demo shows the ladder working.
    print("\n--- overload: tiered KV host-offload (pool_headroom=0.5) ---")
    tight = Engine(cfg, params, EngineConfig(
        max_batch=4, max_seq_len=8 * bs, pool_headroom=0.5,
        auto_release=True))
    for i in range(8):
        tight.submit(Request(
            seq_id=i, prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
            max_new_tokens=20))
    done = sum(1 for _ in tight.stream())
    ov = tight.stats()["overload"]
    print(f"8 requests on a {tight.hybrid_cfg.total_slots}-block pool: "
          f"all finished in {tight.step_count} steps")
    print(f"preempted={ov['preempted_seqs']} resumed={ov['resumed_seqs']} "
          f"swap_out={ov['swap_bytes_out'] / 2**10:.0f}KiB "
          f"swap_in={ov['swap_bytes_in'] / 2**10:.0f}KiB "
          f"still_on_host_tier={ov['host_tier_seqs']}")
    tight.manager.check_invariants()
    print("pool invariants OK after overload drain")

    # ---- crash-safe serving: kill and recover (ISSUE 10) --------------
    # ResilientServe snapshots the COMPLETE engine state every N steps
    # (KV pool + translation tables + scheduler queue + sampling PRNGs,
    # one device_get + one pickle).  An injected step fault mid-run is
    # caught, the last snapshot restored, and the lost steps replayed —
    # the delivered streams are BIT-IDENTICAL to a run that never
    # crashed (the differential suite pins this at every step boundary).
    print("\n--- crash-safe serving: kill at step 5, recover, replay ---")
    import tempfile
    from repro.ckpt import CheckpointManager
    from repro.runtime import ResilientServe, ServeFaultInjector

    def crash_reqs(e):
        for i in range(4):
            e.submit(Request(
                seq_id=i,
                prompt=(np.asarray(system_prompt) + i) % cfg.vocab_size,
                max_new_tokens=8,
                sampling=SamplingParams(temperature=0.8, top_p=0.9,
                                        seed=100 + i)))

    ref = Engine(cfg, params, EngineConfig(
        max_batch=4, max_seq_len=8 * bs, auto_release=True))
    crash_reqs(ref)
    ref_streams = {}
    for out in ref.stream():
        ref_streams.setdefault(out.seq_id, []).extend(out.new_token_ids)

    with tempfile.TemporaryDirectory() as snapdir:
        crashy = Engine(cfg, params, EngineConfig(
            max_batch=4, max_seq_len=8 * bs, auto_release=True,
            fault_injector=ServeFaultInjector(crash_at=[(5, "pre")])))
        sup = ResilientServe(crashy, CheckpointManager(snapdir),
                             snapshot_every=3, max_restarts=3)
        crash_reqs(sup)
        got = {}
        while sup.has_unfinished():
            for out in sup.poll():
                got[out.seq_id] = list(out.token_ids)
        rec = sup.stats()["recovery"]
        print(f"crashed at step 5: restarts={rec['restarts']} "
              f"replayed_steps={rec['replayed_steps']} "
              f"snapshots={rec['snapshots']} "
              f"(every {rec['snapshot_every']} steps, persisted)")
        assert got == ref_streams, "recovered streams diverged"
        print("recovered streams bit-identical to uncrashed run: OK")
        sup.ckpt.wait()

    # deadlines and cancellation ride the same lifecycle: a request
    # past its wall-clock budget is cancelled with FULL slot/cache/
    # ledger cleanup and finishes with finish_reason="deadline"
    dl = Engine(cfg, params, EngineConfig(
        max_batch=2, max_seq_len=8 * bs, auto_release=True))
    dl.submit(Request(seq_id=0, prompt=system_prompt, max_new_tokens=50,
                      deadline_ms=1.0))
    dl.submit(Request(seq_id=1, prompt=system_prompt, max_new_tokens=4))
    reasons = {o.seq_id: o.finish_reason for o in dl.stream() if o.finished}
    dl.manager.check_invariants()
    print(f"deadline demo: finish reasons {reasons} (invariants OK)")

    # ---- SPMD serving over a real mesh (ISSUE 7) ----------------------
    # mesh_shape=(data, model) shards the KV pool and the TAR/SF/flex
    # translation structures over the model axis; each shard translates
    # once per step over its own table slice and the streams stay
    # bit-identical to the single-device run.  Needs >= 2 devices — on
    # CPU run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
    from repro.launch.mesh import make_local_mesh  # noqa: F401 (doc ref)
    if jax.device_count() >= 2:
        print("\n--- sharded serving (mesh_shape=(1, 2)) ---")
        sharded = Engine(cfg, params, EngineConfig(
            max_batch=3, max_seq_len=10 * bs, auto_release=True,
            mesh_shape=(1, 2)))
        sharded.add_request(Request(seq_id=0, prompt=system_prompt,
                                    max_new_tokens=10))
        for out in sharded.stream():
            pass
        sharded.check_invariants()
        st = sharded.stats()
        print(f"seq 0 (sharded): {list(sharded.finished[0].generated)}")
        assert list(sharded.finished[0].generated) \
            == list(results[0].token_ids), "sharded stream diverged"
        per = [(s['rsw_hits'], s['flex_walks']) for s in st['shards']]
        print(f"per-shard (rsw_hits, flex_walks): {per} "
              f"-> global ({st['rsw_hits']}, {st['flex_walks']})")
        print("sharded stream identical to single-device: OK")
    else:
        print("\n(sharded serving demo skipped: needs >= 2 devices; set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


if __name__ == "__main__":
    main()
