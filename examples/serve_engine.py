"""End-to-end driver: serve a stream of requests through the admission
scheduler.

Continuous batching over the Utopia hybrid-translated KV pool: more
requests than batch slots are submitted up front, the engine admits them
under a per-step prefill token budget (a long prompt is CHUNKED across
steps so it interleaves with decode instead of stalling it), finished
sequences auto-release so their slots recycle, prefix sharing links
related prompts (FlexSeg refcounts), and the manager's translation
statistics print at the end (the serving analogue of the paper's §8
analysis).

Run:  PYTHONPATH=src python examples/serve_engine.py
"""
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model_dims, init_params
from repro.serve import Engine, Request


def main() -> None:
    cfg = reduced(ARCHS["granite-8b"])
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    # budget = 2 blocks/step: the 6-block prompt below takes 3 admission
    # steps, decoding the already-live sequences in between
    eng = Engine(cfg, params, max_batch=3, max_seq_len=10 * bs,
                 prefill_budget=2 * bs, auto_release=True)
    rng = np.random.RandomState(0)

    system_prompt = rng.randint(0, cfg.vocab_size, 2 * bs)
    eng.add_request(Request(seq_id=0, prompt=system_prompt,
                            max_new_tokens=10))
    # second request shares the system-prompt prefix (FlexSeg refcounts)
    eng.submit(Request(seq_id=1, prompt=system_prompt, max_new_tokens=10),
               share_prefix_from=0, shared_blocks=1)
    # long prompt: chunked over three steps under the 2-block budget
    eng.submit(Request(seq_id=2, prompt=rng.randint(0, cfg.vocab_size,
                                                    6 * bs),
                       max_new_tokens=6))
    # more requests than batch slots: admitted as soon as a slot recycles
    for sid in (3, 4):
        eng.submit(Request(seq_id=sid,
                           prompt=rng.randint(0, cfg.vocab_size, 2 * bs),
                           max_new_tokens=6))

    t0 = time.time()
    step = 0
    while eng.waiting or any(not r.done for r in eng.requests.values()):
        out = eng.step()
        step += 1
        queued = len(eng.waiting)
        print(f"step {step:2d}: tokens={out} (queued={queued})")
    dt = time.time() - t0

    print(f"\ngenerated in {dt:.2f}s over {step} steps:")
    everyone = {**eng.finished, **eng.requests}
    for sid, r in sorted(everyone.items()):
        print(f"  seq {sid}: {r.generated}")
    st = eng.stats()
    total = st.get("rsw_hits", 0) + st.get("flex_walks", 0)
    print(f"\ntranslation stats: rsw_hits={st.get('rsw_hits', 0)} "
          f"({100 * st.get('rsw_hits', 0) / max(total, 1):.1f}%) "
          f"flex_walks={st.get('flex_walks', 0)} "
          f"shared_blocks={st.get('shared_blocks', 0)} "
          f"migrations={st.get('migrations_rest_to_flex', 0) + st.get('migrations_flex_to_rest', 0)} "
          f"swaps={st.get('swap_out', 0)}")
    for sid in list(eng.requests):
        eng.release(sid)
    eng.manager.check_invariants()
    print("released; invariants OK")


if __name__ == "__main__":
    main()
