"""Quickstart: the Utopia hybrid translation in 60 lines.

Builds a hybrid KV manager, allocates blocks fault-based into the RestSeg,
translates on device (RSW ∥ flexible walk), triggers conflict evictions and
cost-tracked promotions, and prints the translation statistics the paper's
figures are built from.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (HybridConfig, HybridKVManager, translate,
                        REST, FLEX)


def main() -> None:
    cfg = HybridConfig(
        block_size=64,            # tokens per KV block ("page size")
        total_slots=256,          # physical pool in blocks
        restseg_fraction=0.5,     # half set-associative, half flexible
        assoc=8,
        max_seqs=16,
        max_blocks_per_seq=32,
    )
    m = HybridKVManager(cfg)

    # --- fault-based allocation: new blocks go straight to the RestSeg ---
    for seq in range(8):
        m.register_sequence(seq)
        for block in range(24):
            m.allocate_block(seq, block)
    print(f"allocations: rest={m.stats['rest_allocs']} "
          f"flex={m.stats['flex_allocs']} "
          f"evictions={m.stats['rest_evictions']} "
          f"swap={m.stats['swap_out']}")

    # --- device-side hybrid translation (what the serve step does) -------
    ts = m.device_state()
    vpns = jnp.asarray([m.cfg.vpn(m.seq_slot(s), b)
                        for s in range(8) for b in range(24)], jnp.int32)
    res = translate(ts, vpns)
    print(f"translations: {len(vpns)}  RSW hits: {int(res.in_rest.sum())} "
          f"({100 * float(res.in_rest.mean()):.1f}%)  "
          f"avg structure accesses/translation: "
          f"{float(res.accesses.mean()):.2f}  "
          f"avg metadata bytes: {float(res.bytes_touched.mean()):.1f}")

    # --- cost-tracked promotion (PTW-Tracking analogue) -------------------
    flex_vpns = np.array([v for v, i in m.blocks.items() if i.seg == FLEX])
    if flex_vpns.size:
        for _ in range(6):   # simulate frequent costly flexible walks
            m.record_device_stats(flex_vpns,
                                  np.zeros(len(flex_vpns), bool),
                                  np.full(len(flex_vpns), 4))
        promoted = m.run_promotions()
        print(f"promoted {promoted} costly-to-translate blocks into the "
              f"RestSeg (pending data copies: {len(m.pending_copies)})")

    # --- prefix sharing needs the flexible segment ------------------------
    shared = m.share_prefix(0, 1, 4)
    print(f"shared {shared} prompt-prefix blocks between seq 0 and 1 "
          f"(restrictive slots migrate to FlexSeg on share)")
    m.check_invariants()
    print("invariants OK")


if __name__ == "__main__":
    main()
