"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the full production stack on local hardware: config system, synthetic
data pipeline, AdamW + cosine schedule, remat, async checkpointing, and
the resilient loop (checkpoint/restart).  A failure is injected mid-run to
demonstrate restart-and-replay.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import FwdOptions, model_dims
from repro.train import TrainConfig, make_train_step, init_state
from repro.data import DataConfig, SyntheticLM
from repro.ckpt import CheckpointManager
from repro.runtime import FaultInjector, ResilientLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="granite-8b",
                    help="any registry arch; dims rescaled to ~100M params")
    args = ap.parse_args()

    base = ARCHS[args.arch]
    # ~100M-param variant that trains at laptop scale
    cfg = dataclasses.replace(
        base, num_layers=min(base.num_layers, 8), d_model=640,
        num_heads=8 if base.num_heads else 0,
        num_kv_heads=min(base.num_kv_heads, 4) if base.num_kv_heads else 0,
        head_dim=64 if base.num_heads else None,
        d_ff=2560 if base.d_ff else 0, vocab_size=32768,
        moe_num_experts=min(base.moe_num_experts, 8),
        encoder_layers=2 if base.is_encoder_decoder else 0,
        frontend_tokens=16 if base.frontend != "none" else 0)
    dims = model_dims(cfg, tp=1)
    tc = TrainConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps,
                     dtype=jnp.float32)
    state = init_state(jax.random.PRNGKey(0), cfg, dims, tc)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"optimizer={cfg.optimizer}")

    step_fn = jax.jit(make_train_step(
        cfg, dims, tc, FwdOptions(attn_impl="dense", dtype=jnp.float32,
                                  remat=True)))
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=128, global_batch=8, seed=0,
        frontend_tokens=cfg.frontend_tokens, d_model=cfg.d_model))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        ckpt = CheckpointManager(ckpt_dir, keep_last=2)
        loop = ResilientLoop(
            ckpt, data, step_fn, ckpt_every=50,
            injector=FaultInjector([args.steps // 2]))  # mid-run failure
        t0 = time.time()
        report = loop.run(state, total_steps=args.steps)
        dt = time.time() - t0
    print(f"ran {report.steps_run} steps ({report.restarts} restart) in "
          f"{dt:.1f}s  loss {report.losses[0]:.3f} -> "
          f"{report.losses[-1]:.3f}")
    assert report.losses[-1] < report.losses[0]


if __name__ == "__main__":
    main()
