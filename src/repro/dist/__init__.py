"""Distribution layer: sharding rules, pipeline/tensor parallelism,
gradient compression.

* ``sharding``    — logical parameter/activation sharding rules (GSPMD),
* ``pipeline``    — GPipe schedule (reference + SPMD over a stage axis),
* ``megatron``    — hand-scheduled tensor-parallel forward (explicit
                    collectives; the GSPMD forward is the oracle),
* ``compression`` — int8 + error-feedback gradient compression for the
                    cross-pod data-parallel hop.
"""
from . import compression
from .sharding import (ShardingRules, make_pins, param_shardings, batch_spec,
                       kv_state_specs)
from .pipeline import gpipe_reference, gpipe_spmd, bubble_fraction

__all__ = [
    "compression",
    "ShardingRules", "make_pins", "param_shardings", "batch_spec",
    "kv_state_specs",
    "gpipe_reference", "gpipe_spmd", "bubble_fraction",
]
