"""Logical sharding rules for parameters and activations (GSPMD).

Two contracts live here:

* ``param_shardings`` — walks the parameter pytree and assigns each leaf a
  ``NamedSharding`` from its *logical* spec (``_logical_param_spec``): the
  model axis carries tensor parallelism (column/row-parallel linears,
  vocab-sharded embeddings, expert-sharded MoE weights) and, when
  ``zero_params`` is set, the data axes additionally shard the non-model
  dimension (ZeRO-3/FSDP).  Per-layer stacks (``lax.scan`` leading dims)
  are never sharded — logical specs are written against the unstacked leaf
  and left-padded with ``None``.

* ``make_pins`` — activation sharding constraints by *name* (the stable
  contract points threaded through models/ as ``pins(name, x)``).  Pins
  only steer layout, never numerics, so every spec passes ``_guard``:
  axes that do not divide the dimension are dropped rather than erroring.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mesh-logical axis assignment.

    ``data_axes``: mesh axes carrying data parallelism (("data",) on one
    pod, ("pod", "data") on a multipod mesh).  ``zero_params``: shard the
    non-model parameter dim over the data axes (ZeRO-3); off = pure
    replication outside the model axis (faster for small models).
    """

    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    zero_params: bool = True


def _axes_size(axes, mesh: Mesh) -> int:
    ax = (axes,) if isinstance(axes, str) else tuple(axes)
    return int(np.prod([mesh.shape[a] for a in ax]))


def _guard(spec, shape, mesh: Mesh) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim."""
    spec = tuple(spec)[:len(shape)]
    spec = spec + (None,) * (len(shape) - len(spec))
    out = []
    for dim, axes in zip(shape, spec):
        if axes is None:
            out.append(None)
        else:
            out.append(axes if dim % _axes_size(axes, mesh) == 0 else None)
    return P(*out)


def _logical_param_spec(path: Tuple[str, ...],
                        rules: ShardingRules) -> Optional[tuple]:
    """Logical spec of one (unstacked) parameter leaf; None = replicated.

    ``path`` is the tuple of dict keys down to the leaf, e.g.
    ``("layers", "attn", "q", "w")``.
    """
    D = tuple(rules.data_axes) if rules.zero_params else None
    M = rules.model_axis
    name = path[-1]

    # small / replicated leaves: norms, biases, mamba scalars
    if "norm" in name or name in ("b", "conv_b", "A_log", "D", "dt_bias"):
        return None
    if name == "table":                 # embed / lm_head: vocab over model
        return (M, D)
    if name == "w":
        parent = path[-2] if len(path) > 1 else ""
        if parent in ("q", "k", "v", "gate", "up"):   # column-parallel
            return (D, M)
        if parent in ("o", "down"):                   # row-parallel
            return (M, D)
        if parent == "cross":
            return (D, M)
        return (D, None)                # router / frontend_proj / misc
    # MoE expert stacks are raw 3D arrays (E, d_in, d_out): experts over
    # the model axis (expert parallelism), ZeRO over d_model
    if name in ("gate", "up"):
        return (M, D, None)
    if name == "down":
        return (M, None, D)
    # mamba projections
    if name in ("in_z", "in_x", "in_dt"):
        return (D, M)
    if name in ("in_B", "in_C"):
        return (D, None)
    if name == "conv_w":
        return (None, M)
    if name == "out_proj":
        return (M, D)
    return None


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_shardings(params, rules: ShardingRules, mesh: Mesh):
    """NamedSharding pytree for a parameter pytree (arrays or ShapeDtype)."""

    def leaf_sharding(path, leaf):
        spec = _logical_param_spec(_path_names(path), rules)
        if spec is None:
            return NamedSharding(mesh, P())
        # left-pad for scan-stack dims (layers / hybrid sub-stacks)
        pad = (None,) * max(0, len(leaf.shape) - len(spec))
        return NamedSharding(mesh, _guard(pad + tuple(spec), leaf.shape,
                                          mesh))

    return jax.tree_util.tree_map_with_path(leaf_sharding, params)


def batch_spec(batch, rules: ShardingRules, mesh: Mesh):
    """Batch shardings: leading dim over the data axes, rest replicated."""
    D = tuple(rules.data_axes)

    def leaf(x):
        return NamedSharding(mesh, _guard((D,), x.shape, mesh))

    return jax.tree.map(leaf, batch)


# ---------------------------------------- KV / translation state rules

def kv_state_specs(state, spec):
    """PartitionSpecs of the SPMD engine's decode state (one per key).

    The sharded serving layout (DESIGN.md §sharded-serving): the KV pool
    is slot-sharded over the model axis in the shard-contiguous physical
    numbering of ``core.partition.Partition``, the TAR/SF tables are
    set-index-partitioned and the flat flex table vpn-range-partitioned
    over the same axis; everything else — context lengths, sampling
    state, recurrent (ssm/conv) state, cross K/V, spec-decode history —
    is replicated (the compute is fully replicated; only KV *storage*
    and translation shard).  ``state`` is the decode-state dict (arrays,
    ShapeDtypeStructs or just its keys); ``spec`` a ``DecodeSpec``.

    Used three ways, which MUST agree: device placement of the state,
    the whole-step shard_map in/out specs, and the host-side delta-sync
    scatter routing.
    """
    ma = spec.model_axis
    table = {
        "k_pool": P(None, ma),          # (L, pool_slots, bs, KV, hd)
        "v_pool": P(None, ma),
        "tar": P(None, ma, None),       # (G=1, n_sets_padded, assoc)
        "sf": P(None, ma),              # (G=1, n_sets_padded)
        "flex": P(None, ma),            # (G=1, vpn_padded)
    }
    return {k: table.get(k, P()) for k in state}


# ------------------------------------------------------- activation pins

def _pin_table(rules: ShardingRules):
    D, M = tuple(rules.data_axes), rules.model_axis
    return {
        # training activations
        "act_btd": (D, None, M),      # residual stream: d sharded between
        "act_full": (D, None, None),  # gathered ONCE for q/k/v + mlp input
        "act_q": (D, None, M, None),
        "act_kv": (D, None, M, None),
        "act_ff": (D, None, M),
        "logits": (D, None, M),
        # MoE dispatch: groups over data, experts over model
        "moe_gtd": (D, None, None),
        "moe_gecd": (D, M, None, None),
        "moe_gecf": (D, M, None, None),
        "ssm_inner": (D, None, M),
        # decode step
        "dec_bd": (D, None),
        "dec_logits": (D, M),
    }


def make_pins(mesh: Mesh, rules: ShardingRules):
    """pins(name, x): with_sharding_constraint by contract-point name."""
    table = _pin_table(rules)

    def pins(name: str, x):
        spec = table.get(name)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, _guard(spec, x.shape, mesh)))

    return pins
