"""Gradient compression for the cross-pod data-parallel hop.

int8 block quantization with error feedback (EF): the quantization
residual is carried to the next step so the *sum* of transmitted gradients
tracks the sum of true gradients (1-bit-Adam-style guarantee):

    g_hat_t = Q(g_t + e_{t-1});  e_t = (g_t + e_{t-1}) - g_hat_t
    =>  sum_t g_hat_t + e_T == sum_t g_t        (exactly, per leaf)

Only the transmitted tensor is quantized — optimizer math stays fp32.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: jnp.ndarray


def init_ef(params) -> dict:
    """Per-leaf EF residuals, mirroring the parameter pytree."""
    return jax.tree.map(
        lambda p: EFState(residual=jnp.zeros(p.shape, jnp.float32)), params)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8.  Returns (q int8, scale f32 scalar)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_ef(g: jnp.ndarray, ef: EFState
                     ) -> Tuple[jnp.ndarray, EFState]:
    """One leaf: quantize (g + residual), return (g_hat, new EF state)."""
    total = g.astype(jnp.float32) + ef.residual.astype(jnp.float32)
    q, scale = quantize_int8(total)
    g_hat = dequantize_int8(q, scale)
    return g_hat.astype(g.dtype), EFState(residual=(total - g_hat))


def tree_compress_with_ef(grads, ef_tree):
    """Whole-tree EF compression; ef_tree leaves are ``EFState``."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_tree)
    out = [compress_with_ef(g, e) for g, e in zip(flat_g, flat_e)]
    g_hat = treedef.unflatten([o[0] for o in out])
    new_ef = treedef.unflatten([o[1] for o in out])
    return g_hat, new_ef
