"""Pipeline parallelism: GPipe schedule over a mesh "stage" axis.

``gpipe_reference`` is the sequential oracle (stage chain applied to every
microbatch).  ``gpipe_spmd`` runs the same computation inside a
``shard_map`` over the stage axis: at clock tick ``t`` stage ``s``
processes microbatch ``t - s`` and hands its activation to stage ``s+1``
via ``ppermute`` — the classic (n_micro + S - 1)-tick schedule whose idle
fraction is ``bubble_fraction``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """Idle fraction of the GPipe schedule: (S-1) / (n_micro + S - 1)."""
    if n_stages <= 1:
        return 0.0
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe_reference(stage_fn, params, x):
    """Sequential oracle.  params leaves are (S, ...); x is (n_micro, ...).

    Applies the S-stage chain to every microbatch.
    """
    S = jax.tree.leaves(params)[0].shape[0]

    def chain(micro):
        h = micro
        for s in range(S):
            p_s = jax.tree.map(lambda a, s=s: a[s], params)
            h = stage_fn(p_s, h)
        return h

    return jax.vmap(chain)(x)


def gpipe_spmd(stage_fn, params, x, mesh, axis: str = "stage"):
    """GPipe over ``mesh.shape[axis]`` stages.

    params leaves: (S, ...) — stage-sharded; x: (n_micro, mb, ...) —
    replicated (each stage sees all microbatch inputs but only stage 0's
    compute on them is ever consumed).  Returns (n_micro, mb, ...) outputs
    gathered from the last stage.
    """
    S = mesh.shape[axis]
    n_micro = x.shape[0]
    T = n_micro + S - 1

    def local(p, xs):
        p = jax.tree.map(lambda a: a[0], p)          # this stage's params
        sid = jax.lax.axis_index(axis)
        fwd = [(i, i + 1) for i in range(S - 1)]
        buf = jnp.zeros_like(xs[0])                  # inbound activation
        outs = jnp.zeros_like(xs)
        for t in range(T):
            inject = xs[min(t, n_micro - 1)]         # stage 0's feed
            inp = jnp.where(sid == 0, inject, buf)
            out = stage_fn(p, inp)
            mt = t - (S - 1)                         # microbatch leaving
            if 0 <= mt < n_micro:
                outs = outs.at[mt].set(
                    jnp.where(sid == S - 1, out, outs[mt]))
            if fwd:
                buf = jax.lax.ppermute(out, axis, fwd)
        # only the last stage holds real outputs; psum broadcasts them
        outs = jnp.where(sid == S - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    p_specs = jax.tree.map(lambda a: P(axis), params)
    fn = jax.shard_map(local, mesh=mesh, in_specs=(p_specs, P()),
                       out_specs=P(), check_vma=False)
    return fn(params, x)
