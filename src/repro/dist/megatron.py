"""Hand-scheduled Megatron tensor-parallel forward (dense family).

The GSPMD forward in ``repro.models`` lets the compiler place the
collectives; this module writes them out explicitly inside a
``shard_map`` — the Megatron schedule:

* vocab-sharded embedding: local masked gather + ``psum`` over the model
  axis;
* per layer: column-parallel q/k/v (heads sliced over the model axis,
  KV heads replicated when ``n_kv % TP != 0`` — the MQA case), local
  attention over the head slice, row-parallel output projection closed by
  one ``psum``; column-parallel gate/up + row-parallel down ``psum`` for
  the MLP;
* vocab-sharded unembed closed by a tiled ``all_gather``.

Numerics must match the GSPMD forward bit-for-tolerance — that equivalence
is the test (tests/test_dist.py::TestMegatronExplicit).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.attention import attention
from repro.models.transformer import ModelDims, _zero_aux

from .sharding import ShardingRules, _path_names


def _kv_sharded(dims: ModelDims, tp: int) -> bool:
    return (dims.n_kv * dims.head_dim) % tp == 0 and dims.n_kv % tp == 0


def _mega_spec(path: Tuple[str, ...], dims: ModelDims, tp: int, M: str):
    """PartitionSpec for one parameter leaf under the explicit schedule."""
    name = path[-1]
    if "norm" in name:
        return P()
    if name == "table":
        return P(M, None) if dims.vocab % tp == 0 else P()
    parent = path[-2] if len(path) > 1 else ""
    if name == "w":
        if parent == "q" or (parent in ("k", "v") and _kv_sharded(dims, tp)):
            return P(None, None, M)          # column-parallel (stacked)
        if parent in ("k", "v"):
            return P(None, None, None)       # replicated KV (MQA)
        if parent == "o":
            return P(None, M, None)          # row-parallel
        if parent in ("gate", "up"):
            return P(None, None, M)
        if parent == "down":
            return P(None, M, None)
        return P()
    if name == "b":
        if parent == "q" or (parent in ("k", "v") and _kv_sharded(dims, tp)):
            return P(None, M)
        return P()
    return P()


def megatron_param_shardings(params, mesh: Mesh, rules: ShardingRules):
    """NamedShardings matching the explicit schedule's in_specs."""
    M = rules.model_axis
    tp = mesh.shape[M]
    vocab_div = params["embed"]["table"].shape[0] % tp == 0
    kv_div = params["layers"]["attn"]["k"]["w"].shape[-1] % tp == 0

    def leaf(path, x):
        names = _path_names(path)
        name = names[-1]
        parent = names[-2] if len(names) > 1 else ""
        if name == "table":
            spec = P(M, None) if vocab_div else P()
        elif name == "w" and (parent in ("q", "gate", "up")
                              or (parent in ("k", "v") and kv_div)):
            spec = P(None, None, M)
        elif name == "w" and parent in ("o", "down"):
            spec = P(None, M, None)
        elif name == "b" and (parent == "q"
                              or (parent in ("k", "v") and kv_div)):
            spec = P(None, M)
        else:
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, params)


def make_megatron_forward(cfg: ArchConfig, dims: ModelDims, mesh: Mesh,
                          data_axes: Tuple[str, ...] = ("data",),
                          attn_impl: str = "dense",
                          triangular: bool = False, remat: bool = False,
                          model_axis: str = "model"):
    """Returns fwd(params, batch) -> (logits, aux, None) for dense models."""
    if cfg.family != "dense":
        raise ValueError("explicit megatron schedule is dense-only")
    DA = tuple(data_axes)
    M = model_axis
    tp = mesh.shape[M]
    kv_sh = _kv_sharded(dims, tp)
    vocab_sh = dims.vocab % tp == 0
    H_loc = dims.n_heads // tp
    KV_loc = dims.n_kv // tp if kv_sh else dims.n_kv

    def local(params, tokens):
        m_idx = jax.lax.axis_index(M)
        B, S = tokens.shape
        pos = jnp.arange(S)[None, :]

        # ---- vocab-sharded embedding -------------------------------------
        table = params["embed"]["table"]
        if vocab_sh:
            v_loc = table.shape[0]
            idx = tokens - m_idx * v_loc
            ok = (idx >= 0) & (idx < v_loc)
            x = jnp.take(table, jnp.clip(idx, 0, v_loc - 1), axis=0)
            x = jnp.where(ok[..., None], x, 0)
            x = jax.lax.psum(x, M)
        else:
            x = jnp.take(table, tokens, axis=0)

        def layer(x, blk):
            # attention: column-parallel qkv, row-parallel o
            h = L.rms_norm(x, blk["norm1"].astype(jnp.float32), cfg.norm_eps)
            q = L.linear(blk["attn"]["q"], h).reshape(
                B, S, H_loc, dims.head_dim)
            k = L.linear(blk["attn"]["k"], h).reshape(
                B, S, KV_loc, dims.head_dim)
            v = L.linear(blk["attn"]["v"], h).reshape(
                B, S, KV_loc, dims.head_dim)
            if cfg.rope_theta > 0:
                q = L.apply_rope(q, pos, cfg.rope_theta)
                k = L.apply_rope(k, pos, cfg.rope_theta)
            o = attention(q, k, v, impl=attn_impl, causal=True,
                          triangular_schedule=triangular)
            o = L.linear(blk["attn"]["o"], o.reshape(B, S, -1))
            x = x + jax.lax.psum(o, M)
            # MLP: column-parallel gate/up, row-parallel down
            h = L.rms_norm(x, blk["norm2"].astype(jnp.float32), cfg.norm_eps)
            p = blk["mlp"]
            ff = jax.nn.silu(L.linear(p["gate"], h)) * L.linear(p["up"], h)
            x = x + jax.lax.psum(L.linear(p["down"], ff), M)
            return x, None

        body = jax.checkpoint(layer) if remat else layer
        x, _ = jax.lax.scan(body, x, params["layers"])

        # ---- final norm + vocab-sharded unembed --------------------------
        x = L.rms_norm(x, params["final_norm"].astype(jnp.float32),
                       cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = x @ head["table"].T.astype(x.dtype)
        if vocab_sh:
            logits = jax.lax.all_gather(logits, M, axis=2, tiled=True)
        if dims.vocab > dims.logical_vocab:
            mask = jnp.arange(dims.vocab) < dims.logical_vocab
            logits = jnp.where(mask, logits,
                               jnp.asarray(-1e9, logits.dtype))
        return logits

    def param_specs(params):
        return jax.tree_util.tree_map_with_path(
            lambda path, x: _mega_spec(_path_names(path), dims, tp, M),
            params)

    def fwd(params, batch):
        tokens = batch["tokens"]
        fn = jax.shard_map(
            local, mesh=mesh,
            in_specs=(param_specs(params), P(DA, None)),
            out_specs=P(DA, None, None), check_vma=False)
        logits = fn(params, tokens)
        return logits, _zero_aux(), None

    return fwd
