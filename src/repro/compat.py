"""Compatibility shims for older jax releases.

The codebase targets the current jax API surface; some environments pin an
older jaxlib (e.g. 0.4.x) that predates three spellings we rely on:

* ``jax.sharding.AxisType``        — enum introduced with explicit sharding;
* ``jax.make_mesh(..., axis_types=...)`` — keyword added alongside it;
* ``jax.shard_map(..., check_vma=...)``  — top-level export of
  ``jax.experimental.shard_map.shard_map`` (whose flag is ``check_rep``).

``install()`` patches the missing names in place (no-ops on modern jax) so
the same source runs under both API generations.  It is invoked from
``sitecustomize.py`` (``src`` is on ``PYTHONPATH`` for every entry point in
this repo), and is idempotent.

Importing jax here is safe even for scripts that set ``XLA_FLAGS`` before
their own ``import jax``: XLA flags are consumed lazily at first backend
initialization, not at module import (verified against jaxlib 0.4.36).
"""
from __future__ import annotations

import enum
import functools
import inspect

_installed = False


def install() -> None:
    global _installed
    if _installed:
        return
    import jax
    import jax.sharding as jsh

    if not hasattr(jsh, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jsh.AxisType = AxisType

    if hasattr(jax, "make_mesh") and \
            "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _orig_make_mesh = jax.make_mesh

        @functools.wraps(_orig_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            # old jax has no axis-type concept; Auto is the only behavior
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                      **kwargs):
            if check_vma is not None:
                kwargs.setdefault("check_rep", bool(check_vma))
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)

        jax.shard_map = shard_map

    _installed = True
