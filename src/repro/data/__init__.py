from .pipeline import DataConfig, SyntheticLM, PackedFileDataset, host_slice
