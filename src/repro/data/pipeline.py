"""Deterministic, shardable token data pipeline.

* ``SyntheticLM`` — seeded zipfian token stream (self-contained; used by
  the example drivers and tests).
* ``PackedFileDataset`` — memory-mapped uint32 token file, packed into
  fixed-length rows.
* Determinism & fault tolerance: batches are a pure function of
  (seed, step), so restart-at-step-k reproduces the exact stream without
  any saved iterator state — the checkpoint only needs the step counter.
* Sharding: ``host_slice`` carves the per-host batch rows by
  (host_index, host_count), matching the DP axis layout.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    pad_id: int = -1
    frontend_tokens: int = 0
    d_model: int = 0


class SyntheticLM:
    """Batch = f(seed, step): restartable with zero iterator state."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, host_index: int = 0,
                 host_count: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = cfg.global_batch // host_count
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[0, 0, step, host_index]))
        z = rng.zipf(cfg.zipf_a, size=(rows, cfg.seq_len + 1))
        tokens = (z % (cfg.vocab_size - 1)).astype(np.int32) + 1
        batch = {"tokens": tokens[:, :-1],
                 "labels": tokens[:, 1:].astype(np.int32)}
        if cfg.frontend_tokens:
            batch["frontend"] = rng.standard_normal(
                (rows, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PackedFileDataset:
    """Flat uint32 token file -> packed (batch, seq_len+1) rows.

    Row selection is a pure function of (seed, step) over the valid window
    count, so restarts are deterministic here too.
    """

    def __init__(self, path: str, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=np.uint32, mode="r")
        self.n_windows = (len(self.tokens) - 1) // cfg.seq_len
        if self.n_windows < 1:
            raise ValueError(f"{path}: too few tokens for seq_len "
                             f"{cfg.seq_len}")

    def batch_at(self, step: int, host_index: int = 0,
                 host_count: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = cfg.global_batch // host_count
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[0, 1, step, host_index]))
        idx = rng.integers(0, self.n_windows, size=rows)
        out = np.stack([
            self.tokens[i * cfg.seq_len:(i + 1) * cfg.seq_len + 1]
            for i in idx]).astype(np.int32)
        out = np.minimum(out, cfg.vocab_size - 1)
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}


def host_slice(batch: Dict[str, np.ndarray], host_index: int,
               host_count: int) -> Dict[str, np.ndarray]:
    def sl(x):
        rows = x.shape[0] // host_count
        return x[host_index * rows:(host_index + 1) * rows]
    return {k: sl(v) for k, v in batch.items()}
