"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_linear(lr: float, warmup: int, total: int):
    def f(step):
        step = step.astype(jnp.float32)
        wu = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        decay = jnp.maximum(1.0 - (step - warmup) / jnp.maximum(
            total - warmup, 1), 0.0)
        return lr * wu * jnp.where(step < warmup, 1.0, decay)
    return f


def warmup_cosine(lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def f(step):
        step = step.astype(jnp.float32)
        wu = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0., 1.)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * wu * jnp.where(step < warmup, 1.0, cos)
    return f
