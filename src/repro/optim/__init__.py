from .optimizers import (adamw_init, adamw_update, adafactor_init,
                         adafactor_update, make_optimizer, global_norm,
                         clip_by_global_norm)
from .schedules import warmup_cosine, warmup_linear, constant

__all__ = ["adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
           "make_optimizer", "global_norm", "clip_by_global_norm",
           "warmup_cosine", "warmup_linear", "constant"]
