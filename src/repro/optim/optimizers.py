"""Optimizers: AdamW and Adafactor (factored second moment for 100B+ models).

Functional, pytree-based; optimizer state inherits the parameter sharding
(plus the ZeRO data-axis sharding), so at 256+ chips the state is fully
distributed.  Adafactor keeps a rank-1 factorization of the second moment
for >=2D tensors — the reason qwen2-72b/jamba-398b fit the v5e HBM budget
(see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def _zip_map(fn, treedef, *flats):
    outs = [fn(*leaves) for leaves in zip(*flats)]
    n_out = len(outs[0])
    return tuple(treedef.unflatten([o[i] for o in outs]) for i in range(n_out))


# ------------------------------------------------------------------- AdamW

def adamw_init(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def adamw_update(grads, state, params, step, lr, *, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        new_p = p.astype(jnp.float32) - lr * (u + weight_decay *
                                              p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    new_params, new_m, new_v = _zip_map(upd, treedef, flat_g, flat_m,
                                        flat_v, flat_p)
    return new_params, {"m": new_m, "v": new_v}


# --------------------------------------------------------------- Adafactor

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    flat_p, treedef = jax.tree.flatten(params)
    return {"v": treedef.unflatten([init(p) for p in flat_p])}


_CHUNK_THRESHOLD = 1 << 26   # elements; above this, update in chunks


def adafactor_update(grads, state, params, step, lr, *, decay=0.8,
                     eps=1e-30, clip_threshold=1.0, weight_decay=0.0):
    t = (step + 1).astype(jnp.float32)
    beta2 = 1.0 - t ** (-decay)

    def stats_and_u(g32, s):
        """Factored second-moment update + unclipped update direction."""
        g2 = g32 * g32 + eps
        if _factored(g32.shape):
            vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
            u = g32 / (jnp.sqrt(vr / denom)[..., None]
                       * jnp.sqrt(vc)[..., None, :])
            return u, {"vr": vr, "vc": vc}
        v = beta2 * s["v"] + (1 - beta2) * g2
        return g32 / jnp.sqrt(v), {"v": v}

    def upd_small(g, s, p):
        u, new_s = stats_and_u(g.astype(jnp.float32), s)
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        new_p = p.astype(jnp.float32) - lr * (u + weight_decay *
                                              p.astype(jnp.float32))
        return new_p.astype(p.dtype), new_s

    def upd_chunked(g, s, p):
        """Two sequential chunked passes over axis 0: caps the fp32 update
        temporaries at 1/n_chunks of the leaf (100B+ models would otherwise
        keep ~6 fp32 leaf-sized copies live; measured on jamba-398b)."""
        n = g.shape[0]

        def pass1(args):
            g_c, s_c = args
            u, new_s = stats_and_u(g_c.astype(jnp.float32), s_c)
            return jnp.sum(jnp.square(u)), new_s

        ss, new_s = jax.lax.map(pass1, (g, s))
        # float(): leaves can exceed int32 (29e9 elements on jamba-398b)
        rms_u = jnp.sqrt(ss.sum() / float(g.size) + 1e-12)
        scale = jnp.maximum(1.0, rms_u / clip_threshold)

        def pass2(args):
            g_c, s_c, p_c = args
            u, _ = stats_and_u(g_c.astype(jnp.float32), s_c)
            p32 = p_c.astype(jnp.float32)
            return (p32 - lr * (u / scale + weight_decay * p32)
                    ).astype(p_c.dtype)

        # pass2 re-derives u from the PRE-update stats: feed the old state
        new_p = jax.lax.map(pass2, (g, s, p))
        return new_p, new_s

    def upd(g, s, p):
        if g.size > _CHUNK_THRESHOLD and _factored(g.shape) and g.ndim >= 3:
            return upd_chunked(g, s, p)
        return upd_small(g, s, p)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    new_params, new_state = _zip_map(upd, treedef, flat_g, flat_s, flat_p)
    return new_params, {"v": new_state}


# ---------------------------------------------------------------- factory

@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable  # (grads, state, params, step, lr) -> (params, state)


def make_optimizer(name: str, **kwargs) -> Optimizer:
    if name == "adamw":
        upd = lambda g, s, p, step, lr: adamw_update(g, s, p, step, lr,
                                                     **kwargs)
        return Optimizer("adamw", adamw_init, upd)
    if name == "adafactor":
        upd = lambda g, s, p, step, lr: adafactor_update(g, s, p, step, lr,
                                                         **kwargs)
        return Optimizer("adafactor", adafactor_init, upd)
    raise ValueError(f"unknown optimizer {name!r}")
