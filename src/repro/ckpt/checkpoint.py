"""Sharded, async, atomic checkpointing with elastic restore.

Layout: <dir>/step_<n>/
    shard_<k>.npz       flat {index -> array} leaves owned by host k
    manifest.json       treedef + leaf metadata + mesh/topology record
    COMMIT              written last: a checkpoint without it is ignored

* **Async**: ``save`` snapshots device arrays to host memory synchronously
  (cheap) and writes to disk on a background thread — the train loop keeps
  stepping (overlap of I/O with compute).
* **Atomic**: the COMMIT marker makes half-written checkpoints (killed
  host) invisible to ``latest_step``; restarts fall back to the last
  complete one.
* **Elastic restore**: leaves are saved *unsharded per host shard* with
  global metadata, so a restore may target a different mesh/topology —
  arrays are re-sharded by the caller's shardings (``restore`` returns
  numpy; the launcher device_puts with the new mesh's shardings).
* Retention: ``keep_last`` checkpoints are retained, older ones pruned.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.save_count = 0

    # ------------------------------------------------------------- saving
    def save(self, step: int, state, blocking: bool = False) -> None:
        """Snapshot now; write in the background (unless blocking)."""
        flat, treedef = jax.tree.flatten(state)
        host_flat = [np.asarray(x) for x in flat]   # device -> host snapshot
        self.wait()                                  # one writer at a time

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{str(i): a for i, a in enumerate(host_flat)})
            manifest = {
                "step": step,
                "n_leaves": len(host_flat),
                "treedef": str(treedef),
                "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                           for a in host_flat],
                "time": time.time(),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "COMMIT"), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._prune()
            self.save_count += 1

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ loading
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "COMMIT")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: Optional[int] = None):
        """Returns a pytree of numpy arrays shaped like ``state_like``.

        ``state_like`` may be ShapeDtypeStructs (elastic restore onto a new
        mesh: caller device_puts with new shardings afterwards).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "shard_0.npz")) as z:
            flat = [z[str(i)] for i in range(len(z.files))]
        _, treedef = jax.tree.flatten(state_like)
        restored = jax.tree.unflatten(treedef, flat)
        # shape check against the target
        for tgt, got in zip(jax.tree.leaves(state_like), flat):
            if tuple(tgt.shape) != tuple(got.shape):
                raise ValueError(
                    f"checkpoint leaf {got.shape} != target {tgt.shape} — "
                    "elastic restore requires matching global shapes")
        return restored, step
