"""Sharded, async, atomic checkpointing with elastic restore.

Layout: <dir>/step_<n>/
    shard_<k>.npz       flat {index -> array} leaves owned by host k
    manifest.json       treedef + leaf metadata + mesh/topology record
    COMMIT              written last: a checkpoint without it is ignored

* **Async**: ``save`` snapshots device arrays to host memory synchronously
  (cheap) and writes to disk on a background thread — the train loop keeps
  stepping (overlap of I/O with compute).
* **Atomic**: every marker file (manifest, COMMIT) is written to a temp
  name and ``os.replace``-d into place, and the step directory itself is
  assembled under a ``.tmp_`` name and renamed last — a crash at ANY
  point mid-``save`` leaves either the previous committed step or an
  uncommitted temp dir that ``latest_step`` ignores, never a
  half-written step that ``restore`` trusts.
* **Self-healing restore**: a committed step whose shard is corrupt or
  truncated (torn write below the COMMIT rename, bit rot) is skipped
  with a warning and the previous committed step restored instead —
  the serving twin of the benchmark harness's skip-and-warn policy —
  rather than raising and leaving the caller unrecoverable.
* **Elastic restore**: leaves are saved *unsharded per host shard* with
  global metadata, so a restore may target a different mesh/topology —
  arrays are re-sharded by the caller's shardings (``restore`` returns
  numpy; the launcher device_puts with the new mesh's shardings).
* **Named-array checkpoints**: ``save_named``/``restore_named`` persist a
  flat ``{name: array}`` dict without a treedef or target shapes —
  entries may change shape between steps (the serving engine's pickled
  host state does), which the positional ``save``/``restore`` pair's
  shape check forbids.
* Retention: ``keep_last`` checkpoints are retained, older ones pruned.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import warnings
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _write_atomic(path: str, data: str) -> None:
    """Write ``data`` to ``path`` via a temp file + ``os.replace`` so a
    crash mid-write can never leave a truncated file under the final
    name (the manifest/COMMIT durability hole this PR closes)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.save_count = 0

    # ------------------------------------------------------------- saving
    def _write_step(self, step: int, named: Dict[str, np.ndarray],
                    extra_manifest: Dict[str, Any]) -> None:
        """Assemble step_<n> under a temp dir and rename it into place."""
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        # a stale temp dir from a previous crashed save must not leak
        # old shards into this attempt
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "shard_0.npz"), **named)
        manifest = {
            "step": step,
            "n_leaves": len(named),
            "names": list(named),
            "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                       for a in named.values()],
            "time": time.time(),
        }
        manifest.update(extra_manifest)
        _write_atomic(os.path.join(tmp, "manifest.json"),
                      json.dumps(manifest))
        _write_atomic(os.path.join(tmp, "COMMIT"), "ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()
        self.save_count += 1

    def save(self, step: int, state, blocking: bool = False) -> None:
        """Snapshot now; write in the background (unless blocking)."""
        flat, treedef = jax.tree.flatten(state)
        host_flat = [np.asarray(x) for x in flat]   # device -> host snapshot
        self.wait()                                  # one writer at a time
        named = {str(i): a for i, a in enumerate(host_flat)}
        extra = {"treedef": str(treedef)}

        if blocking:
            self._write_step(step, named, extra)
        else:
            self._thread = threading.Thread(
                target=self._write_step, args=(step, named, extra),
                daemon=True)
            self._thread.start()

    def save_named(self, step: int, arrays: Dict[str, np.ndarray],
                   blocking: bool = False) -> None:
        """Persist a flat ``{name: array}`` dict (shapes may vary between
        steps — no treedef is recorded and ``restore_named`` needs no
        target structure)."""
        host = {str(k): np.asarray(v) for k, v in arrays.items()}
        self.wait()
        if blocking:
            self._write_step(step, host, {"named": True})
        else:
            self._thread = threading.Thread(
                target=self._write_step, args=(step, host, {"named": True}),
                daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ loading
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "COMMIT")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_shard(self, step: int) -> Dict[str, np.ndarray]:
        """Read one step's shard fully into memory; raises on corruption
        (the fallback loops below catch and skip)."""
        path = os.path.join(self.dir, f"step_{step}", "shard_0.npz")
        with np.load(path) as z:
            return {k: z[k] for k in z.files}

    def _load_with_fallback(self, step: Optional[int]
                            ) -> Tuple[Dict[str, np.ndarray], int]:
        """Load ``step`` (default: latest), falling back to the previous
        committed step — with a warning naming the corrupt one — when a
        shard is truncated/corrupt.  Raises only when NO committed step
        is readable."""
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(
                f"no committed checkpoint in {self.dir}")
        if step is None:
            step = steps[-1]
        candidates = [s for s in steps if s <= step]
        if not candidates:
            raise FileNotFoundError(
                f"no committed checkpoint at or before step {step} in "
                f"{self.dir}")
        for s in reversed(candidates):
            try:
                return self._load_shard(s), s
            except (OSError, zipfile.BadZipFile, ValueError, KeyError,
                    EOFError) as e:
                warnings.warn(
                    f"checkpoint step_{s} is corrupt or truncated "
                    f"({type(e).__name__}: {e}); falling back to the "
                    "previous committed step", stacklevel=3)
        raise FileNotFoundError(
            f"every committed checkpoint at or before step {step} in "
            f"{self.dir} is corrupt")

    def restore(self, state_like, step: Optional[int] = None):
        """Returns a pytree of numpy arrays shaped like ``state_like``.

        ``state_like`` may be ShapeDtypeStructs (elastic restore onto a new
        mesh: caller device_puts with new shardings afterwards).  A
        corrupt/truncated shard under a COMMIT marker is skipped with a
        warning and the previous committed step restored instead; the
        returned step says which one actually loaded.
        """
        shard, step = self._load_with_fallback(step)
        flat = [shard[str(i)] for i in range(len(shard))]
        _, treedef = jax.tree.flatten(state_like)
        restored = jax.tree.unflatten(treedef, flat)
        # shape check against the target
        for tgt, got in zip(jax.tree.leaves(state_like), flat):
            if tuple(tgt.shape) != tuple(got.shape):
                raise ValueError(
                    f"checkpoint leaf {got.shape} != target {tgt.shape} — "
                    "elastic restore requires matching global shapes")
        return restored, step

    def restore_named(self, step: Optional[int] = None
                      ) -> Tuple[Dict[str, np.ndarray], int]:
        """Load a ``save_named`` checkpoint back as ``{name: array}``,
        with the same corrupt-shard skip-and-warn fallback."""
        return self._load_with_fallback(step)
