from .checkpoint import CheckpointManager
