"""Live serving metrics: per-step events, rolling windows, pluggable sinks.

The serving stack's headline counters — RestSeg hits, flexible walks,
pool occupancy, spec acceptance, preempt/resume traffic — were only
visible as point-in-time ``Engine.stats()`` snapshots and hand-run
``BENCH_*.json`` files.  ``MetricsLogger`` turns them into a trajectory:
the engine feeds it ONE host-side event per step (cumulative counters +
gauges), the logger differentiates the counters into per-step deltas,
maintains rolling ring-buffer windows exposing medians/p99s, and fans
every event out to pluggable sinks (a JSONL file, an in-memory list for
tests — the ``wandblog`` idiom, backend-free).

Everything here is host-side arithmetic over counters the engine already
tracks: attaching a logger performs NO device operation, perturbs no
PRNG, and token streams are bit-identical logger-on vs logger-off
(pinned in tests/test_metrics.py).

Event schema (DESIGN.md §observability):

* ``{"kind": "step", "step": n, "wall_s": w, "tokens": d, ...}`` — one
  per engine step; counter fields are DELTAS over the previous step
  (``tokens``, ``rsw_hits``, ``flex_walks``, ``swap_faults``,
  ``spec_drafted``, ``spec_accepted``, ``request_preempts``,
  ``request_resumes``, ``swap_bytes_out``, ``swap_bytes_in``,
  ``prefix_lookups``, ``prefix_hits``, ``cancelled``,
  ``deadline_expired``, per-shard
  ``shard_swap_bytes_out/in`` lists), gauge fields are point-in-time
  (``occupancy``, ``mapped_blocks``, ``pool_blocks``, ``live``,
  ``queued``, ``host_tier_seqs``).
* ``{"kind": "submit", "step": n, "seq_id": s}`` — request enqueued.
* ``{"kind": "finish", "step": n, "seq_id": s, "latency_s": t,
  "tokens": k, "finish_reason": r}`` — request finished; ``latency_s``
  is submit-to-finish on the logger's monotonic clock
  (``time.perf_counter`` — wall-clock ``time.time`` is NTP-step-prone).
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Protocol

import numpy as np

__all__ = ["MetricsSink", "MemorySink", "JsonlSink", "RollingWindow",
           "MetricsLogger", "STEP_COUNTER_KEYS"]

# counter fields of a "step" event (monotone on the engine, emitted as
# per-step deltas; the logger's ``totals`` re-integrates them, so
# ``totals[k] == Engine counters`` at every step — the agreement oracle)
STEP_COUNTER_KEYS = (
    "tokens", "rsw_hits", "flex_walks", "swap_faults",
    "spec_drafted", "spec_accepted", "request_preempts",
    "request_resumes", "swap_bytes_out", "swap_bytes_in",
    "prefix_lookups", "prefix_hits", "cancelled", "deadline_expired",
)


class MetricsSink(Protocol):
    """Where events go.  ``emit`` receives one JSON-serializable mapping
    per event; ``close`` flushes/releases whatever the sink holds."""

    def emit(self, event: Mapping[str, Any]) -> None: ...

    def close(self) -> None: ...


class MemorySink:
    """In-memory sink: events accumulate on ``.events`` (tests, demos)."""

    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: Mapping[str, Any]) -> None:
        self.events.append(dict(event))

    def close(self) -> None:
        pass


class JsonlSink:
    """Append-only JSONL file sink: one event per line, flushed per
    event so a ``tail -f`` (or a crashed run's post-mortem) sees every
    step that actually completed."""

    def __init__(self, path: str) -> None:
        self.path = path
        self._f = open(path, "a")

    def emit(self, event: Mapping[str, Any]) -> None:
        self._f.write(json.dumps(event, separators=(",", ":")) + "\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a JSONL sink file back into the event list (round-trip
    helper for tests and offline analysis)."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


class RollingWindow:
    """Fixed-capacity ring buffer over floats with order-preserving
    reads: the rolling median/percentile of the last ``capacity``
    pushes, O(capacity) per query, zero allocation per push."""

    def __init__(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError(f"window capacity must be >= 1, got "
                             f"{capacity}")
        self.capacity = capacity
        self._buf = np.zeros(capacity, np.float64)
        self._n = 0          # total pushes ever
        self._i = 0          # next write slot

    def push(self, x: float) -> None:
        self._buf[self._i] = float(x)
        self._i = (self._i + 1) % self.capacity
        self._n += 1

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def values(self) -> np.ndarray:
        """Window contents in push order (oldest first)."""
        k = len(self)
        if self._n <= self.capacity:
            return self._buf[:k].copy()
        return np.roll(self._buf, -self._i)[:k].copy()

    def median(self) -> float:
        return float(np.median(self.values())) if len(self) else 0.0

    def percentile(self, q: float) -> float:
        return (float(np.percentile(self.values(), q))
                if len(self) else 0.0)

    def sum(self) -> float:
        return float(self.values().sum()) if len(self) else 0.0


class MetricsLogger:
    """Streaming serving telemetry: per-step events in, rolling-window
    aggregates + sink fan-out.

    The engine calls ``on_submit`` / ``on_step`` / ``on_finish``
    (``EngineConfig.metrics``); drivers read ``rolling()`` /
    ``dashboard_line()`` / ``totals`` / ``request_latencies``.  The
    logger is purely observational — it never touches device state, so
    attaching it cannot change a token stream.

    ``clock`` is injectable for tests; the default is the monotonic
    ``time.perf_counter`` (request latencies must survive an NTP step).
    """

    def __init__(self, sinks: Optional[List[MetricsSink]] = None, *,
                 window: int = 128,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        self.sinks: List[MetricsSink] = list(sinks or [])
        self.window = window
        self._clock = clock
        self.n_steps = 0                      # step events seen
        self.totals: Dict[str, int] = {k: 0 for k in STEP_COUNTER_KEYS}
        self._prev: Dict[str, int] = {}       # last absolute counters
        self._prev_shard: Dict[str, List[int]] = {}
        # rolling windows over the last ``window`` step events
        self._wall = RollingWindow(window)
        self._tokens = RollingWindow(window)
        self._occ = RollingWindow(window)
        self._hits = RollingWindow(window)       # rsw_hits deltas
        self._walks = RollingWindow(window)      # flex_walks deltas
        self._drafted = RollingWindow(window)
        self._accepted = RollingWindow(window)
        self._pc_lookups = RollingWindow(window)
        self._pc_hits = RollingWindow(window)
        # request lifecycle (latency on the injected monotonic clock)
        self._submit_t: Dict[int, float] = {}
        self.request_latencies: Dict[int, float] = {}
        self.wall_s_total = 0.0

    # ------------------------------------------------------ engine-facing
    def on_submit(self, seq_id: int, step: int) -> None:
        self._submit_t[seq_id] = self._clock()
        self._emit({"kind": "submit", "step": step, "seq_id": seq_id})

    def on_finish(self, seq_id: int, step: int, tokens: int,
                  finish_reason: Optional[str]) -> None:
        t0 = self._submit_t.pop(seq_id, None)
        lat = None if t0 is None else self._clock() - t0
        if lat is not None:
            self.request_latencies[seq_id] = lat
        self._emit({"kind": "finish", "step": step, "seq_id": seq_id,
                    "latency_s": lat, "tokens": tokens,
                    "finish_reason": finish_reason})

    def on_step(self, step: int, wall_s: float,
                counters: Mapping[str, int],
                gauges: Mapping[str, Any]) -> None:
        """One engine step: ``counters`` are the engine's ABSOLUTE
        monotone counters (the logger differentiates), ``gauges`` are
        point-in-time values copied into the event verbatim."""
        event: Dict[str, Any] = {"kind": "step", "step": step,
                                 "wall_s": round(float(wall_s), 9)}
        for k in STEP_COUNTER_KEYS:
            cur = int(counters.get(k, 0))
            d = cur - self._prev.get(k, 0)
            self._prev[k] = cur
            self.totals[k] = cur
            event[k] = d
        for k, v in counters.items():
            if k in STEP_COUNTER_KEYS:
                continue
            # list-valued counters (per-shard swap bytes): elementwise
            # deltas so the event stays a per-step account
            cur_list = [int(x) for x in v]
            prev = self._prev_shard.get(k, [0] * len(cur_list))
            event[k] = [c - p for c, p in zip(cur_list, prev)]
            self._prev_shard[k] = cur_list
        event.update(gauges)
        self.n_steps += 1
        self.wall_s_total += float(wall_s)
        self._wall.push(wall_s)
        self._tokens.push(event["tokens"])
        self._occ.push(float(gauges.get("occupancy", 0.0)))
        self._hits.push(event["rsw_hits"])
        self._walks.push(event["flex_walks"])
        self._drafted.push(event["spec_drafted"])
        self._accepted.push(event["spec_accepted"])
        self._pc_lookups.push(event["prefix_lookups"])
        self._pc_hits.push(event["prefix_hits"])
        self._emit(event)

    def rebase(self, counters: Mapping[str, int]) -> None:
        """Re-anchor the delta baseline at ``counters`` without emitting
        an event.  ``Engine.restore`` calls this: a snapshot restore
        REWINDS the engine's absolute counters, and differentiating
        across the rewind would emit large negative deltas (and corrupt
        ``totals``, which must agree with ``Engine.stats()`` at every
        step).  After rebase the next ``on_step`` sees deltas relative
        to the restored state — the replayed steps are counted again,
        which is truthful: the engine really did re-execute them."""
        for k in STEP_COUNTER_KEYS:
            cur = int(counters.get(k, 0))
            self._prev[k] = cur
            self.totals[k] = cur
        for k, v in counters.items():
            if k not in STEP_COUNTER_KEYS:
                self._prev_shard[k] = [int(x) for x in v]

    # ----------------------------------------------------------- rollups
    def rolling(self) -> Dict[str, float]:
        """Rolling-window aggregates over the last ``window`` steps:
        step-latency median/p99, throughput, and the paper's headline
        rates (RestSeg hit rate, spec acceptance, prefix-cache hit
        rate), plus the latest pool occupancy."""
        wall = self._wall.sum()
        seen = self._hits.sum() + self._walks.sum()
        drafted = self._drafted.sum()
        lookups = self._pc_lookups.sum()
        occ = self._occ.values()
        return {
            "steps": self.n_steps,
            "window_steps": len(self._wall),
            "step_ms_p50": self._wall.median() * 1e3,
            "step_ms_p99": self._wall.percentile(99) * 1e3,
            "tokens_per_s": (self._tokens.sum() / wall) if wall else 0.0,
            "rsw_hit_rate": (self._hits.sum() / seen) if seen else 0.0,
            "acceptance_rate": ((self._accepted.sum() / drafted)
                                if drafted else 0.0),
            "prefix_hit_rate": ((self._pc_hits.sum() / lookups)
                                if lookups else 0.0),
            "occupancy": float(occ[-1]) if occ.size else 0.0,
        }

    def dashboard_line(self) -> str:
        """The one-line live dashboard ``launch/serve.py --metrics``
        prints every N steps."""
        r = self.rolling()
        t = self.totals
        return (f"[metrics] step {r['steps']:>5d} | "
                f"{r['tokens_per_s']:7.1f} tok/s | "
                f"p50 {r['step_ms_p50']:6.2f} ms "
                f"p99 {r['step_ms_p99']:6.2f} ms | "
                f"occ {r['occupancy']:4.0%} | "
                f"rsw {r['rsw_hit_rate']:4.0%} | "
                f"acc {r['acceptance_rate']:4.0%} | "
                f"pfx {r['prefix_hit_rate']:4.0%} | "
                f"pre {t['request_preempts']}/{t['request_resumes']} | "
                f"cxl {t['cancelled']}/{t['deadline_expired']}")

    # ------------------------------------------------------------ plumbing
    def _emit(self, event: Mapping[str, Any]) -> None:
        for s in self.sinks:
            s.emit(event)

    def close(self) -> None:
        for s in self.sinks:
            s.close()

    def __enter__(self) -> "MetricsLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
