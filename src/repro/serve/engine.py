"""Continuous-batching serving engine over the hybrid-translated KV pool.

The engine is the "operating system" of the serving stack (paper §5.6):

* admission: prefill a prompt, allocate its KV blocks fault-based (straight
  into the RestSeg), install K/V into the pool slots the manager assigned;
* steady state: every decode step (i) allocates the current block when a
  sequence crosses a block boundary, (ii) uploads the (tiny) TAR/SF deltas
  + flex table, (iii) runs the jitted serve_step, (iv) feeds translation
  stats back to the manager (PTW-cost tracking), (v) applies any pending
  slot-to-slot migrations (the DMA page copies of Fig. 16);
* prefix sharing between requests with a common prompt prefix (FlexSeg
  refcounts — the paper's inter-process page sharing);
* eviction/swap: pool exhaustion surfaces as swap events exactly as in the
  restrictive-only experiment (Fig. 9).

Single-host configuration (G = 1 data group); the SPMD decode step in
serve/decode.py is the same code the launcher shards across a pod.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import HybridConfig, HybridKVManager
from repro.models import FwdOptions, forward, model_dims
from repro.models.transformer import ModelDims
from .decode import (DecodeSpec, make_serve_step, init_decode_state,
                     make_decode_spec)


@dataclasses.dataclass
class Request:
    seq_id: int
    prompt: np.ndarray
    frontend: Optional[np.ndarray] = None
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 4,
                 max_seq_len: int = 256, pool_headroom: float = 1.25,
                 mode: str = "hybrid", attn_impl: str = "dense",
                 dtype=jnp.float32, restseg_fraction: float = 0.75,
                 track_stats: bool = True):
        self.cfg = cfg
        self.dims = model_dims(cfg, tp=1)
        self.params = params
        bs = cfg.kv_block_size
        max_blocks = max_seq_len // bs
        self.hybrid_cfg = HybridConfig(
            block_size=bs,
            total_slots=max(16, int(max_batch * max_blocks * pool_headroom)
                            // 8 * 8),
            restseg_fraction=restseg_fraction, assoc=8,
            max_seqs=max_batch, max_blocks_per_seq=max_blocks, mode=mode)
        self.track_stats = track_stats
        self.manager = HybridKVManager(self.hybrid_cfg)
        self.spec = DecodeSpec(
            block_size=bs, max_blocks_per_seq=max_blocks,
            slots_per_group=self.hybrid_cfg.total_slots,
            n_sets=self.hybrid_cfg.num_sets, assoc=self.hybrid_cfg.assoc,
            mode="batch", hash_name=self.hybrid_cfg.hash_name)
        self.dstate = init_decode_state(cfg, self.dims, self.spec,
                                        max_batch, 1, dtype=dtype)
        self.max_batch = max_batch
        self.fwd = FwdOptions(attn_impl=attn_impl, dtype=dtype,
                              collect_cache=True)
        self._serve_step = jax.jit(make_serve_step(
            cfg, self.dims, self.spec, mesh=None, dtype=dtype))
        self.requests: Dict[int, Request] = {}
        self._slot_of: Dict[int, int] = {}
        self._n_attn_layers = sum(cfg.attn_on_layer(l)
                                  for l in range(cfg.num_layers))

    # ------------------------------------------------------------ admission
    def add_request(self, req: Request,
                    share_prefix_from: Optional[int] = None,
                    shared_blocks: int = 0) -> int:
        m = self.manager
        slot = m.register_sequence(req.seq_id)
        self._slot_of[req.seq_id] = slot
        self.requests[req.seq_id] = req
        bs = self.cfg.kv_block_size
        prompt = np.asarray(req.prompt)
        S = len(prompt)
        if S % bs:
            raise ValueError(f"prompt length {S} must be a multiple of the "
                             f"KV block size {bs} (pad upstream)")
        if share_prefix_from is not None and shared_blocks:
            m.share_prefix(share_prefix_from, req.seq_id, shared_blocks)
            # drain migration copies NOW: the freed RestSeg slots may be
            # reallocated by the prefill below, and a stale deferred copy
            # would then clobber the shared slot (ordering invariant:
            # copies apply before any further pool mutation)
            self._apply_copies()

        # ---- prefill forward: logits + caches ----
        batch = {"tokens": jnp.asarray(prompt)[None, :]}
        if req.frontend is not None:
            batch["frontend"] = jnp.asarray(req.frontend)[None]
        logits, _, caches = forward(self.params, batch, self.cfg, self.dims,
                                    self.fwd)
        # ---- install attention KV blocks (vlm: includes image prefix) ----
        if self._n_attn_layers and caches.get("k") is not None:
            k = caches["k"]            # (L_attn, 1, S_total, KV, hd)
            v = caches["v"]
            S_inst = k.shape[2]
            if S_inst % bs:
                raise ValueError(f"cache length {S_inst} (prompt+prefix) "
                                 f"must divide block size {bs}")
            nblk = S_inst // bs
            k = k.reshape(k.shape[0], nblk, bs, k.shape[3], k.shape[4])
            v = v.reshape(v.shape[0], nblk, bs, v.shape[3], v.shape[4])
            slots = []
            for b in range(nblk):
                info = m.allocate_block(req.seq_id, b)
                if info.seg == 2:       # SWAP: pool exhausted
                    raise RuntimeError("pool exhausted during prefill")
                slots.append(info.slot)
            # allocation-time evictions queued copies: drain before scatter
            self._apply_copies()
            slots = jnp.asarray(slots, jnp.int32)
            self.dstate["k_pool"] = self.dstate["k_pool"].at[:, slots].set(
                k.astype(self.dstate["k_pool"].dtype))
            self.dstate["v_pool"] = self.dstate["v_pool"].at[:, slots].set(
                v.astype(self.dstate["v_pool"].dtype))
        # ---- install recurrent caches ----
        if "ssm" in caches and caches["ssm"] is not None:
            ssm = caches["ssm"]
            conv = ssm.conv if hasattr(ssm, "conv") else None
            state = ssm.state if hasattr(ssm, "state") else ssm
            st = state.reshape((-1,) + state.shape[-4:])
            cv = conv.reshape((-1,) + conv.shape[-3:])
            self.dstate["ssm"] = self.dstate["ssm"].at[:, slot].set(st[:, 0])
            self.dstate["conv"] = self.dstate["conv"].at[:, slot].set(
                cv[:, 0].astype(self.dstate["conv"].dtype))
        if self.cfg.is_encoder_decoder:
            self.dstate["cross_k"] = self.dstate["cross_k"].at[:, slot].set(
                caches["ck"][:, 0].astype(self.dstate["cross_k"].dtype))
            self.dstate["cross_v"] = self.dstate["cross_v"].at[:, slot].set(
                caches["cv"][:, 0].astype(self.dstate["cross_v"].dtype))
        ctx0 = S + (self.cfg.frontend_tokens if self.cfg.family == "vlm"
                    else 0)
        self.dstate["ctx_len"] = self.dstate["ctx_len"].at[slot].set(ctx0)
        # first generated token from prefill logits
        nxt = int(jnp.argmax(logits[0, -1]))
        req.generated.append(nxt)
        self._sync_translation()
        return slot

    # ------------------------------------------------------------- serving
    def _sync_translation(self) -> None:
        m = self.manager
        self.dstate["tar"] = jnp.asarray(m.tar)[None]
        self.dstate["sf"] = jnp.asarray(m.sf)[None]
        self.dstate["flex"] = jnp.asarray(m.flex_table.reshape(-1))[None]

    def _apply_copies(self) -> None:
        copies = self.manager.take_pending_copies()
        for src, dst in copies:
            self.dstate["k_pool"] = self.dstate["k_pool"].at[:, dst].set(
                self.dstate["k_pool"][:, src])
            self.dstate["v_pool"] = self.dstate["v_pool"].at[:, dst].set(
                self.dstate["v_pool"][:, src])

    def step(self) -> Dict[int, int]:
        """One decode step for all live sequences."""
        live = [r for r in self.requests.values() if not r.done]
        if not live:
            return {}
        m = self.manager
        bs = self.cfg.kv_block_size
        # allocate current blocks at boundaries; gather last tokens
        tokens = np.zeros(self.max_batch, np.int64)
        for r in live:
            slot = self._slot_of[r.seq_id]
            pos = int(self.dstate["ctx_len"][slot])
            if self._n_attn_layers and pos % bs == 0:
                info = m.allocate_block(r.seq_id, pos // bs)
                if info.seg == 2:
                    info = m.swap_in(r.seq_id, pos // bs)
            tokens[slot] = r.generated[-1]
        self._apply_copies()
        self._sync_translation()

        logits, self.dstate = self._serve_step(
            self.params, self.dstate, jnp.asarray(tokens))

        # feed translation stats back (PTW-cost tracking) + promotions
        if self._n_attn_layers and self.track_stats:
            from repro.core import translate
            ts = m.device_state()
            for r in live:
                slot = self._slot_of[r.seq_id]
                pos = int(self.dstate["ctx_len"][slot])
                nblk = (pos + bs - 1) // bs
                vpns = np.array([m.cfg.vpn(slot, b) for b in range(nblk)])
                res = translate(ts, jnp.asarray(vpns, jnp.int32))
                m.record_device_stats(vpns, np.asarray(res.in_rest),
                                      np.asarray(res.accesses))
            m.run_promotions()
            self._apply_copies()

        out = {}
        for r in live:
            slot = self._slot_of[r.seq_id]
            nxt = int(jnp.argmax(logits[slot]))
            r.generated.append(nxt)
            out[r.seq_id] = nxt
            if len(r.generated) >= r.max_new_tokens:
                r.done = True
        return out

    def release(self, seq_id: int) -> None:
        self.manager.free_sequence(seq_id)
        slot = self._slot_of.pop(seq_id)
        self.dstate["ctx_len"] = self.dstate["ctx_len"].at[slot].set(0)
        self.requests.pop(seq_id, None)
        self._sync_translation()

    def stats(self) -> dict:
        return dict(self.manager.stats)
