"""Continuous-batching serving engine over the hybrid-translated KV pool.

The engine is the "operating system" of the serving stack (paper §5.6):

* admission: prefill a prompt, allocate its KV blocks fault-based (straight
  into the RestSeg), install K/V into the pool slots the manager assigned;
* steady state: every decode step (i) allocates the current block when a
  sequence crosses a block boundary, (ii) scatters the *dirty deltas* of
  TAR/SF/flex to the device (only entries that changed since the last
  step), (iii) runs the jitted serve_step — which translates once and
  returns the translation telemetry as an auxiliary output, (iv) feeds
  that telemetry back to the manager (PTW-cost tracking) with no extra
  translation, (v) applies any pending slot-to-slot migrations as ONE
  batched gather/scatter (the DMA page copies of Fig. 16);
* prefix sharing between requests with a common prompt prefix (FlexSeg
  refcounts — the paper's inter-process page sharing);
* eviction/swap: pool exhaustion surfaces as swap events exactly as in the
  restrictive-only experiment (Fig. 9).

Hot-path contract (DESIGN.md §translate-once): the steady-state ``step()``
performs a BOUNDED number of host<->device transfers — at most three
dirty-delta scatters, two pool copy dispatches, the step dispatch itself,
and ONE device_get of {next tokens, ctx lengths, telemetry} — independent
of batch size, sequence count, or pending-copy count.

Single-host configuration (G = 1 data group); the SPMD decode step in
serve/decode.py is the same code the launcher shards across a pod.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import HybridConfig, HybridKVManager
from repro.models import FwdOptions, forward, model_dims
from repro.models.transformer import ModelDims
from .decode import (DecodeSpec, make_serve_step, init_decode_state,
                     make_decode_spec)


def _pad_pow2(idx: np.ndarray, fill) -> np.ndarray:
    """Pad an index vector to the next power of two (bounded set of XLA
    scatter shapes: without this every distinct dirty/copy count compiles
    a fresh executable, which dwarfs the dispatch it feeds)."""
    n = 1 << max(0, int(idx.size - 1).bit_length())
    if n == idx.size:
        return idx
    return np.concatenate([idx, np.full(n - idx.size, fill, idx.dtype)])


@dataclasses.dataclass
class Request:
    seq_id: int
    prompt: np.ndarray
    frontend: Optional[np.ndarray] = None
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 4,
                 max_seq_len: int = 256, pool_headroom: float = 1.25,
                 mode: str = "hybrid", attn_impl: str = "dense",
                 dtype=jnp.float32, restseg_fraction: float = 0.75,
                 track_stats: bool = True):
        self.cfg = cfg
        self.dims = model_dims(cfg, tp=1)
        self.params = params
        bs = cfg.kv_block_size
        max_blocks = max_seq_len // bs
        self.hybrid_cfg = HybridConfig(
            block_size=bs,
            total_slots=max(16, int(max_batch * max_blocks * pool_headroom)
                            // 8 * 8),
            restseg_fraction=restseg_fraction, assoc=8,
            max_seqs=max_batch, max_blocks_per_seq=max_blocks, mode=mode)
        self.track_stats = track_stats
        self.manager = HybridKVManager(self.hybrid_cfg)
        self.spec = DecodeSpec(
            block_size=bs, max_blocks_per_seq=max_blocks,
            slots_per_group=self.hybrid_cfg.total_slots,
            n_sets=self.hybrid_cfg.num_sets, assoc=self.hybrid_cfg.assoc,
            mode="batch", hash_name=self.hybrid_cfg.hash_name)
        self.dstate = init_decode_state(cfg, self.dims, self.spec,
                                        max_batch, 1, dtype=dtype)
        self.max_batch = max_batch
        self.fwd = FwdOptions(attn_impl=attn_impl, dtype=dtype,
                              collect_cache=True)
        self._serve_step = jax.jit(make_serve_step(
            cfg, self.dims, self.spec, mesh=None, dtype=dtype))
        self.requests: Dict[int, Request] = {}
        self._slot_of: Dict[int, int] = {}
        self._n_attn_layers = sum(cfg.attn_on_layer(l)
                                  for l in range(cfg.num_layers))
        # host mirror of ctx_len: block-boundary checks must not read the
        # device array per request (that is one D2H sync per sequence)
        self._ctx_host = np.zeros(max_batch, np.int64)
        self._synced_full = False

    # ------------------------------------------------------------ admission
    def add_request(self, req: Request,
                    share_prefix_from: Optional[int] = None,
                    shared_blocks: int = 0) -> int:
        m = self.manager
        slot = m.register_sequence(req.seq_id)
        self._slot_of[req.seq_id] = slot
        self.requests[req.seq_id] = req
        bs = self.cfg.kv_block_size
        prompt = np.asarray(req.prompt)
        S = len(prompt)
        if S % bs:
            raise ValueError(f"prompt length {S} must be a multiple of the "
                             f"KV block size {bs} (pad upstream)")
        if share_prefix_from is not None and shared_blocks:
            m.share_prefix(share_prefix_from, req.seq_id, shared_blocks)
            # drain migration copies NOW: the freed RestSeg slots may be
            # reallocated by the prefill below, and a stale deferred copy
            # would then clobber the shared slot (ordering invariant:
            # copies apply before any further pool mutation)
            self._apply_copies()

        # ---- prefill forward: logits + caches ----
        batch = {"tokens": jnp.asarray(prompt)[None, :]}
        if req.frontend is not None:
            batch["frontend"] = jnp.asarray(req.frontend)[None]
        logits, _, caches = forward(self.params, batch, self.cfg, self.dims,
                                    self.fwd)
        # ---- install attention KV blocks (vlm: includes image prefix) ----
        if self._n_attn_layers and caches.get("k") is not None:
            k = caches["k"]            # (L_attn, 1, S_total, KV, hd)
            v = caches["v"]
            S_inst = k.shape[2]
            if S_inst % bs:
                raise ValueError(f"cache length {S_inst} (prompt+prefix) "
                                 f"must divide block size {bs}")
            nblk = S_inst // bs
            k = k.reshape(k.shape[0], nblk, bs, k.shape[3], k.shape[4])
            v = v.reshape(v.shape[0], nblk, bs, v.shape[3], v.shape[4])
            slots = []
            for b in range(nblk):
                info = m.allocate_block(req.seq_id, b)
                if info.seg == 2:       # SWAP: pool exhausted
                    raise RuntimeError("pool exhausted during prefill")
                slots.append(info.slot)
            # allocation-time evictions queued copies: drain before scatter
            self._apply_copies()
            slots = jnp.asarray(slots, jnp.int32)
            self.dstate["k_pool"] = self.dstate["k_pool"].at[:, slots].set(
                k.astype(self.dstate["k_pool"].dtype))
            self.dstate["v_pool"] = self.dstate["v_pool"].at[:, slots].set(
                v.astype(self.dstate["v_pool"].dtype))
        # ---- install recurrent caches ----
        if "ssm" in caches and caches["ssm"] is not None:
            ssm = caches["ssm"]
            conv = ssm.conv if hasattr(ssm, "conv") else None
            state = ssm.state if hasattr(ssm, "state") else ssm
            st = state.reshape((-1,) + state.shape[-4:])
            cv = conv.reshape((-1,) + conv.shape[-3:])
            self.dstate["ssm"] = self.dstate["ssm"].at[:, slot].set(st[:, 0])
            self.dstate["conv"] = self.dstate["conv"].at[:, slot].set(
                cv[:, 0].astype(self.dstate["conv"].dtype))
        if self.cfg.is_encoder_decoder:
            self.dstate["cross_k"] = self.dstate["cross_k"].at[:, slot].set(
                caches["ck"][:, 0].astype(self.dstate["cross_k"].dtype))
            self.dstate["cross_v"] = self.dstate["cross_v"].at[:, slot].set(
                caches["cv"][:, 0].astype(self.dstate["cross_v"].dtype))
        ctx0 = S + (self.cfg.frontend_tokens if self.cfg.family == "vlm"
                    else 0)
        self.dstate["ctx_len"] = self.dstate["ctx_len"].at[slot].set(ctx0)
        self._ctx_host[slot] = ctx0
        # first generated token from prefill logits
        nxt = int(jnp.argmax(logits[0, -1]))
        req.generated.append(nxt)
        self._sync_translation()
        return slot

    # ------------------------------------------------------------- serving
    def _sync_translation(self, full: bool = False) -> None:
        """Upload TAR/SF/flex changes.

        First call (or ``full=True``) uploads everything; afterwards only
        the entries dirtied since the previous sync are scattered — three
        bounded-size dispatches instead of re-streaming the whole tables.
        """
        m = self.manager
        if full or not self._synced_full:
            m.take_dirty()             # everything is covered below
            self.dstate["tar"] = jnp.asarray(m.tar)[None]
            self.dstate["sf"] = jnp.asarray(m.sf)[None]
            self.dstate["flex"] = jnp.asarray(m.flex_table.reshape(-1))[None]
            self._synced_full = True
            return
        sets, flex_idx = m.take_dirty()
        if sets.size:
            # pad to pow2 with a duplicate index (same value — benign)
            sets = _pad_pow2(sets, sets[0])
            js = jnp.asarray(sets)
            self.dstate["tar"] = self.dstate["tar"].at[0, js].set(
                jnp.asarray(m.tar[sets]))
            self.dstate["sf"] = self.dstate["sf"].at[0, js].set(
                jnp.asarray(m.sf[sets]))
        if flex_idx.size:
            flex_idx = _pad_pow2(flex_idx, flex_idx[0])
            jf = jnp.asarray(flex_idx)
            self.dstate["flex"] = self.dstate["flex"].at[0, jf].set(
                jnp.asarray(m.flex_table.reshape(-1)[flex_idx]))

    def _apply_copies(self) -> None:
        """Apply pending slot migrations as ONE gather/scatter per pool.

        Chains inside a drain (a->b, b->c) are resolved host-side to the
        original source so the batched gather reads pre-copy contents with
        sequential semantics.
        """
        copies = self.manager.take_pending_copies()
        if not copies:
            return
        root: Dict[int, int] = {}
        for src, dst in copies:
            root[dst] = root.get(src, src)
        pairs = [(d, s) for d, s in root.items() if d != s]
        if not pairs:
            return
        # pad to pow2 by duplicating the first pair (duplicate scatter
        # index with the same value — benign): bounded scatter shapes
        dst = _pad_pow2(np.asarray([d for d, _ in pairs], np.int32),
                        pairs[0][0])
        src = _pad_pow2(np.asarray([s for _, s in pairs], np.int32),
                        pairs[0][1])
        dst, src = jnp.asarray(dst), jnp.asarray(src)
        for key in ("k_pool", "v_pool"):
            pool = self.dstate[key]
            self.dstate[key] = pool.at[:, dst].set(pool[:, src])

    def step(self) -> Dict[int, int]:
        """One decode step for all live sequences."""
        live = [r for r in self.requests.values() if not r.done]
        if not live:
            return {}
        m = self.manager
        bs = self.cfg.kv_block_size
        # allocate current blocks at boundaries; gather last tokens —
        # all from host state, no device reads
        tokens = np.zeros(self.max_batch, np.int64)
        for r in live:
            slot = self._slot_of[r.seq_id]
            pos = int(self._ctx_host[slot])
            if self._n_attn_layers and pos % bs == 0:
                info = m.allocate_block(r.seq_id, pos // bs)
                if info.seg == 2:
                    info = m.swap_in(r.seq_id, pos // bs)
            tokens[slot] = r.generated[-1]
        self._apply_copies()
        self._sync_translation()

        logits, self.dstate, tstats = self._serve_step(
            self.params, self.dstate, jnp.asarray(tokens))

        # ---- the step's ONE device->host fetch --------------------------
        fetch = {"next": tstats["next_token"],
                 "ctx": self.dstate["ctx_len"]}
        want_stats = self._n_attn_layers and self.track_stats
        if want_stats:
            fetch["in_rest"] = tstats["in_rest"]
            fetch["accesses"] = tstats["accesses"]
        host = jax.device_get(fetch)
        self._ctx_host[:] = host["ctx"]

        # ---- feed translation telemetry back (PTW-cost tracking) --------
        if want_stats:
            nblk = self.spec.max_blocks_per_seq
            live_mask = np.zeros(self.max_batch, bool)
            live_mask[[self._slot_of[r.seq_id] for r in live]] = True
            n_alloc = (self._ctx_host + bs - 1) // bs    # post-step blocks
            valid = (live_mask[:, None]
                     & (np.arange(nblk)[None, :] < n_alloc[:, None]))
            vpns = (np.arange(self.max_batch)[:, None] * nblk
                    + np.arange(nblk)[None, :])
            m.record_device_stats(vpns[valid],
                                  host["in_rest"][0][valid],
                                  host["accesses"][0][valid])
            m.run_promotions()
            self._apply_copies()

        out = {}
        for r in live:
            slot = self._slot_of[r.seq_id]
            nxt = int(host["next"][slot])
            r.generated.append(nxt)
            out[r.seq_id] = nxt
            if len(r.generated) >= r.max_new_tokens:
                r.done = True
        return out

    def release(self, seq_id: int) -> None:
        self.manager.free_sequence(seq_id)
        slot = self._slot_of.pop(seq_id)
        self.dstate["ctx_len"] = self.dstate["ctx_len"].at[slot].set(0)
        self._ctx_host[slot] = 0
        self.requests.pop(seq_id, None)
        self._sync_translation()

    def stats(self) -> dict:
        return dict(self.manager.stats)
