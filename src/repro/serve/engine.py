"""Continuous-batching serving engine over the hybrid-translated KV pool.

The engine is the "operating system" of the serving stack (paper §5.6):

* admission: a waiting queue plus a per-step *prefill token budget*
  (DESIGN.md §admission-scheduler).  ``submit()`` enqueues; every
  ``step()`` admits up to the budget, bucketing variable-length prompts
  into padded power-of-two length buckets (bounded compile shapes, the
  ``_pad_pow2`` trick applied to whole prompts) and installing ALL
  admitted sequences' KV blocks with one batched prefill dispatch per
  bucket.  Prompts longer than the budget are *chunked*: each step
  installs the next budget's worth of blocks, so a long prompt
  interleaves with decode instead of stalling it;
* steady state: every decode step (i) allocates the current block when a
  sequence crosses a block boundary, (ii) scatters the *dirty deltas* of
  TAR/SF/flex to the device (only entries that changed since the last
  step), (iii) runs the jitted serve_step — which translates once and
  returns the translation telemetry as an auxiliary output, (iv) feeds
  that telemetry back to the manager (PTW-cost tracking) with no extra
  translation, (v) applies any pending slot-to-slot migrations as ONE
  batched gather/scatter (the DMA page copies of Fig. 16);
* termination: a sequence finishes on its ``max_new_tokens`` budget or on
  its ``eos_token``; with ``auto_release=True`` the engine frees its
  sequence slot and KV blocks immediately (results stay readable in
  ``finished``), so slots recycle under sustained load;
* prefix sharing between requests with a common prompt prefix (FlexSeg
  refcounts — the paper's inter-process page sharing);
* eviction/swap: pool exhaustion surfaces as swap events exactly as in the
  restrictive-only experiment (Fig. 9).

Hot-path contract (DESIGN.md §translate-once): the steady-state ``step()``
performs a BOUNDED number of host<->device transfers — at most three
dirty-delta scatters, two pool copy dispatches, the step dispatch itself,
and ONE device_get — independent of batch size, sequence count, or
pending-copy count.  Admission steps add one prefill dispatch per length
bucket, but the fetch stays single: prefill first-tokens ride in the same
``device_get`` as the decode telemetry.

Single-host configuration (G = 1 data group); the SPMD prefill/decode
steps in serve/prefill.py and serve/decode.py are the same code the
launcher shards across a pod.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import HybridConfig, HybridKVManager, PoolExhausted, SWAP
from repro.models import FwdOptions, model_dims
from .decode import DecodeSpec, make_serve_step, init_decode_state
from .prefill import make_prefill_step


def _pad_pow2(idx: np.ndarray, fill) -> np.ndarray:
    """Pad an index vector to the next power of two (bounded set of XLA
    scatter shapes: without this every distinct dirty/copy count compiles
    a fresh executable, which dwarfs the dispatch it feeds)."""
    n = 1 << max(0, int(idx.size - 1).bit_length())
    if n == idx.size:
        return idx
    return np.concatenate([idx, np.full(n - idx.size, fill, idx.dtype)])


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


@dataclasses.dataclass
class Request:
    seq_id: int
    prompt: np.ndarray
    frontend: Optional[np.ndarray] = None
    max_new_tokens: int = 16
    eos_token: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 4,
                 max_seq_len: int = 256, pool_headroom: float = 1.25,
                 mode: str = "hybrid", attn_impl: str = "dense",
                 dtype=jnp.float32, restseg_fraction: float = 0.75,
                 track_stats: bool = True,
                 prefill_budget: Optional[int] = None,
                 auto_release: bool = False):
        self.cfg = cfg
        self.dims = model_dims(cfg, tp=1)
        self.params = params
        bs = cfg.kv_block_size
        max_blocks = max_seq_len // bs
        self.hybrid_cfg = HybridConfig(
            block_size=bs,
            total_slots=max(16, int(max_batch * max_blocks * pool_headroom)
                            // 8 * 8),
            restseg_fraction=restseg_fraction, assoc=8,
            max_seqs=max_batch, max_blocks_per_seq=max_blocks, mode=mode)
        self.track_stats = track_stats
        self.manager = HybridKVManager(self.hybrid_cfg)
        self.spec = DecodeSpec(
            block_size=bs, max_blocks_per_seq=max_blocks,
            slots_per_group=self.hybrid_cfg.total_slots,
            n_sets=self.hybrid_cfg.num_sets, assoc=self.hybrid_cfg.assoc,
            mode="batch", hash_name=self.hybrid_cfg.hash_name)
        self.dstate = init_decode_state(cfg, self.dims, self.spec,
                                        max_batch, 1, dtype=dtype)
        self.max_batch = max_batch
        # tokens of NEW prompt admitted per step; chunk granularity is the
        # KV block, so the effective budget is floor(budget / bs) blocks
        self.prefill_budget = (prefill_budget if prefill_budget is not None
                               else 4 * bs * max_batch)
        self.auto_release = auto_release
        self.fwd = FwdOptions(attn_impl=attn_impl, dtype=dtype,
                              collect_cache=True)
        self._serve_step = jax.jit(make_serve_step(
            cfg, self.dims, self.spec, mesh=None, dtype=dtype))
        # one jitted callable; XLA re-specializes per (bucket_B, bucket_S)
        # — both power-of-two padded, so the executable set is bounded
        self._prefill_step = jax.jit(make_prefill_step(
            cfg, self.dims, self.spec, mesh=None, fwd=self.fwd))
        self.requests: Dict[int, Request] = {}
        self.finished: Dict[int, Request] = {}
        self.waiting: Deque[Request] = deque()
        self._slot_of: Dict[int, int] = {}
        self._prefilling: Dict[int, int] = {}   # seq_id -> tokens installed
        self._share: Dict[int, Tuple[int, int]] = {}
        self._n_attn_layers = sum(cfg.attn_on_layer(l)
                                  for l in range(cfg.num_layers))
        self._has_recurrent = cfg.family in ("ssm", "hybrid")
        # host mirror of ctx_len: block-boundary checks must not read the
        # device array per request (that is one D2H sync per sequence)
        self._ctx_host = np.zeros(max_batch, np.int64)
        self._synced_full = False

    # ------------------------------------------------------------ admission
    def submit(self, req: Request, share_prefix_from: Optional[int] = None,
               shared_blocks: int = 0) -> None:
        """Enqueue a request; ``step()`` admits it under the token budget."""
        bs = self.cfg.kv_block_size
        S = len(np.asarray(req.prompt))
        if S == 0:
            raise ValueError("empty prompt: an unadmittable request would "
                             "stall the FIFO queue head forever")
        if S % bs:
            raise ValueError(f"prompt length {S} must be a multiple of the "
                             f"KV block size {bs} (pad upstream)")
        front = self._front_tokens()
        if front % bs:
            raise ValueError(f"frontend length {front} must be a multiple "
                             f"of the KV block size {bs}")
        if share_prefix_from is not None and shared_blocks:
            self._share[req.seq_id] = (share_prefix_from, shared_blocks)
        self.waiting.append(req)

    def add_request(self, req: Request,
                    share_prefix_from: Optional[int] = None,
                    shared_blocks: int = 0) -> int:
        """Legacy blocking admission: enqueue, then prefill the whole
        prompt immediately (draining anything queued ahead of it)."""
        self.submit(req, share_prefix_from, shared_blocks)
        pending = self._admit(budget=None)
        if any(r is req for r in self.waiting):   # could not even register
            raise PoolExhausted("no free sequence slot for blocking "
                                "add_request; release a sequence first")
        slot = self._slot_of[req.seq_id]   # before auto-release can free it
        host = jax.device_get({f"p{r.seq_id}": t for r, t in pending})
        for r, _ in pending:
            self._complete_prefill(r, int(host[f"p{r.seq_id}"]))
        return slot

    def _front_tokens(self) -> int:
        """Frontend tokens that occupy KV blocks (vlm image prefix; the
        audio frontend lives in the encoder, not the decoder cache)."""
        return self.cfg.frontend_tokens if self.cfg.family == "vlm" else 0

    def _admit(self, budget: Optional[int]
               ) -> List[Tuple[Request, jnp.ndarray]]:
        """Admit waiting prompts up to ``budget`` NEW tokens (None =
        unbounded), in FIFO order, chunked at KV-block granularity.

        Returns [(request, in-graph first-token array)] for every request
        whose FINAL chunk was installed this call; the caller folds the
        arrays into its single device fetch.
        """
        if not self.waiting:
            return []
        m = self.manager
        bs = self.cfg.kv_block_size
        if budget is None:
            budget = sum(len(np.asarray(r.prompt)) for r in self.waiting)
        chunks: List[Tuple[Request, int, int, bool]] = []
        while self.waiting and budget >= bs:
            req = self.waiting[0]
            if req.seq_id not in self._slot_of:
                if not m._free_seq_slots:
                    break                      # wait for a release
                slot = m.register_sequence(req.seq_id)
                self._slot_of[req.seq_id] = slot
                self.requests[req.seq_id] = req
                self._prefilling[req.seq_id] = 0
                share = self._share.pop(req.seq_id, None)
                # the source may have finished and auto-released while the
                # sharer waited in the queue: sharing is an optimization,
                # so fall back to plain (recomputed) prefill, not a crash
                if share is not None and share[0] in m._seq_ids:
                    m.share_prefix(share[0], req.seq_id, share[1])
                    # drain migration copies NOW: the freed RestSeg slots
                    # may be reallocated by the prefill below, and a stale
                    # deferred copy would then clobber the shared slot
                    self._apply_copies()
            start = self._prefilling[req.seq_id]
            total = len(np.asarray(req.prompt))
            take = min(total - start, budget // bs * bs)
            if take <= 0:
                break
            end = start + take
            budget -= take
            self._prefilling[req.seq_id] = end
            final = end == total
            chunks.append((req, start, end, final))
            if final:
                self.waiting.popleft()
            # a partial chunk leaves the request at the queue head with
            # budget < bs, ending the loop: it continues next step

        # ---- bucket by padded prefix length; one dispatch per bucket ----
        # Right padding is exact ONLY under causal attention; a recurrent
        # (SSM/conv) state integrates the pad tokens, so ssm/hybrid
        # families bucket at EXACT block-aligned lengths instead of pow2
        # (more compile shapes, but correct state installs).
        pending: List[Tuple[Request, jnp.ndarray]] = []
        buckets: Dict[int, list] = defaultdict(list)
        for ch in chunks:
            end_blk = ch[2] // bs
            s_pad = (ch[2] if self._has_recurrent
                     else bs * _next_pow2(end_blk))
            buckets[s_pad].append(ch)
        front = self._front_tokens()
        for s_pad, grp in sorted(buckets.items()):
            pending.extend(self._prefill_bucket(grp, s_pad, front))
        return pending

    def _prefill_bucket(self, grp, s_pad: int, front: int):
        """Allocate blocks and run ONE batched prefill dispatch for a
        bucket of same-padded-length chunks."""
        m = self.manager
        bs = self.cfg.kv_block_size
        B_pad = _next_pow2(len(grp))
        nblk_cache = (front + s_pad) // bs
        tokens = np.zeros((B_pad, s_pad), np.int64)
        slots = -np.ones((B_pad, nblk_cache), np.int32)
        slot_ids = np.full(B_pad, -1, np.int32)
        ctx = np.zeros(B_pad, np.int32)
        last_pos = np.zeros(B_pad, np.int32)
        frontend = None
        if self.cfg.frontend != "none":
            frontend = np.zeros((B_pad, self.cfg.frontend_tokens,
                                 self.cfg.d_model), np.float32)
        for i, (req, start, end, final) in enumerate(grp):
            prompt = np.asarray(req.prompt)
            tokens[i, :end] = prompt[:end]
            slot_ids[i] = self._slot_of[req.seq_id]
            ctx[i] = end + front
            last_pos[i] = end - 1
            if frontend is not None:
                frontend[i] = req.frontend
            # new cache blocks this chunk (the first chunk also covers the
            # frontend prefix); blocks already mapped — earlier chunks,
            # shared prefix — install nothing.  Attention-free families
            # have no KV blocks to translate (DESIGN.md
            # §Arch-applicability), so nothing is allocated either.
            if not self._n_attn_layers:
                continue
            cb0 = (front + start) // bs if start else 0
            for cb in range(cb0, (front + end) // bs):
                if m.lookup(req.seq_id, cb)[0] >= 0:
                    continue
                info = m.allocate_block(req.seq_id, cb)
                if info.seg == SWAP:
                    raise RuntimeError("pool exhausted during prefill")
                slots[i, cb] = info.slot
        # allocation-time evictions queued copies: drain before the scatter
        self._apply_copies()
        batch = {"tokens": jnp.asarray(tokens)}
        if frontend is not None:
            batch["frontend"] = jnp.asarray(frontend)
        _, self.dstate, pstats = self._prefill_step(
            self.params, self.dstate, batch, jnp.asarray(slots),
            jnp.asarray(slot_ids), jnp.asarray(ctx), jnp.asarray(last_pos))
        out = []
        for i, (req, start, end, final) in enumerate(grp):
            self._ctx_host[slot_ids[i]] = int(ctx[i])
            if final:
                out.append((req, pstats["next_token"][i]))
        return out

    def _complete_prefill(self, req: Request, nxt: int) -> None:
        self._prefilling.pop(req.seq_id, None)
        req.generated.append(nxt)
        self._maybe_finish(req, nxt)

    def _maybe_finish(self, req: Request, nxt: int) -> None:
        if req.done:
            return
        hit_eos = req.eos_token is not None and nxt == req.eos_token
        if hit_eos or len(req.generated) >= req.max_new_tokens:
            req.done = True
            if self.auto_release and req.seq_id in self._slot_of:
                self.release(req.seq_id)

    # ------------------------------------------------------------- serving
    def _sync_translation(self, full: bool = False) -> None:
        """Upload TAR/SF/flex changes.

        First call (or ``full=True``) uploads everything; afterwards only
        the entries dirtied since the previous sync are scattered — three
        bounded-size dispatches instead of re-streaming the whole tables.
        """
        m = self.manager
        if full or not self._synced_full:
            m.take_dirty()             # everything is covered below
            self.dstate["tar"] = jnp.asarray(m.tar)[None]
            self.dstate["sf"] = jnp.asarray(m.sf)[None]
            self.dstate["flex"] = jnp.asarray(m.flex_table.reshape(-1))[None]
            self._synced_full = True
            return
        sets, flex_idx = m.take_dirty()
        if sets.size:
            # pad to pow2 with a duplicate index (same value — benign)
            sets = _pad_pow2(sets, sets[0])
            js = jnp.asarray(sets)
            self.dstate["tar"] = self.dstate["tar"].at[0, js].set(
                jnp.asarray(m.tar[sets]))
            self.dstate["sf"] = self.dstate["sf"].at[0, js].set(
                jnp.asarray(m.sf[sets]))
        if flex_idx.size:
            flex_idx = _pad_pow2(flex_idx, flex_idx[0])
            jf = jnp.asarray(flex_idx)
            self.dstate["flex"] = self.dstate["flex"].at[0, jf].set(
                jnp.asarray(m.flex_table.reshape(-1)[flex_idx]))

    def _apply_copies(self) -> None:
        """Apply pending slot migrations as ONE gather/scatter per pool.

        Chains inside a drain (a->b, b->c) are resolved host-side to the
        original source so the batched gather reads pre-copy contents with
        sequential semantics.
        """
        copies = self.manager.take_pending_copies()
        if not copies:
            return
        root: Dict[int, int] = {}
        for src, dst in copies:
            root[dst] = root.get(src, src)
        pairs = [(d, s) for d, s in root.items() if d != s]
        if not pairs:
            return
        # pad to pow2 by duplicating the first pair (duplicate scatter
        # index with the same value — benign): bounded scatter shapes
        dst = _pad_pow2(np.asarray([d for d, _ in pairs], np.int32),
                        pairs[0][0])
        src = _pad_pow2(np.asarray([s for _, s in pairs], np.int32),
                        pairs[0][1])
        dst, src = jnp.asarray(dst), jnp.asarray(src)
        for key in ("k_pool", "v_pool"):
            pool = self.dstate[key]
            self.dstate[key] = pool.at[:, dst].set(pool[:, src])

    def step(self) -> Dict[int, int]:
        """One engine step: admit under the prefill budget, then decode
        all live sequences.  Returns {seq_id: token} for every sequence
        that produced a token (prefill completions AND decodes)."""
        fetch = {}
        pending = self._admit(self.prefill_budget)
        for r, tok in pending:
            fetch[f"p{r.seq_id}"] = tok
        live = [r for r in self.requests.values()
                if not r.done and r.seq_id not in self._prefilling]
        m = self.manager
        bs = self.cfg.kv_block_size
        if live:
            # allocate current blocks at boundaries; gather last tokens —
            # all from host state, no device reads
            tokens = np.zeros(self.max_batch, np.int64)
            active = np.zeros(self.max_batch, bool)
            for r in live:
                slot = self._slot_of[r.seq_id]
                active[slot] = True
                pos = int(self._ctx_host[slot])
                if self._n_attn_layers and pos % bs == 0:
                    info = m.allocate_block(r.seq_id, pos // bs)
                    if info.seg == SWAP:
                        info = m.swap_in(r.seq_id, pos // bs)
                tokens[slot] = r.generated[-1]
            self._apply_copies()
            self._sync_translation()
            # pre-step context snapshot: the telemetry mask below must
            # count the blocks that existed when the step TRANSLATED, and
            # the boundary block only if its allocation actually mapped
            ctx_pre = self._ctx_host.copy()

            logits, self.dstate, tstats = self._serve_step(
                self.params, self.dstate, jnp.asarray(tokens),
                jnp.asarray(active))

            fetch["next"] = tstats["next_token"]
            fetch["ctx"] = self.dstate["ctx_len"]
            want_stats = self._n_attn_layers and self.track_stats
            if want_stats:
                fetch["in_rest"] = tstats["in_rest"]
                fetch["accesses"] = tstats["accesses"]
                fetch["mapped"] = tstats["mapped"]

        if not fetch:
            return {}
        # ---- the step's ONE device->host fetch --------------------------
        host = jax.device_get(fetch)

        out: Dict[int, int] = {}
        if live:
            self._ctx_host[:] = host["ctx"]
            # ---- feed translation telemetry back (PTW-cost tracking) ----
            if want_stats:
                nblk = self.spec.max_blocks_per_seq
                live_mask = np.zeros(self.max_batch, bool)
                live_mask[[self._slot_of[r.seq_id] for r in live]] = True
                # pre-step block counts: blocks covering positions
                # [0, pos] — NOT the post-step ctx, whose boundary block
                # may not exist yet — further masked by the device
                # ``mapped`` flag so a failed (swapped) allocation is not
                # recorded as a flexible walk and fed to the promoter
                n_pre = np.minimum(ctx_pre // bs + 1, nblk)
                valid = (live_mask[:, None]
                         & (np.arange(nblk)[None, :] < n_pre[:, None])
                         & np.asarray(host["mapped"][0], bool))
                vpns = (np.arange(self.max_batch)[:, None] * nblk
                        + np.arange(nblk)[None, :])
                m.record_device_stats(vpns[valid],
                                      host["in_rest"][0][valid],
                                      host["accesses"][0][valid])
                m.run_promotions()
                self._apply_copies()
            for r in live:
                slot = self._slot_of[r.seq_id]
                nxt = int(host["next"][slot])
                r.generated.append(nxt)
                out[r.seq_id] = nxt
                self._maybe_finish(r, nxt)
        for r, _ in pending:
            nxt = int(host[f"p{r.seq_id}"])
            self._complete_prefill(r, nxt)
            out[r.seq_id] = nxt
        return out

    def release(self, seq_id: int) -> None:
        self.manager.free_sequence(seq_id)
        slot = self._slot_of.pop(seq_id)
        self.dstate["ctx_len"] = self.dstate["ctx_len"].at[slot].set(0)
        self._ctx_host[slot] = 0
        req = self.requests.pop(seq_id, None)
        if req is not None:
            self.finished[seq_id] = req
        self._prefilling.pop(seq_id, None)
        self._sync_translation()

    def stats(self) -> dict:
        return dict(self.manager.stats)
