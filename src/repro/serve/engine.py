"""Request-centric continuous-batching engine over the hybrid KV pool.

The engine is the "operating system" of the serving stack (paper §5.6),
fronted by a request-centric API:

* ``EngineConfig`` — immutable construction options (replaces the old
  12-kwarg constructor pile; the legacy kwargs still work through a
  deprecation shim that warns once);
* ``Request`` — an immutable submission (prompt, ``SamplingParams``,
  priority, eos/max_tokens).  All mutable per-request runtime state
  (generated tokens, done flag, per-request translation telemetry)
  lives in an engine-internal ``RequestState`` and is surfaced through
  ``RequestOutput`` snapshots from ``Engine.poll()`` / ``stream()``;
* admission — a pluggable :class:`~repro.serve.scheduler.Scheduler`
  (FIFO / shortest-prompt-first / priority-with-aging) orders waiting
  requests; the engine itself owns budgets, chunking, slot registration
  and prefix sharing.  Every ``step()`` admits up to the per-step
  prefill token budget, bucketing variable-length prompts into padded
  power-of-two length buckets (bounded compile shapes) and installing
  ALL admitted sequences' KV blocks with one batched prefill dispatch
  per bucket.  Prompts longer than the budget are *chunked* so a long
  prompt interleaves with decode instead of stalling it; chunks k > 0
  run the PREFIX-KV step (serve/prefill.py) — only the chunk's own
  tokens are forwarded, attention reads the prefix from the installed
  pool blocks and recurrent layers continue saved state, so chunk cost
  is linear in chunk length (``prefill_mode="recompute"`` keeps the
  full-re-forward path as the correctness oracle);
* sampling — per-request temperature / top-k / top-p with per-slot PRNG
  keys runs IN-GRAPH (serve/sampling.py): the engine scatters a
  request's SamplingParams into per-slot device arrays at admission and
  both jitted steps emit token ids, so the per-step fetch stays O(B)
  token ids.  Greedy (temperature 0) is the fast path, bit-identical to
  the pre-sampling engine;
* steady state: every decode step (i) allocates the current block when a
  sequence crosses a block boundary, (ii) scatters the *dirty deltas* of
  TAR/SF/flex to the device, (iii) runs the jitted serve_step — which
  translates once and returns translation telemetry as an auxiliary
  output, (iv) feeds that telemetry back to the manager globally AND
  attributed per request (``stats()["per_request"]``), (v) applies
  pending slot migrations as ONE batched gather/scatter (Fig. 16);
* speculative decoding (``spec_decode="ngram"``, serve/spec_decode.py):
  every decode dispatch verifies K self-drafted tokens and commits all
  leading matches plus one bonus token — variable-length advance,
  rejected-tail block dealloc and eos/max-token truncation rewinds are
  the engine's commit job; LOSSLESS (greedy and seeded-sampled streams
  are token-identical to spec-off) and the fetch below stays single;
* termination: ``max_new_tokens`` ("length") or ``eos_token`` ("stop");
  with ``auto_release=True`` the slot and KV blocks free immediately and
  recycle under sustained load;
* prefix sharing: an AUTOMATIC content-addressed prefix cache
  (core/prefix_cache.py, ``EngineConfig.prefix_cache``, on by default)
  hash-chains every installed prompt block into a set-associative
  directory — the paper's restrictive mapping reused as a
  content->physical map — so any later request sharing a prompt prefix
  attaches the same physical blocks read-only (FlexSeg refcounts — the
  paper's inter-process page sharing) and prefills only its tail;
  unreferenced cache entries are the cheapest reclaim rung under
  capacity pressure, and streams stay bit-identical to cache-off;
* eviction/swap: pool exhaustion surfaces as swap events exactly as in
  the restrictive-only experiment (Fig. 9);
* overload (ISSUE 6, DESIGN.md §tiered-KV-and-overload): when a KV
  block cannot be allocated the engine walks the degradation ladder —
  admit less, chunk, PREEMPT a victim sequence to the host KV tier
  (``preempt_request``: one batched gather of its blocks + recurrent /
  cross / history rows), and only rejects requests that can never run.
  Preempted requests re-enter the scheduler queue with their original
  arrival and resume bit-identically (KV restored bitwise, sampling
  keys re-derived from (seed, seq_id) and folded with absolute
  position); a ``runtime.fault.ServeFaultInjector`` can force
  allocation failures and preemptions at the step's safe points for
  chaos testing.

Both steady-state contracts survive preemption: translation still
happens once inside the dispatch, and a steady step still performs ONE
``device_get`` — ``preempt_request`` adds its own batched gather only
when a victim is actually swapped, never on the untriggered path.

Hot-path contract (DESIGN.md §translate-once): the steady-state
``step()`` performs a BOUNDED number of host<->device transfers — at
most three dirty-delta scatters, two pool copy dispatches, the step
dispatch itself, and ONE device_get — independent of batch size,
sequence count, or pending-copy count.  Admission steps add one prefill
dispatch per length bucket plus the sampling-state scatters, but the
fetch stays single: prefill first-tokens ride in the same ``device_get``
as the decode telemetry.

Single-host configuration (G = 1 data group); the SPMD prefill/decode
steps in serve/prefill.py and serve/decode.py are the same code the
launcher shards across a pod.
"""
from __future__ import annotations

import dataclasses
import functools
import pickle
import time
import warnings
from collections import defaultdict
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import (HybridConfig, HybridKVManager, Partition,
                        PoolExhausted, PrefixCache, SWAP, CHAIN_SEED,
                        block_hash_chain)
from repro.dist.sharding import kv_state_specs
from repro.models import FwdOptions, model_dims
from .decode import DecodeSpec, make_serve_step, init_decode_state
from .prefill import make_prefill_step, make_prefix_prefill_step
from .sampling import GREEDY, SamplingParams, prng_key_data
from .scheduler import Scheduler, make_scheduler
from .spec_decode import make_spec_decode_step


def _pad_pow2(idx: np.ndarray, fill) -> np.ndarray:
    """Pad an index vector to the next power of two (bounded set of XLA
    scatter shapes: without this every distinct dirty/copy count compiles
    a fresh executable, which dwarfs the dispatch it feeds)."""
    n = 1 << max(0, int(idx.size - 1).bit_length())
    if n == idx.size:
        return idx
    return np.concatenate([idx, np.full(n - idx.size, fill, idx.dtype)])


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


@jax.jit
def _scatter_delta(tar, sf, flex, sets_idx, tar_rows, sf_rows, flex_idx,
                   flex_vals):
    """Apply one dirty-delta sync as a SINGLE jitted dispatch.

    The pre-fix path issued up to three eager ``.at[].set`` calls per
    sync; under speculative decoding — where block dealloc/realloc
    dirties the tables almost every step — the per-op python dispatch
    overhead of those eager scatters dominated the verify dispatch
    itself (~5 ms/step measured on CPU).  Indices are pow2-padded by the
    caller (bounded executable set, keyed by the two pad lengths);
    out-of-bounds sentinel indices drop, so an empty side of the delta
    costs one dropped row.
    """
    tar = tar.at[0, sets_idx].set(tar_rows, mode="drop")
    sf = sf.at[0, sets_idx].set(sf_rows, mode="drop")
    flex = flex.at[0, flex_idx].set(flex_vals, mode="drop")
    return tar, sf, flex


# ------------------------------------------------------------- request API

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine construction options.

    ``scheduler`` is a policy name (``"fifo"`` / ``"spf"`` /
    ``"priority"``), a ready Scheduler instance, or a zero-arg factory.
    ``prefill_budget`` is NEW prompt tokens admitted per step (None =
    ``4 * block_size * max_batch``).
    """
    max_batch: int = 4
    max_seq_len: int = 256
    pool_headroom: float = 1.25
    mode: str = "hybrid"
    attn_impl: str = "dense"
    dtype: Any = jnp.float32
    restseg_fraction: float = 0.75
    track_stats: bool = True
    prefill_budget: Optional[int] = None
    auto_release: bool = False
    scheduler: Any = "fifo"
    # how chunks k > 0 of a budget-split prompt are prefilled:
    # "prefix_kv" forwards ONLY the chunk's tokens, attending over the
    # prefix's installed pool blocks (linear chunk cost); "recompute" is
    # the PR-2 full-prefix re-forward — the correctness oracle the
    # differential suite pins prefix_kv against
    prefill_mode: str = "prefix_kv"
    # prefix-KV pool read: "exact" (bit-identical dense gather) or
    # "paged" (Q>1 paged-attention read + online-softmax merge)
    prefix_gather: str = "exact"
    # speculative decoding (serve/spec_decode.py): None/False = off (the
    # default — spec-off is bit-identical to the pre-spec engine);
    # "ngram" (or True) = self-drafted n-gram / prompt-lookup drafter,
    # ``num_draft_tokens`` drafts verified per decode dispatch.  Greedy
    # AND seeded-sampled streams stay token-identical to spec-off
    # (lossless verification); recurrent (ssm/hybrid) families fall back
    # to non-speculative decode with a warn-once.
    spec_decode: Any = None
    num_draft_tokens: int = 4
    spec_ngram: int = 2
    # overload behaviour when a KV block cannot be allocated (ISSUE 6):
    # "preempt" (default) swaps a victim sequence out to the host tier
    # and re-admits it through the scheduler queue — poll()/stream()
    # make progress instead of raising; "fail" is the fail-fast
    # baseline: admission defers until the request's full footprint
    # fits and a decode-time miss raises PoolExhausted (it also fixes
    # the pre-overload silent corruption where a SWAP'd current block
    # dropped its KV write behind a masked w_valid)
    overload_policy: str = "preempt"
    # a runtime.fault.ServeFaultInjector (or None): forced allocation
    # failures and preemptions for the chaos suite
    fault_injector: Any = None
    # SPMD serving (DESIGN.md §sharded-serving): ``(data, model)`` builds
    # a local mesh; the KV pool and TAR/SF/flex tables shard over the
    # model axis (set-index / block-range partitioning), every step runs
    # once per shard under one shard_map, and token streams stay bitwise
    # identical to ``mesh_shape=None``.  The data axis replicates the
    # engine state (it scales compute only, so data > 1 requires no
    # state changes).  None = the single-device engine, trace-identical
    # to every pre-SPMD release.
    mesh_shape: Optional[Tuple[int, int]] = None
    # Utopia-native global prefix cache (core/prefix_cache.py): "auto"
    # (default) builds a PrefixCache whenever the configuration supports
    # it (attention KV blocks + a flexible segment; silently off
    # otherwise), True demands it (raises where unsupported), None/False
    # disables it, and a ready PrefixCache instance is used as-is.
    # Enabled, every submitted prompt automatically attaches its longest
    # cached prefix read-only and only the tail runs prefill; token
    # streams stay bit-identical to a cache-off run.
    prefix_cache: Any = "auto"
    # a serve.metrics.MetricsLogger (or None): the engine feeds it one
    # host-side event per step (counter deltas + occupancy gauges) and
    # request submit/finish lifecycle events.  Purely observational —
    # no device operation, token streams bit-identical logger-on vs
    # logger-off (pinned in tests/test_metrics.py).
    metrics: Any = None


class ChunkRecord(NamedTuple):
    """One admitted prompt chunk in ``Engine.admission_log``.

    ``fwd_tokens`` is the number of tokens actually fed through the chunk
    forward: ``end - start`` on the prefix-KV path (constant in chunk
    index — the linearity contract), ``frontend + end`` on the recompute
    path (grows with every chunk).
    """
    seq_id: int
    start: int
    end: int
    path: str          # "prefix_kv" | "recompute"
    fwd_tokens: int


@dataclasses.dataclass(frozen=True, eq=False)
class Request:
    """Immutable request submission.

    The prompt (and frontend) arrays are defensively copied and marked
    read-only at construction.  Runtime state — generated tokens, the
    done flag, finish reason, per-request telemetry — lives in the
    engine's internal ``RequestState``; consume it via the
    ``RequestOutput`` snapshots that ``Engine.poll()`` returns.  The
    ``generated`` / ``done`` properties remain readable for pre-redesign
    call sites: after submission they proxy the engine-held state.
    """
    seq_id: int
    prompt: np.ndarray
    frontend: Optional[np.ndarray] = None
    max_new_tokens: int = 16
    eos_token: Optional[int] = None
    sampling: SamplingParams = GREEDY
    priority: int = 0
    # wall-clock budget from submission, in milliseconds (None = no
    # deadline).  ``poll()`` cancels the request — wherever it is:
    # queued, mid-chunk prefill, decoding, or parked on the host tier —
    # once the budget elapses, releasing its slot, cache pins and
    # ledger claims; the stream finishes with finish_reason="deadline".
    # The clock is the monotonic ``time.perf_counter`` (NTP-immune);
    # across a snapshot/restore the REMAINING budget carries over.
    deadline_ms: Optional[float] = None

    def __post_init__(self):
        p = np.array(self.prompt, copy=True)
        p.setflags(write=False)
        object.__setattr__(self, "prompt", p)
        if self.frontend is not None:
            f = np.array(self.frontend, copy=True)
            f.setflags(write=False)
            object.__setattr__(self, "frontend", f)

    # -- compatibility views over the engine-held state ------------------
    @property
    def generated(self) -> List[int]:
        st = getattr(self, "_engine_state", None)
        return st.generated if st is not None else []

    @property
    def done(self) -> bool:
        st = getattr(self, "_engine_state", None)
        return st.done if st is not None else False


@dataclasses.dataclass
class RequestState:
    """Engine-internal mutable per-request state."""
    request: Request
    arrival: int                     # engine step at submission (aging)
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # "stop" | "length" | "cancelled" | "deadline"
    finish_reason: Optional[str] = None
    # absolute monotonic deadline (perf_counter seconds), set at submit
    # from Request.deadline_ms; snapshot/restore rebases it so only the
    # REMAINING budget survives a crash
    deadline_at: Optional[float] = None
    new_tokens: List[int] = dataclasses.field(default_factory=list)
    finish_reported: bool = False
    # per-request translation telemetry (stats()["per_request"])
    rsw_hits: int = 0
    flex_walks: int = 0
    swap_faults: int = 0
    # speculative-decode telemetry: drafts proposed for / accepted into
    # this request's stream (rows sum exactly to the engine's global
    # spec_drafted / spec_accepted counters)
    drafted: int = 0
    accepted: int = 0
    # prefix-cache hits: blocks attached from the cache at admission
    # (rows sum exactly to the global dedup_blocks counter)
    cached_blocks: int = 0
    # overload bookkeeping: step of the latest commit (the LRU key for
    # victim selection) and how often this request was preempted /
    # resumed.  The aggregates in stats()["overload"] are SEPARATE
    # monotone engine counters, not sums over these rows: a finished
    # request's row is dropped on seq_id reuse, and a global that
    # summed rows would silently shrink (rows + dropped == global is
    # the pinned invariant).
    last_step: int = 0
    preempts: int = 0
    resumes: int = 0


@dataclasses.dataclass
class _HostTierSeq:
    """One preempted sequence parked in host memory (the KV tier).

    Everything the sequence needs to continue bit-identically: the pool
    blocks it had mapped (``kv`` stacked as (2=k/v, L_attn, n_blocks,
    block, KV, hd) in pool dtype — a bitwise round-trip), its per-slot
    recurrent/cross-attention rows, the spec-decode history row, the
    committed context length and — for a mid-prefill victim — how many
    prompt tokens were installed.  Sampling state needs no save: per-slot
    PRNG keys derive from (seed, seq_id) and fold the absolute position,
    so they are re-scattered on resume (PR-3 invariant)."""
    seq_id: int
    ctx: int
    prefill_progress: Optional[int]     # tokens installed, None = done
    blocks: List[Tuple[int, bool]]      # (block_idx, writable) at preempt
    kv: Optional[np.ndarray]
    rows: Dict[str, np.ndarray]         # ssm/conv/cross_k/cross_v/hist
    nbytes: int


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """Streaming snapshot for one request, drained by ``Engine.poll()``.

    ``new_token_ids`` — tokens produced since the previous poll;
    ``token_ids`` — all tokens generated so far; ``finish_reason`` —
    ``"stop"`` (eos), ``"length"`` (max_new_tokens), ``"cancelled"``
    (``Engine.cancel``) or ``"deadline"`` (``Request.deadline_ms``
    elapsed) once finished.
    """
    seq_id: int
    new_token_ids: Tuple[int, ...]
    token_ids: Tuple[int, ...]
    finished: bool
    finish_reason: Optional[str]


# ------------------------------------------------------- crash-safe snapshot

SNAPSHOT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class EngineSnapshot:
    """Complete serving state at a step boundary (DESIGN.md
    §crash-recovery).

    ``dstate`` holds HOST (numpy) copies of every decode-state device
    array EXCEPT the TAR/SF/flex translation mirrors — the host tables
    inside ``host_blob`` are authoritative for those, and
    ``Engine.restore`` rebuilds the device mirrors through the existing
    full-sync path.  ``host_blob`` pickles the whole host side in one
    dump (manager + prefix cache + scheduler + request states + the
    host KV tier + monotone counters), so shared references — the
    cache's manager pointer, a ``Request`` reachable from both the
    scheduler queue and ``_states`` — survive as the SAME object on
    restore.

    ``to_arrays``/``from_arrays`` flatten to a ``{name: ndarray}`` dict
    for ``ckpt.CheckpointManager.save_named`` (the host blob's length
    varies per snapshot, which the positional checkpoint API's shape
    check forbids).
    """
    version: int
    step: int
    dstate: Dict[str, np.ndarray]
    host_blob: bytes

    def to_arrays(self) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {
            "meta": np.asarray([self.version, self.step], np.int64),
            "host": np.frombuffer(self.host_blob, np.uint8),
        }
        for k, v in self.dstate.items():
            out[f"d.{k}"] = np.asarray(v)
        return out

    @classmethod
    def from_arrays(cls, arrays: Dict[str, np.ndarray]
                    ) -> "EngineSnapshot":
        meta = np.asarray(arrays["meta"])
        return cls(
            version=int(meta[0]), step=int(meta[1]),
            dstate={k[2:]: np.asarray(v) for k, v in arrays.items()
                    if k.startswith("d.")},
            host_blob=np.asarray(arrays["host"]).tobytes())


_LEGACY_KWARGS_WARNED = False
_SPEC_FALLBACK_WARNED = False
_SHARE_KWARG_WARNED = False


def _warn_share_kwarg() -> None:
    global _SHARE_KWARG_WARNED
    if _SHARE_KWARG_WARNED:
        return
    _SHARE_KWARG_WARNED = True
    warnings.warn(
        "submit(share_prefix_from=..., shared_blocks=...) is deprecated: "
        "the engine's content-addressed prefix cache "
        "(EngineConfig.prefix_cache, on by default) dedupes shared "
        "prompt prefixes automatically — the kwargs are accepted but "
        "the cache decides what is shared; with the cache disabled the "
        "prompt is simply recomputed (sharing was always best-effort)",
        DeprecationWarning, stacklevel=3)


def _warn_spec_fallback(family: str) -> None:
    global _SPEC_FALLBACK_WARNED
    if _SPEC_FALLBACK_WARNED:
        return
    _SPEC_FALLBACK_WARNED = True
    warnings.warn(
        f"speculative decoding is not supported for recurrent family "
        f"{family!r} (ssm/conv state rollback for rejected drafts is "
        "not cheap — ROADMAP item); falling back to non-speculative "
        "decode", stacklevel=3)


def _warn_legacy_kwargs(kwargs) -> None:
    global _LEGACY_KWARGS_WARNED
    if _LEGACY_KWARGS_WARNED:
        return
    _LEGACY_KWARGS_WARNED = True
    warnings.warn(
        f"Engine(cfg, params, {', '.join(sorted(kwargs))}=...) kwargs are "
        "deprecated; pass Engine(cfg, params, EngineConfig(...)) instead",
        DeprecationWarning, stacklevel=3)


# ------------------------------------------------------------------ engine

class Engine:
    def __init__(self, cfg: ArchConfig, params,
                 config: Optional[EngineConfig] = None, **legacy):
        if legacy:
            if config is not None:
                raise TypeError("pass an EngineConfig OR legacy kwargs, "
                                "not both")
            known = {f.name for f in dataclasses.fields(EngineConfig)}
            unknown = set(legacy) - known
            if unknown:
                raise TypeError(f"unknown Engine kwargs {sorted(unknown)}")
            _warn_legacy_kwargs(legacy)
            config = EngineConfig(**legacy)
        elif config is None:
            config = EngineConfig()
        self.config = config
        self.cfg = cfg
        self.dims = model_dims(cfg, tp=1)
        self.params = params
        bs = cfg.kv_block_size
        max_batch, max_seq_len = config.max_batch, config.max_seq_len
        max_blocks = max_seq_len // bs
        self.hybrid_cfg = HybridConfig(
            block_size=bs,
            total_slots=max(16, int(max_batch * max_blocks
                                    * config.pool_headroom) // 8 * 8),
            restseg_fraction=config.restseg_fraction, assoc=8,
            max_seqs=max_batch, max_blocks_per_seq=max_blocks,
            mode=config.mode)
        self.track_stats = config.track_stats
        self.manager = HybridKVManager(self.hybrid_cfg)
        if config.prefill_mode not in ("prefix_kv", "recompute"):
            raise ValueError(f"unknown prefill_mode {config.prefill_mode!r}"
                             " (expected 'prefix_kv' or 'recompute')")
        self.prefill_mode = config.prefill_mode
        if self.prefill_mode == "prefix_kv" and config.attn_impl != "dense":
            # the prefix chunk forward implements the dense softmax; mixing
            # it with a flash/pallas chunk-0 forward would let chunk k>0
            # drift from the recompute oracle in float summation order
            warnings.warn(
                f"prefix-KV chunked prefill is defined against the dense "
                f"attention forward; falling back to "
                f"prefill_mode='recompute' for attn_impl="
                f"{config.attn_impl!r}", stacklevel=2)
            self.prefill_mode = "recompute"
        # ---- SPMD mesh / partition (DESIGN.md §sharded-serving) ---------
        self.mesh = None
        self.partition: Optional[Partition] = None
        kv_shards = 0
        if config.mesh_shape is not None:
            from repro.launch.mesh import make_local_mesh
            data, model = config.mesh_shape
            self.mesh = make_local_mesh(data=data, model=model)
            # kv_shards >= 1 selects the SPMD layout even at model == 1
            # (same code path regardless of shard count); the data axis
            # replicates state, so the partition covers the model axis
            kv_shards = int(model)
            self.partition = Partition.for_hybrid(self.hybrid_cfg, model)
            self.manager.set_partition(self.partition)
        self.spec = DecodeSpec(
            block_size=bs, max_blocks_per_seq=max_blocks,
            slots_per_group=self.hybrid_cfg.total_slots,
            n_sets=self.hybrid_cfg.num_sets, assoc=self.hybrid_cfg.assoc,
            mode="batch", hash_name=self.hybrid_cfg.hash_name,
            prefix_gather=config.prefix_gather, kv_shards=kv_shards)
        dtype = config.dtype
        self.dstate = init_decode_state(cfg, self.dims, self.spec,
                                        max_batch, 1, dtype=dtype,
                                        part=self.partition)
        self.max_batch = max_batch
        # tokens of NEW prompt admitted per step; chunk granularity is the
        # KV block, so the effective budget is floor(budget / bs) blocks
        self.prefill_budget = (config.prefill_budget
                               if config.prefill_budget is not None
                               else 4 * bs * max_batch)
        if self.prefill_budget < bs:
            raise ValueError(
                f"prefill_budget {self.prefill_budget} is smaller than "
                f"the KV block size {bs}: no prompt chunk could ever be "
                "admitted")
        self.auto_release = config.auto_release
        if config.overload_policy not in ("preempt", "fail"):
            raise ValueError(
                f"unknown overload_policy {config.overload_policy!r} "
                "(expected 'preempt' or 'fail')")
        self.overload_policy = config.overload_policy
        self._injector = config.fault_injector
        # host KV tier: preempted sequences parked off-device (ISSUE 6)
        self._preempted: Dict[int, _HostTierSeq] = {}
        self._swap_bytes_out = 0
        self._swap_bytes_in = 0
        # per-shard swap traffic (mesh only): KV bytes attributed to the
        # shard owning each swapped block, non-pool rows to shard 0 —
        # the shard rows sum EXACTLY to the global counters
        n_sh = self.partition.n_shards if self.partition else 1
        self._shard_swap_out = np.zeros(n_sh, np.int64)
        self._shard_swap_in = np.zeros(n_sh, np.int64)
        # monotone count of preempt/resume events: poll()'s no-progress
        # detector treats any of them as progress (a step that only
        # rearranges residency is not a stuck step)
        self._progress_events = 0
        # monotone engine-level preempt/resume counters.  These are NOT
        # derived from the per-request rows: a finished request's state
        # is dropped when its seq_id is reused, so a sum over
        # ``self._states`` silently loses counts.  The dropped share is
        # tracked too — sum(rows) + dropped == global is an invariant
        # ``check_invariants`` asserts.
        self._request_preempts = 0
        self._request_resumes = 0
        self._dropped_preempts = 0
        self._dropped_resumes = 0
        # monotone count of tokens committed to any stream (decode,
        # spec commit, prefill first-tokens): the metrics logger's
        # per-step tokens delta and the dashboard tokens/s numerator
        self._tokens_emitted = 0
        # request-lifecycle monotone counters (ISSUE 10): explicit
        # cancellations and wall-clock deadline expiries
        self._cancelled = 0
        self._deadline_expired = 0
        # live metrics stream (serve/metrics.py): fed one host-side
        # event per step; None = zero overhead on the hot path
        self.metrics = config.metrics
        self.scheduler: Scheduler = make_scheduler(config.scheduler)
        # a scheduler instance is MUTABLE state: sharing one between two
        # engines (e.g. via a reused EngineConfig holding an instance)
        # would let engine B admit — and decode with B's params — a
        # request submitted to engine A
        if getattr(self.scheduler, "_bound_engine", None) is not None:
            raise ValueError(
                "scheduler instance is already bound to another Engine; "
                "pass a policy name or factory in EngineConfig instead")
        try:
            self.scheduler._bound_engine = self
        except AttributeError:
            pass                       # slotted/frozen scheduler: skip
        self.fwd = FwdOptions(attn_impl=config.attn_impl, dtype=dtype,
                              collect_cache=True)
        # ``sample`` is static: at most two cached executables (all-greedy
        # / any-sampled); the all-greedy one is the pre-sampling argmax
        # hot path, with no sort/softmax/gumbel in the trace
        self._serve_step = jax.jit(make_serve_step(
            cfg, self.dims, self.spec, mesh=self.mesh, dtype=dtype,
            part=self.partition),
            static_argnames=("sample",))
        # one jitted callable; XLA re-specializes per (bucket_B, bucket_S)
        # — both power-of-two padded, so the executable set is bounded
        self._prefill_step = jax.jit(make_prefill_step(
            cfg, self.dims, self.spec, mesh=self.mesh, fwd=self.fwd,
            part=self.partition),
            static_argnames=("sample",))
        # prefix-KV chunk step: chunks k > 0 forward only their own tokens
        # and read the prefix from the pool (shapes keyed additionally by
        # the pow2 prefix-buffer width — still a bounded set)
        self._prefix_step = jax.jit(make_prefix_prefill_step(
            cfg, self.dims, self.spec, mesh=self.mesh, fwd=self.fwd,
            part=self.partition),
            static_argnames=("sample",))
        # ---- speculative decoding (serve/spec_decode.py) ----------------
        sd = config.spec_decode
        if sd is True:
            sd = "ngram"
        if sd not in (None, False, "ngram"):
            raise ValueError(f"unknown spec_decode drafter {sd!r} "
                             "(expected None/False or 'ngram')")
        self.spec_K = 0
        if sd:
            if cfg.family in ("ssm", "hybrid"):
                # state rollback for rejected drafts is not cheap:
                # warn once and keep the non-speculative step
                _warn_spec_fallback(cfg.family)
            else:
                if config.num_draft_tokens < 1:
                    raise ValueError("num_draft_tokens must be >= 1, got "
                                     f"{config.num_draft_tokens}")
                if config.spec_ngram < 1:
                    # a non-positive n-gram would silently degrade the
                    # drafter to repeat-current-token (all-rejected
                    # worst case) — loud error, like num_draft_tokens
                    raise ValueError("spec_ngram must be >= 1, got "
                                     f"{config.spec_ngram}")
                self.spec_K = int(config.num_draft_tokens)
                self._spec_step = jax.jit(make_spec_decode_step(
                    cfg, self.dims, self.spec, self.spec_K, mesh=self.mesh,
                    dtype=dtype, ngram=config.spec_ngram,
                    part=self.partition),
                    static_argnames=("sample",))
                # per-slot token history the in-graph drafter matches
                # against (prompt scattered at admission, accepted tokens
                # appended in-graph; -1 = unknown)
                self.dstate["hist"] = jnp.full(
                    (max_batch, max_seq_len), -1, jnp.int32)
        self._spec_drafted = 0
        self._spec_accepted = 0
        # mesh layout: place the decode state per the SAME specs the
        # whole-step shard_map uses (they must agree — kv_state_specs is
        # the single source of truth) and replicate the params; route
        # dirty-delta syncs through the ownership-masked sharded scatter
        if self.mesh is not None:
            specs = kv_state_specs(self.dstate, self.spec)
            self.dstate = {
                k: jax.device_put(v, NamedSharding(self.mesh, specs[k]))
                for k, v in self.dstate.items()}
            self.params = jax.device_put(
                self.params, NamedSharding(self.mesh, P()))
            self._scatter_delta = self._make_sharded_scatter()
        else:
            self._scatter_delta = _scatter_delta
        self.requests: Dict[int, Request] = {}      # registered, live
        self.finished: Dict[int, Request] = {}
        self._states: Dict[int, RequestState] = {}
        self._current: Optional[Request] = None     # mid-chunk prefill
        self._slot_of: Dict[int, int] = {}
        self._prefilling: Dict[int, int] = {}   # seq_id -> tokens installed
        self._pending_samp: List[Tuple[int, Request]] = []
        self._step_count = 0                    # scheduler clock (aging)
        # chunk trace: one ChunkRecord (seq_id, start, end, path,
        # fwd_tokens) per admitted chunk — scheduler tests pin the order,
        # the prefix-KV tests pin the per-chunk forward-token linearity
        self.admission_log: List[ChunkRecord] = []
        self._n_attn_layers = sum(cfg.attn_on_layer(l)
                                  for l in range(cfg.num_layers))
        self._has_recurrent = cfg.family in ("ssm", "hybrid")
        # host mirror of ctx_len: block-boundary checks must not read the
        # device array per request (that is one D2H sync per sequence)
        self._ctx_host = np.zeros(max_batch, np.int64)
        self._synced_full = False
        # ---- Utopia-native prefix cache (core/prefix_cache.py) ----------
        pc = config.prefix_cache
        reason = self._prefix_cache_unsupported()
        if pc is True and reason is not None:
            raise ValueError(f"prefix_cache=True is unsupported here: "
                             f"{reason}")
        self.prefix_cache: Optional[PrefixCache] = None
        if pc not in (None, False) and reason is None:
            self.prefix_cache = (pc if isinstance(pc, PrefixCache)
                                 else PrefixCache(self.manager))
        # per-request memo of the prompt's block chain hashes (computed
        # once, used by both the admission-time match and the
        # post-dispatch inserts)
        self._chain_cache: Dict[int, np.ndarray] = {}

    def _prefix_cache_unsupported(self) -> Optional[str]:
        """Why the prefix cache cannot run on this configuration (None =
        supported).  ``prefix_cache="auto"`` silently disables on these;
        ``prefix_cache=True`` raises with the reason."""
        if not self._n_attn_layers:
            return ("the model family has no attention KV blocks to "
                    "cache")
        if self.hybrid_cfg.mode == "restrictive_only":
            return ("content sharing needs a flexible segment (a "
                    "restrictive slot is tag-bound to a single vpn)")
        if self._front_tokens():
            return ("vlm frontend KV blocks precede the prompt blocks, "
                    "so prompt-block indices are not content-pure")
        return None

    # ------------------------------------------------------------ admission
    @property
    def waiting(self) -> Tuple[Request, ...]:
        """Requests whose prompt is not fully installed yet: the
        engine-owned mid-chunk request (if any) first, then the
        scheduler's queue."""
        head = (self._current,) if self._current is not None else ()
        return head + tuple(self.scheduler.pending())

    def has_unfinished(self) -> bool:
        return bool(self.waiting) or any(
            not self._states[sid].done for sid in self.requests)

    def submit(self, req: Request, share_prefix_from: Optional[int] = None,
               shared_blocks: int = 0) -> None:
        """Enqueue a request; ``step()`` admits it under the token budget
        in the order the configured scheduler decides.

        A ``seq_id`` may be reused once its previous request FINISHED;
        the engine then forgets the old incarnation entirely (its entry
        in ``finished`` and its ``stats()["per_request"]`` row are
        dropped).  Reusing a queued or live id raises."""
        bs = self.cfg.kv_block_size
        S = len(np.asarray(req.prompt))
        if S == 0:
            raise ValueError("empty prompt: an unadmittable request would "
                             "stall the queue head forever")
        if S % bs:
            raise ValueError(f"prompt length {S} must be a multiple of the "
                             f"KV block size {bs} (pad upstream)")
        front = self._front_tokens()
        if front % bs:
            raise ValueError(f"frontend length {front} must be a multiple "
                             f"of the KV block size {bs}")
        old = self._states.get(req.seq_id)
        if old is not None and not old.done:
            raise ValueError(f"seq_id {req.seq_id} is already queued or "
                             "live")
        if req.seq_id in self._slot_of:
            # finished but never released (auto_release=False): its slot,
            # ctx and KV blocks are still registered — re-admitting the
            # id would inherit them
            raise ValueError(
                f"seq_id {req.seq_id} finished but still holds its "
                f"sequence slot; call release({req.seq_id}) first or "
                "construct the engine with auto_release=True")
        if old is not None:
            # the old incarnation's telemetry row is about to be
            # dropped: bank its preempt/resume counts so the monotone
            # globals stay reconcilable (sum(rows) + dropped == global)
            self._dropped_preempts += old.preempts
            self._dropped_resumes += old.resumes
        self.finished.pop(req.seq_id, None)   # forget a finished reuse
        self._chain_cache.pop(req.seq_id, None)   # fresh chains on reuse
        if share_prefix_from is not None and shared_blocks:
            # legacy pairwise sharing: superseded by the automatic
            # content-addressed prefix cache — the source's prompt blocks
            # were published at its own admission, so the cache match at
            # THIS request's admission attaches the same physical slots
            # the explicit kwargs used to
            _warn_share_kwarg()
        state = RequestState(request=req, arrival=self._step_count)
        if req.deadline_ms is not None:
            if req.deadline_ms < 0:
                raise ValueError(f"deadline_ms must be >= 0, got "
                                 f"{req.deadline_ms}")
            state.deadline_at = time.perf_counter() + req.deadline_ms / 1e3
        object.__setattr__(req, "_engine_state", state)
        self._states[req.seq_id] = state
        self.scheduler.add(req, state.arrival)
        if self.metrics is not None:
            self.metrics.on_submit(req.seq_id, self._step_count)

    def add_request(self, req: Request,
                    share_prefix_from: Optional[int] = None,
                    shared_blocks: int = 0) -> int:
        """Legacy blocking admission: enqueue, then prefill the whole
        prompt immediately (draining anything queued ahead of it)."""
        self.submit(req, share_prefix_from, shared_blocks)
        pending = self._admit(budget=None)
        if any(r is req for r in self.waiting):   # could not even register
            raise PoolExhausted("no free sequence slot for blocking "
                                "add_request; release a sequence first")
        slot = self._slot_of[req.seq_id]   # before auto-release can free it
        host = jax.device_get({f"p{r.seq_id}": t for r, t in pending})
        for r, _ in pending:
            self._complete_prefill(r, int(host[f"p{r.seq_id}"]))
        return slot

    def _front_tokens(self) -> int:
        """Frontend tokens that occupy KV blocks (vlm image prefix; the
        audio frontend lives in the encoder, not the decoder cache)."""
        return self.cfg.frontend_tokens if self.cfg.family == "vlm" else 0

    def _admit(self, budget: Optional[int]
               ) -> List[Tuple[Request, jnp.ndarray]]:
        """Admit waiting prompts up to ``budget`` NEW tokens (None =
        unbounded), in scheduler order, chunked at KV-block granularity.

        Returns [(request, in-graph first-token array)] for every request
        whose FINAL chunk was installed this call; the caller folds the
        arrays into its single device fetch.
        """
        if self._current is None and not len(self.scheduler):
            return []
        m = self.manager
        bs = self.cfg.kv_block_size
        front = self._front_tokens()
        if budget is None:
            budget = sum(len(np.asarray(r.prompt)) for r in self.waiting)
        chunks: List[Tuple[Request, int, int, bool, bool]] = []
        # cache-hit regions attached at registration: extra hist spans
        # (the tail chunks never cover the attached prefix's tokens)
        hist_extra: List[Tuple[Request, int, int, bool, bool]] = []
        # exact capacity gating (ISSUE 6): every accepted chunk's
        # unmapped covering blocks are reserved against a dry-run ledger
        # BEFORE the chunk is committed, so the bucket allocations below
        # can never hit pool exhaustion mid-prefill.  ``reserved``
        # accumulates this round's not-yet-allocated vpns; each gate
        # replays them against a FRESH ledger (sharing/migration at
        # registration may have consumed flex slots since the last one).
        reserved: List[int] = []
        gate_alloc = (self._n_attn_layers > 0
                      and self.hybrid_cfg.mode != "restrictive_only")
        while budget >= bs:
            req = self._current
            if req is None:
                req = self.scheduler.select(self._step_count)
                if req is None:
                    break
                if req.seq_id in self._preempted:
                    # a preempted sequence re-entered through the queue:
                    # resume restores its saved blocks and rows, charging
                    # no prefill budget (nothing is re-forwarded)
                    if not self._resume_preempted(req, reserved):
                        break              # no slot / no capacity yet
                    if req.seq_id not in self._prefilling:
                        continue           # decode-live again this step
                    req = self._current    # mid-prefill: keep chunking
            if req.seq_id not in self._slot_of:
                if not m._free_seq_slots:
                    break                      # wait for a release
                if (self.overload_policy == "fail" and gate_alloc
                        and not self._footprint_admit(req)):
                    break          # fail-fast: serve only what fits whole
                slot = m.register_sequence(req.seq_id)
                self._slot_of[req.seq_id] = slot
                self.requests[req.seq_id] = req
                self._prefilling[req.seq_id] = 0
                self._pending_samp.append((slot, req))
                if self.prefix_cache is not None:
                    self._attach_cached_prefix(req, hist_extra)
            start = self._prefilling[req.seq_id]
            total = len(np.asarray(req.prompt))
            take = min(total - start, budget // bs * bs)
            if take <= 0:
                break
            end = start + take
            if gate_alloc:
                need = self._chunk_vpns(req, start, end, front)
                forced = (bool(need) and self._injector is not None
                          and self._injector.alloc_unavailable(
                              self._step_count, "admit"))
                if forced:
                    break      # injected transient denial: defer a step
                if need and not self._capacity_ok(reserved, need):
                    if (not reserved
                            and not self._others_hold_blocks(req.seq_id)):
                        # nothing else holds (or will hold) pool blocks,
                        # yet this prompt still does not fit: no amount
                        # of preemption can ever admit it
                        raise PoolExhausted(
                            f"request {req.seq_id}'s prompt alone "
                            "exceeds the KV pool and cannot be admitted",
                            **self._pool_diag())
                    st_in = self._states[req.seq_id]
                    if (self.overload_policy != "preempt"
                            or not self._make_room(
                                st_in, reserved, need,
                                exclude={c[0].seq_id for c in chunks}
                                | {req.seq_id})):
                        break          # defer: stay queued / mid-prefill
                reserved.extend(need)
            if self._current is None:
                # first chunk admitted: the engine owns the request until
                # its final chunk installs (a policy can reorder queued
                # requests, never interleave half-prefilled prompts)
                self.scheduler.pop(req)
                self._current = req
            budget -= take
            self._prefilling[req.seq_id] = end
            final = end == total
            # chunk 0 has no prefix to read; later chunks consume the
            # installed prefix unless the oracle flag forces recompute
            use_prefix = self.prefill_mode == "prefix_kv" and start > 0
            chunks.append((req, start, end, final, use_prefix))
            self.admission_log.append(ChunkRecord(
                req.seq_id, start, end,
                "prefix_kv" if use_prefix else "recompute",
                (end - start) if use_prefix else front + end))
            if final:
                self._current = None
            # a partial chunk stays engine-owned with budget < bs, ending
            # the loop: it continues next step

        # newly registered sequences' SamplingParams must be on device
        # before any prefill dispatch samples its first token
        self._install_sampling()
        # ... and, under speculative decoding, so must their prompt
        # tokens: the in-graph drafter matches against the history —
        # including cache-attached prefixes, which no chunk ever covers
        self._install_hist(chunks + hist_extra)

        # ---- bucket by padded length; one dispatch per bucket -----------
        # Recompute chunks bucket by padded PREFIX length (the forward
        # redoes the whole prefix); prefix-KV chunks bucket by padded
        # CHUNK length (only the new tokens are forwarded).  Right padding
        # is exact under causal attention, and the recurrent (SSM/conv)
        # families pass per-row ``seq_len`` masks that zero dt past the
        # real length — pad positions become exact identity transitions —
        # so EVERY family shares the pow2 buckets (PR-2 bucketed ssm and
        # hybrid at exact lengths instead).
        pending: List[Tuple[Request, jnp.ndarray]] = []
        buckets: Dict[int, list] = defaultdict(list)
        pbuckets: Dict[Tuple[int, int], list] = defaultdict(list)
        for req, start, end, final, use_prefix in chunks:
            if use_prefix:
                take = end - start
                s_pad = bs * _next_pow2(take // bs)
                # the prefix read-buffer width must equal the padded KV
                # extent the recompute forward would pad THIS row to:
                # float reductions nest bitwise across pow2 tails but not
                # across arbitrary length pairs, so a shared max-width
                # buffer would break the bit-identical differential
                # contract (part of the bucket key, not a bucket max)
                nblk_buf = front // bs + _next_pow2(end // bs)
                pbuckets[(s_pad, nblk_buf)].append((req, start, end, final))
            else:
                s_pad = bs * _next_pow2(end // bs)
                buckets[s_pad].append((req, start, end, final))
        for s_pad, grp in sorted(buckets.items()):
            pending.extend(self._prefill_bucket(grp, s_pad, front))
        for (s_pad, nblk_buf), grp in sorted(pbuckets.items()):
            pending.extend(self._prefix_bucket(grp, s_pad, nblk_buf, front))
        # ---- publish installed chunks to the prefix cache ---------------
        # POST-dispatch: entries become matchable from the NEXT admission
        # round onward, so a same-round duplicate can never attach a
        # block whose install dispatch has not run, and the pin
        # migrations' pending copies land with the step's normal
        # _apply_copies before anything reads the cached slots
        if self.prefix_cache is not None:
            for req, start, end, final, use_prefix in chunks:
                self._cache_insert_chunk(req, start, end)
        return pending

    # -------------------------------------------- overload / host KV tier
    def _chunk_vpns(self, req, start: int, end: int,
                    front: int) -> List[int]:
        """Vpns a prompt chunk's bucket allocation will actually fault in
        (unmapped covering blocks; mirrors _prefill_bucket/_prefix_bucket
        coverage exactly, including the frontend prefix on chunk 0)."""
        m = self.manager
        bs = self.cfg.kv_block_size
        s = m.seq_slot(req.seq_id)
        cb0 = (front + start) // bs if start else 0
        return [self.hybrid_cfg.vpn(s, cb)
                for cb in range(cb0, (front + end) // bs)
                if m.lookup(req.seq_id, cb)[0] < 0]

    # --------------------------------------------- prefix cache plumbing
    def _chains(self, req: Request) -> np.ndarray:
        """Memoized per-block chain hashes of a request's prompt."""
        c = self._chain_cache.get(req.seq_id)
        if c is None:
            c = block_hash_chain(np.asarray(req.prompt),
                                 self.cfg.kv_block_size)
            self._chain_cache[req.seq_id] = c
        return c

    def _attach_cached_prefix(self, req: Request, hist_extra) -> None:
        """Longest-cached-prefix match at registration: matched blocks
        attach read-only (the cache slot's refcount grows per attacher)
        and prefill starts at the tail.  The match is capped one block
        short of the full prompt so the FINAL chunk always runs — it
        produces the request's first-token logits."""
        pc = self.prefix_cache
        m = self.manager
        bs = self.cfg.kv_block_size
        pc.stats["lookups"] += 1
        prompt = np.asarray(req.prompt)
        entries = pc.match(prompt, self._chains(req))
        entries = entries[:len(prompt) // bs - 1]
        if not entries:
            return
        for cb, e in enumerate(entries):
            m.attach_cached_block(req.seq_id, cb, e.slot)
        matched = len(entries) * bs
        self._prefilling[req.seq_id] = matched
        st = self._states[req.seq_id]
        st.cached_blocks += len(entries)
        pc.stats["hits"] += 1
        pc.stats["dedup_blocks"] += len(entries)
        if self.spec_K:
            # the tail chunks never cover [0, matched): scatter the
            # attached prefix's tokens into the drafter history here
            hist_extra.append((req, 0, matched, False, False))

    def _cache_insert_chunk(self, req: Request, start: int,
                            end: int) -> None:
        """Publish a freshly installed chunk's blocks to the cache (one
        insert per covered block, parent-chained; dedup / full-set
        bypass handled inside :meth:`PrefixCache.insert`)."""
        bs = self.cfg.kv_block_size
        chains = self._chains(req)
        prompt = np.asarray(req.prompt)
        for cb in range(start // bs, end // bs):
            parent = CHAIN_SEED if cb == 0 else int(chains[cb - 1])
            self.prefix_cache.insert(
                int(chains[cb]), parent, prompt[cb * bs:(cb + 1) * bs],
                req.seq_id, cb)

    def _capacity_ok(self, reserved, need) -> bool:
        """Exact dry-run: could the pool allocate ``reserved`` (this
        round's already-accepted vpns) PLUS ``need`` right now?  A miss
        first reclaims UNREFERENCED prefix-cache entries — the cheapest
        rung of the degradation ladder: dropping clean cache frees one
        FlexSeg slot per entry and re-runs the dry-run against a fresh
        ledger — before the caller escalates to preemption."""
        want = list(reserved) + list(need)
        while True:
            if self.manager.alloc_ledger().reserve(want):
                return True
            if (self.prefix_cache is None
                    or not self.prefix_cache.evict_one()):
                return False

    def _others_hold_blocks(self, seq_id: int) -> bool:
        m = self.manager
        s = m.seq_slot(seq_id)
        nblk = self.hybrid_cfg.max_blocks_per_seq
        return any(vpn // nblk != s for vpn in m.blocks)

    def _pick_victim(self, exclude=frozenset()):
        """Choose a preemption victim via the scheduler's policy.

        Decode-live sequences are preferred over mid-prefill ones (a
        mid-prefill victim re-runs no work either way, but decode-live
        sequences hold full contexts — the policy gets the richer pool);
        finished-but-unreleased sequences are never victims (``release``
        is the tool for those).  Returns a RequestState or None."""
        decode, prefill = [], []
        for sid in self._slot_of:
            if sid in exclude:
                continue
            st = self._states.get(sid)
            if st is None or st.done:
                continue
            (prefill if sid in self._prefilling else decode).append(st)
        cands = decode or prefill
        if not cands:
            return None
        vic_fn = getattr(self.scheduler, "victim", None)
        if vic_fn is None:
            from .scheduler import default_victim as vic_fn
        return vic_fn(cands, self._step_count)

    def _make_room(self, incoming_st, reserved, need, exclude) -> bool:
        """Preempt policy-approved victims until ``reserved + need``
        fits.  ``should_preempt`` gates every eviction (FIFO/SPF always
        say no — admission waits; priority lets a strictly
        higher-effective request evict), so this can only loop as long
        as victims keep being approved, and each preemption removes one
        candidate."""
        while not self._capacity_ok(reserved, need):
            vic = self._pick_victim(exclude)
            if vic is None:
                return False
            sp = getattr(self.scheduler, "should_preempt", None)
            if sp is None or not sp(incoming_st.request,
                                    incoming_st.arrival, vic,
                                    self._step_count):
                return False
            self.preempt_request(vic.request.seq_id)
        return True

    def _footprint_blocks(self, req) -> int:
        """Whole-request KV footprint in blocks (prompt + frontend + all
        of max_new_tokens, plus one spare block for a speculative window
        overshoot), clamped to the per-sequence maximum."""
        bs = self.cfg.kv_block_size
        total = (self._front_tokens() + len(np.asarray(req.prompt))
                 + req.max_new_tokens)
        need = (total + bs - 1) // bs + (1 if self.spec_K else 0)
        return min(need, self.spec.max_blocks_per_seq)

    def _footprint_admit(self, req) -> bool:
        """Fail-fast admission gate: admit only when the request's FULL
        footprint fits next to every resident sequence's — "serve only
        what fits", the PR-5 behaviour made explicit.  Raises for a
        request whose footprint alone exceeds the pool."""
        m = self.manager
        need = self._footprint_blocks(req)
        cap = self.hybrid_cfg.total_slots
        if need > cap:
            raise PoolExhausted(
                f"request {req.seq_id} needs {need} KV blocks but the "
                f"pool only has {cap}", **self._pool_diag())
        held = 0
        nblk = self.spec.max_blocks_per_seq
        for sid in self._slot_of:
            st = self._states[sid]
            if st.done:        # finished-unreleased: count actual blocks
                held += sum(1 for b in range(nblk)
                            if m.lookup(sid, b)[0] >= 0)
            else:
                held += self._footprint_blocks(st.request)
        return held + need <= cap

    def _pool_diag(self) -> Dict[str, int]:
        """Structured occupancy diagnostics attached to PoolExhausted."""
        m = self.manager
        return dict(
            pool_blocks=self.hybrid_cfg.total_slots,
            mapped_blocks=sum(1 for i in m.blocks.values() if i.slot >= 0),
            free_flex=len(m.flex_free),
            queued=len(self.waiting),
            live=sum(1 for sid in self.requests
                     if not self._states[sid].done),
            finished_unreleased=sum(1 for sid in self._slot_of
                                    if self._states[sid].done),
            preempted=len(self._preempted))

    def _attribute_swap(self, counter: np.ndarray, rec, slots) -> None:
        """Split a swap record's bytes across shards so the per-shard
        counters sum EXACTLY to the global one: KV bytes go to each
        block's owning shard (equal share per block — blocks are
        uniform), everything else (recurrent/cross rows, spec history)
        is replicated state and is charged to shard 0."""
        kv_bytes = 0 if rec.kv is None else int(np.asarray(rec.kv).nbytes)
        if kv_bytes and slots:
            per, extra = divmod(kv_bytes, len(slots))
            for i, s in enumerate(slots):
                owner = (self.partition.shard_of_slot(int(s))
                         if self.partition is not None else 0)
                counter[owner] += per + (extra if i == 0 else 0)
        counter[0] += rec.nbytes - kv_bytes

    def preempt_request(self, seq_id: int) -> None:
        """Swap a live sequence out to the host KV tier (ISSUE 6).

        Safe points only: between steps, or inside ``step()`` before
        admission / after the commit (the injector's "pre"/"post"
        phases) — never between a dispatch and its fetch.  Everything
        needed to continue bit-identically is captured in ONE batched
        ``device_get``: the mapped pool blocks (KV), the recurrent
        (ssm/conv) and cross-attention rows, the spec history row and
        the committed context.  Sampling keys need no save — they derive
        from (seed, seq_id) and fold the absolute position, so a resumed
        request samples exactly what it would have uninterrupted.  The
        request re-enters the scheduler queue with its ORIGINAL arrival
        step, so aging policies keep its seniority."""
        st = self._states.get(seq_id)
        if st is None or st.done or seq_id not in self._slot_of:
            raise ValueError(f"sequence {seq_id} is not live")
        m = self.manager
        slot = self._slot_of[seq_id]
        # pending migration copies must land BEFORE the gather: the
        # manager's slot map is post-copy, the pool data may not be yet
        self._apply_copies()
        fetch: Dict[str, Any] = {}
        mapped: List[int] = []
        if self._n_attn_layers:
            for b in range(self.spec.max_blocks_per_seq):
                bslot, _ = m.lookup(seq_id, b)
                if bslot >= 0:
                    mapped.append(bslot)
            if mapped:
                mp = np.asarray(mapped, np.int32)
                if self.partition is not None:
                    mp = self.partition.phys(mp)
                sl = jnp.asarray(mp)
                fetch["kv"] = jnp.stack([self.dstate["k_pool"][:, sl],
                                         self.dstate["v_pool"][:, sl]])
        for key in ("ssm", "conv", "cross_k", "cross_v"):
            if key in self.dstate:
                fetch[key] = self.dstate[key][:, slot]
        if self.spec_K:
            fetch["hist"] = self.dstate["hist"][slot]
        host = jax.device_get(fetch) if fetch else {}
        saved = m.preempt(seq_id)
        assert len(saved) == len(mapped), "gather/release block mismatch"
        rec = _HostTierSeq(
            seq_id=seq_id, ctx=int(self._ctx_host[slot]),
            prefill_progress=self._prefilling.get(seq_id),
            blocks=saved, kv=host.get("kv"),
            rows={k: v for k, v in host.items() if k != "kv"},
            nbytes=sum(np.asarray(v).nbytes for v in host.values()))
        # engine-side slot teardown (release() minus the finishing)
        del self._slot_of[seq_id]
        self.dstate["ctx_len"] = self.dstate["ctx_len"].at[slot].set(0)
        self._ctx_host[slot] = 0
        if self.spec_K:
            self.dstate["hist"] = self.dstate["hist"].at[slot].set(-1)
        req = self.requests.pop(seq_id)
        self._prefilling.pop(seq_id, None)
        if self._current is not None and self._current.seq_id == seq_id:
            self._current = None
        self._pending_samp = [(s, r) for s, r in self._pending_samp
                              if r.seq_id != seq_id]
        self._preempted[seq_id] = rec
        self._swap_bytes_out += rec.nbytes
        self._attribute_swap(self._shard_swap_out, rec, mapped)
        st.preempts += 1
        self._request_preempts += 1
        self._progress_events += 1
        self.scheduler.add(req, st.arrival)
        self._sync_translation()

    def _resume_preempted(self, req: Request, reserved) -> bool:
        """Bring a preempted sequence back from the host tier: fresh
        sequence slot, fresh pool slots (capacity-gated against the
        ledger, preempting policy-approved victims if needed), saved KV
        scattered back, rows and context restored, sampling re-scattered.
        A mid-prefill victim becomes the engine-owned chunk request again
        and continues through the normal prefix-KV chunk path.  Returns
        False — leaving the request queued — when no sequence slot or
        capacity is available yet."""
        m = self.manager
        sid = req.seq_id
        rec = self._preempted[sid]
        st = self._states[sid]
        if not m._free_seq_slots:
            return False
        if (self._injector is not None
                and self._injector.alloc_unavailable(self._step_count,
                                                     "resume")):
            return False
        trial = m._free_seq_slots[-1]    # the slot register_sequence pops
        vpns = [self.hybrid_cfg.vpn(trial, b) for b, _ in rec.blocks]
        if not self._capacity_ok(reserved, vpns):
            if (self.overload_policy != "preempt"
                    or not self._make_room(st, reserved, vpns,
                                           exclude={sid})):
                return False
        self.scheduler.pop(req)
        slot = m.register_sequence(sid)
        m.resume(sid, rec.blocks)
        self._apply_copies()        # resume-time evictions land first
        if rec.kv is not None:
            # re-resolve AFTER the copies: a later block's allocation may
            # have evict-migrated an earlier one within this same resume,
            # so the scatter must target where each block lives now
            dh = np.asarray([m.lookup(sid, b)[0] for b, _ in rec.blocks],
                            np.int32)
            if self.partition is not None:
                dh = self.partition.phys(dh)
            dst = jnp.asarray(dh)
            kv = jnp.asarray(rec.kv)
            self.dstate["k_pool"] = \
                self.dstate["k_pool"].at[:, dst].set(kv[0])
            self.dstate["v_pool"] = \
                self.dstate["v_pool"].at[:, dst].set(kv[1])
        for key, row in rec.rows.items():
            if key == "hist":
                self.dstate["hist"] = \
                    self.dstate["hist"].at[slot].set(jnp.asarray(row))
            else:
                self.dstate[key] = \
                    self.dstate[key].at[:, slot].set(jnp.asarray(row))
        self.dstate["ctx_len"] = \
            self.dstate["ctx_len"].at[slot].set(rec.ctx)
        self._ctx_host[slot] = rec.ctx
        self._slot_of[sid] = slot
        self.requests[sid] = req
        self._pending_samp.append((slot, req))
        if rec.prefill_progress is not None:
            self._prefilling[sid] = rec.prefill_progress
            self._current = req
        del self._preempted[sid]
        self._swap_bytes_in += rec.nbytes
        self._attribute_swap(
            self._shard_swap_in, rec,
            [m.lookup(sid, b)[0] for b, _ in rec.blocks])
        st.last_step = self._step_count
        st.resumes += 1
        self._request_resumes += 1
        self._progress_events += 1
        return True

    def _run_forced_preempts(self, targets) -> None:
        """Apply the injector's forced preemptions; ``"auto"`` targets
        resolve through the victim policy, invalid/finished targets are
        skipped (the schedule may outlive the sequence it named)."""
        for t in targets:
            if t == "auto" or t is None:
                vic = self._pick_victim()
                sid = None if vic is None else vic.request.seq_id
            else:
                sid = int(t)
            st = self._states.get(sid) if sid is not None else None
            if (st is None or st.done or sid not in self._slot_of
                    or self.hybrid_cfg.mode == "restrictive_only"):
                continue
            self.preempt_request(sid)

    def _ensure_decode_blocks(self, st: RequestState) -> None:
        """Map every block the next decode dispatch will write for
        ``st`` (the boundary block, or the whole [pos, pos+K] window
        under speculation).

        Hybrid/flexible: a capacity miss walks the degradation ladder —
        preempt a policy-chosen victim and retry — instead of the
        pre-overload SWAP fall-through, where a SWAP'd current block
        made ``w_valid`` mask the KV write: the token stream kept going
        but the cache entry was silently dropped.  Under
        ``overload_policy="fail"`` the miss raises ``PoolExhausted``
        with occupancy diagnostics.  ``restrictive_only`` keeps the
        legacy per-block swap_in path bit-for-bit (set conflicts swap by
        design, Fig. 9)."""
        m = self.manager
        bs = self.cfg.kv_block_size
        K = self.spec_K
        nblk = self.spec.max_blocks_per_seq
        sid = st.request.seq_id
        pos = int(self._ctx_host[self._slot_of[sid]])
        if K:
            blocks = range(pos // bs, min((pos + K) // bs, nblk - 1) + 1)
        elif pos % bs == 0:
            blocks = (pos // bs,)
        else:
            return
        restrictive = self.hybrid_cfg.mode == "restrictive_only"
        for b in blocks:
            bslot, seg = m.lookup(sid, b)
            if bslot >= 0:
                continue
            if restrictive:
                info = m.allocate_block(sid, b)
                if info.seg == SWAP:
                    m.swap_in(sid, b)
                    st.swap_faults += 1
                continue
            in_swap = seg == SWAP     # legacy per-block swap bookkeeping
            vpn = self.hybrid_cfg.vpn(m.seq_slot(sid), b)
            first = True
            while True:
                forced = (first and self._injector is not None
                          and self._injector.alloc_unavailable(
                              self._step_count, "decode"))
                first = False
                if not forced and self._capacity_ok((), (vpn,)):
                    if in_swap:
                        m.swap_in(sid, b)
                        st.swap_faults += 1
                    else:
                        m.allocate_block(sid, b)
                    break
                if self.overload_policy != "preempt":
                    raise PoolExhausted(
                        f"decode step cannot allocate a KV block for "
                        f"sequence {sid}", **self._pool_diag())
                vic = self._pick_victim(exclude={sid})
                if vic is None:
                    raise PoolExhausted(
                        f"sequence {sid} cannot hold its own KV blocks "
                        "and nothing is left to preempt",
                        **self._pool_diag())
                self.preempt_request(vic.request.seq_id)

    def _install_sampling(self) -> None:
        """Scatter newly registered requests' SamplingParams into the
        per-slot device arrays (4 pow2-padded scatters; admission path
        only — the steady-state step never touches these)."""
        if not self._pending_samp:
            return
        rows = np.asarray([s for s, _ in self._pending_samp], np.int32)
        sp = [r.sampling for _, r in self._pending_samp]
        keys = np.stack([prng_key_data(p, r.seq_id)
                         for p, (_, r) in zip(sp, self._pending_samp)])
        self._pending_samp.clear()
        n = _next_pow2(rows.size)

        def pad(a):
            reps = n - a.shape[0]
            if reps:
                a = np.concatenate([a, np.repeat(a[:1], reps, axis=0)])
            return a

        # duplicate scatter index with duplicated value — benign
        ji = jnp.asarray(pad(rows))
        self.dstate["samp_temp"] = self.dstate["samp_temp"].at[ji].set(
            jnp.asarray(pad(np.asarray([p.temperature for p in sp],
                                       np.float32))))
        self.dstate["samp_topk"] = self.dstate["samp_topk"].at[ji].set(
            jnp.asarray(pad(np.asarray([p.top_k for p in sp], np.int32))))
        self.dstate["samp_topp"] = self.dstate["samp_topp"].at[ji].set(
            jnp.asarray(pad(np.asarray([p.top_p for p in sp], np.float32))))
        self.dstate["samp_key"] = self.dstate["samp_key"].at[ji].set(
            jnp.asarray(pad(keys.astype(np.uint32))))

    def _install_hist(self, chunks) -> None:
        """Scatter admitted prompt chunks into the per-slot token history
        the in-graph drafter matches against (ONE pow2-padded flat
        scatter per admission call; steady-state decode steps append
        accepted tokens in-graph and never touch this path).  Frontend
        (vlm) positions stay -1 — no token ever matches them."""
        if not self.spec_K or not chunks:
            return
        H = self.dstate["hist"].shape[1]
        front = self._front_tokens()
        idxs, vals = [], []
        for req, start, end, final, use_prefix in chunks:
            slot = self._slot_of[req.seq_id]
            base = slot * H + front + start
            idxs.append(np.arange(base, base + (end - start), dtype=np.int64))
            vals.append(np.asarray(req.prompt[start:end], np.int32))
        idx = np.concatenate(idxs)
        val = np.concatenate(vals)
        # pad to pow2 with an out-of-bounds index (dropped): bounded
        # scatter shapes, same discipline as the dirty-delta syncs
        idx = _pad_pow2(idx, self.max_batch * H)
        val = _pad_pow2(val, 0)
        self.dstate["hist"] = self.dstate["hist"].reshape(-1).at[
            jnp.asarray(idx)].set(jnp.asarray(val),
                                  mode="drop").reshape(self.max_batch, H)

    def _prefill_bucket(self, grp, s_pad: int, front: int):
        """Allocate blocks and run ONE batched prefill dispatch for a
        bucket of same-padded-length chunks."""
        m = self.manager
        bs = self.cfg.kv_block_size
        B_pad = _next_pow2(len(grp))
        nblk_cache = (front + s_pad) // bs
        tokens = np.zeros((B_pad, s_pad), np.int64)
        slots = -np.ones((B_pad, nblk_cache), np.int32)
        slot_ids = np.full(B_pad, -1, np.int32)
        ctx = np.zeros(B_pad, np.int32)
        last_pos = np.zeros(B_pad, np.int32)
        allocated: List[Tuple[int, int, int]] = []
        frontend = None
        if self.cfg.frontend != "none":
            frontend = np.zeros((B_pad, self.cfg.frontend_tokens,
                                 self.cfg.d_model), np.float32)
        for i, (req, start, end, final) in enumerate(grp):
            prompt = np.asarray(req.prompt)
            tokens[i, :end] = prompt[:end]
            slot_ids[i] = self._slot_of[req.seq_id]
            ctx[i] = end + front
            last_pos[i] = end - 1
            if frontend is not None:
                frontend[i] = req.frontend
            # new cache blocks this chunk (the first chunk also covers the
            # frontend prefix); blocks already mapped — earlier chunks,
            # shared prefix — install nothing.  Attention-free families
            # have no KV blocks to translate (DESIGN.md
            # §Arch-applicability), so nothing is allocated either.
            if not self._n_attn_layers:
                continue
            cb0 = (front + start) // bs if start else 0
            for cb in range(cb0, (front + end) // bs):
                if m.lookup(req.seq_id, cb)[0] >= 0:
                    continue
                info = m.allocate_block(req.seq_id, cb)
                if info.seg == SWAP:
                    raise RuntimeError("pool exhausted during prefill")
                allocated.append((i, req.seq_id, cb))
        # allocation-time evictions queued copies: drain before the
        # scatter, then RE-resolve every slot — a later allocation in
        # this same loop may have evict-migrated an earlier one, and the
        # scatter must write where the block lives NOW, not where it was
        # first placed (under a tight pool the stale slot already
        # belongs to another block)
        self._apply_copies()
        for i, sid, cb in allocated:
            slots[i, cb] = m.lookup(sid, cb)[0]
        batch = {"tokens": jnp.asarray(tokens)}
        if self._has_recurrent:
            # per-row real lengths: dt is zeroed past them, so the pow2
            # pad tail is an exact identity transition of the SSM state
            batch["seq_len"] = jnp.asarray(ctx - front)
        if frontend is not None:
            batch["frontend"] = jnp.asarray(frontend)
        any_sampled = any(not req.sampling.is_greedy
                          for req, _, _, _ in grp)
        _, self.dstate, pstats = self._prefill_step(
            self.params, self.dstate, batch, jnp.asarray(slots),
            jnp.asarray(slot_ids), jnp.asarray(ctx), jnp.asarray(last_pos),
            sample=any_sampled)
        out = []
        for i, (req, start, end, final) in enumerate(grp):
            self._ctx_host[slot_ids[i]] = int(ctx[i])
            if final:
                out.append((req, pstats["next_token"][i]))
        return out

    def _prefix_bucket(self, grp, s_pad: int, nblk_buf: int, front: int):
        """ONE batched prefix-KV dispatch for a bucket of same-shaped
        chunks: allocate the chunks' new blocks, then forward ONLY the
        chunk tokens, attending over the prefix's installed pool blocks
        (gathered via the translated slots) — linear chunk cost.

        ``nblk_buf`` (part of the bucket key) is each row's padded KV
        extent in blocks, chosen in ``_admit`` to match what the
        recompute forward would pad the same row to — the bit-identity
        contract of the differential oracle suite."""
        m = self.manager
        bs = self.cfg.kv_block_size
        B_pad = _next_pow2(len(grp))
        nblk_chunk = s_pad // bs
        tokens = np.zeros((B_pad, s_pad), np.int64)
        new_slots = -np.ones((B_pad, nblk_chunk), np.int32)
        prefix_slots = -np.ones((B_pad, nblk_buf), np.int32)
        slot_ids = np.full(B_pad, -1, np.int32)
        ctx = np.zeros(B_pad, np.int32)
        pctx = np.zeros(B_pad, np.int32)
        last_pos = np.zeros(B_pad, np.int32)
        allocated: List[Tuple[int, int, int, int]] = []
        for i, (req, start, end, final) in enumerate(grp):
            prompt = np.asarray(req.prompt)
            take = end - start
            tokens[i, :take] = prompt[start:end]
            slot_ids[i] = self._slot_of[req.seq_id]
            ctx[i] = end + front
            pctx[i] = start + front
            last_pos[i] = take - 1
            if not self._n_attn_layers:
                continue
            start_blk = (front + start) // bs
            for j, cb in enumerate(range(start_blk, (front + end) // bs)):
                if m.lookup(req.seq_id, cb)[0] >= 0:
                    continue      # shared-prefix block: already installed
                info = m.allocate_block(req.seq_id, cb)
                if info.seg == SWAP:
                    raise RuntimeError("pool exhausted during prefill")
                allocated.append((i, j, req.seq_id, cb))
        # allocation-time evictions queue slot migrations: drain them
        # BEFORE reading the prefix slots so the gather below sees the
        # post-copy pool layout — and re-resolve the NEW slots too: a
        # later allocation in the loop above may have evict-migrated an
        # earlier one, so the write slot captured at allocation time can
        # be stale (it already belongs to the evicting block)
        self._apply_copies()
        for i, j, sid, cb in allocated:
            new_slots[i, j] = m.lookup(sid, cb)[0]
        if self._n_attn_layers:
            for i, (req, start, end, final) in enumerate(grp):
                for cb in range((front + start) // bs):
                    slot, _ = m.lookup(req.seq_id, cb)
                    if slot < 0:
                        # a prefix block was evicted to swap: its data is
                        # gone and the prefix-KV read cannot rebuild it
                        raise RuntimeError(
                            "prefix block swapped out during chunked "
                            "prefill; grow the pool or use "
                            "prefill_mode='recompute'")
                    prefix_slots[i, cb] = slot
        any_sampled = any(not req.sampling.is_greedy
                          for req, _, _, _ in grp)
        _, self.dstate, pstats = self._prefix_step(
            self.params, self.dstate, {"tokens": jnp.asarray(tokens)},
            jnp.asarray(new_slots), jnp.asarray(prefix_slots),
            jnp.asarray(slot_ids), jnp.asarray(ctx), jnp.asarray(pctx),
            jnp.asarray(last_pos), sample=any_sampled)
        out = []
        for i, (req, start, end, final) in enumerate(grp):
            self._ctx_host[slot_ids[i]] = int(ctx[i])
            if final:
                out.append((req, pstats["next_token"][i]))
        return out

    def _complete_prefill(self, req: Request, nxt: int) -> None:
        self._prefilling.pop(req.seq_id, None)
        st = self._states[req.seq_id]
        st.generated.append(nxt)
        st.new_tokens.append(nxt)
        self._tokens_emitted += 1
        self._maybe_finish(st, nxt)

    def _finish(self, st: RequestState, reason: str) -> None:
        st.done = True
        st.finish_reason = reason
        if self.auto_release and st.request.seq_id in self._slot_of:
            self.release(st.request.seq_id)
        if self.metrics is not None:
            self.metrics.on_finish(st.request.seq_id, self._step_count,
                                   len(st.generated), reason)

    def _maybe_finish(self, st: RequestState, nxt: int) -> None:
        if st.done:
            return
        req = st.request
        hit_eos = req.eos_token is not None and nxt == req.eos_token
        if hit_eos or len(st.generated) >= req.max_new_tokens:
            self._finish(st, "stop" if hit_eos else "length")

    # ------------------------------------------------------------- serving
    def _make_sharded_scatter(self):
        """Build the mesh twin of ``_scatter_delta``: one jitted
        shard_map in which each shard keeps ONLY the delta entries whose
        set index (resp. flex vpn) falls in its own range, rebases them,
        and drops the rest out of bounds — dirty deltas are routed to the
        owning shard and nowhere else (DESIGN.md §sharded-serving).  The
        caller's out-of-bounds sentinels (padded device sizes) fall
        outside every shard's range, so an empty delta side still costs
        one dropped row, exactly like the local path."""
        part, spec = self.partition, self.spec
        spm, vpm = part.sets_per_shard, part.vpns_per_shard
        ma = spec.model_axis

        def local(tar, sf, flex, sets_idx, tar_rows, sf_rows, flex_idx,
                  flex_vals):
            mi = jax.lax.axis_index(ma)
            lo = (mi * spm).astype(sets_idx.dtype)
            si = jnp.where((sets_idx >= lo) & (sets_idx < lo + spm),
                           sets_idx - lo, spm)
            tar = tar.at[0, si].set(tar_rows, mode="drop")
            sf = sf.at[0, si].set(sf_rows, mode="drop")
            flo = (mi * vpm).astype(flex_idx.dtype)
            fi = jnp.where((flex_idx >= flo) & (flex_idx < flo + vpm),
                           flex_idx - flo, vpm)
            flex = flex.at[0, fi].set(flex_vals, mode="drop")
            return tar, sf, flex

        fn = jax.shard_map(
            local, mesh=self.mesh,
            in_specs=(P(None, ma, None), P(None, ma), P(None, ma),
                      P(), P(), P(), P(), P()),
            out_specs=(P(None, ma, None), P(None, ma), P(None, ma)),
            check_vma=False)
        return jax.jit(fn)

    def _sync_translation(self, full: bool = False) -> None:
        """Upload TAR/SF/flex changes.

        First call (or ``full=True``) uploads everything; afterwards only
        the entries dirtied since the previous sync are scattered — three
        bounded-size dispatches instead of re-streaming the whole tables.
        Under the mesh layout the full upload builds the PADDED mirrors
        (zero TAR rows / -1 flex entries past the real sizes) and places
        them with the state's shardings; deltas go through the
        ownership-routed sharded scatter.
        """
        m = self.manager
        if full or not self._synced_full:
            m.take_dirty()             # everything is covered below
            part = self.partition
            if part is None:
                self.dstate["tar"] = jnp.asarray(m.tar)[None]
                self.dstate["sf"] = jnp.asarray(m.sf)[None]
                self.dstate["flex"] = jnp.asarray(
                    m.flex_table.reshape(-1))[None]
            else:
                tar_h = np.zeros((part.n_sets_padded,) + m.tar.shape[1:],
                                 m.tar.dtype)
                tar_h[:m.tar.shape[0]] = m.tar
                sf_h = np.zeros(part.n_sets_padded, m.sf.dtype)
                sf_h[:m.sf.shape[0]] = m.sf
                flat = m.flex_table.reshape(-1)
                flex_h = np.full(part.vpn_padded, -1, flat.dtype)
                flex_h[:flat.size] = flat
                specs = kv_state_specs(self.dstate, self.spec)
                put = lambda k, a: jax.device_put(
                    a, NamedSharding(self.mesh, specs[k]))
                self.dstate["tar"] = put("tar", tar_h[None])
                self.dstate["sf"] = put("sf", sf_h[None])
                self.dstate["flex"] = put("flex", flex_h[None])
            self._synced_full = True
            return
        sets, flex_idx = m.take_dirty()
        if not sets.size and not flex_idx.size:
            return
        # pad to pow2 with a duplicate index (same value — benign); an
        # empty side passes one out-of-bounds sentinel row that the
        # jitted scatter drops.  ONE dispatch applies the whole delta.
        if sets.size:
            sets = _pad_pow2(sets, sets[0])
            tar_rows, sf_rows = m.tar[sets], m.sf[sets]
        else:
            sets = np.asarray([m.tar.shape[0]], np.int64)
            tar_rows = np.zeros((1,) + m.tar.shape[1:], m.tar.dtype)
            sf_rows = np.zeros(1, m.sf.dtype)
        flat = m.flex_table.reshape(-1)
        if flex_idx.size:
            flex_idx = _pad_pow2(flex_idx, flex_idx[0])
            flex_vals = flat[flex_idx]
        else:
            flex_idx = np.asarray([flat.size], np.int64)
            flex_vals = np.zeros(1, flat.dtype)
        if self.partition is not None:
            # sentinels must be out of bounds for the PADDED device
            # tables: the unpadded flex size can alias a padded position
            # (which must stay -1) and must not be written.
            sets = np.where(sets == m.tar.shape[0],
                            self.dstate["tar"].shape[1], sets)
            flex_idx = np.where(flex_idx == flat.size,
                                self.dstate["flex"].shape[1], flex_idx)
        self.dstate["tar"], self.dstate["sf"], self.dstate["flex"] = \
            self._scatter_delta(
                self.dstate["tar"], self.dstate["sf"], self.dstate["flex"],
                jnp.asarray(sets), jnp.asarray(tar_rows),
                jnp.asarray(sf_rows), jnp.asarray(flex_idx),
                jnp.asarray(flex_vals))

    def _apply_copies(self) -> None:
        """Apply pending slot migrations as ONE gather/scatter per pool.

        Chains inside a drain (a->b, b->c) are resolved host-side to the
        original source so the batched gather reads pre-copy contents with
        sequential semantics.
        """
        copies = self.manager.take_pending_copies()
        if not copies:
            return
        root: Dict[int, int] = {}
        for src, dst in copies:
            root[dst] = root.get(src, src)
        pairs = [(d, s) for d, s in root.items() if d != s]
        if not pairs:
            return
        # pad to pow2 by duplicating the first pair (duplicate scatter
        # index with the same value — benign): bounded scatter shapes
        dst = _pad_pow2(np.asarray([d for d, _ in pairs], np.int32),
                        pairs[0][0])
        src = _pad_pow2(np.asarray([s for _, s in pairs], np.int32),
                        pairs[0][1])
        if self.partition is not None:
            # manager slots are logical; the sharded pool is laid out in
            # shard-contiguous physical order.  GSPMD turns this into the
            # exact cross-shard data movement.
            dst = self.partition.phys(dst)
            src = self.partition.phys(src)
        dst, src = jnp.asarray(dst), jnp.asarray(src)
        for key in ("k_pool", "v_pool"):
            pool = self.dstate[key]
            self.dstate[key] = pool.at[:, dst].set(pool[:, src])

    def step(self) -> Dict[int, int]:
        """One engine step: admit under the prefill budget, then decode
        all live sequences.  Returns {seq_id: token} for every sequence
        that produced a token (prefill completions AND decodes).

        With speculative decoding a step can commit SEVERAL tokens per
        sequence; the returned value is the LAST token committed this
        step (the scalar contract preserved for direct-step drivers).
        Consume the full stream through ``poll()`` / ``stream()`` —
        their ``RequestOutput.new_token_ids`` carry every committed
        token — or ``Request.generated``.

        With a ``MetricsLogger`` attached (``EngineConfig.metrics``)
        each step additionally emits one host-side event — wall time on
        the monotonic clock, counter deltas, occupancy gauges — after
        the commit.  The logger path performs no device operation, so
        logger-on streams are bit-identical to logger-off."""
        if self.metrics is None:
            return self._step_impl()
        t0 = time.perf_counter()
        out = self._step_impl()
        wall = time.perf_counter() - t0
        self.metrics.on_step(self._step_count, wall,
                             self._metrics_counters(),
                             self._metrics_gauges())
        return out

    def _step_impl(self) -> Dict[int, int]:
        self._step_count += 1
        if self._injector is not None:
            # crash point "pre": the step boundary BEFORE this step
            # mutated anything — a scheduled InjectedStepFault simulates
            # the process dying here; recovery is restore-from-snapshot
            # (runtime/resilient_serve.py), never unwinding
            crash = getattr(self._injector, "maybe_crash", None)
            if crash is not None:
                crash(self._step_count, "pre")
            # safe point #1: before admission — a forced "pre" preempt
            # tears a victim out between prompt chunks / decode steps
            self._run_forced_preempts(
                self._injector.forced_preempts(self._step_count, "pre"))
        fetch = {}
        pending = self._admit(self.prefill_budget)
        for r, tok in pending:
            fetch[f"p{r.seq_id}"] = tok
        live = [self._states[sid] for sid, r in self.requests.items()
                if not self._states[sid].done
                and sid not in self._prefilling]
        m = self.manager
        bs = self.cfg.kv_block_size
        K = self.spec_K
        nblk = self.spec.max_blocks_per_seq
        if live and self._n_attn_layers:
            # map the blocks this dispatch will write FIRST: an
            # allocation miss may preempt another live sequence (it then
            # drops out of the batch below), so tokens/active are built
            # only after residency settles
            for st in live:
                if st.request.seq_id in self._slot_of:
                    self._ensure_decode_blocks(st)
            live = [st for st in live
                    if st.request.seq_id in self._slot_of]
        if live:
            # gather last tokens — all from host state, no device reads
            tokens = np.zeros(self.max_batch, np.int64)
            active = np.zeros(self.max_batch, bool)
            for st in live:
                slot = self._slot_of[st.request.seq_id]
                active[slot] = True
                tokens[slot] = st.generated[-1]
            self._apply_copies()
            self._sync_translation()
            # pre-step context snapshot: the telemetry mask below must
            # count the blocks that existed when the step TRANSLATED, and
            # the boundary block only if its allocation actually mapped
            ctx_pre = self._ctx_host.copy()

            any_sampled = any(not st.request.sampling.is_greedy
                              for st in live)
            step_fn = self._spec_step if K else self._serve_step
            logits, self.dstate, tstats = step_fn(
                self.params, self.dstate, jnp.asarray(tokens),
                jnp.asarray(active), sample=any_sampled)

            if K:
                # (B, K+1) window tokens + per-slot emitted counts ride
                # the same single fetch the scalar path uses
                fetch["next"] = tstats["acc_tokens"]
                fetch["n_emit"] = tstats["n_emit"]
            else:
                fetch["next"] = tstats["next_token"]
            fetch["ctx"] = self.dstate["ctx_len"]
            want_stats = self._n_attn_layers and self.track_stats
            if want_stats:
                fetch["in_rest"] = tstats["in_rest"]
                fetch["accesses"] = tstats["accesses"]
                fetch["mapped"] = tstats["mapped"]

        if not fetch:
            if self._injector is not None:
                self._run_forced_preempts(
                    self._injector.forced_preempts(self._step_count,
                                                   "post"))
                crash = getattr(self._injector, "maybe_crash", None)
                if crash is not None:
                    crash(self._step_count, "post")
            return {}
        # ---- the step's ONE device->host fetch --------------------------
        host = jax.device_get(fetch)

        out: Dict[int, int] = {}
        if live:
            self._ctx_host[:] = host["ctx"]
            # ---- feed translation telemetry back (PTW-cost tracking) ----
            if want_stats:
                live_slots = [self._slot_of[st.request.seq_id]
                              for st in live]
                live_mask = np.zeros(self.max_batch, bool)
                live_mask[live_slots] = True
                # pre-step block counts: blocks covering positions
                # [0, pos] — [0, pos+K] under speculation, the window the
                # verify dispatch attends — NOT the post-step ctx, whose
                # boundary block may not exist yet — further masked by
                # the device ``mapped`` flag so a failed (swapped)
                # allocation is not recorded as a flexible walk and fed
                # to the promoter
                n_pre = np.minimum((ctx_pre + K) // bs + 1, nblk)
                valid = (live_mask[:, None]
                         & (np.arange(nblk)[None, :] < n_pre[:, None])
                         & np.asarray(host["mapped"][0], bool))
                vpns = (np.arange(self.max_batch)[:, None] * nblk
                        + np.arange(nblk)[None, :])
                in_rest = np.asarray(host["in_rest"][0], bool)
                m.record_device_stats(vpns[valid], in_rest[valid],
                                      host["accesses"][0][valid])
                # the same telemetry, attributed per request: RestSeg
                # hits vs flexible walks for each sequence's own blocks
                hits_slot = (valid & in_rest).sum(axis=1)
                walks_slot = (valid & ~in_rest).sum(axis=1)
                for st, slot in zip(live, live_slots):
                    st.rsw_hits += int(hits_slot[slot])
                    st.flex_walks += int(walks_slot[slot])
                m.run_promotions()
                self._apply_copies()
            if K:
                self._commit_spec(live, host, ctx_pre, out)
            else:
                for st in live:
                    sid = st.request.seq_id
                    nxt = int(host["next"][self._slot_of[sid]])
                    st.generated.append(nxt)
                    st.new_tokens.append(nxt)
                    st.last_step = self._step_count
                    self._tokens_emitted += 1
                    out[sid] = nxt
                    self._maybe_finish(st, nxt)
        for r, _ in pending:
            nxt = int(host[f"p{r.seq_id}"])
            self._complete_prefill(r, nxt)
            out[r.seq_id] = nxt
        if self._injector is not None:
            # safe point #2: after the commit — under speculation this is
            # the adversarial moment between a window's verify/commit and
            # the next dispatch
            self._run_forced_preempts(
                self._injector.forced_preempts(self._step_count, "post"))
            # crash point "post": this step's commit is fully applied —
            # a crash here loses NOTHING the snapshot cadence covers, it
            # only forces the supervisor to replay from the last snapshot
            crash = getattr(self._injector, "maybe_crash", None)
            if crash is not None:
                crash(self._step_count, "post")
        return out

    def _commit_spec(self, live, host, ctx_pre, out) -> None:
        """Variable-length commit of the speculative window.

        The device already advanced ``ctx_len`` by ``n_emit`` in-graph;
        the host walks the emitted tokens in order, stopping early at
        eos / ``max_new_tokens`` exactly where sequential decode would.
        A truncated row's ``ctx_len`` is rewound (one batched scatter —
        upload, not fetch: the single-``device_get`` contract holds), and
        blocks a rejected or truncated tail had crossed into are
        deallocated (they hold nothing committed; KV inside kept blocks
        needs no rewind — positions at or beyond ``ctx_len`` are masked
        by every later read and rewritten before they are attended).
        """
        m = self.manager
        bs = self.cfg.kv_block_size
        K = self.spec_K
        nblk = self.spec.max_blocks_per_seq
        rewinds: Dict[int, int] = {}
        for st in live:
            sid = st.request.seq_id
            slot = self._slot_of[sid]
            pos = int(ctx_pre[slot])
            # capacity clamp: a window tail past the last KV block had
            # its K/V writes range-masked in-graph, so tokens emitted
            # from those query positions are NOT exact — never commit
            # them (the truncation rewind below restores ctx).  Callers
            # need no special max_seq_len sizing; overrun costs
            # re-verification, not correctness.  At cap == 0 even the
            # fed token's K/V write was masked, so NOTHING within the
            # window can ever become exact: the row is out of KV
            # capacity and finishes with a "length" stop.
            cap = self.spec.max_blocks_per_seq * bs - pos
            n_emit = int(host["n_emit"][slot])
            n = min(n_emit, cap) if cap > 0 else 0
            toks = host["next"][slot]
            committed = 0
            for i in range(n):
                t = int(toks[i])
                st.generated.append(t)
                st.new_tokens.append(t)
                out[sid] = t
                committed += 1
                self._maybe_finish(st, t)
                if st.done:
                    break
            # acceptance telemetry counts REALIZED drafts: the ones that
            # entered the stream (committed - 1; the +1 bonus token is
            # the target's own).  Rows sum exactly to the globals by
            # construction (cross-checked in tests).
            st.drafted += K
            st.accepted += max(committed - 1, 0)
            st.last_step = self._step_count
            self._tokens_emitted += committed
            self._spec_drafted += K
            self._spec_accepted += max(committed - 1, 0)
            if cap <= 0 and not st.done:
                self._finish(st, "length")
            if sid not in self._slot_of:
                continue    # finished AND auto-released: state already reset
            new_ctx = pos + committed
            # rewind whenever the host committed fewer tokens than the
            # device advanced IN-GRAPH (n_emit) — eos/max_new truncation
            # AND the capacity clamp above both leave ctx ahead otherwise
            if committed < n_emit:
                rewinds[slot] = new_ctx
                self._ctx_host[slot] = new_ctx
            if self._n_attn_layers:
                # free blocks a rejected/truncated tail faulted in past
                # the committed context.  A LIVE row keeps the block
                # containing its next write position (the engine feeds
                # the committed bonus token there on the very next step:
                # freeing it would be pure free->refault->resync churn,
                # ~25% step overhead measured at K=1).  A row that
                # finished mid-window gets the strict rule — nothing it
                # won't use may stay mapped.
                threshold = new_ctx if st.done else new_ctx + 1
                first_free = (threshold + bs - 1) // bs
                for b in range(first_free,
                               min((pos + K) // bs, nblk - 1) + 1):
                    m.free_block(sid, b)
        if rewinds:
            slots = _pad_pow2(np.fromiter(rewinds.keys(), np.int32,
                                          len(rewinds)),
                              next(iter(rewinds.keys())))
            vals = _pad_pow2(np.fromiter(rewinds.values(), np.int64,
                                         len(rewinds)),
                             next(iter(rewinds.values())))
            self.dstate["ctx_len"] = self.dstate["ctx_len"].at[
                jnp.asarray(slots)].set(
                    jnp.asarray(vals, self.dstate["ctx_len"].dtype))

    # ---------------------------------------------------- streaming output
    @property
    def step_count(self) -> int:
        """Engine steps executed so far (the scheduler's aging clock)."""
        return self._step_count

    def poll(self) -> List[RequestOutput]:
        """Advance the engine one step (if any work remains) and return a
        ``RequestOutput`` per request that produced tokens or finished
        since the previous poll.

        Under overload (``overload_policy="preempt"``, the default) a
        full pool preempts victims to the host KV tier and keeps
        serving; ``PoolExhausted`` survives only for requests that can
        NEVER run — a prompt whose footprint alone exceeds the pool, or
        a queue stuck behind finished-but-unreleased sequences — and
        carries structured occupancy diagnostics (``exc.diag``).

        Raises ``PoolExhausted`` when a step makes NO progress — no
        token decoded, no prompt chunk admitted, no sequence preempted
        or resumed — while requests are still queued: every slot is held
        by a finished-but-unreleased sequence (``auto_release=False``),
        so iterating would spin forever.  Release sequences or enable
        ``auto_release``."""
        self._enforce_deadlines()
        if self.has_unfinished():
            # slot count included: a zero-token finish (capacity stop)
            # that auto-releases its slot IS progress — the freed slot
            # admits a queued request on the next step.  So are
            # preempt/resume events (_progress_events): a step that only
            # rearranged residency is working, not stuck.
            before = (dict(self._prefilling), len(self.waiting),
                      len(self._slot_of), self._progress_events)
            out = self.step()
            if (not out and self.waiting
                    and before == (self._prefilling, len(self.waiting),
                                   len(self._slot_of),
                                   self._progress_events)):
                raise PoolExhausted(
                    f"{len(self.waiting)} queued request(s) cannot be "
                    "admitted and nothing is decoding: release finished "
                    "sequences or construct the engine with "
                    "auto_release=True", **self._pool_diag())
        return self._drain_outputs()

    def stream(self):
        """Iterate ``RequestOutput`` snapshots until every submitted
        request finishes."""
        while self.has_unfinished():
            yield from self.poll()
        # outputs produced by direct step() calls before streaming began
        yield from self._drain_outputs()

    def _drain_outputs(self) -> List[RequestOutput]:
        outs = []
        for sid, st in self._states.items():
            if st.new_tokens or (st.done and not st.finish_reported):
                outs.append(RequestOutput(
                    seq_id=sid, new_token_ids=tuple(st.new_tokens),
                    token_ids=tuple(st.generated), finished=st.done,
                    finish_reason=st.finish_reason))
                st.new_tokens = []
                if st.done:
                    st.finish_reported = True
        return outs

    # -------------------------------------------- cancellation / deadlines
    def cancel(self, seq_id: int, reason: str = "cancelled") -> bool:
        """Terminate a request wherever it is in its lifecycle — queued,
        mid-chunk prefill, decoding, or parked on the host KV tier — and
        reclaim everything it holds: its sequence slot, KV blocks, prefix
        cache refcounts and ledger claims (``check_invariants`` stays
        green afterwards, pinned in tests/test_recovery.py).

        The final ``RequestOutput`` carries ``finished=True`` with
        ``finish_reason="cancelled"`` (or ``"deadline"`` when invoked by
        the deadline sweep) and whatever tokens were generated before the
        cut.  Returns False — touching nothing — when the id is unknown
        or the request already finished.  The slot is force-released even
        under ``auto_release=False``: a cancelled request's holder has by
        definition stopped consuming it."""
        st = self._states.get(seq_id)
        if st is None or st.done:
            return False
        req = st.request
        if self._current is not None and self._current.seq_id == seq_id:
            self._current = None
        try:
            # queued (never admitted) or preempted requests sit in the
            # scheduler queue; live decoders do not
            self.scheduler.remove(req)
        except (ValueError, AttributeError):
            pass
        # a host-tier copy dies with the cancel: nothing left to resume
        # (preempt_request already freed the manager/ledger side)
        self._preempted.pop(seq_id, None)
        self._pending_samp = [(s, r) for s, r in self._pending_samp
                              if r.seq_id != seq_id]
        st.done = True
        st.finish_reason = reason
        if reason == "deadline":
            self._deadline_expired += 1
        else:
            self._cancelled += 1
        # a cancel IS progress for poll()'s no-progress detector: the
        # freed capacity admits a queued request on the next step
        self._progress_events += 1
        if seq_id in self._slot_of:
            self.release(seq_id)     # frees slot, blocks, pins, ledger
        else:
            # queued / preempted: no slot to tear down (preempt already
            # freed the manager side), only the registry bookkeeping
            rq = self.requests.pop(seq_id, None)
            self.finished[seq_id] = rq if rq is not None else req
            self._prefilling.pop(seq_id, None)
            self._chain_cache.pop(seq_id, None)
        if self.metrics is not None:
            self.metrics.on_finish(seq_id, self._step_count,
                                   len(st.generated), reason)
        return True

    def _enforce_deadlines(self) -> None:
        """Cancel every live request whose wall-clock budget elapsed
        (``Request.deadline_ms``), with ``finish_reason="deadline"``.
        Called at the top of ``poll()`` — deadline enforcement rides the
        serving loop, costing one clock read per poll and nothing when
        no request carries a deadline."""
        now = None
        for sid in [s for s, st in self._states.items()
                    if not st.done and st.deadline_at is not None]:
            if now is None:
                now = time.perf_counter()
            st = self._states[sid]
            if st.deadline_at is not None and now >= st.deadline_at:
                self.cancel(sid, reason="deadline")

    # ------------------------------------------------------------ teardown
    def release(self, seq_id: int) -> None:
        self.manager.free_sequence(seq_id)
        self._chain_cache.pop(seq_id, None)
        slot = self._slot_of.pop(seq_id)
        self.dstate["ctx_len"] = self.dstate["ctx_len"].at[slot].set(0)
        self._ctx_host[slot] = 0
        if self.spec_K:
            # a recycled slot must not draft from its predecessor's tokens
            self.dstate["hist"] = self.dstate["hist"].at[slot].set(-1)
        req = self.requests.pop(seq_id, None)
        if req is not None:
            self.finished[seq_id] = req
        if self._current is not None and self._current.seq_id == seq_id:
            self._current = None
        self._prefilling.pop(seq_id, None)
        self._sync_translation()

    # --------------------------------------------------- snapshot / restore
    _SNAP_FIELDS = (
        "requests", "finished", "_states", "_current", "_slot_of",
        "_prefilling", "_pending_samp", "_step_count", "admission_log",
        "_preempted", "_swap_bytes_out", "_swap_bytes_in",
        "_progress_events", "_request_preempts", "_request_resumes",
        "_dropped_preempts", "_dropped_resumes", "_tokens_emitted",
        "_spec_drafted", "_spec_accepted", "_cancelled",
        "_deadline_expired", "_chain_cache",
    )

    def snapshot(self) -> EngineSnapshot:
        """Capture the COMPLETE serving state as one portable value.

        Device side: every decode-state array except the tar/sf/flex
        translation mirrors — those are pure functions of the host tables
        and are rebuilt on restore through the exact
        ``_sync_translation(full=True)`` path live serving uses, so the
        snapshot never stores the same truth twice.  One batched
        ``device_get`` fetches everything (KV pools, ctx_len, recurrent
        ssm/conv/cross rows, the spec ``hist`` matrix, per-slot sampling
        params + PRNG keys).

        Host side: ONE ``pickle.dumps`` of the manager (TAR/SF/flex
        tables, AllocLedger, refcounts), prefix cache (directory + pins
        — it references the SAME manager object, and pickle's memo
        preserves that sharing), scheduler queue, request registries and
        ``RequestState``s (mid-chunk prefill progress, preempted
        host-tier sequences included), pending sampling scatters and all
        monotone counters.  Absolute ``deadline_at`` clocks are
        rebased to REMAINING budget (a monotonic timestamp is
        meaningless in the restoring process).

        Legal call points are step boundaries only — the same safe
        points as ``preempt_request`` — which is where
        ``ResilientServe`` calls it.  The snapshot is a value: it stays
        valid after the engine advances, and restoring it on a fresh
        engine of the same config replays bit-identically."""
        # pending slot migrations must land first so the fetched pool
        # bytes agree with the manager's (pickled) post-copy slot map
        self._apply_copies()
        dstate = {k: np.asarray(v) for k, v in jax.device_get(
            {k: v for k, v in self.dstate.items()
             if k not in ("tar", "sf", "flex")}).items()}
        now = time.perf_counter()
        deadline_remaining = {
            sid: st.deadline_at - now
            for sid, st in self._states.items()
            if st.deadline_at is not None and not st.done}
        payload: Dict[str, Any] = {
            f: getattr(self, f) for f in self._SNAP_FIELDS}
        payload["manager"] = self.manager
        payload["prefix_cache"] = self.prefix_cache
        payload["scheduler"] = self.scheduler
        payload["_ctx_host"] = self._ctx_host
        payload["_shard_swap_out"] = self._shard_swap_out
        payload["_shard_swap_in"] = self._shard_swap_in
        payload["deadline_remaining"] = deadline_remaining
        # the scheduler's back-pointer would drag the whole Engine (and
        # its params) into the blob; strip it around the dump
        sched = self.scheduler
        bound = getattr(sched, "_bound_engine", None)
        if bound is not None:
            sched._bound_engine = None
        try:
            blob = pickle.dumps(payload, pickle.HIGHEST_PROTOCOL)
        finally:
            if bound is not None:
                sched._bound_engine = bound
        return EngineSnapshot(version=SNAPSHOT_VERSION,
                              step=self._step_count, dstate=dstate,
                              host_blob=blob)

    def restore(self, snap: EngineSnapshot) -> None:
        """Overwrite this engine's serving state with ``snap``'s.

        The engine must have the same configuration the snapshot was
        taken under (same arch/pool/mesh shapes — the device key set is
        checked loudly).  Everything live is discarded: requests
        submitted after the snapshot are gone and must be resubmitted by
        the caller (``ResilientServe`` journals and replays them).
        After restore the engine continues bit-identically to the run
        that took the snapshot — pinned by the crash oracle in
        tests/test_recovery.py across greedy/sampled × spec on/off ×
        prefix-cache on/off × (1,2) mesh."""
        if snap.version != SNAPSHOT_VERSION:
            raise ValueError(
                f"snapshot version {snap.version} != engine "
                f"{SNAPSHOT_VERSION}: cross-version restore unsupported")
        expect = {k for k in self.dstate if k not in ("tar", "sf", "flex")}
        got = set(snap.dstate)
        if got != expect:
            raise ValueError(
                "snapshot device state does not match this engine "
                f"config: missing {sorted(expect - got)}, unexpected "
                f"{sorted(got - expect)}")
        host = pickle.loads(snap.host_blob)
        for f in self._SNAP_FIELDS:
            setattr(self, f, host[f])
        self.manager = host["manager"]
        self.prefix_cache = host["prefix_cache"]
        if getattr(self.scheduler, "_bound_engine", None) is self:
            self.scheduler._bound_engine = None
        self.scheduler = host["scheduler"]
        try:
            self.scheduler._bound_engine = self
        except AttributeError:
            pass
        self._ctx_host = np.asarray(host["_ctx_host"], np.int64).copy()
        self._shard_swap_out = np.asarray(host["_shard_swap_out"],
                                          np.int64).copy()
        self._shard_swap_in = np.asarray(host["_shard_swap_in"],
                                         np.int64).copy()
        # deadline budgets restart from the remaining time at snapshot:
        # the crash + restore pause does not count against a request
        now = time.perf_counter()
        for sid, rem in host["deadline_remaining"].items():
            st = self._states.get(sid)
            if st is not None:
                st.deadline_at = now + rem
        # device state: put the fetched arrays back (with the mesh's
        # shardings when sharded — specs computed from the CURRENT
        # dstate before overwriting, the key sets are identical)
        if self.mesh is not None:
            specs = kv_state_specs(self.dstate, self.spec)
            for k, v in snap.dstate.items():
                self.dstate[k] = jax.device_put(
                    v, NamedSharding(self.mesh, specs[k]))
        else:
            for k, v in snap.dstate.items():
                self.dstate[k] = jnp.asarray(v)
        # translation mirrors: rebuilt from the restored host tables via
        # the one true sync path (also clears the manager's dirty set)
        self._synced_full = False
        self._sync_translation(full=True)
        if self.metrics is not None:
            # the logger differentiates ABSOLUTE counters: rebase its
            # baseline so the rewind does not produce negative deltas
            self.metrics.rebase(self._metrics_counters())

    def _kv_block_bytes(self) -> int:
        """Device bytes one pool block occupies across both KV pools
        (all attention layers): the unit behind ``bytes_saved``."""
        k = self.dstate.get("k_pool")
        if k is None:
            return 0
        n_slots = int(k.shape[1])
        return int((k.nbytes + self.dstate["v_pool"].nbytes)
                   // max(n_slots, 1))

    # -------------------------------------------------------- live metrics
    def _metrics_counters(self) -> Dict[str, Any]:
        """ABSOLUTE monotone counters for the metrics logger (it
        differentiates them into per-step deltas).  Host-side reads
        only — the logger's totals agree with ``stats()`` at every step
        by construction (pinned in tests/test_metrics.py)."""
        m = self.manager
        pc = self.prefix_cache
        c: Dict[str, Any] = {
            "tokens": self._tokens_emitted,
            "rsw_hits": int(m.stats.get("rsw_hits", 0)),
            "flex_walks": int(m.stats.get("flex_walks", 0)),
            "swap_faults": int(m.stats.get("faults", 0)),
            "spec_drafted": self._spec_drafted,
            "spec_accepted": self._spec_accepted,
            "request_preempts": self._request_preempts,
            "request_resumes": self._request_resumes,
            "swap_bytes_out": self._swap_bytes_out,
            "swap_bytes_in": self._swap_bytes_in,
            "prefix_lookups": int(pc.stats["lookups"]) if pc else 0,
            "prefix_hits": int(pc.stats["hits"]) if pc else 0,
            "cancelled": self._cancelled,
            "deadline_expired": self._deadline_expired,
        }
        if self.partition is not None:
            c["shard_swap_bytes_out"] = [int(x)
                                         for x in self._shard_swap_out]
            c["shard_swap_bytes_in"] = [int(x)
                                        for x in self._shard_swap_in]
        return c

    def _metrics_gauges(self) -> Dict[str, Any]:
        """Point-in-time gauges copied into the step event verbatim."""
        m = self.manager
        total = self.hybrid_cfg.total_slots
        mapped = sum(1 for i in m.blocks.values() if i.slot >= 0)
        return {
            "pool_blocks": total,
            "mapped_blocks": mapped,
            "occupancy": mapped / max(total, 1),
            "live": sum(1 for sid in self.requests
                        if not self._states[sid].done),
            "queued": len(self.waiting),
            "host_tier_seqs": len(self._preempted),
        }

    def stats(self) -> dict:
        """Global manager counters plus ``"per_request"``: RestSeg hits /
        flexible walks / swap faults — and, under speculative decoding,
        drafts proposed (``drafted``) and accepted into the stream
        (``accepted``) — attributed to each seq_id (decode steps; live
        and finished requests both included).  The per-request
        ``drafted``/``accepted`` rows sum exactly to the global
        ``spec_drafted``/``spec_accepted`` counters (same attribution
        invariant as rsw_hits/flex_walks)."""
        s = dict(self.manager.stats)
        s["spec_drafted"] = self._spec_drafted
        s["spec_accepted"] = self._spec_accepted
        # overload/host-tier telemetry (ISSUE 6): sequence-granularity
        # preempt/resume counts, current host-tier residency, and the
        # host<->device swap traffic in bytes
        # request_preempts/resumes are MONOTONE engine counters, not
        # sums over the per-request rows: a finished request's row is
        # dropped on seq_id reuse, so a row sum would silently shrink.
        # The dropped share is surfaced too — sum(per-request rows) +
        # dropped == global (asserted in check_invariants, pinned with
        # a reuse test).
        s["overload"] = {
            "preempted_seqs": int(self.manager.stats.get("preempt_out", 0)),
            "resumed_seqs": int(self.manager.stats.get("preempt_in", 0)),
            "host_tier_seqs": len(self._preempted),
            "swap_bytes_out": self._swap_bytes_out,
            "swap_bytes_in": self._swap_bytes_in,
            "request_preempts": self._request_preempts,
            "request_resumes": self._request_resumes,
            "dropped_request_preempts": self._dropped_preempts,
            "dropped_request_resumes": self._dropped_resumes,
        }
        # prefix-cache telemetry: the per-request cached_blocks rows sum
        # exactly to the global dedup_blocks counter (same attribution
        # invariant as rsw_hits/flex_walks — cross-checked in tests)
        pc = self.prefix_cache
        # request-lifecycle robustness (ISSUE 10): explicit cancels and
        # wall-clock deadline expiries (monotone; survive snapshot/restore)
        s["lifecycle"] = {
            "cancelled": self._cancelled,
            "deadline_expired": self._deadline_expired,
        }
        s["prefix_cache"] = {
            "enabled": pc is not None,
            "lookups": int(pc.stats["lookups"]) if pc else 0,
            "hits": int(pc.stats["hits"]) if pc else 0,
            "dedup_blocks": int(pc.stats["dedup_blocks"]) if pc else 0,
            "bytes_saved": (int(pc.stats["dedup_blocks"])
                            * self._kv_block_bytes() if pc else 0),
            "inserts": int(pc.stats["inserts"]) if pc else 0,
            "insert_bypass": int(pc.stats["insert_bypass"]) if pc else 0,
            "evictions": int(pc.stats["evictions"]) if pc else 0,
            "cached_blocks": pc.n_entries if pc else 0,
        }
        s["per_request"] = {
            sid: {"rsw_hits": st.rsw_hits, "flex_walks": st.flex_walks,
                  "swap_faults": st.swap_faults, "drafted": st.drafted,
                  "accepted": st.accepted,
                  "cached_blocks": st.cached_blocks,
                  "preempts": st.preempts, "resumes": st.resumes}
            for sid, st in self._states.items()}
        if self.partition is not None:
            # per-shard view: each key sums EXACTLY to its global above
            # (shared mutation sites, not post-hoc reconciliation).
            # Spec counters describe replicated compute, charged to
            # shard 0 — NOT scaled by the shard count.
            s["shards"] = [
                {"rsw_hits": int(ss.get("rsw_hits", 0)),
                 "flex_walks": int(ss.get("flex_walks", 0)),
                 "swap_bytes_out": int(self._shard_swap_out[i]),
                 "swap_bytes_in": int(self._shard_swap_in[i]),
                 "spec_drafted": self._spec_drafted if i == 0 else 0,
                 "spec_accepted": self._spec_accepted if i == 0 else 0}
                for i, ss in enumerate(self.manager.shard_stats)]
        return s

    def check_invariants(self) -> None:
        """Engine-level oracle on top of the manager's: the device
        translation mirrors must equal the host tables (with zeroed /
        -1 padding past the real sizes under the mesh layout), and the
        per-shard swap-byte attribution must sum exactly to the global
        swap counters."""
        self.manager.check_invariants()
        if self.prefix_cache is not None:
            self.prefix_cache.check_invariants()
        # preempt/resume accounting: the monotone globals must equal the
        # surviving per-request rows plus the counts banked when rows
        # were dropped on seq_id reuse (ISSUE 9 bugfix — the old row-sum
        # global silently shrank on reuse)
        assert (sum(st.preempts for st in self._states.values())
                + self._dropped_preempts == self._request_preempts), \
            "per-request preempts + dropped != global request_preempts"
        assert (sum(st.resumes for st in self._states.values())
                + self._dropped_resumes == self._request_resumes), \
            "per-request resumes + dropped != global request_resumes"
        m = self.manager
        tar = np.asarray(jax.device_get(self.dstate["tar"]))[0]
        sf = np.asarray(jax.device_get(self.dstate["sf"]))[0]
        flex = np.asarray(jax.device_get(self.dstate["flex"]))[0]
        n_sets, flat = m.tar.shape[0], m.flex_table.reshape(-1)
        assert (tar[:n_sets] == m.tar).all(), "device TAR != host TAR"
        assert (sf[:n_sets] == m.sf).all(), "device SF != host SF"
        assert (flex[:flat.size] == flat).all(), "device flex != host flex"
        if self.partition is not None:
            assert (tar[n_sets:] == 0).all(), "padded TAR rows dirtied"
            assert (sf[n_sets:] == 0).all(), "padded SF rows dirtied"
            assert (flex[flat.size:] == -1).all(), "padded flex dirtied"
            assert int(self._shard_swap_out.sum()) == self._swap_bytes_out, \
                "per-shard swap-out bytes != global"
            assert int(self._shard_swap_in.sum()) == self._swap_bytes_in, \
                "per-shard swap-in bytes != global"
