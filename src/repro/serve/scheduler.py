"""Pluggable admission schedulers: the Scheduler protocol + 3 policies.

The engine's admission loop (``serve/engine.py::Engine._admit``) is
policy-free: it asks its scheduler WHICH waiting request to prefill next
and keeps budgets / chunking / slot registration / sharing to itself.  A
scheduler orders only requests whose prefill has NOT started — once a
request's first chunk is admitted the engine pops it and owns it as the
in-progress chunk until the final chunk installs, so a policy can never
interleave half-prefilled prompts.

Shipped policies (``EngineConfig.scheduler`` takes the name, an
instance, or a zero-arg factory):

* ``fifo``     — submission order; bit-for-bit the PR-2 hard-coded deque
  behaviour (pinned by tests/test_scheduler.py).
* ``spf``      — shortest-prompt-first: under a tight prefill budget,
  short prompts stop queueing behind long ones (ROADMAP item).
* ``priority`` — highest ``Request.priority`` first with linear aging:
  ``effective(now) = priority + aging_rate * (now - arrival)`` grows
  without bound while a request waits, so a low-priority long prompt is
  never starved by a stream of bounded-priority arrivals
  (hypothesis property test in tests/test_scheduler.py).

``now``/``arrival`` are in engine steps (the engine's step counter).
All policies break ties by submission order, so equal-keyed requests
drain FIFO.

Overload (ISSUE 6): policies additionally pick preemption VICTIMS.  When
the engine cannot get a KV block it calls ``victim(candidates, now)``
with the live ``RequestState`` objects (each exposing ``.request``,
``.arrival`` and ``.last_step`` — the step of its latest commit) and
preempts the returned one to the host tier; ``should_preempt(req,
arrival, victim_state, now)`` decides whether an INCOMING request may
evict a live one at admission time (only the priority policy ever says
yes — FIFO/SPF admission waits instead, avoiding preemption churn for
queue-position gains).  Both methods are optional on custom schedulers:
the engine falls back to :func:`default_victim` / never-preempt.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional, Protocol, Tuple, runtime_checkable

import numpy as np


@runtime_checkable
class Scheduler(Protocol):
    """Admission-ordering policy over not-yet-prefilling requests."""

    def add(self, req, arrival: int) -> None:
        """Enqueue a submitted request (``arrival`` = engine step)."""

    def select(self, now: int) -> Optional[object]:
        """Return the request to prefill next (without removing it), or
        None when empty.  Must be stable: repeated calls with the same
        queue and ``now`` return the same request."""

    def pop(self, req) -> None:
        """Remove ``req`` (the one ``select`` returned) from the queue."""

    def remove(self, req) -> None:
        """Remove ``req`` from ANY queue position (cancellation /
        deadline expiry — unlike ``pop``, the target need not be the
        currently selected head).  Raises ``ValueError`` when not
        queued."""

    def pending(self) -> Tuple[object, ...]:
        """Queued requests, best-first is NOT required (introspection)."""

    def __len__(self) -> int:
        ...


def default_victim(candidates, now: int):
    """LRU-decode victim selection (the engine's fallback policy).

    Prefer the sequence that committed least recently (``last_step``);
    among those, the youngest arrival — the oldest request has the most
    sunk work, so it is protected — and finally the latest-submitted
    ``seq_id``.  ``candidates`` is a non-empty list of the engine's
    ``RequestState`` objects."""
    return min(candidates,
               key=lambda st: (st.last_step, -st.arrival,
                               -st.request.seq_id))


class FIFOScheduler:
    """Submission order — the PR-2 deque, bit-for-bit."""

    def __init__(self) -> None:
        self._q: Deque = deque()

    # overload hooks: FIFO preempts the least-recently-decoded/youngest
    # sequence and never preempts on behalf of an incoming request
    victim = staticmethod(default_victim)

    def should_preempt(self, req, arrival: int, victim_state,
                       now: int) -> bool:
        return False

    def add(self, req, arrival: int) -> None:
        self._q.append(req)

    def select(self, now: int):
        return self._q[0] if self._q else None

    def pop(self, req) -> None:
        assert self._q and self._q[0] is req, "pop != selected head"
        self._q.popleft()

    def remove(self, req) -> None:
        # deque.remove compares with ==; Request is eq=False so this is
        # identity matching, same as the scan-based policies below
        try:
            self._q.remove(req)
        except ValueError:
            raise ValueError("request not queued") from None

    def pending(self) -> tuple:
        return tuple(self._q)

    def __len__(self) -> int:
        return len(self._q)


class ShortestPromptFirst:
    """Admit the shortest queued prompt first (ties: submission order).

    Under a tight prefill budget a long prompt chunks across many steps;
    admitting short prompts first bounds every short request's queueing
    delay by one long-prompt CHUNK instead of the whole long prompt.
    """

    def __init__(self) -> None:
        self._entries: List[Tuple[int, int, object]] = []
        self._n = 0                                   # insertion counter

    def add(self, req, arrival: int) -> None:
        self._entries.append((int(np.asarray(req.prompt).size), self._n,
                              req))
        self._n += 1

    @staticmethod
    def victim(candidates, now: int):
        """Longest prompt first — the mirror of the admission order: the
        sequence SPF values least is the one holding the most blocks."""
        return max(candidates,
                   key=lambda st: (int(np.asarray(st.request.prompt).size),
                                   st.arrival, st.request.seq_id))

    def should_preempt(self, req, arrival: int, victim_state,
                       now: int) -> bool:
        return False

    def select(self, now: int):
        if not self._entries:
            return None
        return min(self._entries)[2]

    def pop(self, req) -> None:
        for i, (_, _, r) in enumerate(self._entries):
            if r is req:
                del self._entries[i]
                return
        raise ValueError("request not queued")

    remove = pop       # pop already removes from any queue position

    def pending(self) -> tuple:
        return tuple(r for _, _, r in self._entries)

    def __len__(self) -> int:
        return len(self._entries)


class PriorityAgingScheduler:
    """Highest effective priority first, aging linearly while waiting.

    ``effective(now) = priority + aging_rate * (now - arrival)``; ties go
    to the earliest submission.  With ``aging_rate > 0`` and bounded
    request priorities, every waiting request's effective priority
    eventually exceeds any fresh arrival's, so nothing starves; with
    ``aging_rate = 0`` this is strict priority scheduling.
    """

    def __init__(self, aging_rate: float = 0.25) -> None:
        if aging_rate < 0:
            raise ValueError("aging_rate must be >= 0")
        self.aging_rate = aging_rate
        self._entries: List[Tuple[object, int, int]] = []  # (req, arr, n)
        self._n = 0

    def add(self, req, arrival: int) -> None:
        self._entries.append((req, int(arrival), self._n))
        self._n += 1

    def _effective(self, req, arrival: int, now: int) -> float:
        return float(getattr(req, "priority", 0)
                     + self.aging_rate * max(0, now - arrival))

    def select(self, now: int):
        best, best_key = None, None
        for req, arrival, n in self._entries:
            key = (self._effective(req, arrival, now), -n)
            if best_key is None or key > best_key:
                best, best_key = req, key
        return best

    def pop(self, req) -> None:
        for i, (r, _, _) in enumerate(self._entries):
            if r is req:
                del self._entries[i]
                return
        raise ValueError("request not queued")

    remove = pop       # pop already removes from any queue position

    def victim(self, candidates, now: int):
        """Lowest effective priority loses its blocks first; ties go to
        the youngest arrival, then the latest submission."""
        return min(candidates,
                   key=lambda st: (self._effective(st.request, st.arrival,
                                                   now),
                                   -st.arrival, -st.request.seq_id))

    def should_preempt(self, req, arrival: int, victim_state,
                       now: int) -> bool:
        """An incoming request may evict a live one only when its aged
        effective priority STRICTLY exceeds the victim's — equal
        priorities wait, so same-class traffic never thrashes."""
        return (self._effective(req, arrival, now)
                > self._effective(victim_state.request,
                                  victim_state.arrival, now))

    def pending(self) -> tuple:
        return tuple(r for r, _, _ in self._entries)

    def __len__(self) -> int:
        return len(self._entries)


SCHEDULERS = {
    "fifo": FIFOScheduler,
    "spf": ShortestPromptFirst,
    "priority": PriorityAgingScheduler,
}


def make_scheduler(spec) -> Scheduler:
    """Resolve ``EngineConfig.scheduler``: a policy name, a ready
    Scheduler instance, or a zero-arg factory/class."""
    if spec is None:
        return FIFOScheduler()
    if isinstance(spec, str):
        try:
            return SCHEDULERS[spec]()
        except KeyError:
            raise ValueError(f"unknown scheduler {spec!r}; known: "
                             f"{sorted(SCHEDULERS)}") from None
    if isinstance(spec, type) or not hasattr(spec, "select"):
        if callable(spec):
            return spec()
        raise TypeError(f"cannot resolve scheduler from {spec!r}")
    return spec
