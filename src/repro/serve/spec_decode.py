"""Speculative decoding on the serve path: draft → verify → accept,
K tokens per dispatch, in one graph.

Utopia's thesis is that per-access translation cost dominates when every
access pays the full lookup; the decode hot path has the same shape —
every generated token pays one full dispatch (RSW/TAR translate + layer
stack + device fetch).  Speculative decoding amortizes that fixed
per-step cost across a window of K draft tokens: ONE ``translate_step``,
ONE dispatch and ONE ``device_get`` now yield up to K+1 accepted tokens
(the SPARTA amortize-translation-across-accesses strategy, PAPERS.md).

Pieces, all in-graph so ``Engine.step()`` keeps its single-fetch
contract:

* **drafter** — self-drafted n-gram / prompt lookup: each slot's token
  history (``dstate["hist"]``, prompt scattered at admission, generated
  tokens appended in-graph) is matched against its own last ``ngram``
  tokens; the K tokens that followed the most recent earlier occurrence
  are proposed.  No second model, no extra dispatch, no host round-trip.
* **verify** — the target model runs over all K+1 window positions
  (the committed token plus K drafts) in one forward: K/V for every
  window position is written to its pool slot first (write slots are
  *gathered from the step's single translation* — no second lookup),
  then the Q>1 paged-attention path reads the pool with PER-QUERY
  extents ``pos + i + 1`` — exactly the mask sequential decode applies,
  so each position's logits match the non-speculative step's bitwise.
* **accept** — exact-match for greedy rows; for sampled rows the
  position-folded per-slot PRNG draw plays a maximal gumbel coupling of
  the rejection sampler (serve/sampling.py): lossless, and the emitted
  stream is token-identical to the non-speculative stream in BOTH
  modes (the differential oracle in tests/test_spec_decode.py).

Rejected tails need no device-side KV rewind: positions at or beyond the
advanced ``ctx_len`` are masked by every later read and rewritten before
they are ever attended.  The *engine* rewinds the host-visible state —
variable-length commit, eos/max-token truncation (with a ``ctx_len``
scatter back), and deallocation of blocks a rejected tail had crossed
into (``HybridKVManager.free_block``).

Recurrent (ssm/conv) families are not supported here — state rollback
for rejected tokens is not cheap — and the engine falls back to
non-speculative decode with a warn-once (ROADMAP item).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.partition import Partition
from repro.dist.sharding import kv_state_specs
from repro.models import layers as Lmod
from repro.models.transformer import ModelDims
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_attention_blocks)
from .decode import (DecodeSpec, _psum_gather_blocks, decode_cross,
                     decode_ffn, project_logits, translate_step,
                     translate_step_sharded)
from .sampling import sample_tokens_q, verify_draft_tokens

# families whose decode state is position-indexed only (KV pool / cross
# K/V): a rejected tail costs nothing to abandon.  ssm/hybrid carry
# recurrent state that every fed token mutates — rolling it back would
# need a per-layer state checkpoint per window position.
SPEC_FAMILIES = ("dense", "moe", "vlm", "audio")


def propose_ngram_drafts(hist: jax.Array, ctx: jax.Array, K: int,
                         ngram: int = 2) -> jax.Array:
    """In-graph prompt-lookup drafter.

    ``hist (B, H) int32`` — per-slot token history with the CURRENT token
    already written at position ``ctx[b]`` (unknown positions hold -1);
    ``ctx (B,)`` — the current token's position.  Returns ``(B, K)``
    proposed continuation tokens: the tokens that followed the most
    recent earlier occurrence of the history's last ``ngram``-gram.  When
    no earlier occurrence exists (or the match runs off the known
    history) the current token is repeated — any proposal is *valid*
    (verification is lossless); an unlikely one just accepts nothing.
    """
    B, H = hist.shape
    pos = jnp.arange(H, dtype=jnp.int32)[None, :]            # candidate end j
    match = (pos >= ngram - 1) & (pos < ctx[:, None])
    for d in range(ngram):
        suf = jnp.take_along_axis(
            hist, jnp.maximum(ctx[:, None] - d, 0), axis=1)  # (B, 1)
        # hist[j - d] via roll; j >= ngram-1 >= d keeps the wrap masked
        match = match & (jnp.roll(hist, d, axis=1) == suf)
    j_star = jnp.max(jnp.where(match, pos, -1), axis=1)      # (B,) latest
    has = j_star >= 0
    idx = j_star[:, None] + 1 + jnp.arange(K, dtype=jnp.int32)[None]
    known = idx <= ctx[:, None]
    gathered = jnp.take_along_axis(hist, jnp.clip(idx, 0, H - 1), axis=1)
    t0 = jnp.take_along_axis(hist, jnp.clip(ctx[:, None], 0, H - 1), axis=1)
    drafts = jnp.where(has[:, None] & known, gathered, t0)
    return jnp.maximum(drafts, 0)                            # -1 guard


def make_spec_decode_step(cfg: ArchConfig, dims: ModelDims,
                          spec: DecodeSpec, num_draft_tokens: int,
                          mesh=None, pins=Lmod.no_pins,
                          dtype=jnp.bfloat16, ngram: int = 2,
                          part: Partition = None):
    """Returns spec_step(params, dstate, tokens (B,), active, *, sample)
    -> (logits (B, K+1, V), new dstate, stats).

    ``stats`` carries the usual translation telemetry plus
    ``acc_tokens (B, K+1)`` / ``n_emit (B,)`` (commit
    ``acc_tokens[b, :n_emit[b]]``) and ``draft_tokens (B, K)`` — all
    in-graph, so the engine's fetch stays single.  ``dstate`` must hold
    the ``hist`` history buffer (the engine installs it when speculative
    decoding is configured).  Translation runs exactly once
    (``translate_step``); the K+1 per-position write slots are gathered
    from its result, never re-looked-up.
    """
    sharded = mesh is not None and spec.kv_shards >= 1
    if mesh is not None and not sharded:
        raise NotImplementedError(
            "speculative decode is single-host for now; the SPMD serve "
            "path (ROADMAP) drives the non-speculative step")
    if sharded and part is None:
        raise ValueError("spec.kv_shards >= 1 requires a Partition")
    if cfg.family not in SPEC_FAMILIES:
        raise ValueError(
            f"speculative decode does not support family {cfg.family!r} "
            "(recurrent state rollback); the engine falls back to "
            "non-speculative decode")
    K = int(num_draft_tokens)
    if K < 1:
        raise ValueError(f"num_draft_tokens must be >= 1, got {K}")
    Qw = K + 1
    bs = spec.block_size
    nblk = spec.max_blocks_per_seq
    fam = cfg.family

    def qkv_verify(blk, x, positions):
        B = x.shape[0]
        h = Lmod.rms_norm(x, blk["norm1"].astype(jnp.float32), cfg.norm_eps)
        q = Lmod.linear(blk["attn"]["q"], h).reshape(B, Qw, dims.n_heads,
                                                     dims.head_dim)
        k = Lmod.linear(blk["attn"]["k"], h).reshape(B, Qw, dims.n_kv,
                                                     dims.head_dim)
        v = Lmod.linear(blk["attn"]["v"], h).reshape(B, Qw, dims.n_kv,
                                                     dims.head_dim)
        if cfg.rope_theta > 0:
            q = Lmod.apply_rope(q, positions, cfg.rope_theta)
            k = Lmod.apply_rope(k, positions, cfg.rope_theta)
        return q, k, v

    def attn_sublayer(blk, x, kp_l, vp_l, slots_b, w_slot, w_valid,
                      positions, ctx_q):
        B = x.shape[0]
        q, k, v = qkv_verify(blk, x, positions)
        # write ALL window positions' K/V into their pre-resolved slots;
        # invalid (unmapped / inactive / out-of-range) scatter out of
        # bounds and drop — clamping would clobber a live block
        t_loc = positions % bs
        if sharded:
            # ownership-masked write + exact bit-psum gather; the Q>1
            # attention math itself is the same replicated path
            m_idx = jax.lax.axis_index(spec.model_axis)
            cps = part.slots_per_shard
            wp = part.phys(w_slot)
            mine_w = w_valid & ((wp // cps) == m_idx)
            ws = jnp.where(mine_w, wp - m_idx * cps, kp_l.shape[0])
            kp_l = kp_l.at[ws, t_loc].set(k.astype(kp_l.dtype),
                                          mode="drop")
            vp_l = vp_l.at[ws, t_loc].set(v.astype(vp_l.dtype),
                                          mode="drop")
            gk = _psum_gather_blocks(kp_l, slots_b, part, spec.model_axis)
            gv = _psum_gather_blocks(vp_l, slots_b, part, spec.model_axis)
            o, m_, l_ = paged_attention_blocks(q, gk, gv, slots_b, ctx_q)
        else:
            ws = jnp.where(w_valid, w_slot, kp_l.shape[0])
            kp_l = kp_l.at[ws, t_loc].set(k.astype(kp_l.dtype),
                                          mode="drop")
            vp_l = vp_l.at[ws, t_loc].set(v.astype(vp_l.dtype),
                                          mode="drop")
            # per-query extents pos+i+1: the sequential causal mask,
            # inside one pool read (verify-shaped Q>1 paged attention)
            o, m_, l_ = paged_attention_ref(q, kp_l, vp_l, slots_b, ctx_q)
        out = (o / jnp.maximum(l_, 1e-30)[..., None]).astype(q.dtype)
        o_p = Lmod.linear(blk["attn"]["o"],
                          out.reshape(B, Qw, -1).astype(x.dtype))
        return x + pins("dec_bd", o_p), kp_l, vp_l

    n_layers = cfg.num_layers

    def spec_step(params, dstate, tokens, active=None, *, sample=False):
        pos0 = dstate["ctx_len"]                       # fed token's position
        B = pos0.shape[0]
        act = (jnp.ones_like(pos0, jnp.bool_) if active is None
               else active.astype(jnp.bool_))
        row = jnp.arange(B, dtype=jnp.int32)
        t0 = tokens.astype(jnp.int32)
        hist = dstate["hist"]
        H = hist.shape[1]
        # current token enters the history BEFORE drafting: the drafter
        # matches the ngram that ENDS at it (inactive rows drop)
        p_safe = jnp.where(act & (pos0 < H), pos0, H)
        hist = hist.at[row, p_safe].set(t0, mode="drop")
        drafts = propose_ngram_drafts(hist, pos0, K, ngram)    # (B, K)
        seq_toks = jnp.concatenate([t0[:, None], drafts], axis=1)  # (B, Qw)
        positions = (pos0[:, None]
                     + jnp.arange(Qw, dtype=jnp.int32)[None, :])
        ctx_q = positions + 1                          # per-query extents

        x = jnp.take(params["embed"]["table"], seq_toks,
                     axis=0).astype(dtype)
        x = pins("dec_bd", x)
        new_state = dict(dstate)
        stats = {}

        # ---- the step's single translation dispatch ----------------------
        if sharded:
            trans = translate_step_sharded(
                dstate["tar"], dstate["sf"], dstate["flex"], pos0, spec,
                part)
        else:
            trans = translate_step(dstate["tar"], dstate["sf"],
                                   dstate["flex"], pos0, spec)
        stats.update(slots=trans.slots, in_rest=trans.in_rest,
                     mapped=trans.mapped, accesses=trans.accesses)
        slots_b = trans.slots[0]                       # (B, nblk); G == 1
        # per-position write slots GATHERED from the one translation —
        # position pos+i lives in block (pos+i)//bs, already resolved
        blk_idx = jnp.clip(positions // bs, 0, nblk - 1)
        w_slot = jnp.take_along_axis(slots_b, blk_idx, axis=1)
        w_map = jnp.take_along_axis(trans.mapped[0], blk_idx, axis=1)
        w_valid = w_map & (positions < nblk * bs) & act[:, None]

        xs = {"blk": params["layers"],
              "idx": jnp.arange(n_layers, dtype=jnp.int32)}
        if fam == "audio":
            xs["ck"] = dstate["cross_k"]
            xs["cv"] = dstate["cross_v"]

        def body(carry, xl):
            x, kp, vp = carry
            blk = xl["blk"]
            i = xl["idx"]
            kp_l = jax.lax.dynamic_index_in_dim(kp, i, 0, keepdims=False)
            vp_l = jax.lax.dynamic_index_in_dim(vp, i, 0, keepdims=False)
            x, kp_l, vp_l = attn_sublayer(blk, x, kp_l, vp_l, slots_b,
                                          w_slot, w_valid, positions, ctx_q)
            kp = jax.lax.dynamic_update_index_in_dim(kp, kp_l, i, 0)
            vp = jax.lax.dynamic_update_index_in_dim(vp, vp_l, i, 0)
            if fam == "audio":
                x = decode_cross(blk, x, xl["ck"], xl["cv"], cfg, dims,
                                 pins)
            x = decode_ffn(blk, x, cfg, pins)
            return (x, kp, vp), None

        (x, kp, vp), _ = jax.lax.scan(
            body, (x, dstate["k_pool"], dstate["v_pool"]), xs)
        new_state["k_pool"], new_state["v_pool"] = kp, vp

        logits = project_logits(params, x, cfg, dims, pins)

        # ---- in-graph accept: greedy exact-match / seeded coupled
        # rejection sampling — every target draw folds its ABSOLUTE
        # position, the same key the non-speculative step would fold
        if sample:
            tgt = sample_tokens_q(logits, dstate["samp_temp"],
                                  dstate["samp_topk"], dstate["samp_topp"],
                                  dstate["samp_key"], positions)
        else:
            tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        acc_tokens, n_emit = verify_draft_tokens(tgt, drafts)

        # emitted token i sits at position pos+i+1; rejected tails and
        # inactive rows drop (garbage must not enter the match history)
        wpos = positions + 1
        emit_ok = ((jnp.arange(Qw, dtype=jnp.int32)[None, :]
                    < n_emit[:, None]) & act[:, None] & (wpos < H))
        wp = jnp.where(emit_ok, wpos, H)
        hist = hist.at[row[:, None], wp].set(acc_tokens, mode="drop")
        new_state["hist"] = hist

        # variable-length advance, in-graph (single-fetch contract): only
        # active rows move, by exactly the emitted-token count
        new_state["ctx_len"] = (dstate["ctx_len"]
                                + jnp.where(act, n_emit, 0).astype(
                                    dstate["ctx_len"].dtype))
        stats["acc_tokens"] = acc_tokens
        stats["n_emit"] = n_emit
        stats["draft_tokens"] = drafts
        return logits, new_state, stats

    if not sharded:
        return spec_step

    def spec_step_sharded(params, dstate, tokens, active=None, *,
                          sample=False):
        act = (jnp.ones_like(dstate["ctx_len"], jnp.bool_) if active is None
               else active.astype(jnp.bool_))
        sspecs = kv_state_specs(dstate, spec)
        fn = jax.shard_map(
            functools.partial(spec_step, sample=sample),
            mesh=mesh, in_specs=(P(), sspecs, P(), P()),
            out_specs=(P(), sspecs, P()), check_vma=False)
        return fn(params, dstate, tokens, act)

    return spec_step_sharded
