from .decode import (DecodeSpec, make_decode_spec, make_serve_step,
                     init_decode_state, abstract_decode_state,
                     decode_state_shardings, translate_step,
                     translate_step_sharded)
from .engine import (ChunkRecord, Engine, EngineConfig, EngineSnapshot,
                     Request, RequestOutput, SNAPSHOT_VERSION)
from .metrics import (MetricsLogger, MetricsSink, MemorySink, JsonlSink,
                      RollingWindow)
from .sampling import SamplingParams
from .scheduler import (Scheduler, FIFOScheduler, ShortestPromptFirst,
                        PriorityAgingScheduler, make_scheduler, SCHEDULERS)
from .spec_decode import make_spec_decode_step, propose_ngram_drafts

__all__ = ["DecodeSpec", "make_decode_spec", "make_serve_step",
           "init_decode_state", "abstract_decode_state",
           "decode_state_shardings", "translate_step",
           "translate_step_sharded", "ChunkRecord", "Engine",
           "EngineConfig", "EngineSnapshot", "SNAPSHOT_VERSION",
           "Request", "RequestOutput", "MetricsLogger",
           "MetricsSink", "MemorySink", "JsonlSink", "RollingWindow",
           "SamplingParams",
           "Scheduler", "FIFOScheduler", "ShortestPromptFirst",
           "PriorityAgingScheduler", "make_scheduler", "SCHEDULERS",
           "make_spec_decode_step", "propose_ngram_drafts"]
