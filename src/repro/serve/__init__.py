from .decode import (DecodeSpec, make_decode_spec, make_serve_step,
                     init_decode_state, abstract_decode_state,
                     decode_state_shardings)
from .engine import Engine, Request

__all__ = ["DecodeSpec", "make_decode_spec", "make_serve_step",
           "init_decode_state", "abstract_decode_state",
           "decode_state_shardings", "Engine", "Request"]
