"""SPMD prefill steps: install prompt caches into the hybrid KV pool.

Two ways to admit a prompt chunk, sharing the install/scatter machinery:

* ``make_prefill_step`` — the full-(re)compute forward: the batch rows
  hold the WHOLE prefix up to the chunk end; the training-style forward
  recomputes every position and only the chunk's new blocks are
  installed.  Exact, but a chunked admission pays O(chunks²) compute.
* ``make_prefix_prefill_step`` — the prefix-KV chunk forward (chunk
  k > 0): the rows hold ONLY the chunk's new tokens; attention layers
  attend over (a) the prefix's already-installed pool blocks, gathered
  through the translated ``prefix_slots``, concatenated with (b) the
  chunk's own causal K/V — while recurrent (SSM/conv) layers continue
  from the saved per-slot state instead of recomputing it.  Chunk cost
  is linear in chunk length, independent of how long the prefix already
  is.

Re-admission after preemption (PR 6) enters through the SAME two steps:
a resumed sequence's saved KV is scattered back bitwise first, then any
not-yet-prefilled prompt tail continues as ordinary chunks — chunk k>0
prefix-KV against the restored blocks, no recompute.  There is no
separate resume forward; graceful degradation reuses this machinery.

Prefix-cache hits (PR 8, DESIGN.md §prefix-cache) enter the same way:
a request whose leading blocks matched the content-addressed cache
starts prefill AT THE TAIL — its first chunk is already a k>0 chunk
whose ``prefix_slots`` point at the cache-attached read-only blocks.
No prefill step knows about the cache; it only ever sees installed
prefix blocks, which is why cache-on streams are bit-identical to
cache-off (the PR-4 installed==recomputed pin carries the contract).

One dispatch admits a whole *bucket* of sequences: the prompts' K/V are
computed by the forward, then scattered into the pool slots the manager
translated (``slots`` input, produced host-side by fault-based
allocation) for ALL sequences at once.  The scatter runs inside
shard_map so every write is local to the (data-group, token-shard) that
owns the slot.

Calling convention shared by both steps (the admission scheduler's
contract):

* ``batch["tokens"]`` (B, S) — right-padded token rows.  Causal
  attention makes right padding safe: position t never attends beyond t,
  so every real position's activations are exact regardless of the pad
  tail.
* ``slots`` / ``new_slots`` (B, nblk) int32 — pool slot per cache block
  to install; ``-1`` blocks are DROPPED (pad blocks, blocks a previous
  chunk already installed, prefix-shared blocks).  The recompute step
  indexes blocks absolutely; the prefix step indexes them chunk-locally.
* ``slot_ids`` (B,) int32 — the batch slot each row belongs to; ``-1``
  rows (bucket padding) write nothing at all.
* ``ctx`` (B,) int32 — the post-install context length per row.
* ``last_pos`` (B,) int32 — index of the final real token in the logits
  sequence dim (per-row: rows are padded to the bucket length).

``ctx_len`` is scattered to PARTICIPATING slots only.  The pre-fix code
did ``jnp.full_like(dstate["ctx_len"], ctx)`` — stomping the context
length of every live sequence in the batch, which is what broke
continuous batching (ISSUE 2's headline bug).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import (FwdOptions, forward, dense_attention,
                          causal_attention_parts, merge_attention_parts)
from repro.models import layers as Lmod
from repro.models.layers import no_pins
from repro.models.ssm import MambaCache, mamba_forward
from repro.models.transformer import ModelDims, _ffn, hybrid_ffn_select
from repro.core.partition import Partition
from repro.dist.sharding import kv_state_specs
from repro.kernels.paged_attention.ref import (gather_pool_blocks,
                                               paged_attention_ref,
                                               paged_attention_blocks)
from .decode import DecodeSpec, _psum_gather_blocks
from .sampling import sample_tokens


def _scatter_pool(pool, cache, slots, mesh: Mesh, spec: DecodeSpec):
    """pool (L, G*slots, bs, KV, hd)  P(None, da, ma, None, None)
    cache (L, B, nblk, bs, KV, hd)    P(None, da, None, ma, None, None)
    slots (B, nblk) int32             P(da, None)
    """
    da, ma = spec.data_axes, spec.model_axis

    def local(pool, cache, slots):
        L = pool.shape[0]
        Bl, nblk = slots.shape
        flat = cache.reshape(L, Bl * nblk, *cache.shape[3:])
        sl = slots.reshape(-1)
        idx = jnp.where(sl >= 0, sl, pool.shape[1])  # invalid -> dropped
        return pool.at[:, idx].set(flat.astype(pool.dtype), mode="drop")

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(None, da, ma, None, None),
                  P(None, da, None, ma, None, None),
                  P(da, None)),
        out_specs=P(None, da, ma, None, None),
        check_vma=False)
    return fn(pool, cache, slots)


# --------------------------------------------------- shared install logic

def _install_kv(spec, mesh, dstate, new_state, caches, eff_slots, B,
                part: Optional[Partition] = None):
    """Scatter per-layer chunk K/V (L, B, S, KV, hd) into the pool at
    ``eff_slots`` (B, nblk); -1 entries (pads / already-installed /
    shared blocks) are dropped, never clamped.

    With ``part`` (running under the SPMD engine's whole-step shard_map)
    the scatter is ownership-masked: each shard converts the logical
    slots to physical, keeps only the ones inside its own chunk, and
    drops the rest out of bounds — installs route only to the owning
    shard, bitwise the same blocks the local path writes.
    """
    k, v = caches["k"], caches["v"]              # (L_attn, B, S_tot, KV, hd)
    L, _, S_tot, KV, hd = k.shape
    bs = spec.block_size
    nblk = S_tot // bs
    k = k.reshape(L, B, nblk, bs, KV, hd)
    v = v.reshape(L, B, nblk, bs, KV, hd)
    if part is not None:
        m = jax.lax.axis_index(spec.model_axis)
        cps = part.slots_per_shard
        sl = eff_slots.reshape(-1)
        ph = part.phys(sl)
        mine = (sl >= 0) & ((ph // cps) == m)
        idx = jnp.where(mine, ph - m * cps, dstate["k_pool"].shape[1])
        new_state["k_pool"] = dstate["k_pool"].at[:, idx].set(
            k.reshape(L, B * nblk, bs, KV, hd
                      ).astype(dstate["k_pool"].dtype), mode="drop")
        new_state["v_pool"] = dstate["v_pool"].at[:, idx].set(
            v.reshape(L, B * nblk, bs, KV, hd
                      ).astype(dstate["v_pool"].dtype), mode="drop")
    elif mesh is not None:
        con = NamedSharding(mesh, P(None, spec.data_axes, None,
                                    spec.model_axis, None, None))
        k = jax.lax.with_sharding_constraint(k, con)
        v = jax.lax.with_sharding_constraint(v, con)
        new_state["k_pool"] = _scatter_pool(
            dstate["k_pool"], k, eff_slots, mesh, spec)
        new_state["v_pool"] = _scatter_pool(
            dstate["v_pool"], v, eff_slots, mesh, spec)
    else:
        sl = eff_slots.reshape(-1)
        # -1 -> out-of-bounds, dropped (clamping to 0 would clobber
        # whichever live sequence owns pool slot 0)
        idx = jnp.where(sl >= 0, sl, dstate["k_pool"].shape[1])
        new_state["k_pool"] = dstate["k_pool"].at[:, idx].set(
            k.reshape(L, B * nblk, bs, KV, hd
                      ).astype(dstate["k_pool"].dtype), mode="drop")
        new_state["v_pool"] = dstate["v_pool"].at[:, idx].set(
            v.reshape(L, B * nblk, bs, KV, hd
                      ).astype(dstate["v_pool"].dtype), mode="drop")


def _install_recurrent(dstate, new_state, mc, sid, B):
    """Install per-row SSM/conv states at ``sid`` (pad rows scatter out of
    bounds and drop)."""
    state = mc.state if hasattr(mc, "state") else mc
    conv = mc.conv if hasattr(mc, "conv") else None
    st = state.reshape((-1, B) + dstate["ssm"].shape[2:])
    cv = conv.reshape((-1, B) + dstate["conv"].shape[2:])
    new_state["ssm"] = dstate["ssm"].at[:, sid].set(st, mode="drop")
    new_state["conv"] = dstate["conv"].at[:, sid].set(
        cv.astype(dstate["conv"].dtype), mode="drop")


def _first_token_stats(dstate, last, sid, ctx, n_slots, sample):
    """First generated token per row, computed in-graph so the engine can
    fold it into its single per-step device fetch.

    Sampled rows use the row's per-slot SamplingParams (scattered by the
    engine BEFORE the dispatch).  Fold position is ctx - 1: a token
    sampled from k context tokens folds k - 1, matching the decode step
    (pre-step ctx_len = k) so the stream is chunking- and
    schedule-independent.  Padding rows gather slot 0's params; their
    token is never read.  ``sample`` is trace-static, default False: an
    all-greedy bucket keeps the pre-sampling argmax-only trace.
    """
    if sample:
        sid_safe = jnp.clip(sid, 0, n_slots - 1)
        fold = jnp.maximum(ctx.astype(jnp.int32) - 1, 0)
        return {"next_token": sample_tokens(
            last, dstate["samp_temp"][sid_safe],
            dstate["samp_topk"][sid_safe], dstate["samp_topp"][sid_safe],
            dstate["samp_key"][sid_safe], fold)}
    return {"next_token": jnp.argmax(last, axis=-1).astype(jnp.int32)}


# ------------------------------------------------- full-(re)compute step

def make_prefill_step(cfg: ArchConfig, dims: ModelDims, spec: DecodeSpec,
                      mesh: Optional[Mesh] = None, pins=no_pins,
                      fwd: FwdOptions = FwdOptions(),
                      part: Optional[Partition] = None):
    """Returns prefill_step(params, dstate, batch, slots, slot_ids, ctx,
    last_pos) -> (last_logits (B, V), new dstate, stats).

    ``stats["next_token"]`` is the first generated token per row, computed
    in-graph (see ``_first_token_stats``).  With ``spec.kv_shards >= 1``
    (+ ``part``) the whole step runs under one shard_map over ``mesh``:
    the forward is replicated, only the pool scatter is ownership-routed
    (DESIGN.md §sharded-serving) — logits and installed blocks stay
    bitwise identical to ``mesh=None``.
    """
    fwd_collect = FwdOptions(**{**fwd.__dict__, "collect_cache": True})
    sharded = mesh is not None and spec.kv_shards >= 1
    if sharded and part is None:
        raise ValueError("spec.kv_shards >= 1 requires a Partition")
    part_in = part if sharded else None

    def prefill_step(params, dstate, batch, slots, slot_ids, ctx, last_pos,
                     *, sample=False):
        logits, aux, caches = forward(params, batch, cfg, dims, fwd_collect,
                                      pins)
        new_state = dict(dstate)
        B = batch["tokens"].shape[0]
        row_ok = slot_ids >= 0
        n_slots = dstate["ctx_len"].shape[0]
        # padding rows scatter out of bounds and are dropped
        sid = jnp.where(row_ok, slot_ids, n_slots).astype(jnp.int32)

        if caches.get("k") is not None and "k_pool" in dstate:
            eff_slots = jnp.where(row_ok[:, None], slots, -1)
            _install_kv(spec, None if sharded else mesh, dstate, new_state,
                        caches, eff_slots, B, part=part_in)
        if "ssm" in dstate and caches.get("ssm") is not None:
            _install_recurrent(dstate, new_state, caches["ssm"], sid, B)
        if cfg.is_encoder_decoder and "cross_k" in dstate:
            new_state["cross_k"] = dstate["cross_k"].at[:, sid].set(
                caches["ck"].astype(dstate["cross_k"].dtype), mode="drop")
            new_state["cross_v"] = dstate["cross_v"].at[:, sid].set(
                caches["cv"].astype(dstate["cross_v"].dtype), mode="drop")

        # THE bugfix: scatter ctx_len to participating slots only — never
        # touch the other sequences' context lengths
        new_state["ctx_len"] = dstate["ctx_len"].at[sid].set(
            ctx.astype(dstate["ctx_len"].dtype), mode="drop")

        last = jnp.take_along_axis(
            logits, last_pos[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        stats = _first_token_stats(dstate, last, sid, ctx, n_slots, sample)
        return last, new_state, stats

    if not sharded:
        return prefill_step

    def prefill_step_sharded(params, dstate, batch, slots, slot_ids, ctx,
                             last_pos, *, sample=False):
        sspecs = kv_state_specs(dstate, spec)
        fn = jax.shard_map(
            functools.partial(prefill_step, sample=sample),
            mesh=mesh, in_specs=(P(), sspecs) + (P(),) * 5,
            out_specs=(P(), sspecs, P()), check_vma=False)
        return fn(params, dstate, batch, slots, slot_ids, ctx, last_pos)

    return prefill_step_sharded


# ---------------------------------------------------- prefix-KV chunk step

def make_prefix_prefill_step(cfg: ArchConfig, dims: ModelDims,
                             spec: DecodeSpec,
                             mesh: Optional[Mesh] = None, pins=no_pins,
                             fwd: FwdOptions = FwdOptions(),
                             gather: Optional[str] = None,
                             part: Optional[Partition] = None):
    """Chunk-k (k > 0) prefill: forward ONLY the chunk's new tokens.

    Returns prefix_prefill_step(params, dstate, batch, new_slots,
    prefix_slots, slot_ids, ctx, prefix_ctx, last_pos) ->
    (last_logits (B, V), new dstate, stats) where

    * ``batch["tokens"]`` (B, S) — the chunk's tokens only, right-padded;
    * ``new_slots`` (B, S_pad/bs) — install slot per CHUNK-LOCAL block;
    * ``prefix_slots`` (B, nblk_buf) — the translated pool slot of every
      absolute block below the row's prefix (entries at/after the chunk
      start, and pad rows, are -1);
    * ``prefix_ctx`` (B,) — installed prefix tokens (frontend included):
      the absolute position of the chunk's first token;
    * ``ctx`` (B,) — post-install context length (= prefix_ctx + take).

    Attention layers attend over the gathered prefix blocks concatenated
    with the chunk's own causal K/V; recurrent layers continue from the
    per-slot saved ssm/conv state (state passing, no recompute); audio
    decoders read the installed per-layer cross K/V instead of re-running
    the encoder.  With ``gather="exact"`` (the default, via
    ``spec.prefix_gather``) the combined K/V is materialized at its
    absolute block positions and fed to the SAME dense softmax as the
    recompute forward — installed blocks and logits are bit-identical to
    full recompute, which is the differential-oracle contract.
    ``gather="paged"`` instead reads the pool through the Q>1
    ``kernels/paged_attention`` path (ref, or Pallas when
    ``spec.use_kernels``) and merges with the chunk-causal part by an
    online-softmax combine — O(chunk) memory and kernel-ready, equal to
    "exact" up to float associativity.
    """
    sharded = mesh is not None and spec.kv_shards >= 1
    if mesh is not None and not sharded:
        raise NotImplementedError(
            "prefix-KV prefill is single-host for now; the SPMD admission "
            "path (ROADMAP) still drives the recompute prefill")
    if sharded and part is None:
        raise ValueError("spec.kv_shards >= 1 requires a Partition")
    if gather is None:
        gather = spec.prefix_gather
    if gather not in ("exact", "paged"):
        raise ValueError(f"unknown prefix gather impl {gather!r}")
    if sharded and spec.use_kernels:
        raise NotImplementedError(
            "Pallas prefix gather is single-device; the sharded engine "
            "drives the ref path")
    opt = fwd
    bs = spec.block_size
    fam = cfg.family

    def attn_read(q, k_new, v_new, kp_l, vp_l, prefix_slots, prefix_ctx):
        B, S, H, hd = q.shape
        KV = k_new.shape[2]
        if gather == "paged":
            if sharded:
                # exact bit-psum assembly of the owned blocks, then the
                # SAME replicated Q>1 attention math
                gk = _psum_gather_blocks(kp_l, prefix_slots, part,
                                         spec.model_axis)
                gv = _psum_gather_blocks(vp_l, prefix_slots, part,
                                         spec.model_axis)
                pool = paged_attention_blocks(q, gk, gv, prefix_slots,
                                              prefix_ctx)
            elif spec.use_kernels:
                from repro.kernels.paged_attention.paged_attention import (
                    paged_attention_pallas)
                # interpret mode, stated explicitly: lowering the Pallas
                # kernels non-interpret on real TPU is the open ROADMAP
                # item shared with the decode/RSW kernels
                pool = paged_attention_pallas(q, kp_l, vp_l, prefix_slots,
                                              prefix_ctx, interpret=True)
            else:
                pool = paged_attention_ref(q, kp_l, vp_l, prefix_slots,
                                           prefix_ctx)
            own = causal_attention_parts(q, k_new, v_new)
            return merge_attention_parts([pool, own]).astype(q.dtype)
        # exact: place [gathered prefix | chunk K/V] at their absolute
        # block positions and run the recompute forward's own softmax
        nblk_buf = prefix_slots.shape[1]
        nblk_chunk = S // bs
        if sharded:
            # missing (-1) blocks come back all-zero from the bit-psum
            # gather; the ok-mask below zeroes them again (idempotent),
            # so this is bitwise the clamp-gather + mask of mesh=None
            gk = _psum_gather_blocks(kp_l, prefix_slots, part,
                                     spec.model_axis)
            gv = _psum_gather_blocks(vp_l, prefix_slots, part,
                                     spec.model_axis)
        else:
            gk = gather_pool_blocks(kp_l, prefix_slots)  # (B,nbuf,bs,KV,hd)
            gv = gather_pool_blocks(vp_l, prefix_slots)
        ok = (prefix_slots >= 0)[..., None, None, None]
        gk = jnp.where(ok, gk, 0.0).astype(k_new.dtype)
        gv = jnp.where(ok, gv, 0.0).astype(v_new.dtype)
        ck = k_new.reshape(B, nblk_chunk, bs, KV, hd)
        cv = v_new.reshape(B, nblk_chunk, bs, KV, hd)
        start_blk = (prefix_ctx // bs).astype(jnp.int32)
        j = jnp.arange(nblk_buf, dtype=jnp.int32)
        is_prefix = j[None, :] < start_blk[:, None]
        cj = jnp.clip(j[None, :] - start_blk[:, None], 0, nblk_chunk - 1)
        ck_g = jnp.take_along_axis(ck, cj[..., None, None, None], axis=1)
        cv_g = jnp.take_along_axis(cv, cj[..., None, None, None], axis=1)
        sel = is_prefix[..., None, None, None]
        # buffer blocks past the row's chunk end hold clipped duplicates;
        # they sit above every real query position, so the causal mask
        # removes them exactly (same tail-padding argument as the pow2
        # length buckets)
        k_full = jnp.where(sel, gk, ck_g).reshape(B, nblk_buf * bs, KV, hd)
        v_full = jnp.where(sel, gv, cv_g).reshape(B, nblk_buf * bs, KV, hd)
        return dense_attention(q, k_full, v_full, causal=True,
                               q_offset=prefix_ctx)

    def attn_sublayer(blk, x, kp_l, vp_l, prefix_slots, positions,
                      prefix_ctx):
        B, S, _ = x.shape
        h = Lmod.rms_norm(x, blk["norm1"].astype(jnp.float32), cfg.norm_eps)
        h = pins("act_full", h)
        q, k, v = Lmod.qkv_project(blk["attn"], h, h, dims.n_heads,
                                   dims.n_kv, dims.head_dim, positions,
                                   positions, cfg.rope_theta, pins)
        o = attn_read(q, k, v, kp_l, vp_l, prefix_slots, prefix_ctx)
        o = Lmod.linear(blk["attn"]["o"], o.reshape(B, S, -1))
        return x + pins("act_btd", o), (k, v)

    def mamba_sublayer(blk, x, ssm0, conv0, chunk_len):
        h = Lmod.rms_norm(x, blk["norm1"].astype(jnp.float32), cfg.norm_eps)
        h = pins("act_full", h)
        out, cache = mamba_forward(blk["mamba"], h, dims.mamba,
                                   chunk=cfg.ssm_chunk, pins=pins,
                                   initial_state=ssm0, initial_conv=conv0,
                                   seq_len=chunk_len, return_state=True)
        return x + pins("act_btd", out), cache

    def cross_sublayer(blk, x, ck, cv):
        B, S, _ = x.shape
        h = Lmod.rms_norm(x, blk["norm_x"].astype(jnp.float32), cfg.norm_eps)
        q = Lmod.linear(blk["cross"]["q"], h).reshape(B, S, dims.n_heads,
                                                      dims.head_dim)
        o = dense_attention(q, ck, cv, causal=False)
        return x + pins("act_btd",
                        Lmod.linear(blk["cross"]["o"], o.reshape(B, S, -1)))

    def prefix_prefill_step(params, dstate, batch, new_slots, prefix_slots,
                            slot_ids, ctx, prefix_ctx, last_pos, *,
                            sample=False):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = Lmod.embed(params["embed"], tokens, pins).astype(opt.dtype)
        positions = (prefix_ctx[:, None].astype(jnp.int32)
                     + jnp.arange(S, dtype=jnp.int32)[None, :])
        row_ok = slot_ids >= 0
        n_slots = dstate["ctx_len"].shape[0]
        sid = jnp.where(row_ok, slot_ids, n_slots).astype(jnp.int32)
        sid_safe = jnp.clip(slot_ids, 0, n_slots - 1)
        # per-row real chunk length: the recurrent mask that makes the
        # pow2 pad tail an exact identity transition of the SSM state
        chunk_len = (ctx - prefix_ctx).astype(jnp.int32)

        if fam in ("dense", "moe", "vlm"):
            xs = {"blk": params["layers"],
                  "kp": dstate["k_pool"], "vp": dstate["v_pool"]}

            def body(x, xl):
                x, (k, v) = attn_sublayer(xl["blk"], x, xl["kp"], xl["vp"],
                                          prefix_slots, positions,
                                          prefix_ctx)
                x, _ = _ffn(xl["blk"], x, cfg, dims, opt, pins)
                return x, {"k": k, "v": v}

            x, ys = jax.lax.scan(body, x, xs)
            caches = {"k": ys["k"], "v": ys["v"]}
        elif fam == "ssm":
            xs = {"blk": params["layers"],
                  "ssm": dstate["ssm"][:, sid_safe],
                  "conv": dstate["conv"][:, sid_safe]}

            def body(x, xl):
                x, cache = mamba_sublayer(xl["blk"], x, xl["ssm"],
                                          xl["conv"], chunk_len)
                return x, {"state": cache.state, "conv": cache.conv}

            x, ys = jax.lax.scan(body, x, xs)
            caches = {"ssm": MambaCache(conv=ys["conv"], state=ys["state"])}
        elif fam == "hybrid":
            g = cfg.attn_every
            n_groups = cfg.num_layers // g
            n_mamba = g - 1
            xs = {"blk": params["layers"],
                  "kp": dstate["k_pool"], "vp": dstate["v_pool"],
                  "ssm": dstate["ssm"][:, sid_safe].reshape(
                      (n_groups, n_mamba, B) + dstate["ssm"].shape[2:]),
                  "conv": dstate["conv"][:, sid_safe].reshape(
                      (n_groups, n_mamba, B) + dstate["conv"].shape[2:])}

            def body(x, xl):
                blk = xl["blk"]
                ssm_out, conv_out = [], []
                k = v = None
                for i in range(g):
                    if i < g - 1:
                        sub = jax.tree.map(lambda a, i=i: a[i], blk["mamba"])
                        x, cache = mamba_sublayer(sub, x, xl["ssm"][i],
                                                  xl["conv"][i], chunk_len)
                        ssm_out.append(cache.state)
                        conv_out.append(cache.conv)
                    else:
                        x, (k, v) = attn_sublayer(
                            blk["attn"], x, xl["kp"], xl["vp"],
                            prefix_slots, positions, prefix_ctx)
                    x, _ = _ffn(hybrid_ffn_select(cfg, blk, i), x, cfg,
                                dims, opt, pins)
                return x, {"k": k, "v": v, "ssm": jnp.stack(ssm_out),
                           "conv": jnp.stack(conv_out)}

            x, ys = jax.lax.scan(body, x, xs)
            caches = {"k": ys["k"], "v": ys["v"],
                      "ssm": MambaCache(conv=ys["conv"], state=ys["ssm"])}
        elif fam == "audio":
            xs = {"blk": params["layers"],
                  "kp": dstate["k_pool"], "vp": dstate["v_pool"],
                  "ck": dstate["cross_k"][:, sid_safe],
                  "cv": dstate["cross_v"][:, sid_safe]}

            def body(x, xl):
                x, (k, v) = attn_sublayer(xl["blk"], x, xl["kp"], xl["vp"],
                                          prefix_slots, positions,
                                          prefix_ctx)
                x = cross_sublayer(xl["blk"], x, xl["ck"], xl["cv"])
                x, _ = _ffn(xl["blk"], x, cfg, dims, opt, pins)
                return x, {"k": k, "v": v}

            x, ys = jax.lax.scan(body, x, xs)
            caches = {"k": ys["k"], "v": ys["v"]}
        else:
            raise ValueError(fam)

        new_state = dict(dstate)
        if caches.get("k") is not None and "k_pool" in dstate:
            eff_slots = jnp.where(row_ok[:, None], new_slots, -1)
            _install_kv(spec, None, dstate, new_state, caches,
                        eff_slots, B, part=part if sharded else None)
        if "ssm" in dstate and caches.get("ssm") is not None:
            _install_recurrent(dstate, new_state, caches["ssm"], sid, B)
        # no cross install: chunk 0 (recompute) ran the encoder and
        # installed the per-layer cross K/V this step just read

        new_state["ctx_len"] = dstate["ctx_len"].at[sid].set(
            ctx.astype(dstate["ctx_len"].dtype), mode="drop")

        x = Lmod.rms_norm(x, params["final_norm"].astype(jnp.float32),
                          cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = Lmod.unembed(head, x, dims.logical_vocab, pins)
        last = jnp.take_along_axis(
            logits, last_pos[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        stats = _first_token_stats(dstate, last, sid, ctx, n_slots, sample)
        return last, new_state, stats

    if not sharded:
        return prefix_prefill_step

    def prefix_step_sharded(params, dstate, batch, new_slots, prefix_slots,
                            slot_ids, ctx, prefix_ctx, last_pos, *,
                            sample=False):
        sspecs = kv_state_specs(dstate, spec)
        fn = jax.shard_map(
            functools.partial(prefix_prefill_step, sample=sample),
            mesh=mesh, in_specs=(P(), sspecs) + (P(),) * 7,
            out_specs=(P(), sspecs, P()), check_vma=False)
        return fn(params, dstate, batch, new_slots, prefix_slots, slot_ids,
                  ctx, prefix_ctx, last_pos)

    return prefix_step_sharded
