"""SPMD prefill step: forward + install caches into the hybrid KV pool.

The prompt's K/V are computed by the training-style forward (chunked flash
attention), then scattered into the pool slots the manager translated
(``slots`` input, produced host-side by fault-based allocation).  The
scatter runs inside shard_map so every write is local to the (data-group,
token-shard) that owns the slot — the cache is resharded once
(nblk-split -> block-token-split all-to-all) which the roofline's
collective term accounts for.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import FwdOptions, forward
from repro.models.layers import no_pins
from repro.models.transformer import ModelDims
from .decode import DecodeSpec


def _scatter_pool(pool, cache, slots, mesh: Mesh, spec: DecodeSpec):
    """pool (L, G*slots, bs, KV, hd)  P(None, da, ma, None, None)
    cache (L, B, nblk, bs, KV, hd)    P(None, da, None, ma, None, None)
    slots (B, nblk) int32             P(da, None)
    """
    da, ma = spec.data_axes, spec.model_axis

    def local(pool, cache, slots):
        L = pool.shape[0]
        Bl, nblk = slots.shape
        flat = cache.reshape(L, Bl * nblk, *cache.shape[3:])
        sl = slots.reshape(-1)
        idx = jnp.where(sl >= 0, sl, pool.shape[1])  # invalid -> dropped
        return pool.at[:, idx].set(flat.astype(pool.dtype), mode="drop")

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(None, da, ma, None, None),
                  P(None, da, None, ma, None, None),
                  P(da, None)),
        out_specs=P(None, da, ma, None, None),
        check_vma=False)
    return fn(pool, cache, slots)


def make_prefill_step(cfg: ArchConfig, dims: ModelDims, spec: DecodeSpec,
                      mesh: Optional[Mesh] = None, pins=no_pins,
                      fwd: FwdOptions = FwdOptions()):
    """Returns prefill_step(params, dstate, batch, slots) ->
    (last_logits (B, V), new dstate)."""
    fwd_collect = FwdOptions(**{**fwd.__dict__, "collect_cache": True})

    def prefill_step(params, dstate, batch, slots):
        logits, aux, caches = forward(params, batch, cfg, dims, fwd_collect,
                                      pins)
        new_state = dict(dstate)
        S = batch["tokens"].shape[1]
        B = batch["tokens"].shape[0]
        ctx = S + (cfg.frontend_tokens if cfg.family == "vlm" else 0)

        if caches.get("k") is not None and "k_pool" in dstate:
            k, v = caches["k"], caches["v"]          # (L_attn, B, S_tot, KV, hd)
            L, _, S_tot, KV, hd = k.shape
            bs = spec.block_size
            nblk = S_tot // bs
            k = k.reshape(L, B, nblk, bs, KV, hd)
            v = v.reshape(L, B, nblk, bs, KV, hd)
            if mesh is not None:
                con = NamedSharding(mesh, P(None, spec.data_axes, None,
                                            spec.model_axis, None, None))
                k = jax.lax.with_sharding_constraint(k, con)
                v = jax.lax.with_sharding_constraint(v, con)
                new_state["k_pool"] = _scatter_pool(
                    dstate["k_pool"], k, slots, mesh, spec)
                new_state["v_pool"] = _scatter_pool(
                    dstate["v_pool"], v, slots, mesh, spec)
            else:
                idx = jnp.maximum(slots.reshape(-1), 0)
                new_state["k_pool"] = dstate["k_pool"].at[:, idx].set(
                    k.reshape(L, B * nblk, bs, KV, hd
                              ).astype(dstate["k_pool"].dtype))
                new_state["v_pool"] = dstate["v_pool"].at[:, idx].set(
                    v.reshape(L, B * nblk, bs, KV, hd
                              ).astype(dstate["v_pool"].dtype))

        if "ssm" in dstate and caches.get("ssm") is not None:
            mc = caches["ssm"]
            state = mc.state if hasattr(mc, "state") else mc
            conv = mc.conv if hasattr(mc, "conv") else None
            new_state["ssm"] = state.reshape(dstate["ssm"].shape)
            new_state["conv"] = conv.reshape(dstate["conv"].shape).astype(
                dstate["conv"].dtype)
        if cfg.is_encoder_decoder and "cross_k" in dstate:
            new_state["cross_k"] = caches["ck"].astype(
                dstate["cross_k"].dtype)
            new_state["cross_v"] = caches["cv"].astype(
                dstate["cross_v"].dtype)
        new_state["ctx_len"] = jnp.full_like(dstate["ctx_len"], ctx)
        return logits[:, -1], new_state

    return prefill_step
