"""SPMD prefill step: multi-sequence forward + install caches into the
hybrid KV pool.

One dispatch admits a whole *bucket* of sequences: the prompts' K/V are
computed by the training-style forward (chunked flash attention), then
scattered into the pool slots the manager translated (``slots`` input,
produced host-side by fault-based allocation) for ALL sequences at once.
The scatter runs inside shard_map so every write is local to the
(data-group, token-shard) that owns the slot — the cache is resharded once
(nblk-split -> block-token-split all-to-all) which the roofline's
collective term accounts for.

Calling convention (the admission scheduler's contract):

* ``batch["tokens"]`` (B, S) — right-padded prompt prefixes.  Causal
  attention makes right padding safe: position t never attends beyond t,
  so every real position's activations are exact regardless of the pad
  tail.  For a *chunked* admission the row holds the full prefix up to
  the chunk end (the forward recomputes earlier chunks; only the new
  blocks are installed — their recomputed K/V are bit-identical).
* ``slots`` (B, nblk) int32 — pool slot per cache block to install;
  ``-1`` blocks are DROPPED (pad blocks, blocks a previous chunk already
  installed, prefix-shared blocks).
* ``slot_ids`` (B,) int32 — the batch slot each row belongs to; ``-1``
  rows (bucket padding) write nothing at all.
* ``ctx`` (B,) int32 — the post-install context length per row.
* ``last_pos`` (B,) int32 — index of the final real token in the logits
  sequence dim (per-row: rows are padded to the bucket length).

``ctx_len`` is scattered to PARTICIPATING slots only.  The pre-fix code
did ``jnp.full_like(dstate["ctx_len"], ctx)`` — stomping the context
length of every live sequence in the batch, which is what broke
continuous batching (ISSUE 2's headline bug).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import FwdOptions, forward
from repro.models.layers import no_pins
from repro.models.transformer import ModelDims
from .decode import DecodeSpec
from .sampling import sample_tokens


def _scatter_pool(pool, cache, slots, mesh: Mesh, spec: DecodeSpec):
    """pool (L, G*slots, bs, KV, hd)  P(None, da, ma, None, None)
    cache (L, B, nblk, bs, KV, hd)    P(None, da, None, ma, None, None)
    slots (B, nblk) int32             P(da, None)
    """
    da, ma = spec.data_axes, spec.model_axis

    def local(pool, cache, slots):
        L = pool.shape[0]
        Bl, nblk = slots.shape
        flat = cache.reshape(L, Bl * nblk, *cache.shape[3:])
        sl = slots.reshape(-1)
        idx = jnp.where(sl >= 0, sl, pool.shape[1])  # invalid -> dropped
        return pool.at[:, idx].set(flat.astype(pool.dtype), mode="drop")

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(None, da, ma, None, None),
                  P(None, da, None, ma, None, None),
                  P(da, None)),
        out_specs=P(None, da, ma, None, None),
        check_vma=False)
    return fn(pool, cache, slots)


def make_prefill_step(cfg: ArchConfig, dims: ModelDims, spec: DecodeSpec,
                      mesh: Optional[Mesh] = None, pins=no_pins,
                      fwd: FwdOptions = FwdOptions()):
    """Returns prefill_step(params, dstate, batch, slots, slot_ids, ctx,
    last_pos) -> (last_logits (B, V), new dstate, stats).

    ``stats["next_token"]`` is the greedy first generated token per row,
    computed in-graph so the engine can fold it into its single per-step
    device fetch.
    """
    fwd_collect = FwdOptions(**{**fwd.__dict__, "collect_cache": True})

    def prefill_step(params, dstate, batch, slots, slot_ids, ctx, last_pos,
                     *, sample=False):
        logits, aux, caches = forward(params, batch, cfg, dims, fwd_collect,
                                      pins)
        new_state = dict(dstate)
        B = batch["tokens"].shape[0]
        row_ok = slot_ids >= 0
        n_slots = dstate["ctx_len"].shape[0]
        # padding rows scatter out of bounds and are dropped
        sid = jnp.where(row_ok, slot_ids, n_slots).astype(jnp.int32)

        if caches.get("k") is not None and "k_pool" in dstate:
            k, v = caches["k"], caches["v"]          # (L_attn, B, S_tot, KV, hd)
            L, _, S_tot, KV, hd = k.shape
            bs = spec.block_size
            nblk = S_tot // bs
            k = k.reshape(L, B, nblk, bs, KV, hd)
            v = v.reshape(L, B, nblk, bs, KV, hd)
            eff_slots = jnp.where(row_ok[:, None], slots, -1)
            if mesh is not None:
                con = NamedSharding(mesh, P(None, spec.data_axes, None,
                                            spec.model_axis, None, None))
                k = jax.lax.with_sharding_constraint(k, con)
                v = jax.lax.with_sharding_constraint(v, con)
                new_state["k_pool"] = _scatter_pool(
                    dstate["k_pool"], k, eff_slots, mesh, spec)
                new_state["v_pool"] = _scatter_pool(
                    dstate["v_pool"], v, eff_slots, mesh, spec)
            else:
                sl = eff_slots.reshape(-1)
                # -1 -> out-of-bounds, dropped (clamping to 0 would
                # clobber whichever live sequence owns pool slot 0)
                idx = jnp.where(sl >= 0, sl, dstate["k_pool"].shape[1])
                new_state["k_pool"] = dstate["k_pool"].at[:, idx].set(
                    k.reshape(L, B * nblk, bs, KV, hd
                              ).astype(dstate["k_pool"].dtype), mode="drop")
                new_state["v_pool"] = dstate["v_pool"].at[:, idx].set(
                    v.reshape(L, B * nblk, bs, KV, hd
                              ).astype(dstate["v_pool"].dtype), mode="drop")

        if "ssm" in dstate and caches.get("ssm") is not None:
            mc = caches["ssm"]
            state = mc.state if hasattr(mc, "state") else mc
            conv = mc.conv if hasattr(mc, "conv") else None
            st = state.reshape((-1, B) + dstate["ssm"].shape[2:])
            cv = conv.reshape((-1, B) + dstate["conv"].shape[2:])
            new_state["ssm"] = dstate["ssm"].at[:, sid].set(
                st, mode="drop")
            new_state["conv"] = dstate["conv"].at[:, sid].set(
                cv.astype(dstate["conv"].dtype), mode="drop")
        if cfg.is_encoder_decoder and "cross_k" in dstate:
            new_state["cross_k"] = dstate["cross_k"].at[:, sid].set(
                caches["ck"].astype(dstate["cross_k"].dtype), mode="drop")
            new_state["cross_v"] = dstate["cross_v"].at[:, sid].set(
                caches["cv"].astype(dstate["cross_v"].dtype), mode="drop")

        # THE bugfix: scatter ctx_len to participating slots only — never
        # touch the other sequences' context lengths
        new_state["ctx_len"] = dstate["ctx_len"].at[sid].set(
            ctx.astype(dstate["ctx_len"].dtype), mode="drop")

        last = jnp.take_along_axis(
            logits, last_pos[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        # first generated token, sampled in-graph with the row's per-slot
        # SamplingParams (scattered by the engine BEFORE this dispatch).
        # Fold position is ctx - 1: a token sampled from k context tokens
        # folds k - 1, matching the decode step (pre-step ctx_len = k)
        # so the stream is chunking- and schedule-independent.  Padding
        # rows gather slot 0's params; their token is never read.
        # ``sample`` is trace-static, default False: an all-greedy bucket
        # (and the dryrun prefill cost cells, which never pass it) keeps
        # the pre-sampling argmax-only trace; the engine passes True only
        # when a request in the bucket samples.
        if sample:
            sid_safe = jnp.clip(sid, 0, n_slots - 1)
            fold = jnp.maximum(ctx.astype(jnp.int32) - 1, 0)
            stats = {"next_token": sample_tokens(
                last, dstate["samp_temp"][sid_safe],
                dstate["samp_topk"][sid_safe], dstate["samp_topp"][sid_safe],
                dstate["samp_key"][sid_safe], fold)}
        else:
            stats = {"next_token": jnp.argmax(last, axis=-1
                                              ).astype(jnp.int32)}
        return last, new_state, stats

    return prefill_step
