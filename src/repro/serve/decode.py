"""SPMD decode step: hybrid-translated paged attention + recurrent states.

Layout (design §5): the KV pool is sharded
    (L_attn, slots, block_tokens, KV, hd)
          P(None, DATA,       MODEL, None, None)

* slots over the DATA axes — in **batch mode** each data group owns the
  sequences (and all their blocks) of its batch shard: every gather is
  local.  In **striped mode** (long_500k, batch 1) the single sequence's
  blocks are dealt round-robin over the data groups.
* block tokens over MODEL — each model shard holds a contiguous token
  sub-range of every block; partial softmax results are psum-combined
  (flash-decoding).  This sidesteps GQA-head divisibility entirely
  (kv_heads never needs to divide the model axis).

Translation (the paper's technique) runs **exactly once per step**
(DESIGN.md §translate-once): ``translate_step`` resolves every block vpn
of every group — plus the current block being written — in one hybrid
RSW/flex lookup *before* the layer scan, and the resolved slot table is
what flows into every attention layer.  The per-layer work is pure
gather/scatter over pre-resolved slots; no translation structure is
touched inside the scan body (O(B·nblk) translation per step instead of
O(L·B·nblk)).  The same pass emits the per-vpn telemetry (in_rest /
accesses / mapped) the engine feeds back to the promotion policy, so the
host never re-translates.

Everything outside paged attention (projections, MoE, mamba recurrence,
lm head) stays in pjit/GSPMD land with sharding constraints.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as Lmod
from repro.models import transformer as Tmod
from repro.models.transformer import ModelDims
from repro.models.ssm import MambaCache, mamba_decode_step
from repro.models.moe import moe_decode
from repro.core.tar_sf import RestSegState, rsw, probe_rows
from repro.core.hashes import get_hash
from repro.core.partition import Partition
from repro.dist.sharding import kv_state_specs
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_attention_blocks)
from .sampling import sample_tokens


@dataclasses.dataclass(frozen=True)
class DecodeSpec:
    block_size: int              # tokens per KV block (global)
    max_blocks_per_seq: int      # per data group in striped mode
    slots_per_group: int
    n_sets: int
    assoc: int
    mode: str = "batch"          # batch | striped
    hash_name: str = "modulo"
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    use_kernels: bool = False    # Pallas path (TPU); ref path otherwise
    # prefix-KV chunked prefill: how chunk queries read the installed
    # prefix blocks.  "exact" materializes the gathered K/V at their
    # absolute positions and reuses the recompute forward's dense softmax
    # (bit-identical oracle contract); "paged" is the Q>1
    # kernels/paged_attention read merged by an online-softmax combine
    # (linear memory, kernel-ready; equal up to float associativity).
    prefix_gather: str = "exact"
    # KV/translation sharding over the model axis (DESIGN.md
    # §sharded-serving).  0 = legacy layout: mesh != None selects the
    # token-split flash-decoding path (dryrun compile cells).  >= 1 = the
    # SPMD engine layout: the pool is slot-sharded by the set-index /
    # block-range Partition, the whole step runs under one shard_map, and
    # every float op is replicated so streams stay bitwise identical to
    # mesh=None.  Requires ``part`` to be passed to the step factories.
    kv_shards: int = 0

    @property
    def nblk(self) -> int:
        return self.max_blocks_per_seq


def make_decode_spec(cfg: ArchConfig, seq_len: int, batch: int,
                     data_size: int, mode: str = "batch",
                     headroom: float = 1.25,
                     data_axes: Tuple[str, ...] = ("data",)) -> DecodeSpec:
    bs = cfg.kv_block_size
    total_blocks = (seq_len + bs - 1) // bs * batch
    if mode == "batch":
        blocks_per_group = total_blocks // data_size
        max_blocks = (seq_len + bs - 1) // bs
    else:  # striped: one (or few) seqs, blocks dealt over groups
        blocks_per_group = total_blocks // data_size
        max_blocks = ((seq_len + bs - 1) // bs) // data_size
    assoc = 8
    slots = max(assoc * 2, int(blocks_per_group * headroom))
    rest = max(assoc, int(slots * 0.75) // assoc * assoc)
    slots = rest + max(assoc, slots - rest)
    return DecodeSpec(block_size=bs, max_blocks_per_seq=max_blocks,
                      slots_per_group=slots, n_sets=rest // assoc,
                      assoc=assoc, mode=mode, hash_name=cfg.hash_name
                      if hasattr(cfg, "hash_name") else "modulo",
                      data_axes=data_axes)


# ----------------------------------------------------------- decode state

def abstract_decode_state(cfg: ArchConfig, dims: ModelDims, spec: DecodeSpec,
                          batch: int, data_size: int,
                          dtype=jnp.bfloat16,
                          part: Optional[Partition] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree of the decode state (dry-run friendly).

    With ``part`` (the SPMD engine layout, ``spec.kv_shards >= 1``) the
    pool and translation tables take the shard-padded sizes: every
    shard's chunk is identically shaped, padded TAR rows stay zero and
    padded flex entries -1, so the padded lookup is bit-identical to the
    unpadded one.  ``data_size`` must be 1 in that layout (the data axis
    replicates engine state; it scales *compute* only).
    """
    sd = jax.ShapeDtypeStruct
    G = data_size
    n_attn = sum(cfg.attn_on_layer(l) for l in range(cfg.num_layers))
    n_ssm = cfg.num_layers - n_attn if cfg.family in ("hybrid", "ssm") else 0
    seqs_per_group = max(1, batch // G) if spec.mode == "batch" else batch
    if part is not None and G != 1:
        raise ValueError("sharded decode state requires data_size == 1")
    st: Dict[str, Any] = {}
    if n_attn:
        pool_slots = part.pool_slots if part is not None \
            else G * spec.slots_per_group
        pool = (n_attn, pool_slots, spec.block_size,
                max(dims.n_kv, 1), dims.head_dim)
        n_sets = part.n_sets_padded if part is not None else spec.n_sets
        flex_len = part.vpn_padded if part is not None \
            else seqs_per_group * spec.max_blocks_per_seq
        st["k_pool"] = sd(pool, dtype)
        st["v_pool"] = sd(pool, dtype)
        st["tar"] = sd((G, n_sets, spec.assoc), jnp.int32)
        st["sf"] = sd((G, n_sets), jnp.int32)
        st["flex"] = sd((G, flex_len), jnp.int32)
    if n_ssm:
        md = dims.mamba
        st["ssm"] = sd((n_ssm, batch, md.n_heads, md.head_dim, md.d_state),
                       jnp.float32)
        st["conv"] = sd((n_ssm, batch, md.conv_width - 1, md.conv_channels),
                        dtype)
    if cfg.is_encoder_decoder:
        st["cross_k"] = sd((cfg.num_layers, batch, cfg.frontend_tokens,
                            dims.n_kv, dims.head_dim), dtype)
        st["cross_v"] = sd((cfg.num_layers, batch, cfg.frontend_tokens,
                            dims.n_kv, dims.head_dim), dtype)
    st["ctx_len"] = sd((batch,), jnp.int32)
    # per-slot sampling state (serve/sampling.py): the engine scatters a
    # request's SamplingParams here at admission; zeros = greedy argmax
    st["samp_temp"] = sd((batch,), jnp.float32)
    st["samp_topk"] = sd((batch,), jnp.int32)
    st["samp_topp"] = sd((batch,), jnp.float32)
    st["samp_key"] = sd((batch, 2), jnp.uint32)
    return st


def init_decode_state(cfg, dims, spec, batch, data_size, dtype=jnp.float32,
                      part: Optional[Partition] = None):
    abstract = abstract_decode_state(cfg, dims, spec, batch, data_size, dtype,
                                     part=part)
    st = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), abstract)
    if "flex" in st:
        st["flex"] = st["flex"] - 1            # -1 = unmapped
    return st


def decode_state_shardings(state_shape, mesh: Mesh, spec: DecodeSpec):
    da, ma = spec.data_axes, spec.model_axis
    table = {
        "k_pool": P(None, da, ma, None, None),
        "v_pool": P(None, da, ma, None, None),
        "tar": P(da, None, None),
        "sf": P(da, None),
        "flex": P(da, None),
        "ssm": P(None, da if spec.mode == "batch" else None, ma, None, None),
        "conv": P(None, da if spec.mode == "batch" else None, None, ma),
        "cross_k": P(None, da if spec.mode == "batch" else None, None,
                     None, None),
        "cross_v": P(None, da if spec.mode == "batch" else None, None,
                     None, None),
        "ctx_len": P(),
        "samp_temp": P(),
        "samp_topk": P(),
        "samp_topp": P(),
        "samp_key": P(),
        # speculative-decode token history (serve/spec_decode.py): the
        # engine installs it only when spec decoding is configured, so
        # the spec-off decode state stays exactly the PR-4 pytree
        "hist": P(),
    }

    def guard(name, leaf):
        sp = list(table[name])[:leaf.ndim]
        sp += [None] * (leaf.ndim - len(sp))
        out = []
        for dim, axes in zip(leaf.shape, sp):
            if axes is None:
                out.append(None)
                continue
            ax = (axes,) if isinstance(axes, str) else tuple(axes)
            size = int(np.prod([mesh.shape[a] for a in ax]))
            out.append(axes if dim % size == 0 else None)
        return NamedSharding(mesh, P(*out))

    return {k: guard(k, v) for k, v in state_shape.items()}


# --------------------------------------------- once-per-step translation

class StepTranslation(NamedTuple):
    """Result of the single hybrid translation performed per decode step.

    Group-major: ``G`` leads every device array so the same structure
    serves the mesh path (``P(da, ...)`` — each data group reads row ``g``)
    and the single-device engine (``G == 1``).
    """

    slots: jnp.ndarray      # (G, B_loc, nblk) int32 resolved pool slot, -1
    w_slot: jnp.ndarray     # (G, B_loc) int32 slot of the block being written
    w_valid: jnp.ndarray    # (G, B_loc) bool: mapped & owned by the group
    in_rest: jnp.ndarray    # (G, B_loc, nblk) bool — resolved by the RSW
    mapped: jnp.ndarray     # (G, B_loc, nblk) bool
    accesses: jnp.ndarray   # (G, B_loc, nblk) int32 structure accesses
    vpns: jnp.ndarray       # (B_loc, nblk) int32 local vpn grid


def _hybrid_lookup(vpns: jax.Array, tar: jax.Array, sf: jax.Array,
                   flex_flat: jax.Array, hash_name: str):
    """Hybrid RSW ∥ flex lookup with ``translate()``-compatible accounting.

    This is the ONLY translation primitive the decode step may touch, and
    it must be called exactly once per step (guarded by
    tests/test_engine_hotpath.py::test_translation_runs_once_per_step).
    The RestSeg walk itself is the canonical ``core.tar_sf.rsw`` — one
    source of truth for the paper's RSW semantics; only the flat flex
    gather and the access accounting live here.
    Returns (slot, in_rest, mapped, accesses), each shaped like ``vpns``.
    """
    rest = RestSegState(tar=tar, sf=sf, meta=jnp.zeros_like(tar))
    r = rsw(rest, vpns.astype(jnp.int32), hash_name)
    flex_slot = flex_flat[vpns]
    slot = jnp.where(r.hit, r.slot,
                     jnp.where(flex_slot >= 0, flex_slot, -1))
    mapped = r.hit | (flex_slot >= 0)
    # SF probe (1) + TAR set read unless SF filtered (1) + flex walk on miss
    accesses = (1 + jnp.where(r.sf_skipped, 0, 1)
                + jnp.where(r.hit, 0, 1))
    return (slot.astype(jnp.int32), r.hit, mapped,
            accesses.astype(jnp.int32))


def _hybrid_lookup_sharded(vpns: jax.Array, tar_l: jax.Array,
                           sf_l: jax.Array, flex_l: jax.Array,
                           hash_name: str, part: Partition,
                           model_axis: str):
    """Sharded hybrid lookup: probe the LOCAL table shards, psum-combine.

    Runs under shard_map over ``model_axis``: ``tar_l (spm, assoc)`` /
    ``sf_l (spm,)`` are this shard's set-index range of the TAR/SF
    tables, ``flex_l (vpm,)`` its vpn range of the flat flex table;
    ``vpns`` is replicated.  Each shard probes only the queries whose
    set (resp. vpn) it owns — contributions are combined with integer
    psums, so the result is EXACTLY the global lookup (no float
    reduction): bit-identical slots/telemetry to ``_hybrid_lookup`` on
    the unsharded tables.  Padded TAR rows are all-zero (tags store
    vpn+1, so 0 never matches) and padded flex entries -1, which is why
    the clipped out-of-range probes below cannot spuriously hit.

    Like ``_hybrid_lookup`` this is the ONLY translation primitive the
    sharded decode step may touch, called exactly once per step (pinned
    by tests/test_sharded_serve.py).
    """
    m = jax.lax.axis_index(model_axis)
    spm = part.sets_per_shard
    vpm = part.vpns_per_shard
    set_g = get_hash(hash_name)(vpns.astype(jnp.int32),
                                part.n_sets).astype(jnp.int32)
    mine = (set_g // spm) == m
    loc = jnp.clip(set_g - m * spm, 0, spm - 1)
    l_hit, l_way, l_skip = probe_rows(tar_l[loc], sf_l[loc],
                                      vpns.astype(jnp.int32))
    hit = jax.lax.psum(
        jnp.where(mine & l_hit, 1, 0), model_axis) > 0
    way = jax.lax.psum(
        jnp.where(mine & l_hit, l_way + 1, 0), model_axis) - 1
    sf_skipped = jax.lax.psum(
        jnp.where(mine, l_skip.astype(jnp.int32), 0), model_axis) > 0
    mine_f = (vpns // vpm) == m
    ent = flex_l[jnp.clip(vpns - m * vpm, 0, vpm - 1)]
    # shift by 2 so both "not mine" (0) and "unmapped" (-1 -> 1) slot in
    # below zero after the un-shift; exactly one shard owns each vpn
    flex_slot = jax.lax.psum(
        jnp.where(mine_f, ent + 2, 0), model_axis) - 2
    slot = jnp.where(hit, set_g * part.assoc + jnp.maximum(way, 0),
                     jnp.where(flex_slot >= 0, flex_slot, -1))
    mapped = hit | (flex_slot >= 0)
    accesses = (1 + jnp.where(sf_skipped, 0, 1)
                + jnp.where(hit, 0, 1))
    return (slot.astype(jnp.int32), hit, mapped,
            accesses.astype(jnp.int32))


def _translate_queries(lookup, tar: jax.Array, sf: jax.Array,
                       flex: jax.Array, positions: jax.Array,
                       spec: DecodeSpec) -> StepTranslation:
    """Shared skeleton of the once-per-step translation dispatch.

    Builds the per-group query grid (every block vpn of every sequence
    plus the current write block), runs ``lookup(tar_g, sf_g, flex_g,
    vpns)`` once over it, and packs the ``StepTranslation``.  The lookup
    itself is injected so the single-device and sharded paths share one
    skeleton while keeping separately pin-able primitives.
    """
    G = tar.shape[0]
    nblk = spec.max_blocks_per_seq
    bs = spec.block_size
    B = positions.shape[0]
    if spec.mode == "batch":
        B_loc = B // G
        pos_g = positions.reshape(G, B_loc)
    else:
        B_loc = B
        pos_g = jnp.broadcast_to(positions[None, :], (G, B))
    seq = jnp.arange(B_loc, dtype=jnp.int32)
    grid = (seq[:, None] * nblk
            + jnp.arange(nblk, dtype=jnp.int32)[None, :])   # (B_loc, nblk)

    if spec.mode == "batch":
        cur_block = pos_g // bs
        owner = jnp.ones((G, B_loc), bool)
    else:  # striped: block b lives on group b % G, locally at b // G
        cur_block_global = pos_g // bs
        owner = (cur_block_global % G) == jnp.arange(
            G, dtype=jnp.int32)[:, None]
        cur_block = cur_block_global // G
    # an idle/released slot's ctx_len keeps advancing with the batch, so
    # its current block can run past the sequence's vpn range — without
    # this bound its cur_vpn would alias ANOTHER sequence's vpns and the
    # write below would scatter garbage into a live block
    in_range = cur_block < nblk
    cur_block = jnp.minimum(cur_block, nblk - 1)
    cur_vpn = seq[None, :] * nblk + cur_block               # (G, B_loc)

    n_read = B_loc * nblk
    queries = jnp.concatenate(
        [jnp.broadcast_to(grid.reshape(-1)[None, :], (G, n_read)), cur_vpn],
        axis=1)                                             # (G, n_read+B_loc)
    slot, hit, mapped, acc = jax.vmap(lookup)(tar, sf, flex, queries)

    shape3 = (G, B_loc, nblk)
    return StepTranslation(
        slots=slot[:, :n_read].reshape(shape3),
        w_slot=slot[:, n_read:],
        w_valid=mapped[:, n_read:] & owner & in_range,
        in_rest=hit[:, :n_read].reshape(shape3),
        mapped=mapped[:, :n_read].reshape(shape3),
        accesses=acc[:, :n_read].reshape(shape3),
        vpns=grid,
    )


def translate_step(tar: jax.Array, sf: jax.Array, flex: jax.Array,
                   positions: jax.Array, spec: DecodeSpec
                   ) -> StepTranslation:
    """Translate ALL block vpns of ALL groups once — the step's only
    translation dispatch.

    tar (G, n_sets, assoc), sf (G, n_sets), flex (G, seqs*nblk) are the
    per-group translation structures; ``positions`` (B,) the pre-step
    context lengths.  The current block's write-slot lookup is batched
    into the same dispatch (it is just ``B_loc`` extra vpns).
    """
    return _translate_queries(
        lambda t, s, f, v: _hybrid_lookup(v, t, s, f, spec.hash_name),
        tar, sf, flex, positions, spec)


def translate_step_sharded(tar_l: jax.Array, sf_l: jax.Array,
                           flex_l: jax.Array, positions: jax.Array,
                           spec: DecodeSpec, part: Partition
                           ) -> StepTranslation:
    """Sharded translate-once dispatch (runs under shard_map).

    Same contract as ``translate_step`` — one dispatch per step, LOGICAL
    slot numbering in the returned ``StepTranslation`` (bit-identical to
    ``mesh=None``) — but each shard probes only its own TAR/SF set range
    and flex vpn range; integer psums combine the verdicts.
    """
    assert spec.mode == "batch", "sharded serving is batch-mode only"
    return _translate_queries(
        lambda t, s, f, v: _hybrid_lookup_sharded(
            v, t, s, f, spec.hash_name, part, spec.model_axis),
        tar_l, sf_l, flex_l, positions, spec)


# ------------------------------------------------- paged attention (SPMD)

def _paged_attn_shardmap(q, k_new, v_new, k_pool_l, v_pool_l, slots, w_slot,
                         w_valid, pos, *, spec: DecodeSpec,
                         mesh: Mesh, n_kv: int, head_dim: int):
    """Write + attention over PRE-RESOLVED slots inside shard_map.

    q: (B, H, hd); k_new/v_new: (B, KV, hd); k/v_pool_l: one layer's pool
    (G*slots, bs, KV, hd); slots (G, B_loc, nblk); w_slot/w_valid
    (G, B_loc); pos: (B,) pre-step context lengths (write position AND
    attention extent).  No translation structure is consumed here —
    translation happened once in ``translate_step``.
    Returns (attn_out (B, H, hd) fp32, k_pool_l', v_pool_l').
    """
    da, ma = spec.data_axes, spec.model_axis
    TP = int(np.prod([mesh.shape[a] for a in (ma,)]))
    bs = spec.block_size
    bs_loc = bs // TP
    batch_mode = spec.mode == "batch"

    def local(q, k_new, v_new, kp, vp, slots, w_slot, w_valid, pos):
        # shapes: q (B_loc, H, hd); kp (slots, bs_loc, KV, hd);
        # slots (1, B_loc, nblk) -> squeeze group dim
        slots, w_slot, w_valid = slots[0], w_slot[0], w_valid[0]
        m_idx = jax.lax.axis_index(ma)
        if len(da) == 1:
            g_idx = jax.lax.axis_index(da[0])
        else:
            g_idx = (jax.lax.axis_index(da[0]) * mesh.shape[da[1]]
                     + jax.lax.axis_index(da[1]))

        # ---- write current token's K/V into its pre-resolved slot -------
        tok = pos % bs
        own_tok = (tok // bs_loc) == m_idx
        t_loc = tok % bs_loc
        own = w_valid & own_tok
        # unowned rows scatter to an out-of-bounds slot and are DROPPED —
        # clamping them to slot 0 would collide with a real sequence's
        # block and clobber its fresh write (duplicate-index scatter)
        w_target = jnp.where(own, w_slot, kp.shape[0])
        kp = kp.at[w_target, t_loc].set(k_new.astype(kp.dtype),
                                        mode="drop")
        vp = vp.at[w_target, t_loc].set(v_new.astype(vp.dtype),
                                        mode="drop")

        # ---- paged attention over translated blocks ---------------------
        if batch_mode:
            block_tokens = bs
            tok_offset = m_idx * bs_loc
        else:
            block_tokens = mesh_G * bs
            tok_offset = g_idx * bs + m_idx * bs_loc
        o, m, l = paged_attention_ref(
            q, kp, vp, slots, pos + 1, tok_offset=tok_offset, tok_stride=1,
            block_tokens=block_tokens)
        combine = (ma,) if batch_mode else tuple(da) + (ma,)
        m_glob = jax.lax.pmax(m, combine)
        corr = jnp.exp(m - m_glob)
        o = jax.lax.psum(o * corr[..., None], combine)
        l = jax.lax.psum(l * corr, combine)
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return out, kp, vp

    mesh_G = int(np.prod([mesh.shape[a] for a in da]))
    dspec = P(da) if batch_mode else P()
    in_specs = (
        P(da, None, None) if batch_mode else P(None, None, None),  # q
        P(da, None, None) if batch_mode else P(None, None, None),  # k_new
        P(da, None, None) if batch_mode else P(None, None, None),  # v_new
        P(da, ma, None, None),                                     # k_pool
        P(da, ma, None, None),                                     # v_pool
        P(da, None, None),                                         # slots
        P(da, None),                                               # w_slot
        P(da, None),                                               # w_valid
        dspec,                                                     # pos
    )
    out_specs = (
        P(da, None, None) if batch_mode else P(None, None, None),
        P(da, ma, None, None),
        P(da, ma, None, None),
    )
    fn = jax.shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn(q, k_new, v_new, k_pool_l, v_pool_l, slots, w_slot, w_valid,
              pos)


# ------------------------------------- slot-sharded pool (SPMD engine)

def _psum_gather_blocks(pool_l, slots, part: Partition, model_axis: str):
    """Gather blocks by LOGICAL slot from the slot-sharded pool, exactly.

    Runs under shard_map over ``model_axis``: ``pool_l`` is this shard's
    contiguous physical-slot chunk ``(slots_per_shard, bs, KV, hd)``;
    ``slots`` the replicated logical slot ids (any leading shape, -1 =
    unmapped).  Each shard gathers the blocks it owns, then an INTEGER
    psum over the raw bits assembles the replicated result — float
    psums would tie bit-identity to reduction order; bit psums of
    disjoint one-hot contributions cannot.  Unowned / -1 rows contribute
    zero bits, so missing slots come back as all-zero blocks (which the
    valid-slot masking inside paged attention renders harmless).
    """
    m = jax.lax.axis_index(model_axis)
    cps = part.slots_per_shard
    phys = part.phys(slots)
    mine = (slots >= 0) & ((phys // cps) == m)
    g = pool_l[jnp.where(mine, phys - m * cps, 0)]
    mask = mine.reshape(mine.shape + (1,) * (g.ndim - mine.ndim))
    if g.dtype == jnp.float32:
        bits = jax.lax.bitcast_convert_type(g, jnp.int32)
        bits = jax.lax.psum(jnp.where(mask, bits, 0), model_axis)
        return jax.lax.bitcast_convert_type(bits, jnp.float32)
    # 16-bit dtypes (bf16/f16): widen the bit pattern to int32 for psum
    bits = jax.lax.bitcast_convert_type(g, jnp.uint16).astype(jnp.int32)
    bits = jax.lax.psum(jnp.where(mask, bits, 0), model_axis)
    return jax.lax.bitcast_convert_type(bits.astype(jnp.uint16), g.dtype)


def _paged_attn_shard_local(q, k_new, v_new, kp_l, vp_l,
                            trans: StepTranslation, pos,
                            spec: DecodeSpec, part: Partition):
    """Slot-sharded write + paged attention (runs under shard_map).

    The sharded twin of ``_paged_attn_local_ref``: the current token's
    K/V scatter is ownership-masked (only the shard owning the physical
    slot writes; everyone else drops out of bounds), the block gather is
    the exact bit-psum assembly, and the attention math itself is the
    SAME replicated ``paged_attention_blocks`` — bitwise identical
    output to the mesh-free reference.
    """
    m = jax.lax.axis_index(spec.model_axis)
    cps = part.slots_per_shard
    slots = trans.slots[0]                          # (B, nblk) logical
    w_slot, w_valid = trans.w_slot[0], trans.w_valid[0]
    t = pos % spec.block_size
    wp = part.phys(w_slot)
    mine_w = w_valid & ((wp // cps) == m)
    ws = jnp.where(mine_w, wp - m * cps, cps)       # unowned -> dropped
    kp_l = kp_l.at[ws, t].set(k_new.astype(kp_l.dtype), mode="drop")
    vp_l = vp_l.at[ws, t].set(v_new.astype(vp_l.dtype), mode="drop")
    k = _psum_gather_blocks(kp_l, slots, part, spec.model_axis)
    v = _psum_gather_blocks(vp_l, slots, part, spec.model_axis)
    o, mx, l = paged_attention_blocks(q, k, v, slots, pos + 1)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out, kp_l, vp_l


# ---------------------------------------------- shared decode sublayers
#
# One definition each for the pieces the scalar decode step and the
# speculative verify step (serve/spec_decode.py) must keep EXACTLY in
# sync — the lossless spec contract rests on the two paths computing the
# same function.  All are rank-generic over the leading axes: the scalar
# step passes (B, D) activations, the verify step (B, K+1, D); the
# reshapes are identities for the scalar shapes, so the scalar trace is
# bitwise the pre-refactor one.

def decode_ffn(blk, x, cfg: ArchConfig, pins) -> jax.Array:
    """Post-attention FFN sublayer (dense MLP or decode-time MoE)."""
    h = Lmod.rms_norm(x, blk["norm2"].astype(jnp.float32), cfg.norm_eps)
    if "moe" in blk:
        lead = h.shape[:-1]
        out = moe_decode(blk["moe"], h.reshape(-1, h.shape[-1]),
                         top_k=cfg.moe_top_k,
                         pins=pins).reshape(*lead, -1)
    else:
        out = Lmod.mlp(blk["mlp"], h, pins)
    return x + pins("dec_bd", out)


def decode_cross(blk, x, ck, cv, cfg: ArchConfig, dims: ModelDims, pins
                 ) -> jax.Array:
    """Audio cross-attention over the installed per-slot cross K/V."""
    lead = x.shape[:-1]                       # (B,) or (B, Q)
    B = lead[0]
    h = Lmod.rms_norm(x, blk["norm_x"].astype(jnp.float32), cfg.norm_eps)
    q = Lmod.linear(blk["cross"]["q"], h)
    g = dims.n_heads // dims.n_kv
    qf = q.reshape(B, -1, dims.n_kv, g,
                   dims.head_dim).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bfkd->bqkgf", qf, ck.astype(jnp.float32))
    s = s / math.sqrt(dims.head_dim)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgf,bfkd->bqkgd", p, cv.astype(jnp.float32))
    o = o.reshape(*lead, -1).astype(x.dtype)
    return x + pins("dec_bd", Lmod.linear(blk["cross"]["o"], o))


def project_logits(params, x, cfg: ArchConfig, dims: ModelDims, pins
                   ) -> jax.Array:
    """Final norm -> (tied) head matmul -> vocab-pad mask -> pins."""
    x = Lmod.rms_norm(x, params["final_norm"].astype(jnp.float32),
                      cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head["table"].T.astype(x.dtype)
    vpad = logits.shape[-1]
    if vpad > dims.logical_vocab:
        mask = jnp.arange(vpad) < dims.logical_vocab
        logits = jnp.where(mask, logits, jnp.asarray(-1e9, logits.dtype))
    return pins("dec_logits", logits)


# --------------------------------------------------------- full serve step

def make_serve_step(cfg: ArchConfig, dims: ModelDims, spec: DecodeSpec,
                    mesh: Optional[Mesh] = None, pins=Lmod.no_pins,
                    dtype=jnp.bfloat16, part: Optional[Partition] = None):
    """Returns serve_step(params, dstate, tokens (B,)) ->
    (logits (B, V), new dstate, stats).  One new token per live sequence.

    With ``mesh`` and ``spec.kv_shards >= 1`` (+ ``part``, the engine's
    SPMD layout) the WHOLE step body runs under one shard_map over the
    mesh: translation probes per-shard table ranges, the KV pool is
    slot-sharded, and all float compute is replicated — token streams
    stay bitwise identical to ``mesh=None`` (DESIGN.md
    §sharded-serving).  With ``kv_shards == 0`` a mesh selects the
    legacy token-split flash-decoding path (dryrun compile cells).

    ``stats`` carries the step's translation telemetry (``in_rest`` /
    ``accesses`` / ``mapped`` / ``slots``, all group-major) plus the
    greedy ``next_token`` (B,) — everything the engine needs from the
    device in ONE fetch.  Translation runs exactly once, before the layer
    scan (see ``translate_step``).

    ``active`` (B,) bool (optional) marks the batch slots that are
    decoding this step.  Inactive slots — mid-prefill under the chunked
    admission scheduler, released, or already finished — neither write
    their current KV block (their drifting position could land inside a
    *mapped* block another chunk just installed) nor advance ``ctx_len``.
    ``active=None`` (the pre-scheduler calling convention) treats every
    slot as live.
    """

    def qkv_decode(blk, x, positions):
        B = x.shape[0]
        h = Lmod.rms_norm(x, blk["norm1"].astype(jnp.float32), cfg.norm_eps)
        q = Lmod.linear(blk["attn"]["q"], h).reshape(B, dims.n_heads,
                                                     dims.head_dim)
        k = Lmod.linear(blk["attn"]["k"], h).reshape(B, dims.n_kv,
                                                     dims.head_dim)
        v = Lmod.linear(blk["attn"]["v"], h).reshape(B, dims.n_kv,
                                                     dims.head_dim)
        if cfg.rope_theta > 0:
            q = Lmod.apply_rope(q[:, None], positions[:, None],
                                cfg.rope_theta)[:, 0]
            k = Lmod.apply_rope(k[:, None], positions[:, None],
                                cfg.rope_theta)[:, 0]
        return q, k, v

    sharded = mesh is not None and spec.kv_shards >= 1
    if sharded and part is None:
        raise ValueError("spec.kv_shards >= 1 requires a Partition")

    def attn_sublayer(blk, x, kp_l, vp_l, trans, positions):
        B = x.shape[0]
        q, k, v = qkv_decode(blk, x, positions)
        if sharded:
            out, kp_l, vp_l = _paged_attn_shard_local(
                q, k, v, kp_l, vp_l, trans, positions, spec, part)
        elif mesh is not None:
            out, kp_l, vp_l = _paged_attn_shardmap(
                q, k, v, kp_l, vp_l, trans.slots, trans.w_slot,
                trans.w_valid, positions,
                spec=spec, mesh=mesh, n_kv=dims.n_kv, head_dim=dims.head_dim)
        else:
            out, kp_l, vp_l = _paged_attn_local_ref(
                q, k, v, kp_l, vp_l, trans, positions, spec)
        o = Lmod.linear(blk["attn"]["o"], out.reshape(B, -1).astype(x.dtype))
        return x + pins("dec_bd", o), kp_l, vp_l

    def ffn_sublayer(blk, x):
        return decode_ffn(blk, x, cfg, pins)

    def mamba_sublayer(blk, x, ssm, conv):
        h = Lmod.rms_norm(x, blk["norm1"].astype(jnp.float32), cfg.norm_eps)
        out, cache = mamba_decode_step(
            blk["mamba"], h, MambaCache(conv=conv, state=ssm), dims.mamba)
        return x + pins("dec_bd", out), cache.state, cache.conv

    def cross_sublayer(blk, x, ck, cv, ctx_valid):
        return decode_cross(blk, x, ck, cv, cfg, dims, pins)

    n_attn = sum(cfg.attn_on_layer(l) for l in range(cfg.num_layers))

    def serve_step(params, dstate, tokens, active=None, *, sample=False):
        positions = dstate["ctx_len"]
        act = (jnp.ones_like(positions, jnp.bool_) if active is None
               else active.astype(jnp.bool_))
        x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(dtype)
        x = pins("dec_bd", x)
        fam = cfg.family
        new_state = dict(dstate)
        stats: Dict[str, jax.Array] = {}

        # ---- the step's single translation dispatch ----------------------
        trans = None
        if n_attn:
            if sharded:
                trans = translate_step_sharded(
                    dstate["tar"], dstate["sf"], dstate["flex"],
                    positions, spec, part)
            else:
                trans = translate_step(dstate["tar"], dstate["sf"],
                                       dstate["flex"], positions, spec)
            # group-major view of the active mask gates the KV write
            G = dstate["tar"].shape[0]
            if spec.mode == "batch":
                act_g = act.reshape(G, -1)
            else:
                act_g = jnp.broadcast_to(act[None, :], (G, act.shape[0]))
            trans = trans._replace(w_valid=trans.w_valid & act_g)
            stats.update(slots=trans.slots, in_rest=trans.in_rest,
                         mapped=trans.mapped, accesses=trans.accesses)

        n_layers = cfg.num_layers
        if fam in ("dense", "moe", "vlm", "audio"):
            # KV pools ride in the scan CARRY with per-layer in-place
            # dynamic updates (single live buffer; xs/ys would double-buffer
            # the multi-TB pool)
            xs = {"blk": params["layers"],
                  "idx": jnp.arange(n_layers, dtype=jnp.int32)}
            if fam == "audio":
                xs["ck"] = dstate["cross_k"]
                xs["cv"] = dstate["cross_v"]

            def body(carry, xl):
                x, kp, vp = carry
                blk = xl["blk"]
                i = xl["idx"]
                kp_l = jax.lax.dynamic_index_in_dim(kp, i, 0, keepdims=False)
                vp_l = jax.lax.dynamic_index_in_dim(vp, i, 0, keepdims=False)
                x, kp_l, vp_l = attn_sublayer(blk, x, kp_l, vp_l, trans,
                                              positions)
                kp = jax.lax.dynamic_update_index_in_dim(kp, kp_l, i, 0)
                vp = jax.lax.dynamic_update_index_in_dim(vp, vp_l, i, 0)
                if fam == "audio":
                    x = cross_sublayer(blk, x, xl["ck"], xl["cv"], None)
                x = ffn_sublayer(blk, x)
                return (x, kp, vp), None

            (x, kp, vp), _ = jax.lax.scan(
                body, (x, dstate["k_pool"], dstate["v_pool"]), xs)
            new_state["k_pool"], new_state["v_pool"] = kp, vp
        elif fam == "ssm":
            xs = {"blk": params["layers"], "ssm": dstate["ssm"],
                  "conv": dstate["conv"]}

            def body(x, xl):
                x, s, c = mamba_sublayer(xl["blk"], x, xl["ssm"], xl["conv"])
                return x, {"ssm": s, "conv": c}

            x, ys = jax.lax.scan(body, x, xs)
            # inactive rows keep their recurrent state (the scan advanced
            # every row with whatever token the engine padded in)
            new_state["ssm"] = jnp.where(
                act[None, :, None, None, None], ys["ssm"], dstate["ssm"])
            new_state["conv"] = jnp.where(
                act[None, :, None, None], ys["conv"], dstate["conv"])
        elif fam == "hybrid":
            g = cfg.attn_every
            n_groups = cfg.num_layers // g
            n_mamba = g - 1
            xs = {"blk": params["layers"],
                  "idx": jnp.arange(n_groups, dtype=jnp.int32),
                  "ssm": dstate["ssm"].reshape(
                      (n_groups, n_mamba) + dstate["ssm"].shape[1:]),
                  "conv": dstate["conv"].reshape(
                      (n_groups, n_mamba) + dstate["conv"].shape[1:])}

            def body(carry, xl):
                x, kp, vp = carry
                blk = xl["blk"]
                gi = xl["idx"]
                ssm_out, conv_out = [], []
                for i in range(g):
                    if i < g - 1:
                        sub = jax.tree.map(lambda a, i=i: a[i], blk["mamba"])
                        x, s, c = mamba_sublayer(sub, x, xl["ssm"][i],
                                                 xl["conv"][i])
                        ssm_out.append(s)
                        conv_out.append(c)
                    else:
                        kp_l = jax.lax.dynamic_index_in_dim(
                            kp, gi, 0, keepdims=False)
                        vp_l = jax.lax.dynamic_index_in_dim(
                            vp, gi, 0, keepdims=False)
                        x, kp_l, vp_l = attn_sublayer(
                            blk["attn"], x, kp_l, vp_l, trans, positions)
                        kp = jax.lax.dynamic_update_index_in_dim(
                            kp, kp_l, gi, 0)
                        vp = jax.lax.dynamic_update_index_in_dim(
                            vp, vp_l, gi, 0)
                    x = ffn_sublayer(Tmod.hybrid_ffn_select(cfg, blk, i), x)
                return (x, kp, vp), {"ssm": jnp.stack(ssm_out),
                                     "conv": jnp.stack(conv_out)}

            (x, kp, vp), ys = jax.lax.scan(
                body, (x, dstate["k_pool"], dstate["v_pool"]), xs)
            new_state["k_pool"], new_state["v_pool"] = kp, vp
            new_state["ssm"] = jnp.where(
                act[None, :, None, None, None],
                ys["ssm"].reshape(dstate["ssm"].shape), dstate["ssm"])
            new_state["conv"] = jnp.where(
                act[None, :, None, None],
                ys["conv"].reshape(dstate["conv"].shape), dstate["conv"])
        else:
            raise ValueError(fam)

        logits = project_logits(params, x, cfg, dims, pins)
        # per-slot sampling in-graph: the engine reads token ids, not the
        # (B, V) logits, so the per-step fetch stays O(B).  Greedy rows
        # (samp_temp == 0) take the exact argmax path; sampled rows fold
        # the slot's PRNG key with the pre-step position, making a token
        # a pure function of (seed, position) — independent of admission
        # schedule or batch composition.  ``sample`` is trace-static
        # (jit static_argnames): an all-greedy batch compiles an
        # argmax-only executable with none of the sort/softmax/gumbel
        # work on its hot path.  The default is False so callers that
        # never pass it (dryrun cost cells, direct step tests) keep the
        # pre-sampling argmax trace; the engine passes it explicitly
        if sample:
            stats["next_token"] = sample_tokens(
                logits, dstate["samp_temp"], dstate["samp_topk"],
                dstate["samp_topp"], dstate["samp_key"], positions)
        else:
            stats["next_token"] = jnp.argmax(logits, axis=-1
                                             ).astype(jnp.int32)
        # only active slots advance: an idle slot's ctx_len must not drift
        # (pre-scheduler it advanced unconditionally, which is why the
        # stale-write bound in translate_step exists)
        new_state["ctx_len"] = (dstate["ctx_len"]
                                + act.astype(dstate["ctx_len"].dtype))
        return logits, new_state, stats

    if not sharded:
        return serve_step

    def serve_step_sharded(params, dstate, tokens, active=None, *,
                           sample=False):
        # the whole step under ONE shard_map: params and batch arrays
        # replicated (P() prefix-broadcasts over the pytrees), decode
        # state per kv_state_specs.  ``sample`` is trace-static, so the
        # shard_map is (re)built per sample value under the engine's
        # static_argnames jit — same retrace behaviour as the local step.
        act = (jnp.ones_like(dstate["ctx_len"], jnp.bool_) if active is None
               else active.astype(jnp.bool_))
        sspecs = kv_state_specs(dstate, spec)
        fn = jax.shard_map(
            functools.partial(serve_step, sample=sample),
            mesh=mesh, in_specs=(P(), sspecs, P(), P()),
            out_specs=(P(), sspecs, P()), check_vma=False)
        return fn(params, dstate, tokens, act)

    return serve_step_sharded


# ------------------------------------------------ single-device reference

def _paged_attn_local_ref(q, k_new, v_new, kp_l, vp_l,
                          trans: StepTranslation, pos,
                          spec: DecodeSpec):
    """Mesh-free reference used by the engine on one device (G=1, TP=1).

    Consumes the pre-resolved ``StepTranslation`` — no translation here.
    """
    slots = trans.slots[0]                          # (B, nblk)
    w_slot, w_valid = trans.w_slot[0], trans.w_valid[0]
    t = pos % spec.block_size
    ws = jnp.where(w_valid, w_slot, kp_l.shape[0])  # unowned -> dropped
    kp_l = kp_l.at[ws, t].set(k_new.astype(kp_l.dtype), mode="drop")
    vp_l = vp_l.at[ws, t].set(v_new.astype(vp_l.dtype), mode="drop")
    o, m, l = paged_attention_ref(q, kp_l, vp_l, slots, pos + 1)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out, kp_l, vp_l
