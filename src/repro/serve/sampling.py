"""In-graph per-slot token sampling: SamplingParams -> next_token ids.

The serving API attaches a :class:`SamplingParams` to every ``Request``;
the engine scatters the per-request fields into per-batch-slot device
arrays (``samp_temp`` / ``samp_topk`` / ``samp_topp`` / ``samp_key`` in
the decode state) at admission, and BOTH jitted steps (``serve_step``,
``prefill_step``) turn logits into token ids in-graph via
:func:`sample_tokens`.  The engine keeps fetching token IDS, never
``(B, V)`` logits — sampling does not touch the translate-once /
single-device-fetch contract (DESIGN.md §translate-once, pinned by
tests/test_sampling.py).

Determinism: the per-slot PRNG key is derived once per request
(``PRNGKey(seed)``, default seed = ``seq_id``) and every sampled
position folds the key with its absolute context position, so the token
sampled after ``k`` context tokens is a pure function of
``(seed, logits)`` — independent of admission schedule, prompt
chunking, batch slot, or what other requests share the batch
(tests pin interleaved == sequential for sampled decode).

Greedy (``temperature == 0``) rows take the exact ``argmax`` path the
pre-sampling engine used — bit-identical tokens.

Mask semantics (mirrored by the numpy oracle in tests): temperature
scaling first, then top-k, then top-p over the RENORMALIZED top-k
distribution (the vLLM ordering).  Both filters are thresholds on the
scaled logits — a value tying the cut-off survives — and the top-1
token always survives.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# temperature floor for the scale divide on greedy rows (their sampled
# branch is discarded by the final where, the clamp only avoids inf/nan)
TEMP_EPS = 1e-6


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Immutable per-request sampling configuration.

    temperature == 0 selects greedy argmax (the default, and the fast
    path: bit-identical to the pre-sampling engine).  ``top_k <= 0``
    disables the top-k filter; ``top_p = 1`` disables the nucleus
    filter.  ``seed=None`` derives the request's PRNG stream from its
    ``seq_id``.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if not 0 < self.top_p <= 1:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def is_greedy(self) -> bool:
        return self.temperature == 0


GREEDY = SamplingParams()


def prng_key_data(params: SamplingParams, seq_id: int) -> np.ndarray:
    """Host-side (2,) uint32 key data for a request's sampling stream."""
    seed = params.seed if params.seed is not None else seq_id
    return np.asarray(jax.random.PRNGKey(seed), np.uint32)


def apply_top_k_top_p(logits: jax.Array, top_k: jax.Array,
                      top_p: jax.Array) -> jax.Array:
    """Mask ``logits (B, V)`` to the per-row top-k / top-p support.

    ``top_k (B,) int32`` (<= 0 disables), ``top_p (B,) float32``.
    Returns logits with excluded entries at ``-inf``.  Threshold
    semantics: the cut-off is a VALUE, so ties with the k-th / nucleus
    boundary logit are kept; the top-1 token always survives.
    """
    V = logits.shape[-1]
    neg = -jnp.inf
    desc = jnp.sort(logits, axis=-1)[:, ::-1]
    k = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V).astype(jnp.int32)
    # top-p over the renormalized top-k'd distribution: the nucleus is
    # the shortest descending prefix whose mass reaches top_p
    desc_k = jnp.where(jnp.arange(V)[None, :] < k[:, None], desc, neg)
    probs = jax.nn.softmax(desc_k, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # the rank < k clause re-asserts the top-k cut: the zero-probability
    # tail has cum - probs == 1, which float rounding (cum ~ 0.9999999)
    # would otherwise let past a top_p == 1.0 test
    keep = ((cum - probs) < top_p[:, None]) \
        & (jnp.arange(V)[None, :] < k[:, None])
    last = jnp.maximum(jnp.sum(keep, axis=-1) - 1, 0)
    thr = jnp.take_along_axis(desc_k, last[:, None], axis=-1)   # (B, 1)
    return jnp.where(logits >= thr, logits, neg)


def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array, keys: jax.Array,
                  steps: jax.Array) -> jax.Array:
    """Per-slot next-token ids ``(B,) int32`` from ``logits (B, V)``.

    ``keys (B, 2) uint32`` are the per-slot PRNG keys; ``steps (B,)``
    the absolute context position each row samples at — the key is
    folded with it, so a draw depends only on (key, position).  Rows
    with ``temperature <= 0`` return the exact argmax (bit-identical to
    the pre-sampling greedy path); everything is computed branch-free so
    one trace serves mixed greedy/sampled batches.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = (logits.astype(jnp.float32)
              / jnp.maximum(temperature, TEMP_EPS)[:, None])
    masked = apply_top_k_top_p(scaled, top_k, top_p)

    def gumbel_row(key, step):
        folded = jax.random.fold_in(key, step)
        return jax.random.gumbel(folded, (logits.shape[-1],), jnp.float32)

    # gumbel-max trick: argmax(logits + G) ~ Categorical(softmax(logits));
    # -inf masked entries stay -inf and can never win
    noise = jax.vmap(gumbel_row)(keys, steps)
    sampled = jnp.argmax(masked + noise, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)


# ------------------------------------------- speculative-decode verification

def sample_tokens_q(logits: jax.Array, temperature: jax.Array,
                    top_k: jax.Array, top_p: jax.Array, keys: jax.Array,
                    steps: jax.Array) -> jax.Array:
    """Vectorized multi-position sampler: ``logits (B, Q, V)`` ->
    ``(B, Q) int32``.

    Position ``i`` of row ``b`` draws with the SAME position-folded key
    the single-token :func:`sample_tokens` would fold after ``steps[b, i]``
    context tokens, so each draw is bitwise the token the non-speculative
    stream would emit at that position — the property that lets exact-match
    verification below implement lossless rejection sampling.
    ``temperature``/``top_k``/``top_p``/``keys`` are per-slot ``(B, ...)``
    and shared by every position of the row; ``steps`` is ``(B, Q)``.
    """
    B, Q, V = logits.shape
    rep = lambda a: jnp.repeat(a, Q, axis=0)
    flat = sample_tokens(logits.reshape(B * Q, V), rep(temperature),
                         rep(top_k), rep(top_p), rep(keys),
                         steps.reshape(B * Q))
    return flat.reshape(B, Q)


def verify_draft_tokens(target_tokens: jax.Array,
                        drafts: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """In-graph draft acceptance: ``target_tokens (B, K+1)`` (the target
    model's token at each window position — argmax for greedy rows,
    :func:`sample_tokens_q` draws for sampled rows) vs ``drafts (B, K)``.

    Returns ``(accepted_tokens (B, K+1), n_emit (B,))``: the step emits
    ``accepted_tokens[b, :n_emit[b]]`` — every leading draft that matched
    the target's token, plus the one "bonus" token the target produced at
    the first divergence (or after the last draft).  ``1 <= n_emit <= K+1``.

    Losslessness: for greedy rows this is trivially the greedy stream.
    For sampled rows it is rejection sampling against the deterministic
    (point-mass) drafter through a maximal gumbel coupling: the target's
    seeded draw X_i at position i plays both the accept test
    (accept d_i iff X_i == d_i, which happens with probability
    p_target(d_i) — exactly the min(1, p/q) rule for a point-mass q) and
    the residual resample (X_i | X_i != d_i is the renormalized residual
    distribution).  Every emitted token is therefore an exact draw from
    the target distribution at its position — and, because the draws are
    position-folded, bitwise the token the non-speculative stream emits.
    """
    accept = (target_tokens[:, :-1] == drafts).astype(jnp.int32)   # (B, K)
    keep = jnp.cumprod(accept, axis=1)          # leading-accept prefix
    n_emit = keep.sum(axis=1).astype(jnp.int32) + 1
    return target_tokens.astype(jnp.int32), n_emit
