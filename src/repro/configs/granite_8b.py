"""granite-8b [arXiv:2405.04324; hf] — llama-arch code model."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    source="arXiv:2405.04324; hf",
)
