"""jamba-1.5-large-398b [arXiv:2403.19887; hf] — Mamba+attn 1:7, MoE 16e top-2.

72 layers = 9 groups of 8 (7 mamba + 1 attention); MoE every other layer.
Long-context capable (sub-quadratic: SSM layers O(1)/token, the 1-in-8
attention layers use the paged hybrid-translation cache)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe_num_experts=16,
    moe_top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,            # 1 attention layer per 8 (1:7 interleave)
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    supports_long_context=True,
    optimizer="adafactor",   # 398B total params: factored second moment
    train_microbatches=4,
    source="arXiv:2403.19887; hf",
)
