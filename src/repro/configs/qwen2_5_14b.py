"""qwen2.5-14b [hf:Qwen/Qwen2.5-0.5B; hf] — dense GQA with QKV bias."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    train_microbatches=2,
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)
