"""Architecture + run configuration system.

``ArchConfig`` is the single source of truth for a model family instance.
``resolve(cfg, tp)`` derives the mesh-padded dims (head/vocab padding for a
given tensor-parallel degree) — padding is *explicit and reported* so the
roofline's useful-FLOPs ratio (MODEL_FLOPS / HLO_FLOPs) exposes the waste.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1           # MoE on layers where (layer % moe_every == moe_offset)
    moe_offset: int = 0
    moe_capacity_factor: float = 1.25
    # --- hybrid / SSM (mamba2) ---
    attn_every: int = 0          # jamba: one attn layer per this many (0 = all attn)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 64
    # --- modality frontends (STUBS: input_specs provides embeddings) ---
    frontend: str = "none"       # none | vision | audio
    frontend_tokens: int = 0     # 256 patches / 1500 frames
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    # --- technique & runtime knobs ---
    utopia_applicable: bool = True
    supports_long_context: bool = False  # run the long_500k cell?
    kv_block_size: int = 64
    optimizer: str = "adamw"     # adamw | adafactor (huge models)
    remat: bool = True
    zero_shard_params: bool = True   # FSDP params over the data axis
    train_microbatches: int = 1      # gradient accumulation (activation mem)
    source: str = ""             # provenance tag from the assignment

    def __post_init__(self):
        if self.family not in ("dense", "moe", "vlm", "audio", "hybrid", "ssm"):
            raise ValueError(f"unknown family {self.family}")

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def moe_on_layer(self, layer: int) -> bool:
        if self.moe_num_experts == 0:
            return False
        return layer % self.moe_every == self.moe_offset

    def attn_on_layer(self, layer: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_every <= 1:
            return True
        return layer % self.attn_every == (self.attn_every - 1)

    # ---------------------------------------------------------- accounting
    def param_count(self) -> int:
        """Analytic parameter count (used by roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * hd * nq + 2 * d * hd * nkv + nq * hd * d  # q,k,v,o
        if self.qkv_bias:
            attn += hd * (nq + 2 * nkv)
        dense_mlp = 3 * d * self.d_ff                         # swiglu
        moe_mlp = self.moe_num_experts * 3 * d * self.d_ff \
            + d * self.moe_num_experts                        # experts + router
        d_inner = self.ssm_expand * d
        nheads_ssm = max(1, d_inner // self.ssm_head_dim)
        ssm = (d * (2 * d_inner + 2 * self.ssm_state + nheads_ssm)
               + d_inner * self.ssm_conv_width + 2 * nheads_ssm
               + d_inner * d)
        total = 0
        layers = self.num_layers
        for l in range(layers):
            is_attn = self.attn_on_layer(l)
            total += attn if is_attn else ssm
            if self.family == "ssm":
                total += 0  # mamba2 has no separate MLP
            elif self.moe_on_layer(l):
                total += moe_mlp
            else:
                total += dense_mlp
            total += 2 * d                                    # norms
        if self.is_encoder_decoder:
            enc = self.encoder_layers * (attn + dense_mlp + 2 * d)
            xattn = self.num_layers * (attn + d)              # cross-attn
            total += enc + xattn
        total += self.vocab_size * d                          # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                      # lm head
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if self.moe_num_experts == 0:
            return self.param_count()
        d = self.d_model
        inactive_experts = self.moe_num_experts - self.moe_top_k
        n_moe_layers = sum(self.moe_on_layer(l) for l in range(self.num_layers))
        return self.param_count() - n_moe_layers * inactive_experts * 3 * d * self.d_ff


@dataclasses.dataclass(frozen=True)
class ResolvedDims:
    """Mesh-padded dims for a given TP degree."""
    num_heads: int
    num_kv_heads: int
    vocab_size: int
    d_ff: int
    pad_heads: int      # extra (wasted) q heads
    pad_vocab: int

    @property
    def any_padding(self) -> bool:
        return self.pad_heads > 0 or self.pad_vocab > 0


def resolve(cfg: ArchConfig, tp: int, vocab_align: int = 128) -> ResolvedDims:
    """Pad head/vocab/ff dims to TP divisibility.

    * q heads       -> multiple of tp (replicated KV when kv % tp != 0)
    * vocab         -> multiple of lcm(tp, vocab_align)
    * d_ff          -> multiple of tp (all assigned archs already divide)
    """
    nh = _round_up(cfg.num_heads, tp)
    nkv = cfg.num_kv_heads if cfg.num_kv_heads % tp == 0 else cfg.num_kv_heads
    va = tp * vocab_align // __import__("math").gcd(tp, vocab_align)
    vs = _round_up(cfg.vocab_size, va)
    ff = _round_up(cfg.d_ff, tp) if cfg.d_ff else cfg.d_ff
    return ResolvedDims(num_heads=nh, num_kv_heads=nkv, vocab_size=vs,
                        d_ff=ff, pad_heads=nh - cfg.num_heads,
                        pad_vocab=vs - cfg.vocab_size)


# ---------------------------------------------------------------------------
# Input-shape cells (assignment)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def shape_cell(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Which (arch x shape) cells run; mirrors the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (skip per pool rules)")
    return True, ""


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test reduction: same family/topology, tiny dims."""
    return dataclasses.replace(
        cfg,
        num_layers=max(2, min(4, cfg.num_layers)),
        encoder_layers=2 if cfg.is_encoder_decoder else 0,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(max(1, cfg.num_kv_heads // max(1, cfg.num_heads // 4)), 4),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        moe_num_experts=min(cfg.moe_num_experts, 8),
        moe_top_k=min(cfg.moe_top_k, 2),
        moe_capacity_factor=8.0,   # no token drops in smoke tests (exact
                                   # prefill/decode/forward consistency)
        frontend_tokens=8 if cfg.frontend != "none" else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        kv_block_size=8,
    )
