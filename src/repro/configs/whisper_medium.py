"""whisper-medium [arXiv:2212.04356; unverified] — enc-dec, conv frontend stub.

24 encoder + 24 decoder layers.  The conv/log-mel frontend is a STUB:
``input_specs()`` provides (B, 1500, d_model) precomputed frame embeddings
fed to the encoder.  Decode cells exercise the decoder with a paged
self-attention KV cache + fixed cross-attention KV."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,           # decoder layers
    encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,         # full MHA
    d_ff=4096,
    vocab_size=51865,
    qkv_bias=True,
    frontend="audio",
    frontend_tokens=1500,
    source="arXiv:2212.04356; unverified",
)
