"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

MoE 16 experts top-1; the early-fusion multimodal frontend is out of scope
for the LM cells (text backbone only, per the assignment)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    moe_num_experts=16,
    moe_top_k=1,
    moe_every=1,
    rope_theta=5e5,
    train_microbatches=2,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
