"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf] — 128-expert top-8 MoE."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,            # Qwen3 uses decoupled 128-dim heads
    d_ff=768,                # per-expert ffn dim
    vocab_size=151936,
    moe_num_experts=128,
    moe_top_k=8,
    moe_every=1,             # every layer is MoE
    rope_theta=1e6,
    train_microbatches=2,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
