"""Architecture registry: --arch <id> resolves here."""
from .base import (ArchConfig, ResolvedDims, ShapeCell, SHAPES, shape_cell,
                   resolve, reduced, cell_applicable)

from .qwen3_moe_30b_a3b import CONFIG as _qwen3_moe
from .llama4_scout_17b_a16e import CONFIG as _llama4_scout
from .paligemma_3b import CONFIG as _paligemma
from .whisper_medium import CONFIG as _whisper
from .granite_3_8b import CONFIG as _granite3
from .qwen2_5_14b import CONFIG as _qwen25_14b
from .qwen2_72b import CONFIG as _qwen2_72b
from .granite_8b import CONFIG as _granite8b
from .jamba_1_5_large_398b import CONFIG as _jamba
from .mamba2_130m import CONFIG as _mamba2

ARCHS = {c.name: c for c in (
    _qwen3_moe, _llama4_scout, _paligemma, _whisper, _granite3,
    _qwen25_14b, _qwen2_72b, _granite8b, _jamba, _mamba2,
)}


def get_config(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")


def list_archs():
    return sorted(ARCHS)


__all__ = ["ArchConfig", "ResolvedDims", "ShapeCell", "SHAPES", "shape_cell",
           "resolve", "reduced", "cell_applicable", "ARCHS", "get_config",
           "list_archs"]
