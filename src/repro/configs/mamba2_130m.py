"""mamba2-130m [arXiv:2405.21060; unverified] — SSD (state-space duality).

Attention-free: the Utopia hybrid KV translation is INAPPLICABLE (there is
no block indirection to translate — SSM state is a fixed-size tensor).  The
arch runs without the technique, as recorded in DESIGN.md
§Arch-applicability."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,             # attention-free
    num_kv_heads=0,
    d_ff=0,                  # no separate MLP in mamba2 blocks
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
    supports_long_context=True,
    utopia_applicable=False,
    source="arXiv:2405.21060; unverified",
)
