"""paligemma-3b [arXiv:2407.07726; hf] — SigLIP + gemma backbone.

The SigLIP vision tower is a STUB per the assignment: ``input_specs()``
provides (B, 256, d_model) precomputed patch embeddings that the backbone
prepends to the token stream."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,          # MQA
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    frontend="vision",
    frontend_tokens=256,
    tie_embeddings=True,     # gemma ties embeddings
    source="arXiv:2407.07726; hf",
)
