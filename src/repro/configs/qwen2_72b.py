"""qwen2-72b [arXiv:2407.10671; hf] — dense GQA with QKV bias (largest dense)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    optimizer="adafactor",   # 72B optimizer state must stay factored at 256 chips
    train_microbatches=4,
    source="arXiv:2407.10671; hf",
)
