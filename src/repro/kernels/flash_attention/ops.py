"""Jitted wrapper for the flash attention kernel."""
from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention_pallas
from .ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "q_tile", "kv_tile",
                                             "interpret", "use_kernel"))
def flash_attention(q, k, v, *, causal: bool = True, q_tile: int = 128,
                    kv_tile: int = 128, interpret: bool = True,
                    use_kernel: bool = True):
    if not use_kernel:
        return flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(q, k, v, causal=causal, q_tile=q_tile,
                                  kv_tile=kv_tile, interpret=interpret)
