"""Oracle: dense attention (shared with the model zoo's reference impl)."""
from repro.models.attention import dense_attention


def flash_attention_ref(q, k, v, *, causal: bool = True):
    return dense_attention(q, k, v, causal=causal)
