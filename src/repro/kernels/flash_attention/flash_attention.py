"""Pallas TPU kernel: tiled causal flash attention (train/prefill hot spot).

Grid (batch, q_head, q_tiles, kv_tiles); online softmax carried in VMEM
scratch across the kv_tiles dimension.  GQA is handled in the BlockSpec
index map (kv head = q head // group).  Causal tiles entirely above the
diagonal are skipped via ``pl.when`` (block-triangular schedule — the same
optimization the pure-JAX path exposes as ``triangular_schedule``).

MXU alignment: q/kv tiles default to 128 x head_dim with head_dim >= 128 in
every assigned arch except the reduced smoke configs (interpret mode does
not enforce alignment; production sizes are asserted in ops.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_scr, l_scr, *,
                  causal: bool, q_tile: int, kv_tile: int, n_kv_tiles: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    run = True
    if causal:
        run = kj * kv_tile <= qi * q_tile + q_tile - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (qt, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (kt, D)
        v = v_ref[0, 0].astype(jnp.float32)
        D = q.shape[-1]
        s = (q @ k.T) * (1.0 / math.sqrt(D))           # (qt, kt)
        if causal:
            qpos = qi * q_tile + jnp.arange(q_tile)
            kpos = kj * kv_tile + jnp.arange(kv_tile)
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=-1)
        m_scr[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v

    @pl.when(kj == n_kv_tiles - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, q_tile: int = 128,
                           kv_tile: int = 128, interpret: bool = True):
    """q (B,S,H,D); k/v (B,S,KV,D) -> (B,S,H,D)."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    g = H // KV
    q_tile = min(q_tile, Sq)
    kv_tile = min(kv_tile, Skv)
    assert Sq % q_tile == 0 and Skv % kv_tile == 0
    nq, nk = Sq // q_tile, Skv // kv_tile
    # layout: (B, H, S, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    kernel = functools.partial(_flash_kernel, causal=causal, q_tile=q_tile,
                               kv_tile=kv_tile, n_kv_tiles=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_tile, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kv_tile, D),
                         lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, kv_tile, D),
                         lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_tile, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_tile, D), jnp.float32),
            pltpu.VMEM((q_tile,), jnp.float32),
            pltpu.VMEM((q_tile,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
