"""Pure-jnp oracle for the RSW kernel (wraps the core hybrid translation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashes import get_hash


def rsw_ref(vpns: jax.Array, tar: jax.Array, sf: jax.Array,
            flex_flat: jax.Array, *, hash_name: str = "modulo"):
    n_sets, assoc = tar.shape
    h = get_hash(hash_name)
    set_idx = h(vpns.astype(jnp.int32), n_sets).astype(jnp.int32)
    tags = tar[set_idx]                                  # (N, assoc)
    counters = sf[set_idx]
    eq = tags == (vpns[:, None].astype(jnp.int32) + 1)
    hit = jnp.any(eq, axis=-1) & (counters > 0)
    way = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    rest_slot = set_idx * assoc + jnp.where(hit, way, 0)
    flex_slot = flex_flat[vpns]
    slot = jnp.where(hit, rest_slot, flex_slot)
    mapped = hit | (flex_slot >= 0)
    return (jnp.where(mapped, slot, -1).astype(jnp.int32),
            hit.astype(jnp.int32), mapped.astype(jnp.int32))
