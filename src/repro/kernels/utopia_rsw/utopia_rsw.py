"""Pallas TPU kernel: Utopia RestSeg Walk (hybrid translation).

The paper's RSW (§5.2) adapted to the TPU memory hierarchy:

* TAR and SF live wholly in VMEM (kernel operands with full-array
  BlockSpecs) — the analogue of the paper's dedicated 2 KB TAR/SF SRAM
  caches, except sized so the *entire* structure is resident (a 512 MB-
  equivalent RestSeg needs ~600 KB of TAR+SF, well under VMEM).
* Tag matching is performed as a one-hot matmul over the TAR
  (``one_hot(set_idx) @ tar``): on TPU a data-dependent row gather is
  slow/unsupported on the VPU, while a (tile, n_sets) x (n_sets, assoc)
  matmul maps directly onto the MXU.  This is the central
  hardware-adaptation decision recorded in DESIGN.md.
* The FlexSeg fallback is a flat-table vector gather, only consumed for
  lanes whose RSW missed (the paper's "FSW only on RSW miss").

Grid: one program per tile of ``tile`` vpns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashes import get_hash


def _rsw_kernel(vpn_ref, tar_ref, sf_ref, flex_ref, slot_ref, in_rest_ref,
                mapped_ref, *, assoc: int, hash_name: str):
    vpn = vpn_ref[...]                                  # (tile,)
    tar = tar_ref[...]                                  # (n_sets, assoc)
    sf = sf_ref[...]                                    # (n_sets,)
    n_sets = tar.shape[0]
    h = get_hash(hash_name)
    set_idx = h(vpn, n_sets).astype(jnp.int32)          # (tile,)

    # --- set filtering (SF probe) + tag matching via one-hot MXU matmul ---
    # The row gather stays a one-hot matmul (MXU, DESIGN.md
    # §TAR-match-one-hot), but a tag (vpn+1) can exceed 2^24 and would
    # round in a float32 matmul, mis-hitting.  Each 16-bit half is exactly
    # representable in float32 (a one-hot row selects a single value, so
    # the accumulation is also exact); the halves recombine in int32 and
    # the tag compare itself never leaves integer land.
    onehot = jax.nn.one_hot(set_idx, n_sets, dtype=jnp.float32)  # (tile, n_sets)
    tar_lo = (tar & 0xFFFF).astype(jnp.float32)
    tar_hi = ((tar >> 16) & 0xFFFF).astype(jnp.float32)
    tags = ((onehot @ tar_lo).astype(jnp.int32)
            | ((onehot @ tar_hi).astype(jnp.int32) << 16))        # (tile, assoc)
    counters = (onehot @ sf.astype(jnp.float32)[:, None]
                ).astype(jnp.int32)[:, 0]                        # (tile,)
    eq = tags == (vpn[:, None] + 1)
    hit = jnp.any(eq, axis=-1) & (counters > 0)
    way = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    rest_slot = set_idx * assoc + jnp.where(hit, way, 0)

    # --- flexible fallback (flat block table, consumed on miss only) ---
    flex_slot = flex_ref[...][vpn]                      # (tile,)
    slot = jnp.where(hit, rest_slot, flex_slot)
    mapped = hit | (flex_slot >= 0)

    slot_ref[...] = jnp.where(mapped, slot, -1).astype(jnp.int32)
    in_rest_ref[...] = hit.astype(jnp.int32)
    mapped_ref[...] = mapped.astype(jnp.int32)


def rsw_pallas(vpns: jax.Array, tar: jax.Array, sf: jax.Array,
               flex_flat: jax.Array, *, hash_name: str = "modulo",
               tile: int = 128, interpret: bool = True):
    """vpns (N,) int32 -> (slot (N,), in_rest (N,), mapped (N,)) int32."""
    n = vpns.shape[0]
    n_sets, assoc = tar.shape
    pad = (-n) % tile
    vp = jnp.pad(vpns, (0, pad)) if pad else vpns
    grid = (vp.shape[0] // tile,)
    kernel = functools.partial(_rsw_kernel, assoc=assoc, hash_name=hash_name)
    out_shapes = [jax.ShapeDtypeStruct((vp.shape[0],), jnp.int32)] * 3
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    slot, in_rest, mapped = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            full(tar.shape),          # TAR: fully VMEM-resident
            full(sf.shape),           # SF: fully VMEM-resident
            full(flex_flat.shape),    # flat flex table (validation config)
        ],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,))] * 3,
        out_shape=out_shapes,
        interpret=interpret,
    )(vp, tar, sf, flex_flat)
    return slot[:n], in_rest[:n], mapped[:n]
