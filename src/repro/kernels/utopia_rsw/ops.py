"""Jitted public wrapper for the RSW kernel."""
from __future__ import annotations

import functools

import jax

from .utopia_rsw import rsw_pallas
from .ref import rsw_ref


@functools.partial(jax.jit, static_argnames=("hash_name", "tile", "interpret",
                                             "use_kernel"))
def utopia_rsw(vpns, tar, sf, flex_flat, *, hash_name: str = "modulo",
               tile: int = 128, interpret: bool = True,
               use_kernel: bool = True):
    """Hybrid translate a batch of vpns.

    Returns (slot, in_rest, mapped) int32 arrays of shape ``vpns.shape``.
    ``use_kernel=False`` dispatches to the pure-jnp oracle (CPU fast path).
    """
    shape = vpns.shape
    flat = vpns.reshape(-1)
    if use_kernel:
        out = rsw_pallas(flat, tar, sf, flex_flat, hash_name=hash_name,
                         tile=tile, interpret=interpret)
    else:
        out = rsw_ref(flat, tar, sf, flex_flat, hash_name=hash_name)
    return tuple(o.reshape(shape) for o in out)
