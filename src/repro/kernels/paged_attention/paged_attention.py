"""Pallas TPU kernel: paged attention over the hybrid KV pool.

Seq-major (vLLM-layout analogue): each sequence's query tokens attend over
its logical blocks; physical slots come from the Utopia hybrid translation
(the RSW kernel's output), delivered via *scalar prefetch* so the BlockSpec
``index_map`` can steer the DMA of each grid step to the right pool slot —
the TPU analogue of the paper's "translation resolved before the data
access, overlapped with the previous tile's compute" (software pipelining
replaces the paper's RSW ∥ L2-TLB parallelism).

Queries may be a single token per sequence (decode, ``q (B, H, D)``) or a
whole prefill chunk (prefix-KV admission, ``q (B, Q, H, D)``): the Q chunk
tokens ride in the same VMEM tile and share each pool block's DMA, so a
chunk costs the same pool traffic as one decode token.  All Q queries of a
row attend the same extent ``ctx_len[b]`` (the installed prefix); the
chunk-internal causal part is computed outside and combined through the
(m, l) outputs.

Grid: (batch, num_blocks).  Scratch carries the online-softmax (m, l, acc)
across the block dimension.  Outputs are the *unnormalized* weighted values
plus (m, l) so the caller can combine partial results across model shards
(flash-decoding psum combine) before normalizing.

Holes (slot == -1: unmapped/swapped blocks) and tokens past the context
length are masked; hole blocks are clamped to slot 0 for the DMA and fully
masked in the body.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(slots_ref, ctx_ref, q_ref, k_ref, v_ref,
                       o_ref, m_ref, l_ref,
                       acc_ref, m_scr, l_scr, *,
                       block_tokens: int, tok_offset: int, tok_stride: int,
                       n_blocks: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0].astype(jnp.float32)                    # (Q, H, D)
    k = k_ref[0].astype(jnp.float32)                    # (bs, KV, D)
    v = v_ref[0].astype(jnp.float32)
    Q, H, D = q.shape
    bs, KV, _ = k.shape
    g = H // KV
    scale = 1.0 / math.sqrt(D)

    slot = slots_ref[b, j]
    # global token positions of this (block, local-token-shard) tile
    pos = j * block_tokens + tok_offset + jnp.arange(bs) * tok_stride
    if ctx_ref.ndim == 1:
        # one extent for every query of the row (decode / prefix read)
        valid = ((pos < ctx_ref[b]) & (slot >= 0))[None, :]      # (1, bs)
    else:
        # per-query extents (speculative verify): query i of the draft
        # window sees ctx_ref[b, i] pool tokens — the sequential causal
        # mask, applied inside the shared pool-block DMA
        valid = (pos[None, :] < ctx_ref[b][:, None]) & (slot >= 0)  # (Q, bs)

    qk = q.reshape(Q, KV, g, D)
    s = jnp.einsum("qkgd,tkd->qkgt", qk, k) * scale     # (Q, KV, g, bs)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)

    m_prev = m_scr[...]                                 # (Q, KV, g)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "qkgt,tkd->qkgd", p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == n_blocks - 1)
    def _finish():
        o_ref[0] = acc_ref[...].reshape(Q, H, D).astype(o_ref.dtype)
        m_ref[0] = m_scr[...].reshape(Q, H).astype(m_ref.dtype)
        l_ref[0] = l_scr[...].reshape(Q, H).astype(l_ref.dtype)


def paged_attention_pallas(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           slots: jax.Array, ctx_len: jax.Array, *,
                           tok_offset: int = 0, tok_stride: int = 1,
                           block_tokens: int | None = None,
                           interpret: bool = True):
    """q (B,H,D) or (B,Q,H,D); k/v_pool (slots, bs_local, KV, D);
    slots (B, nblk) int32; ctx_len (B,) int32 — or (B, Q) for the
    speculative-verify shape, giving every query its own attended
    extent.  Returns (o_weighted (B[,Q],H,D), m (B[,Q],H),
    l (B[,Q],H)) — output rank follows the query rank.

    ``tok_offset``/``tok_stride`` describe which global token positions the
    local pool token-shard holds (model-axis token striping); on a single
    shard use (0, 1) and ``block_tokens = bs_local``.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, Q, H, D = q.shape
    n_slots, bs, KV, _ = k_pool.shape
    nblk = slots.shape[1]
    if block_tokens is None:
        block_tokens = bs
    kernel = functools.partial(
        _paged_attn_kernel, block_tokens=block_tokens, tok_offset=tok_offset,
        tok_stride=tok_stride, n_blocks=nblk)
    g = H // KV
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # slots, ctx_len
        grid=(B, nblk),
        in_specs=[
            pl.BlockSpec((1, Q, H, D), lambda b, j, slots, ctx: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, D),
                         lambda b, j, slots, ctx:
                         (jnp.maximum(slots[b, j], 0), 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, D),
                         lambda b, j, slots, ctx:
                         (jnp.maximum(slots[b, j], 0), 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, H, D), lambda b, j, slots, ctx: (b, 0, 0, 0)),
            pl.BlockSpec((1, Q, H), lambda b, j, slots, ctx: (b, 0, 0)),
            pl.BlockSpec((1, Q, H), lambda b, j, slots, ctx: (b, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Q, KV, g, D), jnp.float32),
            pltpu.VMEM((Q, KV, g), jnp.float32),
            pltpu.VMEM((Q, KV, g), jnp.float32),
        ],
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Q, H, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Q, H), jnp.float32),
            jax.ShapeDtypeStruct((B, Q, H), jnp.float32),
        ],
        interpret=interpret,
    )(slots, ctx_len, q, k_pool, v_pool)
    if squeeze:
        return o[:, 0], m[:, 0], l[:, 0]
    return o, m, l
