"""Jitted wrapper: full decode attention = RSW translate + paged attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .paged_attention import paged_attention_pallas
from .ref import paged_attention_ref, normalize


@functools.partial(jax.jit, static_argnames=("tok_offset", "tok_stride",
                                             "block_tokens", "interpret",
                                             "use_kernel", "combine_axes"))
def paged_attention(q, k_pool, v_pool, slots, ctx_len, *,
                    tok_offset: int = 0, tok_stride: int = 1,
                    block_tokens=None, interpret: bool = True,
                    use_kernel: bool = True, combine_axes=()):
    """Decode attention over translated KV blocks.

    ``combine_axes``: mesh axis names to psum-combine partial softmax
    results over (flash-decoding across token/slot shards).  Empty outside
    shard_map.
    Returns normalized output (B, H, D).
    """
    fn = paged_attention_pallas if use_kernel else paged_attention_ref
    kwargs = dict(tok_offset=tok_offset, tok_stride=tok_stride,
                  block_tokens=block_tokens)
    if use_kernel:
        kwargs["interpret"] = interpret
    o, m, l = fn(q, k_pool, v_pool, slots, ctx_len, **kwargs)
    if combine_axes:
        m_glob = jax.lax.pmax(m, combine_axes)
        corr = jnp.exp(m - m_glob)
        o = jax.lax.psum(o * corr[..., None], combine_axes)
        l = jax.lax.psum(l * corr, combine_axes)
    return normalize(o, l).astype(q.dtype)
