"""Pure-jnp oracle for paged attention over the hybrid pool.

Supports one query per sequence (decode, ``q (B, H, D)``) and multi-token
queries (prefix-KV chunked prefill, ``q (B, Q, H, D)``): every query of a
row attends the same pool extent ``ctx_len[b]`` — the installed prefix.
Causal structure *within* a chunk is the caller's separate part (see
``models.attention.causal_attention_parts``), merged through the
unnormalized ``(o_weighted, m, l)`` contract this oracle shares with the
Pallas kernel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_pool_blocks(pool, slots):
    """The translated-slot read path: ``pool (slots, bs, KV, D)`` gathered
    at ``slots (B, nblk)`` (negative entries clamp to slot 0 and must be
    masked by the caller) -> ``(B, nblk, bs, KV, D)``."""
    return pool[jnp.maximum(slots, 0)]


def paged_attention_ref(q, k_pool, v_pool, slots, ctx_len, *,
                        tok_offset: int = 0, tok_stride: int = 1,
                        block_tokens: int | None = None):
    """Same contract as the kernel: returns (o_weighted, m, l).

    ``q`` is (B, H, D) — decode, one token per sequence — or (B, Q, H, D)
    — Q chunk tokens per sequence; outputs follow the query rank:
    (B[, Q], H, D) / (B[, Q], H).  ``ctx_len`` (B,) bounds the attended
    pool positions for every query of the row; a row with ``ctx_len == 0``
    (empty prefix) contributes l == 0 so the flash-decoding combine drops
    it exactly.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, Q, H, D = q.shape
    n_slots, bs, KV, _ = k_pool.shape
    nblk = slots.shape[1]
    if block_tokens is None:
        block_tokens = bs
    g = H // KV
    scale = 1.0 / math.sqrt(D)

    k = gather_pool_blocks(k_pool, slots)               # (B, nblk, bs, KV, D)
    v = gather_pool_blocks(v_pool, slots)
    pos = (jnp.arange(nblk)[:, None] * block_tokens
           + tok_offset + jnp.arange(bs)[None, :] * tok_stride)  # (nblk, bs)
    valid = (pos[None] < ctx_len[:, None, None]) & (slots >= 0)[..., None]

    qk = q.astype(jnp.float32).reshape(B, Q, KV, g, D)
    s = jnp.einsum("bqkgd,bjtkd->bkgqjt", qk, k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, None], s, NEG_INF)
    s = s.reshape(B, KV, g, Q, nblk * bs)
    m = s.max(axis=-1)                                  # (B, KV, g, Q)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid.reshape(B, 1, 1, 1, -1), p, 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgqn,bnkd->bkgqd", p,
                   v.astype(jnp.float32).reshape(B, nblk * bs, KV, D))
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Q, H, D)
    m = m.transpose(0, 3, 1, 2).reshape(B, Q, H)
    l = l.transpose(0, 3, 1, 2).reshape(B, Q, H)
    if squeeze:
        return o[:, 0], m[:, 0], l[:, 0]
    return o, m, l


def normalize(o, l):
    return o / jnp.maximum(l, 1e-30)[..., None]
