"""Pure-jnp oracle for paged attention over the hybrid pool.

Supports one query per sequence (decode, ``q (B, H, D)``) and multi-token
queries (``q (B, Q, H, D)``) in two flavours:

* prefix-KV chunked prefill: every query of a row attends the same pool
  extent ``ctx_len[b]`` — the installed prefix.  Causal structure
  *within* a chunk is the caller's separate part (see
  ``models.attention.causal_attention_parts``), merged through the
  unnormalized ``(o_weighted, m, l)`` contract this oracle shares with
  the Pallas kernel.
* speculative-decode verify: ``ctx_len (B, Q)`` gives every query its
  OWN extent — query ``i`` of the draft window attends ``pos + i + 1``
  pool positions, the exact mask sequential decode would apply, so the
  causal structure of the window lives entirely in the pool read (the
  K+1 tokens' K/V are written to their pool slots before the read).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_pool_blocks(pool, slots):
    """The translated-slot read path: ``pool (slots, bs, KV, D)`` gathered
    at ``slots (B, nblk)`` (negative entries clamp to slot 0 and must be
    masked by the caller) -> ``(B, nblk, bs, KV, D)``."""
    return pool[jnp.maximum(slots, 0)]


def paged_attention_ref(q, k_pool, v_pool, slots, ctx_len, *,
                        tok_offset: int = 0, tok_stride: int = 1,
                        block_tokens: int | None = None):
    """Same contract as the kernel: returns (o_weighted, m, l).

    ``q`` is (B, H, D) — decode, one token per sequence — or (B, Q, H, D)
    — Q chunk tokens per sequence; outputs follow the query rank:
    (B[, Q], H, D) / (B[, Q], H).  ``ctx_len`` bounds the attended pool
    positions: ``(B,)`` applies one extent to every query of the row
    (prefix read), ``(B, Q)`` gives each query its own extent (the
    speculative-verify shape: query ``i`` sees ``pos + i + 1`` tokens).
    A query with extent 0 contributes l == 0 so the flash-decoding
    combine drops it exactly.
    """
    k = gather_pool_blocks(k_pool, slots)               # (B, nblk, bs, KV, D)
    v = gather_pool_blocks(v_pool, slots)
    return paged_attention_blocks(q, k, v, slots, ctx_len,
                                  tok_offset=tok_offset,
                                  tok_stride=tok_stride,
                                  block_tokens=block_tokens)


def paged_attention_blocks(q, k, v, slots, ctx_len, *,
                           tok_offset: int = 0, tok_stride: int = 1,
                           block_tokens: int | None = None):
    """``paged_attention_ref`` with the gather already done.

    ``k``/``v`` are the PRE-GATHERED per-row blocks ``(B, nblk, bs, KV, D)``
    — exactly ``gather_pool_blocks(pool, slots)``, or the sharded engine's
    psum-reconstructed blocks (where a ``slots < 0`` row carries zeros
    instead of the clamp-gathered slot-0 data; both are bitwise-safe, the
    mask below NEG_INFs those scores before they contribute).  ``slots``
    is still taken for the validity mask.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, Q, H, D = q.shape
    nblk, bs, KV = k.shape[1], k.shape[2], k.shape[3]
    if block_tokens is None:
        block_tokens = bs
    g = H // KV
    scale = 1.0 / math.sqrt(D)

    pos = (jnp.arange(nblk)[:, None] * block_tokens
           + tok_offset + jnp.arange(bs)[None, :] * tok_stride)  # (nblk, bs)
    if ctx_len.ndim == 1:
        # one extent per row, identical for every query (broadcast at the
        # query axis keeps the 1-D path's arrays — and results — bitwise
        # unchanged)
        ctx_q = ctx_len[:, None]                        # (B, 1)
    else:
        ctx_q = ctx_len                                 # (B, Q)
    valid = ((pos[None, None] < ctx_q[:, :, None, None])
             & (slots >= 0)[:, None, :, None])          # (B, Qc, nblk, bs)
    vflat = valid.reshape(B, 1, 1, valid.shape[1], -1)  # (B,1,1,Qc,nblk*bs)

    qk = q.astype(jnp.float32).reshape(B, Q, KV, g, D)
    s = jnp.einsum("bqkgd,bjtkd->bkgqjt", qk, k.astype(jnp.float32)) * scale
    s = s.reshape(B, KV, g, Q, nblk * bs)
    s = jnp.where(vflat, s, NEG_INF)
    m = s.max(axis=-1)                                  # (B, KV, g, Q)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(vflat, p, 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgqn,bnkd->bkgqd", p,
                   v.astype(jnp.float32).reshape(B, nblk * bs, KV, D))
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Q, H, D)
    m = m.transpose(0, 3, 1, 2).reshape(B, Q, H)
    l = l.transpose(0, 3, 1, 2).reshape(B, Q, H)
    if squeeze:
        return o[:, 0], m[:, 0], l[:, 0]
    return o, m, l


def normalize(o, l):
    return o / jnp.maximum(l, 1e-30)[..., None]
