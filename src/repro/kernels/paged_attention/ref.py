"""Pure-jnp oracle for decode paged attention over the hybrid pool."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pool, v_pool, slots, ctx_len, *,
                        tok_offset: int = 0, tok_stride: int = 1,
                        block_tokens: int | None = None):
    """Same contract as the kernel: returns (o_weighted, m, l)."""
    B, H, D = q.shape
    n_slots, bs, KV, _ = k_pool.shape
    nblk = slots.shape[1]
    if block_tokens is None:
        block_tokens = bs
    g = H // KV
    scale = 1.0 / math.sqrt(D)

    safe = jnp.maximum(slots, 0)
    k = k_pool[safe]                                    # (B, nblk, bs, KV, D)
    v = v_pool[safe]
    pos = (jnp.arange(nblk)[:, None] * block_tokens
           + tok_offset + jnp.arange(bs)[None, :] * tok_stride)  # (nblk, bs)
    valid = (pos[None] < ctx_len[:, None, None]) & (slots >= 0)[..., None]

    qk = q.astype(jnp.float32).reshape(B, KV, g, D)
    s = jnp.einsum("bkgd,bjtkd->bkgjt", qk, k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    s = s.reshape(B, KV, g, nblk * bs)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None].reshape(B, 1, 1, -1), p, 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgn,bnkd->bkgd", p,
                   v.astype(jnp.float32).reshape(B, nblk * bs, KV, D))
    return (o.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def normalize(o, l):
    return o / jnp.maximum(l, 1e-30)[..., None]
