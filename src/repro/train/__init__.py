from .trainer import (TrainConfig, make_train_step, init_state, abstract_state, state_shardings, make_schedule)
