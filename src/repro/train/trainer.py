"""Distributed train step factory.

Builds a pjit-able ``train_step(state, batch) -> (state, metrics)`` with:
* GSPMD sharding (param specs + activation pins from dist.sharding),
* microbatch gradient accumulation (lax.scan over microbatches),
* remat (per-layer checkpointing inside the model's scan),
* grad clipping + LR schedule,
* optional int8+error-feedback gradient compression for the cross-pod hop.

``abstract_state`` builds the state as ShapeDtypeStructs for the dry-run
(no allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import FwdOptions, loss_fn, model_dims, init_params
from repro.models.layers import no_pins
from repro.dist.sharding import (ShardingRules, make_pins, param_shardings,
                                 batch_spec)
from repro.dist import compression
from repro.optim import make_optimizer, clip_by_global_norm
from repro.optim.schedules import warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    max_grad_norm: float = 1.0
    microbatches: int = 1
    grad_compression: bool = False     # int8 + error feedback (cross-pod DP)
    weight_decay: float = 0.1
    dtype: Any = jnp.bfloat16
    accum_dtype: Any = jnp.float32   # grad-accum buffer (bf16 for 100B+)


def make_schedule(tc: TrainConfig):
    return warmup_cosine(tc.lr, tc.warmup_steps, tc.total_steps)


def init_state(key, cfg: ArchConfig, dims, tc: TrainConfig,
               param_dtype=jnp.float32):
    params = init_params(key, cfg, dims, dtype=param_dtype)
    opt = make_optimizer(cfg.optimizer, weight_decay=tc.weight_decay)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if tc.grad_compression:
        state["ef"] = compression.init_ef(params)
    return state


def abstract_state(cfg: ArchConfig, dims, tc: TrainConfig,
                   param_dtype=jnp.bfloat16):
    """State as ShapeDtypeStructs (dry-run: no allocation)."""
    return jax.eval_shape(
        lambda k: init_state(k, cfg, dims, tc, param_dtype),
        jax.random.PRNGKey(0))


def make_train_step(cfg: ArchConfig, dims, tc: TrainConfig,
                    fwd: FwdOptions, mesh: Optional[Mesh] = None,
                    rules: Optional[ShardingRules] = None,
                    loss_override: Optional[Callable] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``loss_override(params, batch) -> (loss, metrics)`` swaps the model
    forward (e.g. the explicit-schedule Megatron path, dist/megatron.py).
    """
    pins = make_pins(mesh, rules) if mesh is not None else no_pins
    opt = make_optimizer(cfg.optimizer, weight_decay=tc.weight_decay)
    schedule = make_schedule(tc)

    def loss_of(params, batch):
        if loss_override is not None:
            return loss_override(params, batch)
        return loss_fn(params, batch, cfg, dims, fwd, pins)

    def compute_grads(params, batch):
        if tc.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            return loss, metrics, grads
        # gradient accumulation over microbatches (batch dim splits)
        mb = tc.microbatches
        batch_mb = jax.tree.map(
            lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)

        adt = tc.accum_dtype

        def acc_step(acc, micro):
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, micro)
            acc = jax.tree.map(lambda a, g: a + g.astype(adt), acc, grads)
            return acc, (loss, metrics)

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
        acc, (losses, metrics) = jax.lax.scan(acc_step, zero, batch_mb)
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
        grads = jax.tree.map(lambda g: g / mb, acc)
        return losses.mean(), metrics, grads

    def train_step(state, batch):
        params = state["params"]
        loss, metrics, grads = compute_grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tc.max_grad_norm)
        new_state = dict(state)
        if tc.grad_compression:
            grads, new_state["ef"] = compression.tree_compress_with_ef(
                grads, state["ef"])
        lr = schedule(state["step"])
        new_params, new_opt = opt.update(grads, state["opt"], params,
                                         state["step"], lr)
        new_state.update(params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        metrics = dict(metrics)
        metrics.update(grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step


def state_shardings(state_shape, mesh: Mesh, rules: ShardingRules):
    """NamedShardings for the full train state (opt state mirrors params)."""
    p_sh = param_shardings(state_shape["params"], rules, mesh)
    out = {"params": p_sh, "step": NamedSharding(mesh, P())}
    if "opt" in state_shape:
        o = state_shape["opt"]
        if "m" in o:   # adamw: m/v mirror params exactly
            out["opt"] = {"m": p_sh, "v": p_sh}
        else:          # adafactor: vr/vc factors drop one dim's spec
            out["opt"] = {"v": _adafactor_shardings(
                o["v"], state_shape["params"], p_sh, mesh)}
    if "ef" in state_shape:
        # EFState(residual) mirrors the parameter sharding
        out["ef"] = jax.tree.map(
            lambda s: compression.EFState(residual=s), p_sh,
            is_leaf=lambda x: isinstance(x, NamedSharding))
    return out


def _adafactor_shardings(v_tree, params_shape, p_sh, mesh):
    """vr drops the last dim's spec; vc drops the second-to-last."""
    flat_p, treedef = jax.tree.flatten(params_shape)
    flat_sh = treedef.flatten_up_to(p_sh)
    flat_v = treedef.flatten_up_to(v_tree)

    def factor_sh(p, sh, v):
        spec = sh.spec
        full = tuple(spec) + (None,) * (len(p.shape) - len(spec))
        if "vr" in v:
            return {"vr": NamedSharding(mesh, P(*full[:-1])),
                    "vc": NamedSharding(mesh, P(*(full[:-2] + full[-1:])))}
        return {"v": sh}

    return treedef.unflatten(
        [factor_sh(p, sh, v) for p, sh, v in zip(flat_p, flat_sh, flat_v)])
