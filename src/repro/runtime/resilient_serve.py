"""Crash-safe serving supervisor: snapshot, restore, replay (ISSUE 10).

``ResilientServe`` is the serving twin of :class:`runtime.fault.
ResilientLoop`.  The train loop restarts from a checkpoint and replays
data batches; the serving engine's unit of recovery is an
:class:`serve.EngineSnapshot` — the COMPLETE serving state (KV pool,
translation tables, scheduler queue, mid-chunk prefill progress,
sampling keys, monotone counters) as one portable value.  The
supervisor wraps ``Engine.poll()``/``stream()``:

* **Snapshot cadence**: every ``snapshot_every`` engine steps it calls
  ``Engine.snapshot()`` (and, when a ``ckpt.CheckpointManager`` is
  attached, persists the snapshot to disk through ``save_named`` — the
  atomic-commit, corrupt-shard-tolerant path).
* **Recovery**: a caught fault (``InjectedStepFault`` by default; the
  ``catch`` tuple is the extension point for real device failures)
  restores the latest snapshot, resubmits every request the journal
  saw AFTER that snapshot, and replays.  Restarts are budgeted
  (``max_restarts``) — a fault loop re-raises rather than spinning.
* **Exactly-once delivery**: replayed steps re-emit tokens the caller
  already received.  The supervisor remembers what it delivered per
  sequence and forwards only the suffix — the externally observed
  stream of a crashed run is BIT-IDENTICAL to an uncrashed run's
  (pinned by the crash oracle in tests/test_recovery.py).  A replay
  whose re-emitted prefix DIFFERS from what was already delivered is a
  correctness bug, and raises ``ReplayDivergence`` loudly.
* **Watchdog**: poll wall times feed a :class:`runtime.fault.
  StepWatchdog` (EMA-relative, built on ``StragglerMonitor``) so hung
  dispatches surface in ``stats()["recovery"]`` instead of in a silent
  stall.

The supervisor journals submissions, so requests MUST go through
``ResilientServe.submit`` (submitting directly on the wrapped engine
works until the first crash, then those requests silently vanish from
the replay — the constructor's initial snapshot covers anything
submitted before the supervisor existed).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple, Type

from repro.serve.engine import EngineSnapshot, RequestOutput

from .fault import InjectedStepFault, StepWatchdog

__all__ = ["ResilientServe", "ReplayDivergence"]


class ReplayDivergence(AssertionError):
    """A restored replay re-emitted tokens that DIFFER from what was
    already delivered for the same sequence: the snapshot/restore
    bit-identity contract is broken (never expected in production;
    exists so a violation cannot masquerade as a clean stream)."""


class ResilientServe:
    """Supervise an :class:`serve.Engine` with snapshot/restore recovery.

    Parameters
    ----------
    engine:          the engine to supervise (its state at construction
                     is the first snapshot — nothing before is lost).
    ckpt_manager:    optional ``ckpt.CheckpointManager``; when given,
                     every snapshot is also persisted via ``save_named``
                     so a NEW process can resume with
                     :meth:`from_checkpoint`.
    snapshot_every:  engine steps between snapshots (N=10 default: the
                     bench sweeps N∈{10,50} for the overhead/replay
                     trade — see benchmarks/bench_recovery.py).
    max_restarts:    recovery budget; exceeding it re-raises the fault.
    catch:           exception types treated as recoverable crashes.
    """

    def __init__(self, engine, ckpt_manager=None, *,
                 snapshot_every: int = 10, max_restarts: int = 3,
                 catch: Tuple[Type[BaseException], ...] =
                 (InjectedStepFault,),
                 watchdog: Optional[StepWatchdog] = None) -> None:
        if snapshot_every < 1:
            raise ValueError(f"snapshot_every must be >= 1, got "
                             f"{snapshot_every}")
        if max_restarts < 0:
            raise ValueError(f"max_restarts must be >= 0, got "
                             f"{max_restarts}")
        self.engine = engine
        self.ckpt = ckpt_manager
        self.snapshot_every = snapshot_every
        self.max_restarts = max_restarts
        self.catch = tuple(catch)
        self.watchdog = watchdog if watchdog is not None else StepWatchdog()
        # exactly-once delivery ledger: tokens already handed to the
        # caller per sequence, and sequences whose finish was reported
        self._delivered: Dict[int, List[int]] = {}
        self._finish_reported: set = set()
        # submissions since the LAST snapshot (cleared when a snapshot
        # captures them): the replay tail a restore must resubmit
        self._journal: List[Any] = []
        # telemetry
        self.restarts = 0
        self.snapshots = 0
        self.replayed_steps = 0
        self.resubmitted = 0
        self.dedup_tokens = 0
        # the recovery anchor: everything submitted before the
        # supervisor existed is inside this initial snapshot, so even a
        # crash on the very first step restores cleanly
        self._snap: EngineSnapshot = self._take_snapshot()

    # ----------------------------------------------------------- serving
    def submit(self, req, **kw) -> None:
        """Submit through the supervisor so the request is journaled for
        replay (a post-snapshot submission would otherwise vanish on
        restore)."""
        self.engine.submit(req, **kw)
        self._journal.append((req, dict(kw)))
        sid = req.seq_id
        # seq_id reuse: the new incarnation's stream starts empty
        self._delivered[sid] = []
        self._finish_reported.discard(sid)

    def cancel(self, seq_id: int, reason: str = "cancelled") -> bool:
        """Cancel on the engine AND in the journal: a cancelled request
        must not resurrect on replay."""
        out = self.engine.cancel(seq_id, reason=reason)
        self._journal = [(r, kw) for r, kw in self._journal
                         if r.seq_id != seq_id]
        return out

    def poll(self) -> List[RequestOutput]:
        """``Engine.poll`` with crash recovery and exactly-once
        delivery.  One call advances at most one engine step (plus the
        replayed steps hidden inside a recovery)."""
        while True:
            try:
                t0 = time.perf_counter()
                outs = self.engine.poll()
                self.watchdog.record(time.perf_counter() - t0)
                self._maybe_snapshot()
                return self._dedup(outs)
            except self.catch as e:
                self._recover(e)

    def stream(self):
        """Iterate deduplicated ``RequestOutput``s until every request
        finishes — the crash-safe twin of ``Engine.stream()``."""
        while self.engine.has_unfinished():
            yield from self.poll()

    def has_unfinished(self) -> bool:
        return self.engine.has_unfinished()

    # ---------------------------------------------------------- recovery
    def _take_snapshot(self) -> EngineSnapshot:
        snap = self.engine.snapshot()
        if self.ckpt is not None:
            self.ckpt.save_named(snap.step, snap.to_arrays())
        self.snapshots += 1
        return snap

    def _maybe_snapshot(self) -> None:
        if self.engine._step_count - self._snap.step >= self.snapshot_every:
            self._snap = self._take_snapshot()
            # everything journaled so far is inside the new snapshot
            self._journal.clear()

    def _recover(self, exc: BaseException) -> None:
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise exc
        crashed_at = self.engine._step_count
        self.engine.restore(self._snap)
        self.replayed_steps += max(0, crashed_at - self._snap.step)
        for req, kw in self._journal:
            self.engine.submit(req, **kw)
            self.resubmitted += 1

    def _dedup(self, outs: List[RequestOutput]) -> List[RequestOutput]:
        """Forward only what the caller has not seen: per-sequence
        delivered-token suffixing + report each finish exactly once."""
        fresh: List[RequestOutput] = []
        for ro in outs:
            seen = self._delivered.setdefault(ro.seq_id, [])
            full = list(ro.token_ids)
            # mid-replay the engine's stream is a PREFIX of what was
            # delivered (it is still catching up) — only a mismatch in
            # the overlapping region is divergence
            n = min(len(seen), len(full))
            if full[:n] != seen[:n]:
                raise ReplayDivergence(
                    f"seq {ro.seq_id}: replay re-emitted {full[:n]} "
                    f"where {seen[:n]} was already delivered — "
                    "snapshot/restore is not bit-identical")
            new = full[len(seen):]
            self.dedup_tokens += len(ro.new_token_ids) - len(new)
            seen.extend(new)
            finished = bool(ro.finished)
            if finished and ro.seq_id in self._finish_reported:
                finished = False               # already reported
            if finished:
                self._finish_reported.add(ro.seq_id)
            if new or finished:
                fresh.append(RequestOutput(
                    seq_id=ro.seq_id, new_token_ids=tuple(new),
                    token_ids=ro.token_ids, finished=finished,
                    finish_reason=ro.finish_reason))
        return fresh

    # --------------------------------------------------------- telemetry
    def stats(self) -> dict:
        """Engine ``stats()`` plus a ``"recovery"`` block."""
        s = self.engine.stats()
        s["recovery"] = {
            "restarts": self.restarts,
            "max_restarts": self.max_restarts,
            "snapshots": self.snapshots,
            "snapshot_every": self.snapshot_every,
            "last_snapshot_step": self._snap.step,
            "replayed_steps": self.replayed_steps,
            "resubmitted_requests": self.resubmitted,
            "dedup_tokens": self.dedup_tokens,
            "watchdog_flags": len(self.watchdog.flags),
            "persisted": self.ckpt is not None,
        }
        return s

    # -------------------------------------------------- cross-process resume
    @classmethod
    def from_checkpoint(cls, engine, ckpt_manager, **kw) -> "ResilientServe":
        """Resume serving in a NEW process: load the latest persisted
        snapshot (corrupt shards skip-and-warn to the previous step),
        restore it onto ``engine``, and supervise from there."""
        arrays, _step = ckpt_manager.restore_named()
        engine.restore(EngineSnapshot.from_arrays(arrays))
        return cls(engine, ckpt_manager, **kw)
