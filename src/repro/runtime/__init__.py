from .fault import (FaultInjector, InjectedFault, StragglerMonitor, ResilientLoop, LoopReport)
