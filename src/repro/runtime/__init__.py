from .fault import (FaultInjector, ServeFaultInjector, InjectedFault,
                    InjectedStepFault, InjectedAllocFault,
                    StragglerMonitor, StepWatchdog, ResilientLoop,
                    LoopReport)
from .resilient_serve import ResilientServe, ReplayDivergence
