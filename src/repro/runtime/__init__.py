from .fault import (FaultInjector, ServeFaultInjector, InjectedFault,
                    InjectedStepFault, InjectedAllocFault,
                    StragglerMonitor, ResilientLoop, LoopReport)
