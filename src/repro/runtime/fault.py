"""Fault tolerance: failure injection, bounded retry, straggler mitigation.

On a real multi-pod deployment these hooks wrap the per-host train loop;
here the multi-host behaviour is *simulated* (single process) but the
control logic — checkpoint/restart cadence, retry budgets, deterministic
data replay, straggler detection via per-host step-time EMA — is the real
algorithm and is unit-tested.

* ``FaultInjector``      — deterministic failure schedule for tests.
* ``ResilientLoop``      — train driver: periodic async checkpoints,
                           restore-and-replay on failure (data pipeline is
                           f(step), so replay is exact), bounded retries.
* ``StragglerMonitor``   — per-host EMA of step times; hosts slower than
                           ``threshold`` x median are flagged for
                           re-replication (the scheduler callback decides).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


class InjectedFault(RuntimeError):
    pass


class FaultInjector:
    """Raises InjectedFault at the scheduled steps (each fires once)."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise InjectedFault(f"injected failure at step {step}")


class StragglerMonitor:
    def __init__(self, n_hosts: int, alpha: float = 0.3,
                 threshold: float = 1.5):
        self.ema = np.zeros(n_hosts)
        self.alpha = alpha
        self.threshold = threshold
        self.seen = np.zeros(n_hosts, bool)

    def record(self, host: int, step_time: float) -> None:
        if not self.seen[host]:
            self.ema[host] = step_time
            self.seen[host] = True
        else:
            self.ema[host] = (1 - self.alpha) * self.ema[host] \
                + self.alpha * step_time

    def stragglers(self) -> List[int]:
        if not self.seen.any():
            return []
        med = float(np.median(self.ema[self.seen]))
        if med <= 0:
            return []
        return [int(h) for h in np.nonzero(
            self.seen & (self.ema > self.threshold * med))[0]]


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    restarts: int
    final_step: int
    losses: List[float]


class ResilientLoop:
    """Checkpointed train loop with restart-and-replay semantics."""

    def __init__(self, ckpt_manager, data, train_step: Callable,
                 ckpt_every: int = 10, max_restarts: int = 3,
                 injector: Optional[FaultInjector] = None,
                 on_restart: Optional[Callable] = None):
        self.ckpt = ckpt_manager
        self.data = data
        self.train_step = train_step
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.on_restart = on_restart

    def run(self, state, total_steps: int, to_device=None) -> LoopReport:
        import jax
        restarts = 0
        losses: List[float] = []
        step = int(np.asarray(state["step"]))
        while step < total_steps:
            try:
                while step < total_steps:
                    if self.injector is not None:
                        self.injector.maybe_fail(step)
                    batch = self.data.batch_at(step)
                    batch = {k: jax.numpy.asarray(v)
                             for k, v in batch.items()}
                    state, metrics = self.train_step(state, batch)
                    losses.append(float(metrics["loss"]))
                    step += 1
                    if step % self.ckpt_every == 0:
                        self.ckpt.save(step, state)
            except InjectedFault:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    # no checkpoint yet: restart from scratch is the policy
                    step = 0
                    if self.on_restart is not None:
                        state = self.on_restart(None)
                    continue
                restored, step = self.ckpt.restore(
                    jax.tree.map(lambda x: jax.ShapeDtypeStruct(
                        x.shape, x.dtype), state), latest)
                state = (self.on_restart(restored) if self.on_restart
                         else jax.tree.map(jax.numpy.asarray, restored))
        self.ckpt.wait()
        return LoopReport(steps_run=len(losses), restarts=restarts,
                          final_step=step, losses=losses)
