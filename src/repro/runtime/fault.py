"""Fault tolerance: failure injection, bounded retry, straggler mitigation.

On a real multi-pod deployment these hooks wrap the per-host train loop;
here the multi-host behaviour is *simulated* (single process) but the
control logic — checkpoint/restart cadence, retry budgets, deterministic
data replay, straggler detection via per-host step-time EMA — is the real
algorithm and is unit-tested.

* ``FaultInjector``      — deterministic failure schedule for tests.
* ``ServeFaultInjector`` — chaos schedule for the SERVING engine: forced
                           allocation failures and preemptions at
                           adversarial step points (ISSUE 6).
* ``ResilientLoop``      — train driver: periodic async checkpoints,
                           restore-and-replay on failure (data pipeline is
                           f(step), so replay is exact), bounded retries.
* ``StragglerMonitor``   — per-host EMA of step times; hosts slower than
                           ``threshold`` x median are flagged for
                           re-replication (the scheduler callback decides).

Train-loop and serve-loop injection share ONE fault vocabulary, the
``InjectedFault`` taxonomy below: a *step* fault kills a whole unit of
work in flight (the train loop restarts from a checkpoint), an *alloc*
fault denies a resource (the serve engine degrades by preempting a
victim to its host KV tier — it never unwinds a dispatch).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """Base of the shared train/serve fault taxonomy.

    * :class:`InjectedStepFault`  — a step/host died mid-flight; the
      recovery unit is restart-and-replay (``ResilientLoop``).
    * :class:`InjectedAllocFault` — a resource allocation was denied;
      the recovery unit is graceful degradation (the serve engine
      consults :class:`ServeFaultInjector` as a capacity check and
      preempts instead of catching an exception — this class exists so
      tests and logs can name the failure mode).
    """
    kind = "generic"


class InjectedStepFault(InjectedFault):
    kind = "step"


class InjectedAllocFault(InjectedFault):
    kind = "alloc"


class FaultInjector:
    """Raises :class:`InjectedStepFault` at the scheduled steps (each
    fires once).  Serve-path chaos uses :class:`ServeFaultInjector`
    instead — the engine polls for denials rather than catching."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise InjectedStepFault(f"injected failure at step {step}")


class ServeFaultInjector:
    """Chaos schedule for the serving engine (ISSUE 6).

    Unlike :class:`FaultInjector`, the engine CONSULTS this injector
    instead of catching exceptions — an allocation denial is a normal
    capacity-check outcome the engine degrades through (preempt a victim
    to the host KV tier), never an unwound dispatch.

    * ``alloc_fail_at``: iterable of ``(step, point)`` — make ONE
      capacity check at that engine step report failure.  Points are the
      adversarial moments of the step loop: ``"admit"`` (a prompt
      chunk's block reservation — hits mid-chunk-prefill prompts),
      ``"decode"`` (a decode/spec-window boundary block — hits
      mid-spec-window), ``"resume"`` (a host-tier restore's capacity
      gate).
    * ``preempt_at``: iterable of ``(step, phase, target)`` — force a
      preemption at one of the step's two safe points: phase ``"pre"``
      (before admission — a mid-chunk-prefill victim is torn out between
      its chunks) or ``"post"`` (after the commit — between a
      speculative window's verify/commit and the next dispatch).
      ``target`` is a seq_id or ``"auto"`` (the engine's victim policy
      picks).  Each entry fires once.
    * ``crash_at``: iterable of ``(step, phase)`` — the ONE schedule
      that DOES raise: an :class:`InjectedStepFault` at the named step
      boundary (phase ``"pre"``: before the step mutated anything;
      ``"post"``: after the step's full commit).  This simulates the
      process dying — the engine makes no attempt to stay consistent
      across it, and recovery is restore-from-snapshot
      (``runtime/resilient_serve.py``), never unwinding.  Each entry
      fires once.
    * ``seed`` + ``alloc_fail_rate``/``preempt_rate``: random chaos from
      a seeded ``np.random.RandomState`` — a given (seed, workload) run
      is exactly reproducible.

    ``log`` records every fired event as a tuple (``("alloc", step,
    point)`` / ``("preempt", step, phase, target)``) for test
    assertions; ``faults()`` summarizes counts by kind, using the
    :class:`InjectedFault` taxonomy names.
    """

    def __init__(self, alloc_fail_at=(), preempt_at=(), crash_at=(),
                 seed: Optional[int] = None,
                 alloc_fail_rate: float = 0.0,
                 preempt_rate: float = 0.0):
        self._alloc = {(int(s), str(p)) for s, p in alloc_fail_at}
        self._forced: Dict[Tuple[int, str], List] = defaultdict(list)
        for step, phase, target in preempt_at:
            if phase not in ("pre", "post"):
                raise ValueError(f"unknown preempt phase {phase!r} "
                                 "(expected 'pre' or 'post')")
            self._forced[(int(step), str(phase))].append(target)
        self._crash = set()
        for step, phase in crash_at:
            if phase not in ("pre", "post"):
                raise ValueError(f"unknown crash phase {phase!r} "
                                 "(expected 'pre' or 'post')")
            self._crash.add((int(step), str(phase)))
        self._rng = (np.random.RandomState(seed)
                     if seed is not None else None)
        self.alloc_fail_rate = float(alloc_fail_rate)
        self.preempt_rate = float(preempt_rate)
        self.log: List[tuple] = []

    def alloc_unavailable(self, step: int, point: str) -> bool:
        """Should this capacity check be forced to fail?"""
        key = (int(step), str(point))
        if key in self._alloc:
            self._alloc.discard(key)
            self.log.append(("alloc", key[0], key[1]))
            return True
        if (self._rng is not None and self.alloc_fail_rate > 0
                and self._rng.random_sample() < self.alloc_fail_rate):
            self.log.append(("alloc", int(step), str(point)))
            return True
        return False

    def forced_preempts(self, step: int, phase: str) -> List:
        """Sequences to forcibly preempt at this (step, phase)."""
        out = list(self._forced.pop((int(step), str(phase)), ()))
        if (self._rng is not None and self.preempt_rate > 0
                and self._rng.random_sample() < self.preempt_rate):
            out.append("auto")
        for t in out:
            self.log.append(("preempt", int(step), str(phase), t))
        return out

    def maybe_crash(self, step: int, phase: str) -> None:
        """Raise :class:`InjectedStepFault` if a crash is scheduled at
        this step boundary (fires once; the event is logged FIRST so a
        post-mortem sees the crash that killed the run)."""
        key = (int(step), str(phase))
        if key in self._crash:
            self._crash.discard(key)
            self.log.append(("crash", key[0], key[1]))
            raise InjectedStepFault(
                f"injected serve crash at step {key[0]} ({key[1]})")

    def faults(self) -> Dict[str, int]:
        """Fired-event counts keyed by taxonomy kind."""
        out: Dict[str, int] = {InjectedAllocFault.kind: 0, "preempt": 0,
                               InjectedStepFault.kind: 0}
        for ev in self.log:
            out[{"alloc": InjectedAllocFault.kind,
                 "crash": InjectedStepFault.kind}.get(ev[0],
                                                      "preempt")] += 1
        return out


class StragglerMonitor:
    """Per-host EMA of step times; hosts slower than ``threshold`` x the
    median are flagged for re-replication.

    Serving analogue: the engine's overload ladder (admit → chunk →
    preempt → reject, DESIGN.md §tiered-KV-and-overload) plays the same
    role for KV capacity that straggler re-replication plays for
    compute — both are driven by the shared :class:`InjectedFault`
    taxonomy in tests (:class:`ServeFaultInjector` on the serve path,
    :class:`FaultInjector` here)."""

    def __init__(self, n_hosts: int, alpha: float = 0.3,
                 threshold: float = 1.5):
        self.ema = np.zeros(n_hosts)
        self.alpha = alpha
        self.threshold = threshold
        self.seen = np.zeros(n_hosts, bool)

    def record(self, host: int, step_time: float) -> None:
        if not self.seen[host]:
            self.ema[host] = step_time
            self.seen[host] = True
        else:
            self.ema[host] = (1 - self.alpha) * self.ema[host] \
                + self.alpha * step_time

    def stragglers(self) -> List[int]:
        if not self.seen.any():
            return []
        med = float(np.median(self.ema[self.seen]))
        if med <= 0:
            return []
        return [int(h) for h in np.nonzero(
            self.seen & (self.ema > self.threshold * med))[0]]


class StepWatchdog:
    """Hung-dispatch detector for the serving loop, built on
    :class:`StragglerMonitor`.

    A single serving process has no peer hosts to compare against, so
    the watchdog treats the engine's OWN smoothed step time as the
    population: each step is recorded into a one-host monitor's EMA and
    flagged when it exceeds ``threshold`` x the EMA of the steps before
    it (the same threshold semantics the multi-host monitor applies
    against the median host).  ``warmup`` steps are exempt — the first
    dispatches pay XLA compilation and would always flag.
    """

    def __init__(self, threshold: float = 10.0, alpha: float = 0.3,
                 warmup: int = 3):
        self._mon = StragglerMonitor(1, alpha=alpha, threshold=threshold)
        self.threshold = threshold
        self.warmup = warmup
        self.seen = 0
        self.flags: List[Tuple[int, float]] = []   # (step index, wall s)

    def record(self, step_time: float) -> bool:
        """Feed one step's wall time; True when it flagged as hung
        (recorded AFTER the check so the hung step does not drag the
        baseline up before judging itself)."""
        self.seen += 1
        hung = (self.seen > self.warmup and self._mon.seen[0]
                and step_time > self.threshold * float(self._mon.ema[0]))
        if hung:
            self.flags.append((self.seen, float(step_time)))
        self._mon.record(0, step_time)
        return bool(hung)


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    restarts: int
    final_step: int
    losses: List[float]


class ResilientLoop:
    """Checkpointed train loop with restart-and-replay semantics.

    Recovers from :class:`InjectedStepFault` (a whole step died); its
    serving counterpart is ``Engine.preempt_request`` /
    host-tier resume, which recovers from *allocation* denials
    (:class:`InjectedAllocFault` in the shared taxonomy) by swapping a
    victim sequence out instead of restarting anything — see
    :class:`ServeFaultInjector` for how tests force both."""

    def __init__(self, ckpt_manager, data, train_step: Callable,
                 ckpt_every: int = 10, max_restarts: int = 3,
                 injector: Optional[FaultInjector] = None,
                 on_restart: Optional[Callable] = None):
        self.ckpt = ckpt_manager
        self.data = data
        self.train_step = train_step
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.on_restart = on_restart

    def run(self, state, total_steps: int, to_device=None) -> LoopReport:
        import jax
        restarts = 0
        losses: List[float] = []
        step = int(np.asarray(state["step"]))
        while step < total_steps:
            try:
                while step < total_steps:
                    if self.injector is not None:
                        self.injector.maybe_fail(step)
                    batch = self.data.batch_at(step)
                    batch = {k: jax.numpy.asarray(v)
                             for k, v in batch.items()}
                    state, metrics = self.train_step(state, batch)
                    losses.append(float(metrics["loss"]))
                    step += 1
                    if step % self.ckpt_every == 0:
                        self.ckpt.save(step, state)
            except InjectedFault:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    # no checkpoint yet: restart from scratch is the policy
                    step = 0
                    if self.on_restart is not None:
                        state = self.on_restart(None)
                    continue
                restored, step = self.ckpt.restore(
                    jax.tree.map(lambda x: jax.ShapeDtypeStruct(
                        x.shape, x.dtype), state), latest)
                state = (self.on_restart(restored) if self.on_restart
                         else jax.tree.map(jax.numpy.asarray, restored))
        self.ckpt.wait()
        return LoopReport(steps_run=len(losses), restarts=restarts,
                          final_step=step, losses=losses)
