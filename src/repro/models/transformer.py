"""Model builders for all assigned families.

One functional model per family, all sharing the scan-over-layers pattern
(compact HLO: an 80-layer model lowers as one while loop).  Families:

* dense / moe / vlm  -> decoder-only LM (vlm prepends stub patch embeddings)
* audio              -> whisper-style enc-dec (stub frame embeddings)
* hybrid             -> jamba groups: [7 x mamba + 1 x attn], MoE every 2nd ffn
* ssm                -> mamba2 stack (attention-free)

``forward`` returns (logits, aux); ``mode="prefill"`` additionally returns
per-layer KV (and SSM caches) for the serving engine to install into the
hybrid-translated KV pool.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, resolve
from . import layers as L
from .attention import attention
from .moe import init_moe, moe_layer
from .ssm import MambaDims, mamba_dims, init_mamba, mamba_forward


class ModelDims(NamedTuple):
    n_heads: int
    n_kv: int
    head_dim: int
    vocab: int            # padded
    logical_vocab: int
    d_ff: int
    mamba: Optional[MambaDims]
    tp: int


def model_dims(cfg: ArchConfig, tp: int = 1) -> ModelDims:
    r = resolve(cfg, tp)
    md = None
    if cfg.family in ("hybrid", "ssm"):
        md = mamba_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_dim,
                        cfg.ssm_expand, cfg.ssm_conv_width, tp=tp)
    return ModelDims(n_heads=r.num_heads, n_kv=r.num_kv_heads,
                     head_dim=cfg.resolved_head_dim, vocab=r.vocab_size,
                     logical_vocab=cfg.vocab_size, d_ff=r.d_ff, mamba=md,
                     tp=tp)


# --------------------------------------------------------------------- init

def _init_attn_block(key, cfg: ArchConfig, dims: ModelDims, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.init_norm(cfg.d_model, dtype),
        "attn": L.init_attention(k1, cfg.d_model, dims.n_heads, dims.n_kv,
                                 dims.head_dim, cfg.qkv_bias, dtype),
    }


def _init_ffn(key, cfg: ArchConfig, dims: ModelDims, dtype, use_moe: bool):
    if use_moe:
        return {"norm2": L.init_norm(cfg.d_model, dtype),
                "moe": init_moe(key, cfg.d_model, dims.d_ff,
                                cfg.moe_num_experts, dtype)}
    return {"norm2": L.init_norm(cfg.d_model, dtype),
            "mlp": L.init_mlp(key, cfg.d_model, dims.d_ff, dtype)}


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg: ArchConfig, dims: ModelDims, dtype=jnp.float32):
    keys = jax.random.split(key, 16)
    params: Dict[str, Any] = {
        "embed": L.init_embedding(keys[0], dims.vocab, cfg.d_model, dtype),
        "final_norm": L.init_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_embedding(keys[1], dims.vocab,
                                             cfg.d_model, dtype)
    if cfg.frontend != "none":
        params["frontend_proj"] = L.init_linear(keys[2], cfg.d_model,
                                                cfg.d_model, dtype)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        lkeys = jax.random.split(keys[3], cfg.num_layers)
        blocks = []
        for i, lk in enumerate(lkeys):
            ka, kf = jax.random.split(lk)
            blk = _init_attn_block(ka, cfg, dims, dtype)
            blk.update(_init_ffn(kf, cfg, dims, dtype, cfg.moe_on_layer(i)))
            blocks.append(blk)
        params["layers"] = _stack(blocks)
    elif fam == "ssm":
        lkeys = jax.random.split(keys[3], cfg.num_layers)
        blocks = [{"norm1": L.init_norm(cfg.d_model, dtype),
                   "mamba": init_mamba(lk, dims.mamba, dtype)}
                  for lk in lkeys]
        params["layers"] = _stack(blocks)
    elif fam == "hybrid":
        g = cfg.attn_every                       # sublayers per group
        n_groups = cfg.num_layers // g
        gkeys = jax.random.split(keys[3], n_groups)
        groups = []
        for gk in gkeys:
            sk = jax.random.split(gk, 2 * g + 2)
            mambas = [
                {"norm1": L.init_norm(cfg.d_model, dtype),
                 "mamba": init_mamba(sk[i], dims.mamba, dtype)}
                for i in range(g - 1)]
            attn = _init_attn_block(sk[g - 1], cfg, dims, dtype)
            mlps, moes = [], []
            for i in range(g):
                if cfg.moe_on_layer(i):
                    moes.append(_init_ffn(sk[g + i], cfg, dims, dtype, True))
                else:
                    mlps.append(_init_ffn(sk[g + i], cfg, dims, dtype, False))
            grp = {"mamba": _stack(mambas), "attn": attn}
            # a group may be all-MLP (MoE-free hybrid) or all-MoE;
            # _stack([]) is not a tree, so only present kinds get a key —
            # the scan bodies select per-sublayer statically via
            # cfg.moe_on_layer, never touching an absent kind
            if mlps:
                grp["mlp"] = _stack(mlps)
            if moes:
                grp["moe"] = _stack(moes)
            groups.append(grp)
        params["layers"] = _stack(groups)
    elif fam == "audio":
        ekeys = jax.random.split(keys[4], cfg.encoder_layers)
        enc = []
        for ek in ekeys:
            ka, kf = jax.random.split(ek)
            blk = _init_attn_block(ka, cfg, dims, dtype)
            blk.update(_init_ffn(kf, cfg, dims, dtype, False))
            enc.append(blk)
        params["encoder"] = {"layers": _stack(enc),
                             "final_norm": L.init_norm(cfg.d_model, dtype)}
        dkeys = jax.random.split(keys[5], cfg.num_layers)
        dec = []
        for dk in dkeys:
            ka, kc, kf = jax.random.split(dk, 3)
            blk = _init_attn_block(ka, cfg, dims, dtype)
            blk["norm_x"] = L.init_norm(cfg.d_model, dtype)
            blk["cross"] = L.init_attention(kc, cfg.d_model, dims.n_heads,
                                            dims.n_kv, dims.head_dim,
                                            cfg.qkv_bias, dtype)
            blk.update(_init_ffn(kf, cfg, dims, dtype, False))
            dec.append(blk)
        params["layers"] = _stack(dec)
    else:
        raise ValueError(fam)
    return params


# ------------------------------------------------------------------ forward

@dataclasses.dataclass(frozen=True)
class FwdOptions:
    attn_impl: str = "dense"           # dense | flash_jax | pallas
    dtype: Any = jnp.float32
    remat: bool = False
    q_chunk: int = 512
    kv_chunk: int = 512
    triangular_schedule: bool = False
    collect_cache: bool = False        # prefill: emit per-layer KV/SSM caches
    moe_groups: int = 1                # MoE dispatch groups (= DP shards)


def _self_attn(blk, x, cfg, dims, opt, pins, causal=True):
    B, S, _ = x.shape
    pos = jnp.arange(S)[None, :]
    h = L.rms_norm(x, blk["norm1"].astype(jnp.float32), cfg.norm_eps)
    # gather the d-sharded activation ONCE, in bf16, for q/k/v to share
    # (without the pin GSPMD emits one fp32 all-gather per consumer: 7x
    # the bytes — measured on granite-8b, EXPERIMENTS.md §Perf)
    h = pins("act_full", h)
    theta = cfg.rope_theta if causal else 0.0   # encoder: no rope (stub pos)
    q, k, v = L.qkv_project(blk["attn"], h, h, dims.n_heads, dims.n_kv,
                            dims.head_dim, pos, pos, theta, pins)
    o = attention(q, k, v, impl=opt.attn_impl, causal=causal,
                  q_chunk=opt.q_chunk, kv_chunk=opt.kv_chunk,
                  triangular_schedule=opt.triangular_schedule)
    o = L.linear(blk["attn"]["o"], o.reshape(B, S, -1))
    return x + pins("act_btd", o), (k, v)


def _ffn(blk, x, cfg, dims, opt, pins):
    h = L.rms_norm(x, blk["norm2"].astype(jnp.float32), cfg.norm_eps)
    h = pins("act_full", h)
    if "moe" in blk:
        out, aux = moe_layer(blk["moe"], h, top_k=cfg.moe_top_k,
                             capacity_factor=cfg.moe_capacity_factor,
                             n_groups=opt.moe_groups, pins=pins)
        return x + pins("act_btd", out), aux
    out = L.mlp(blk["mlp"], h, pins)
    return x + pins("act_btd", out), None


def hybrid_ffn_select(cfg: ArchConfig, blk, i: int):
    """The group-local FFN params for sublayer ``i`` of a hybrid group:
    the MoE stack when ``cfg.moe_on_layer(i)``, else the corresponding
    stacked MLP.  One source of truth for the group-local index
    arithmetic — the train forward, the decode step and the prefix-KV
    chunk step all select through here."""
    n_moe_before = sum(cfg.moe_on_layer(j) for j in range(i))
    if cfg.moe_on_layer(i):
        return jax.tree.map(lambda a, j=n_moe_before: a[j], blk["moe"])
    return jax.tree.map(lambda a, j=i - n_moe_before: a[j], blk["mlp"])


def _zero_aux():
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32),
            "fraction_dropped": jnp.zeros((), jnp.float32)}


def _acc_aux(acc, aux):
    if aux is None:
        return acc
    return {k: acc[k] + aux[k] for k in acc}


def _mamba_block(blk, x, cfg, dims, opt, pins, collect=False,
                 seq_len=None):
    h = L.rms_norm(x, blk["norm1"].astype(jnp.float32), cfg.norm_eps)
    h = pins("act_full", h)
    out, state = mamba_forward(blk["mamba"], h, dims.mamba,
                               chunk=cfg.ssm_chunk, pins=pins,
                               seq_len=seq_len, return_state=collect)
    return x + pins("act_btd", out), state


def _decoder_body(cfg: ArchConfig, dims: ModelDims, opt: FwdOptions, pins,
                  seq_len=None):
    """Returns the scan body for the family's stacked layers.

    ``seq_len`` (B,) is forwarded to the recurrent (mamba) sublayers so
    right-padded bucket rows install exact SSM states (pad positions are
    identity transitions); attention sublayers need no mask — causal
    attention never reads past the query position."""
    fam = cfg.family

    def body(carry, blk):
        x, aux = carry
        cache = {}
        if fam in ("dense", "moe", "vlm"):
            x, (k, v) = _self_attn(blk, x, cfg, dims, opt, pins)
            x, a = _ffn(blk, x, cfg, dims, opt, pins)
            aux = _acc_aux(aux, a)
            if opt.collect_cache:
                cache = {"k": k, "v": v}
        elif fam == "ssm":
            x, state = _mamba_block(blk, x, cfg, dims, opt, pins,
                                    collect=opt.collect_cache,
                                    seq_len=seq_len)
            if opt.collect_cache:
                cache = {"ssm": state}
        elif fam == "hybrid":
            g = cfg.attn_every
            ssm_states = []
            for i in range(g):
                if i < g - 1:
                    sub = jax.tree.map(lambda a, i=i: a[i], blk["mamba"])
                    x, st = _mamba_block(sub, x, cfg, dims, opt, pins,
                                         collect=opt.collect_cache,
                                         seq_len=seq_len)
                    if opt.collect_cache:
                        ssm_states.append(st)
                    k = v = None
                else:
                    x, (k, v) = _self_attn(blk["attn"], x, cfg, dims, opt, pins)
                x, a = _ffn(hybrid_ffn_select(cfg, blk, i), x, cfg, dims,
                            opt, pins)
                aux = _acc_aux(aux, a)
            if opt.collect_cache:
                stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ssm_states)
                cache = {"k": k, "v": v, "ssm": stacked}
        else:
            raise ValueError(fam)
        return (x, aux), cache

    return body


def _encoder(params, frames, cfg, dims, opt, pins):
    x = L.linear(params["frontend_proj"], frames.astype(opt.dtype))

    def body(x, blk):
        x, _ = _self_attn(blk, x, cfg, dims, opt, pins, causal=False)
        x, _ = _ffn(blk, x, cfg, dims, opt, pins)
        return x, None

    if opt.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return L.rms_norm(x, params["encoder"]["final_norm"].astype(jnp.float32),
                      cfg.norm_eps)


def _audio_decoder_body(cfg, dims, opt, pins, enc_out):
    def body(carry, blk):
        x, aux = carry
        x, (k, v) = _self_attn(blk, x, cfg, dims, opt, pins)
        # cross attention over encoder output
        B, S, _ = x.shape
        h = L.rms_norm(x, blk["norm_x"].astype(jnp.float32), cfg.norm_eps)
        pos = jnp.arange(S)[None, :]
        epos = jnp.arange(enc_out.shape[1])[None, :]
        q, ck, cv = L.qkv_project(blk["cross"], h, enc_out, dims.n_heads,
                                  dims.n_kv, dims.head_dim, pos, epos, 0.0,
                                  pins)
        o = attention(q, ck, cv, impl=opt.attn_impl, causal=False,
                      q_chunk=opt.q_chunk, kv_chunk=opt.kv_chunk)
        x = x + pins("act_btd",
                     L.linear(blk["cross"]["o"], o.reshape(B, S, -1)))
        x, a = _ffn(blk, x, cfg, dims, opt, pins)
        aux = _acc_aux(aux, a)
        cache = {"k": k, "v": v, "ck": ck, "cv": cv} if opt.collect_cache else {}
        return (x, aux), cache

    return body


def forward(params, batch: Dict[str, jax.Array], cfg: ArchConfig,
            dims: ModelDims, opt: FwdOptions = FwdOptions(),
            pins: L.Pins = L.no_pins):
    """batch: tokens (B,S) [+ frontend (B,F,D) for vlm/audio].

    Returns (logits (B,S,vocab_pad), aux, caches) — caches None unless
    ``opt.collect_cache``.
    """
    tokens = batch["tokens"]
    seq_len = batch.get("seq_len")     # (B,) real row lengths (recurrent
                                       # families' pad-exact state installs)
    x = L.embed(params["embed"], tokens, pins).astype(opt.dtype)
    n_front = 0
    enc_out = None
    if cfg.family == "vlm":
        front = L.linear(params["frontend_proj"],
                         batch["frontend"].astype(opt.dtype))
        x = jnp.concatenate([front, x], axis=1)
        n_front = front.shape[1]
        x = pins("act_btd", x)
    elif cfg.family == "audio":
        enc_out = _encoder(params, batch["frontend"], cfg, dims, opt, pins)
        enc_out = pins("act_btd", enc_out)

    if cfg.family == "audio":
        body = _audio_decoder_body(cfg, dims, opt, pins, enc_out)
    else:
        body = _decoder_body(cfg, dims, opt, pins, seq_len)
    if opt.remat:
        body = jax.checkpoint(body)
    (x, aux), caches = jax.lax.scan(body, (x, _zero_aux()), params["layers"])

    x = L.rms_norm(x, params["final_norm"].astype(jnp.float32), cfg.norm_eps)
    if n_front:
        x = jax.lax.slice_in_dim(x, n_front, x.shape[1], axis=1)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x, dims.logical_vocab, pins)
    if cfg.family == "audio" and opt.collect_cache:
        caches = dict(caches)
        caches["enc_out"] = enc_out
    return logits, aux, (caches if opt.collect_cache else None)


def loss_fn(params, batch, cfg: ArchConfig, dims: ModelDims,
            opt: FwdOptions = FwdOptions(), pins: L.Pins = L.no_pins,
            moe_loss_weight: float = 0.01, z_loss_weight: float = 1e-3):
    logits, aux, _ = forward(params, batch, cfg, dims, opt, pins)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    ce = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = ce
    metrics = {"ce": ce}
    if cfg.moe_num_experts:
        loss = loss + moe_loss_weight * aux["lb_loss"] \
            + z_loss_weight * aux["z_loss"]
        metrics.update({k: aux[k] for k in
                        ("lb_loss", "z_loss", "fraction_dropped")})
    metrics["loss"] = loss
    return loss, metrics
