"""Mamba2 blocks via SSD (state-space duality), chunked matmul form.

Implements the minimal-SSD algorithm (Dao & Gu, arXiv:2405.21060): the
sequence is split into chunks; intra-chunk work is a masked matmul (MXU
friendly), inter-chunk work is a tiny recurrence over per-chunk states —
the TPU-native adaptation of the paper's hardware-aware scan.

Decode is the exact SSM recurrence (O(1)/token), which is why ssm/hybrid
archs run the ``long_500k`` cell.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Pins, no_pins, gated_rms_norm, init_norm


class MambaDims(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int
    conv_width: int

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.d_state

    @property
    def in_proj_out(self) -> int:
        # z, x, B, C, dt
        return 2 * self.d_inner + 2 * self.d_state + self.n_heads


def mamba_dims(d_model: int, d_state: int, head_dim: int, expand: int,
               conv_width: int, tp: int = 1) -> MambaDims:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    if n_heads % tp:
        n_heads = ((n_heads + tp - 1) // tp) * tp   # pad heads to TP degree
        d_inner = n_heads * head_dim
    return MambaDims(d_model, d_inner, n_heads, head_dim, d_state, conv_width)


def init_mamba(key, dims: MambaDims, dtype=jnp.float32) -> dict:
    kin, kconv, kout, kdt = jax.random.split(key, 4)
    kz, kxbc = jax.random.split(kin)
    s = 1.0 / math.sqrt(dims.d_model)
    return {
        # z / xBC / dt projections are SEPARATE weights: a packed in_proj's
        # split points (d_inner, d_inner+2n, ...) do not align with model-
        # axis shard boundaries, forcing GSPMD to all-gather the full
        # projection (measured: 2 GiB fp32 per layer on jamba-398b)
        "in_z": (jax.random.normal(
            kz, (dims.d_model, dims.d_inner), jnp.float32) * s
            ).astype(dtype),
        "in_x": (jax.random.normal(
            kxbc, (dims.d_model, dims.d_inner), jnp.float32) * s
            ).astype(dtype),
        "in_B": (jax.random.normal(
            jax.random.fold_in(kxbc, 1),
            (dims.d_model, dims.d_state), jnp.float32) * s).astype(dtype),
        "in_C": (jax.random.normal(
            jax.random.fold_in(kxbc, 2),
            (dims.d_model, dims.d_state), jnp.float32) * s).astype(dtype),
        "in_dt": (jax.random.normal(
            kdt, (dims.d_model, dims.n_heads), jnp.float32) * s
            ).astype(dtype),
        "conv_w": (jax.random.normal(
            kconv, (dims.conv_width, dims.conv_channels), jnp.float32)
            / math.sqrt(dims.conv_width)).astype(dtype),
        "conv_b": jnp.zeros((dims.conv_channels,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, dims.n_heads)
                         ).astype(jnp.float32),
        "D": jnp.ones((dims.n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((dims.n_heads,), jnp.float32),
        "norm": init_norm(dims.d_inner, dtype),
        "out_proj": (jax.random.normal(
            kout, (dims.d_inner, dims.d_model), jnp.float32)
            / math.sqrt(dims.d_inner)).astype(dtype),
    }


def _causal_depthwise_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                           init: Optional[jax.Array] = None):
    """xbc: (B, L, C); w: (W, C) depthwise causal.

    ``init`` (B, W-1, C): the trailing conv inputs of an already-processed
    prefix (prefix-KV chunked prefill).  With it the conv runs VALID over
    ``concat([init, xbc])`` — every chunk position sees the same real
    window it would in a full-sequence forward, instead of the zero
    left-pad a sequence start gets.
    """
    W, C = w.shape
    if init is not None:
        lhs = jnp.concatenate([init.astype(xbc.dtype), xbc], axis=1)
        padding = [(0, 0)]
    else:
        lhs = xbc
        padding = [(W - 1, 0)]
    rhs = w[:, None, :]  # (W, 1, C) 'WIO' with feature groups = C
    out = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=(1,), padding=padding,
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=C)
    return out + b


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., T) -> (..., T, T) lower-tri cumulative segment sums."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(xd: jax.Array, dtA: jax.Array, B_: jax.Array, C_: jax.Array,
                chunk: int, initial_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xd:  (b, l, h, p)  dt-prescaled inputs
    dtA: (b, l, h)     dt * A (negative)
    B_:  (b, l, n)     input projection (single group)
    C_:  (b, l, n)     output projection
    Returns (y (b,l,h,p), final_state (b,h,p,n)).
    """
    b, l, h, p = xd.shape
    n = B_.shape[-1]
    if l % chunk:
        raise ValueError(f"seq len {l} must divide chunk {chunk}")
    c = l // chunk
    xc = xd.reshape(b, c, chunk, h, p).astype(jnp.float32)
    ac = dtA.reshape(b, c, chunk, h).astype(jnp.float32)
    Bc = B_.reshape(b, c, chunk, n).astype(jnp.float32)
    Cc = C_.reshape(b, c, chunk, n).astype(jnp.float32)

    a_cum = jnp.cumsum(ac, axis=2)                       # (b,c,k,h)
    # --- intra-chunk (matmul form) ---
    L = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))       # (b,c,h,k,k)
    scores = jnp.einsum("bckn,bcln->bckl", Cc, Bc)
    y_diag = jnp.einsum("bckl,bchkl,bclhp->bckhp", scores, L, xc)
    # --- per-chunk states ---
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (b,c,k,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_states, xc)
    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])            # (b,c,h)

    def step(s, inp):
        st, dec = inp                                    # (b,h,p,n), (b,h)
        s_new = s * dec[..., None, None] + st
        return s_new, s                                  # emit state BEFORE chunk

    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (b,c,h,p,n)
    # --- off-diagonal contribution ---
    y_off = jnp.einsum("bckn,bchpn,bckh->bckhp", Cc, prev_states,
                       jnp.exp(a_cum))
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final_state


def mamba_forward(p: dict, x: jax.Array, dims: MambaDims, *, chunk: int = 64,
                  pins: Pins = no_pins,
                  initial_state: Optional[jax.Array] = None,
                  initial_conv: Optional[jax.Array] = None,
                  seq_len: Optional[jax.Array] = None,
                  return_state: bool = False):
    """Full mamba2 block on (B, L, D). Returns (out, final_state|None).

    ``initial_state`` (B, H, P, N) and ``initial_conv`` (B, W-1,
    conv_channels) continue a previously processed prefix (prefix-KV
    chunked prefill): the SSD scan starts from the saved state and the
    depthwise conv's first windows read the prefix's trailing raw xBC
    inputs, so forwarding ONLY the chunk reproduces the full-sequence
    forward at the chunk's positions bit for bit.

    ``seq_len`` (B,) marks each row's real length: dt is zeroed past it,
    which makes every pad position an EXACT identity transition of the
    SSD recurrence (decay = exp(0) = 1, contribution = x·dt = 0), so the
    returned state and the real positions' outputs are bitwise invariant
    to right padding — what lets the serving engine put recurrent
    families in the same pow2 length buckets as attention ones.  The
    returned conv tail is gathered at the row's real end, not the padded
    row end.
    """
    B, L, D = x.shape
    di, n = dims.d_inner, dims.d_state
    W1 = dims.conv_width - 1
    z = x @ p["in_z"].astype(x.dtype)
    x_raw = x @ p["in_x"].astype(x.dtype)
    B_raw = x @ p["in_B"].astype(x.dtype)
    C_raw = x @ p["in_C"].astype(x.dtype)
    dt_raw = x @ p["in_dt"].astype(x.dtype)
    xbc_raw = jnp.concatenate([x_raw, B_raw, C_raw], axis=-1)
    if initial_conv is not None:
        ic = initial_conv.astype(x.dtype)
        icx, icB, icC = ic[..., :di], ic[..., di:di + n], ic[..., di + n:]
    else:
        ic = jnp.zeros_like(xbc_raw[:, :W1])   # the conv's zero left-pad
        icx = icB = icC = None
    if seq_len is None:
        conv_tail = jnp.concatenate([ic, xbc_raw], axis=1)[:, -W1:, :]
    else:
        # raw position p sits at index p + W1 of [ic | xbc_raw]; the tail
        # window [len-W1, len) is therefore indices [len, len+W1)
        idx = seq_len[:, None].astype(jnp.int32) + jnp.arange(W1)[None, :]
        conv_tail = jnp.take_along_axis(
            jnp.concatenate([ic, xbc_raw], axis=1), idx[..., None], axis=1)
    # depthwise conv applies per channel, so convolving x/B/C separately is
    # exactly the packed conv (keeps each activation shard-aligned)
    cw = p["conv_w"].astype(x.dtype)
    cb = p["conv_b"].astype(x.dtype)
    xs = jax.nn.silu(_causal_depthwise_conv(x_raw, cw[:, :di], cb[:di], icx))
    B_ = jax.nn.silu(_causal_depthwise_conv(
        B_raw, cw[:, di:di + n], cb[di:di + n], icB))
    C_ = jax.nn.silu(_causal_depthwise_conv(
        C_raw, cw[:, di + n:], cb[di + n:], icC))
    xs = pins("ssm_inner", xs)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
    if seq_len is not None:
        tok_ok = jnp.arange(L)[None, :] < seq_len[:, None]
        dt = jnp.where(tok_ok[:, :, None], dt, 0.0)
    A = -jnp.exp(p["A_log"])                                         # (H,)
    xh = xs.reshape(B, L, dims.n_heads, dims.head_dim)
    pad = (-L) % chunk
    if pad and return_state and seq_len is None:
        raise ValueError(f"seq len {L} must divide chunk {chunk} when the "
                         "final state is needed (prefill) and no seq_len "
                         "mask marks the pad tail")
    if pad:
        # zero-pad dt so padded positions are identity transitions; the
        # causal scan makes y[:, :L] exact regardless of the tail
        xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_p = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_p = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    else:
        xh_p, dt_p, B_p, C_p = xh, dt, B_, C_
    y, final_state = ssd_chunked(
        xh_p.astype(jnp.float32) * dt_p[..., None], dt_p * A, B_p, C_p,
        chunk=chunk, initial_state=initial_state)
    if pad:
        y = y[:, :L]
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, L, dims.d_inner).astype(x.dtype)
    out = gated_rms_norm(y, z, p["norm"].astype(jnp.float32))
    out = out @ p["out_proj"].astype(x.dtype)
    if return_state:
        return out, MambaCache(conv=conv_tail, state=final_state)
    return out, None


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, W-1, conv_channels) trailing conv inputs
    state: jax.Array  # (B, H, P, N) SSM state


def init_mamba_cache(batch: int, dims: MambaDims, dtype=jnp.float32
                     ) -> MambaCache:
    return MambaCache(
        conv=jnp.zeros((batch, dims.conv_width - 1, dims.conv_channels), dtype),
        state=jnp.zeros((batch, dims.n_heads, dims.head_dim, dims.d_state),
                        jnp.float32),
    )


def mamba_decode_step(p: dict, x: jax.Array, cache: MambaCache,
                      dims: MambaDims, pins: Pins = no_pins
                      ) -> Tuple[jax.Array, MambaCache]:
    """One-token recurrence. x: (B, D) -> (out (B, D), new cache)."""
    B, D = x.shape
    di, n = dims.d_inner, dims.d_state
    z = x @ p["in_z"].astype(x.dtype)
    xbc_new = jnp.concatenate(
        [x @ p["in_x"].astype(x.dtype), x @ p["in_B"].astype(x.dtype),
         x @ p["in_C"].astype(x.dtype)], axis=-1)
    dt_raw = x @ p["in_dt"].astype(x.dtype)
    window = jnp.concatenate([cache.conv, xbc_new[:, None, :]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    xbc = jax.nn.silu(conv_out).astype(x.dtype)
    xs, B_, C_ = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, dims.n_heads, dims.head_dim).astype(jnp.float32)
    decay = jnp.exp(dt * A)                                           # (B,H)
    state = cache.state * decay[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", B_.astype(jnp.float32), xh, dt)
    y = jnp.einsum("bn,bhpn->bhp", C_.astype(jnp.float32), state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, dims.d_inner).astype(x.dtype)
    out = gated_rms_norm(y, z, p["norm"].astype(jnp.float32))
    out = out @ p["out_proj"].astype(x.dtype)
    new_cache = MambaCache(conv=window[:, 1:, :], state=state)
    return out, new_cache
