"""Top-k Mixture of Experts with grouped, capacity-bounded dispatch.

GShard semantics (top-k, capacity factor, token dropping) implemented with
*index tables* instead of (T, E, C) one-hot einsums: per token-group we
scatter token ids into an (E, C) table and gather expert inputs from it.
This keeps dispatch cost O(T·D) data movement (no T·E·C·D one-hot matmul,
which at 1M tokens x 128 experts would dwarf the expert compute itself).

Sharding: the group dim ``g`` maps onto the data axes and the expert dim
onto the model axis (expert parallelism) — the pins "moe_*" constraints in
dist/sharding.py steer GSPMD to the all-to-all-style exchange.

Aux losses: Switch-style load balancing + router z-loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import Pins, no_pins, init_linear


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "router": init_linear(kr, d_model, n_experts, jnp.float32),
        "gate": (jax.random.normal(kg, (n_experts, d_model, d_ff), jnp.float32)
                 * s_in).astype(dtype),
        "up": (jax.random.normal(ku, (n_experts, d_model, d_ff), jnp.float32)
               * s_in).astype(dtype),
        "down": (jax.random.normal(kd, (n_experts, d_ff, d_model), jnp.float32)
                 * s_out).astype(dtype),
    }


def _capacity(tokens_per_group: int, n_experts: int, top_k: int,
              capacity_factor: float) -> int:
    c = int(math.ceil(tokens_per_group * top_k / n_experts
                      * capacity_factor))
    return max(4, ((c + 3) // 4) * 4)


def moe_layer(p: dict, x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25, n_groups: int = 1,
              pins: Pins = no_pins) -> Tuple[jax.Array, dict]:
    """x: (B, S, D) -> (out, aux).

    ``n_groups``: token groups for local dispatch (set to the DP shard
    count so each group's scatter/gather stays device-local).
    """
    B, S, D = x.shape
    E = p["gate"].shape[0]
    T = B * S
    if T % n_groups:
        n_groups = 1
    Tg = T // n_groups
    C = _capacity(Tg, E, top_k, capacity_factor)
    xg = x.reshape(n_groups, Tg, D)
    xg = pins("moe_gtd", xg)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"]["w"])                     # (g,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)       # (g,Tg,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # position of each (token, k) in its expert queue, per group
    sel = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)      # (g,Tg,k,E)
    flat_sel = sel.reshape(n_groups, Tg * top_k, E)
    pos_all = jnp.cumsum(flat_sel, axis=1) - flat_sel         # (g,Tg*k,E)
    pos = (pos_all * flat_sel).sum(-1).reshape(n_groups, Tg, top_k)
    keep = pos < C
    gate_vals = gate_vals * keep

    # --- dispatch: scatter token ids into the (E, C) index table ----------
    pos_c = jnp.where(keep, pos, C)                           # dropped -> col C
    table = jnp.zeros((n_groups, E, C + 1), jnp.int32)
    tok_ids = jnp.broadcast_to(
        jnp.arange(Tg, dtype=jnp.int32)[None, :, None],
        (n_groups, Tg, top_k))
    g_ids = jnp.broadcast_to(
        jnp.arange(n_groups, dtype=jnp.int32)[:, None, None],
        (n_groups, Tg, top_k))
    table = table.at[
        g_ids.reshape(-1), expert_idx.reshape(-1), pos_c.reshape(-1)
    ].set(tok_ids.reshape(-1) + 1)
    table = table[:, :, :C]                                   # drop spill col
    occupied = table > 0

    # --- expert compute over gathered inputs ------------------------------
    safe = jnp.maximum(table - 1, 0)                          # (g,E,C)
    # gather: per group, rows of xg at `safe`
    xin = jax.vmap(lambda xrow, idx: xrow[idx])(xg, safe)     # (g,E,C,D)
    xin = jnp.where(occupied[..., None], xin, 0.0)
    xin = pins("moe_gecd", xin).astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin,
                               p["gate"].astype(x.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", xin, p["up"].astype(x.dtype))
    h = pins("moe_gecf", h)
    out_e = jnp.einsum("gecf,efd->gecd", h, p["down"].astype(x.dtype))
    out_e = pins("moe_gecd", out_e)

    # --- combine: gather each token's expert outputs back -----------------
    out_tok = jax.vmap(
        lambda oe, e_idx, p_idx: oe[e_idx, p_idx]             # (Tg,k,D)
    )(out_e, expert_idx, jnp.minimum(pos_c, C - 1))
    out = jnp.einsum("gtkd,gtk->gtd", out_tok,
                     gate_vals.astype(x.dtype))
    out = pins("moe_gtd", out)

    # --- aux losses --------------------------------------------------------
    me = probs.mean(axis=(0, 1))                              # (E,)
    ce = sel.astype(jnp.float32).sum(axis=2).mean(axis=(0, 1))
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - keep.mean()
    aux = {"lb_loss": lb_loss, "z_loss": z_loss,
           "fraction_dropped": dropped.astype(jnp.float32)}
    return out.reshape(B, S, D).astype(x.dtype), aux


def moe_decode(p: dict, x: jax.Array, *, top_k: int,
               pins: Pins = no_pins) -> jax.Array:
    """Decode-time MoE for small token counts: every (sharded) expert
    computes all B tokens; gates mask the result (B << E*C, no capacity)."""
    B, D = x.shape
    E = p["gate"].shape[0]
    logits = x.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    w = jnp.einsum("bke,bk->be", jax.nn.one_hot(expert_idx, E), gate_vals)
    h = jax.nn.silu(jnp.einsum("bd,edf->ebf", x, p["gate"].astype(x.dtype)))
    h = h * jnp.einsum("bd,edf->ebf", x, p["up"].astype(x.dtype))
    out_e = jnp.einsum("ebf,efd->ebd", h, p["down"].astype(x.dtype))
    return jnp.einsum("be,ebd->bd", w.astype(x.dtype), out_e)
