"""Dense building blocks: norms, RoPE, projections, SwiGLU MLP.

Conventions:
* params are nested dicts of arrays; per-layer stacks are built by the
  transformer builders (leading layer axis, consumed by ``lax.scan``).
* every function takes ``pins`` — a callable ``pins(name, x) -> x`` that
  applies ``with_sharding_constraint`` when a mesh is active (identity by
  default).  Names are stable contract points for dist/sharding.py.
* dtype discipline: params stored in ``param_dtype``; activations compute
  in ``dtype`` with fp32 accumulations where it matters (norm, softmax).
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Pins = Callable[[str, jax.Array], jax.Array]


def no_pins(name: str, x: jax.Array) -> jax.Array:
    return x


# ------------------------------------------------------------------ norms

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


def _rms_norm_fwd(x, scale, eps):
    return rms_norm(x, scale, eps), (x, scale)


def _rms_norm_bwd(eps, res, g):
    """fp32 internal math, activation-grad emitted in x.dtype: keeps the
    cross-shard dx all-reduces in bf16 (they dominated the train cells'
    collective term at 2x the bytes in fp32 — EXPERIMENTS.md §Perf)."""
    x, scale = res
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = x32 * rstd
    gs = g32 * scale.astype(jnp.float32)
    dx = rstd * (gs - xhat * jnp.mean(gs * xhat, axis=-1, keepdims=True))
    dscale = jnp.sum(g32 * xhat, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def gated_rms_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                   eps: float = 1e-5) -> jax.Array:
    """Mamba2's RMSNormGated: norm(y) * silu(z)."""
    return (rms_norm(y, scale, eps)
            * jax.nn.silu(z).astype(y.dtype)).astype(y.dtype)


def init_norm(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype)


# ------------------------------------------------------------------- RoPE

def rope_frequencies(head_dim: int, theta: float, dtype=jnp.float32):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return (1.0 / (theta ** exponents)).astype(dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                       # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ projections

def init_linear(key, d_in: int, d_out: int, dtype=jnp.float32,
                bias: bool = False, scale: Optional[float] = None) -> dict:
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# -------------------------------------------------------------- SwiGLU MLP

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, dtype),
        "up": init_linear(k2, d_model, d_ff, dtype),
        "down": init_linear(k3, d_ff, d_model, dtype,
                            scale=1.0 / math.sqrt(d_ff)),
    }


def mlp(p: dict, x: jax.Array, pins: Pins = no_pins) -> jax.Array:
    h = jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x)
    h = pins("act_ff", h)
    return linear(p["down"], h)


# ------------------------------------------------------- attention (GQA)

def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qkv_bias: bool = False, dtype=jnp.float32) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": init_linear(kq, d_model, n_heads * head_dim, dtype, bias=qkv_bias),
        "k": init_linear(kk, d_model, n_kv * head_dim, dtype, bias=qkv_bias),
        "v": init_linear(kv, d_model, n_kv * head_dim, dtype, bias=qkv_bias),
        "o": init_linear(ko, n_heads * head_dim, d_model, dtype,
                         scale=1.0 / math.sqrt(n_heads * head_dim)),
    }


def qkv_project(p: dict, x: jax.Array, xkv: jax.Array, n_heads: int,
                n_kv: int, head_dim: int, positions, kv_positions,
                rope_theta: float, pins: Pins = no_pins):
    """Returns q (B,S,H,hd), k/v (B,Skv,Kv,hd) with RoPE applied."""
    B, S, _ = x.shape
    Skv = xkv.shape[1]
    q = linear(p["q"], x).reshape(B, S, n_heads, head_dim)
    k = linear(p["k"], xkv).reshape(B, Skv, n_kv, head_dim)
    v = linear(p["v"], xkv).reshape(B, Skv, n_kv, head_dim)
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, kv_positions, rope_theta)
    q = pins("act_q", q)
    k = pins("act_kv", k)
    v = pins("act_kv", v)
    return q, k, v


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * 0.02).astype(dtype)}


def embed(p: dict, tokens: jax.Array, pins: Pins = no_pins) -> jax.Array:
    out = jnp.take(p["table"], tokens, axis=0)
    return pins("act_btd", out)


def unembed(p: dict, x: jax.Array, logical_vocab: int,
            pins: Pins = no_pins) -> jax.Array:
    """Project to (padded) vocab; padded ids masked to a large negative."""
    logits = x @ p["table"].T.astype(x.dtype)
    vpad = logits.shape[-1]
    if vpad > logical_vocab:
        mask = (jnp.arange(vpad) < logical_vocab)
        logits = jnp.where(mask, logits, jnp.asarray(-1e9, logits.dtype))
    return pins("logits", logits)
