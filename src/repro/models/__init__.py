"""Model zoo: dense/MoE/VLM/audio/hybrid/SSM families."""
from .transformer import (ModelDims, FwdOptions, model_dims, init_params,
                          forward, loss_fn)
from .attention import (attention, dense_attention, flash_attention_jax,
                        causal_attention_parts, merge_attention_parts)
from . import layers, moe, ssm

__all__ = ["ModelDims", "FwdOptions", "model_dims", "init_params", "forward",
           "loss_fn", "attention", "dense_attention", "flash_attention_jax",
           "causal_attention_parts", "merge_attention_parts",
           "layers", "moe", "ssm"]
