"""Attention implementations: dense reference + memory-safe chunked flash.

* ``dense_attention`` — materializes scores; oracle for tests and small runs.
* ``flash_attention_jax`` — two-level chunked online-softmax attention
  (lax.map over query chunks, lax.scan over KV chunks).  HLO stays compact
  (two nested while loops) and per-tile memory is bounded, which is what
  lets the 32k-prefill cells lower at scale.  The Pallas kernel in
  ``repro.kernels.flash_attention`` implements the same schedule for TPU.

Both support GQA (n_heads = g * n_kv) and causal/full masks.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_fold(q: jax.Array, n_kv: int):
    B, S, H, D = q.shape
    g = H // n_kv
    return q.reshape(B, S, n_kv, g, D), g


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    q_offset=0,
                    kv_len: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,Sq,H,hd), k/v: (B,Skv,Kv,hd) -> (B,Sq,H,hd).

    ``q_offset`` positions the queries inside the causal mask: a scalar
    (train/prefill, all rows share the offset) or a ``(B,)`` array — the
    prefix-KV chunk forward, where each row's chunk starts at its own
    already-installed context length.  The score/softmax/weighted-sum math
    is identical in both branches (only the mask construction differs), so
    a chunk query attending over [gathered prefix + own chunk] K/V laid
    out at their absolute positions reproduces the full-sequence forward
    bit for bit.
    """
    B, Sq, H, D = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    qf, g = _gqa_fold(q, Kv)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qf.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        cpos = jnp.arange(Skv)
        off = jnp.asarray(q_offset)
        if off.ndim == 0:
            qpos = jnp.arange(Sq) + off
            mask = qpos[:, None] >= cpos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        else:                                  # per-row offsets (B,)
            qpos = off[:, None] + jnp.arange(Sq)[None, :]
            mask = qpos[:, :, None] >= cpos[None, None, :]   # (B, Sq, Skv)
            s = jnp.where(mask[:, None, None], s, NEG_INF)
    if kv_len is not None:
        valid = jnp.arange(Skv)[None, :] < kv_len[:, None]     # (B, Skv)
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def causal_attention_parts(q: jax.Array, k: jax.Array, v: jax.Array):
    """Unnormalized causal attention over a chunk's OWN K/V.

    q: (B,S,H,hd), k/v: (B,S,Kv,hd) -> (o_weighted (B,S,H,hd) f32,
    m (B,S,H), l (B,S,H)) — the intra-chunk half of the prefix-KV merge,
    sharing the (m, l) contract of ``kernels.paged_attention`` so the two
    halves combine with a flash-decoding online-softmax correction
    (``merge_attention_parts``).  The mask is chunk-relative: query i
    attends chunk positions j <= i regardless of where the chunk sits in
    the sequence (the installed prefix is entirely in the other part).
    """
    B, Sq, H, D = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    qf, g = _gqa_fold(q, Kv)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qf.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1)                                   # (B,Kv,g,Sq)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgqc,bckd->bkgqd", p, v.astype(jnp.float32))
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return o, m.transpose(0, 3, 1, 2).reshape(B, Sq, H), \
        l.transpose(0, 3, 1, 2).reshape(B, Sq, H)


def merge_attention_parts(parts):
    """Flash-decoding combine: [(o_weighted, m, l), ...] -> normalized o.

    Each part is an unnormalized online-softmax partial over a disjoint
    KV range (pool prefix / own chunk / other shards); a part with l == 0
    everywhere (empty prefix) drops out exactly.
    """
    m_glob = functools.reduce(jnp.maximum, [m for _, m, _ in parts])
    o = sum(o * jnp.exp(m - m_glob)[..., None] for o, m, _ in parts)
    l = sum(l * jnp.exp(m - m_glob) for _, m, l in parts)
    return o / jnp.maximum(l, 1e-30)[..., None]


def pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (prefer multiples of 128)."""
    target = min(target, n)
    best = 1
    for c in range(target, 0, -1):
        if n % c == 0:
            if c % 128 == 0:
                return c
            best = max(best, c) if best == 1 else best
    return best


def flash_attention_jax(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        q_chunk: int = 512,
                        kv_chunk: int = 512,
                        q_offset: int = 0,
                        triangular_schedule: bool = False) -> jax.Array:
    """Chunked online-softmax attention.

    ``triangular_schedule``: for causal attention, skip KV chunks entirely
    above the diagonal (per-query-chunk dynamic trip count).  This is the
    §Perf "causal flash wastes half its FLOPs" optimization; the baseline
    scans every KV chunk and masks.
    """
    B, Sq, H, D = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    g = H // Kv
    q_chunk = pick_chunk(Sq, q_chunk)
    kv_chunk = pick_chunk(Skv, kv_chunk)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / math.sqrt(D)

    qc = q.reshape(B, nq, q_chunk, Kv, g, D).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, kv_chunk, Kv, D)
    vc = v.reshape(B, nk, kv_chunk, Kv, D)

    def one_q_chunk(args):
        qi, q_i = args                                   # q_i (B,qc,Kv,g,D)
        q32 = q_i.astype(jnp.float32) * scale

        def kv_step(carry, j):
            m, l, o = carry
            k_j = jax.lax.dynamic_index_in_dim(kc, j, axis=1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vc, j, axis=1, keepdims=False)
            s = jnp.einsum("bqkgd,bckd->bkgqc", q32,
                           k_j.astype(jnp.float32))      # (B,Kv,g,qc,kc)
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk) + q_offset
                cpos = j * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= cpos[None, :]
                s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p, v_j.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, g, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, Kv, g, q_chunk, D), jnp.float32)
        if causal and triangular_schedule:
            # only chunks at or below the diagonal contribute
            n_active = jnp.minimum(
                (qi * q_chunk + q_chunk - 1 + q_offset) // kv_chunk + 1, nk)
            (m, l, o), _ = jax.lax.scan(
                lambda c, j: jax.lax.cond(j < n_active,
                                          lambda: kv_step(c, j),
                                          lambda: (c, None)),
                (m0, l0, o0), jnp.arange(nk))
        else:
            (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0), jnp.arange(nk))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return o.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, Kv * g, D)

    out = jax.lax.map(one_q_chunk, (jnp.arange(nq), qc))   # (nq,B,qc,H,D)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D).astype(q.dtype)


def attention(q, k, v, *, impl: str = "dense", causal: bool = True,
              q_offset: int = 0, q_chunk: int = 512, kv_chunk: int = 512,
              triangular_schedule: bool = False,
              kv_len: Optional[jax.Array] = None) -> jax.Array:
    if impl == "dense":
        return dense_attention(q, k, v, causal=causal, q_offset=q_offset,
                               kv_len=kv_len)
    if impl == "flash_jax":
        if kv_len is not None:
            raise NotImplementedError("flash_jax is for train/prefill "
                                      "(full-length KV)")
        return flash_attention_jax(
            q, k, v, causal=causal, q_offset=q_offset, q_chunk=q_chunk,
            kv_chunk=kv_chunk, triangular_schedule=triangular_schedule)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal)
    raise ValueError(f"unknown attention impl {impl!r}")
