"""Utopia core: hybrid restrictive/flexible KV-block translation."""
from .segments import HybridConfig, RestSegConfig, FlexSegConfig, pool_slots_for
from .hashes import HASHES, get_hash
from .tar_sf import (RestSegState, RSWResult, init_restseg, rsw, insert,
                     remove, probe_rows)
from .partition import Partition
from .flex_table import FlexTable, RadixTable, RadixBuilder, init_flex_table
from .translate import (TranslationState, TranslateResult, translate,
                        translate_radix, translate_ech, translate_pom)
from .policies import SRRIP, CostTracker, CostTrackerConfig
from .kv_manager import (HybridKVManager, BlockInfo, PoolExhausted,
                         AllocLedger, REST, FLEX, SWAP)
from .prefix_cache import (PrefixCache, CacheEntry, block_hash_chain,
                           CHAIN_SEED)
from .ech import ElasticCuckooTable, ECHState
from .pom_tlb import POMTLB, POMTLBState

__all__ = [
    "HybridConfig", "RestSegConfig", "FlexSegConfig", "pool_slots_for",
    "HASHES", "get_hash",
    "RestSegState", "RSWResult", "init_restseg", "rsw", "insert", "remove",
    "probe_rows", "Partition",
    "FlexTable", "RadixTable", "RadixBuilder", "init_flex_table",
    "TranslationState", "TranslateResult", "translate",
    "translate_radix", "translate_ech", "translate_pom",
    "SRRIP", "CostTracker", "CostTrackerConfig",
    "HybridKVManager", "BlockInfo", "PoolExhausted", "AllocLedger",
    "REST", "FLEX", "SWAP",
    "PrefixCache", "CacheEntry", "block_hash_chain", "CHAIN_SEED",
    "ElasticCuckooTable", "ECHState", "POMTLB", "POMTLBState",
]
