"""Placement/eviction policies (paper §5.5, §5.6).

* SRRIP re-reference interval prediction over the ways of each RestSeg set
  (the paper's replacement policy, [Jaleel et al.]).
* Cost tracking: per-vpn flexible-walk frequency and cost counters (the
  PTW-Tracking migration policy) stored in "unused PTE bits" — here, two
  small side arrays clamped to the 9 bits the paper steals from the PTE.
* Fault-based allocation preference (treat every new block as
  costly-to-translate; put it in the RestSeg at allocation time).

SRRIP also ages the prefix-cache DIRECTORY (core/prefix_cache.py,
DESIGN.md §prefix-cache): the content-addressed cache is a second
set-associative consumer of this class — hit promotion on every prefix
match, victim selection restricted to unreferenced entries — so cached
prompt blocks join the same replacement machinery as RestSeg ways.

Host-side (numpy): allocation decisions are made by the engine between
device steps, exactly as the OS makes them between faults in the paper.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class SRRIP:
    """SRRIP over (n_sets, assoc) ways.  rrpv in [0, 2^bits - 1]."""

    def __init__(self, n_sets: int, assoc: int, bits: int = 2):
        self.max_rrpv = (1 << bits) - 1
        # insert with "long re-reference interval" = max-1
        self.insert_rrpv = self.max_rrpv - 1
        self.rrpv = np.full((n_sets, assoc), self.max_rrpv, np.int8)

    def on_insert(self, s: int, w: int) -> None:
        self.rrpv[s, w] = self.insert_rrpv

    def on_hit(self, s: int, w: int) -> None:
        self.rrpv[s, w] = 0

    def on_hit_batch(self, s: np.ndarray, w: np.ndarray) -> None:
        """Vectorized hit promotion (one fancy-indexed write per step)."""
        self.rrpv[s, w] = 0

    def on_remove(self, s: int, w: int) -> None:
        self.rrpv[s, w] = self.max_rrpv

    def victim(self, s: int, valid_mask: np.ndarray) -> int:
        """Pick a victim among valid ways; age the set until one saturates."""
        row = self.rrpv[s]
        if not valid_mask.any():
            raise ValueError("victim() called on an empty set")
        while True:
            cand = np.nonzero(valid_mask & (row >= self.max_rrpv))[0]
            if cand.size:
                return int(cand[0])
            row[valid_mask] = np.minimum(row[valid_mask] + 1, self.max_rrpv)


@dataclasses.dataclass
class CostTrackerConfig:
    freq_threshold: int = 4    # flexible walks before a block is "frequent"
    cost_threshold: int = 8    # cumulative walk accesses before "costly"
    counter_bits: int = 9      # paper: unused PTE bits budget (split 5/4)


class CostTracker:
    """PTW-Tracking analogue: counts flexible-walk frequency & cost per vpn.

    ``record_walk`` is fed from device-side stats after each serve step;
    ``take_promotions`` drains vpns whose *both* counters crossed their
    thresholds (paper: migrate when frequency AND cost exceed the
    programmable registers), resetting their counters.
    """

    def __init__(self, vpn_space: int, cfg: CostTrackerConfig = CostTrackerConfig()):
        self.cfg = cfg
        fb = cfg.counter_bits - cfg.counter_bits // 2
        cb = cfg.counter_bits // 2
        self._freq_cap = (1 << fb) - 1
        self._cost_cap = (1 << cb) - 1
        self.freq = np.zeros(vpn_space, np.int16)
        self.cost = np.zeros(vpn_space, np.int16)

    def record_walk(self, vpn, accesses) -> None:
        vpn = np.atleast_1d(np.asarray(vpn, np.int64))
        accesses = np.broadcast_to(np.asarray(accesses, np.int64), vpn.shape)
        np.add.at(self.freq, vpn, 1)
        np.add.at(self.cost, vpn, accesses)
        np.minimum(self.freq, self._freq_cap, out=self.freq, casting="unsafe")
        np.minimum(self.cost, self._cost_cap, out=self.cost, casting="unsafe")

    def take_promotions(self) -> np.ndarray:
        mask = (self.freq >= self.cfg.freq_threshold) & \
               (self.cost >= self.cfg.cost_threshold)
        vpns = np.nonzero(mask)[0]
        self.freq[vpns] = 0
        self.cost[vpns] = 0
        return vpns

    def reset(self, vpn: int) -> None:
        self.freq[vpn] = 0
        self.cost[vpn] = 0
