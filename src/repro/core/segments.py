"""Segment geometry for the hybrid restrictive/flexible KV-block mapping.

Paper mapping (Utopia, Kanellopoulos et al.):
  RestSeg  -> set-associative region of the physical KV-block pool.
  FlexSeg  -> fully-flexible region addressed through a block table.
  page     -> one KV block of ``block_size`` tokens (all layers share one
              translation; the pool carries a layer dimension).

Slot numbering is global over the pool: slots ``[0, rest_slots)`` belong to
the RestSeg (slot = set * assoc + way), slots ``[rest_slots, total_slots)``
belong to the FlexSeg.

Swap consistency (PR 6): a third logical segment, SWAP, holds mappings
whose data lives on the host tier.  A SWAP mapping owns NO slot — the
slot was released at swap-out — so segment geometry never counts it
against RestSeg/FlexSeg occupancy; it only reserves the vpn so a
resume/fault can re-materialise through the normal allocation path.
See DESIGN.md §tiered-KV-and-overload.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class RestSegConfig:
    """Set-associative restrictive segment (paper §5.1)."""

    num_slots: int          # N physical KV blocks in the RestSeg
    assoc: int = 8          # M ways per set
    hash_name: str = "modulo"  # §8.3.8: modulo wins perf/complexity

    def __post_init__(self) -> None:
        if self.num_slots % self.assoc != 0:
            raise ValueError(
                f"RestSeg slots {self.num_slots} not divisible by assoc {self.assoc}"
            )
        if self.num_slots <= 0:
            raise ValueError("RestSeg must have at least one slot")

    @property
    def num_sets(self) -> int:
        return self.num_slots // self.assoc

    # --- structure sizes (paper §5.1.2, Fig. 13) -------------------------
    def tag_bits(self, vpn_space_bits: int = 48) -> int:
        """Bits per TAR tag: vpn bits minus set-index bits, plus 10 metadata."""
        set_bits = max(1, int(math.ceil(math.log2(self.num_sets))))
        return max(1, vpn_space_bits - set_bits) + 10

    def tar_bytes(self, vpn_space_bits: int = 48) -> int:
        return (self.num_slots * self.tag_bits(vpn_space_bits) + 7) // 8

    def sf_bytes(self) -> int:
        counter_bits = int(math.ceil(math.log2(self.assoc))) + 1
        return (self.num_sets * counter_bits + 7) // 8


@dataclasses.dataclass(frozen=True)
class FlexSegConfig:
    """Fully-flexible segment addressed by a block table (paper §5.3)."""

    num_slots: int
    radix_levels: int = 4   # baseline multi-level table ("radix PT" analogue)
    radix_fanout: int = 512 # 9 bits per level, as in x86-64

    def table_bytes(self, num_mapped: int, entry_bytes: int = 8) -> int:
        """Approximate radix-table footprint for ``num_mapped`` mapped blocks.

        Mirrors the paper's Fig. 13 accounting: leaf level is fully densely
        allocated per 512-entry node touched; upper levels amortize.
        """
        nodes = 0
        level_entries = num_mapped
        for _ in range(self.radix_levels):
            level_nodes = max(1, math.ceil(level_entries / self.radix_fanout))
            nodes += level_nodes
            level_entries = level_nodes
        return nodes * self.radix_fanout * entry_bytes


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Full hybrid mapping configuration (one RestSeg + one FlexSeg).

    The paper uses two RestSegs (4K/2M pages); for the KV cache a single
    block size is the norm, so one RestSeg suffices — ``n_restsegs`` pages
    the design if more granularities are needed.
    """

    block_size: int = 64            # tokens per KV block ("page size")
    total_slots: int = 1024         # pool size in blocks
    restseg_fraction: float = 0.75  # fraction of pool run restrictively
    assoc: int = 8
    hash_name: str = "modulo"
    max_seqs: int = 64
    max_blocks_per_seq: int = 64
    # policies (paper §5.5)
    alloc_evicts: bool = True       # page-fault alloc may evict (SRRIP) to flex
    promote_freq_threshold: int = 4  # flex-walk frequency counter threshold
    promote_cost_threshold: int = 8  # flex-walk cost (accesses) threshold
    mode: str = "hybrid"            # hybrid | restrictive_only | flexible_only

    def __post_init__(self) -> None:
        if self.mode not in ("hybrid", "restrictive_only", "flexible_only"):
            raise ValueError(f"bad mode {self.mode}")
        if self.rest_slots % self.assoc != 0:
            raise ValueError(
                f"rest slots {self.rest_slots} not divisible by assoc {self.assoc}"
            )

    @property
    def rest_slots(self) -> int:
        if self.mode == "flexible_only":
            return 0
        if self.mode == "restrictive_only":
            # round down to assoc multiple
            return (self.total_slots // self.assoc) * self.assoc
        raw = int(self.total_slots * self.restseg_fraction)
        return max(self.assoc, (raw // self.assoc) * self.assoc)

    @property
    def flex_slots(self) -> int:
        return self.total_slots - self.rest_slots

    @property
    def num_sets(self) -> int:
        return max(1, self.rest_slots // self.assoc)

    @property
    def vpn_space(self) -> int:
        return self.max_seqs * self.max_blocks_per_seq

    def restseg(self) -> RestSegConfig:
        return RestSegConfig(
            num_slots=max(self.assoc, self.rest_slots),
            assoc=self.assoc,
            hash_name=self.hash_name,
        )

    def flexseg(self) -> FlexSegConfig:
        return FlexSegConfig(num_slots=self.flex_slots)

    def vpn(self, seq_slot: int, block_idx: int) -> int:
        if not (0 <= seq_slot < self.max_seqs):
            raise ValueError(f"seq_slot {seq_slot} out of range")
        if not (0 <= block_idx < self.max_blocks_per_seq):
            raise ValueError(f"block_idx {block_idx} out of range")
        return seq_slot * self.max_blocks_per_seq + block_idx


def pool_slots_for(num_logical_blocks: int, headroom: float = 1.25,
                   assoc: int = 8) -> int:
    """Pool sizing helper: logical blocks plus headroom, assoc-aligned."""
    raw = int(math.ceil(num_logical_blocks * headroom))
    return max(assoc, ((raw + assoc - 1) // assoc) * assoc)
