"""Utopia-native global prefix cache: content-addressed KV dedup.

The paper's restrictive mapping is a hash-indexed, set-associative
content->physical map with compact tags.  This module reuses exactly
that structure as an AUTOMATIC, engine-wide prompt-prefix cache: the
"content" being mapped is a hash CHAIN over prompt blocks —

    chain_0 = H(CHAIN_SEED,  tokens[0:bs])
    chain_k = H(chain_{k-1}, tokens[k*bs:(k+1)*bs])

at KV-block granularity (``H`` built from :func:`core.hashes.mix32`,
the same int32-safe family the RestSeg set index uses), so a chain hash
identifies a whole prefix, not just a block, and two prompts share a
cache entry iff they share every token up to and including that block.

Directory layout — the RestSeg recipe, one level up:

* ``num_sets x assoc`` ways, the set index = ``hash(chain, num_sets)``
  with the manager's configured hash function (paper §8.3.8 family);
* SRRIP re-reference prediction over the ways of each set (the same
  :class:`core.policies.SRRIP` the RestSeg eviction uses), aged on
  insert, promoted on every prefix match;
* an entry pins one FlexSeg pool slot via the manager's refcount
  machinery (``cache_pin_block`` / ``cache_unpin_slot``): physical
  sharing MUST live in the flexible segment — a restrictive slot is
  tag-bound to a single vpn, the paper's own sharing limitation — so
  pinning copy-on-share migrates REST-resident blocks out first, just
  like ``share_prefix``.

Ownership / eviction rules (cross-checked by ``check_invariants``):

* a cached slot's ``slot_refcount`` == live attachers + 1 (the cache's
  own reference), so a cached block survives every sequence release;
* only UNREFERENCED entries (refcount == 1, cache-only) are eviction
  victims — a block a live sequence reads is never dropped from under
  it, and cached blocks are never writable, so a cache hit can never
  observe a torn write;
* capacity pressure reclaims cache-only entries before any live
  sequence is preempted (the cheapest rung of the engine's overload
  ladder — dropping clean cache frees a slot for free).

Bit-identity contract: entries verify the EXACT block tokens (the hash
only routes; collisions cannot alias), and the pool bytes behind an
entry are whatever the writer's prefill installed — which the PR-4
differential oracle pins bitwise against the blocking recompute of the
same tokens, independent of chunk schedule or pow2 padding.  A cache
hit therefore feeds the prefix-KV chunk path the same bytes the
request's own prefill would have written, and cache-on streams are
bit-identical to cache-off.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import List, Optional

import numpy as np

from .hashes import get_hash, mix32
from .policies import SRRIP

# chain root: any odd int32 constant; shared by every engine so caches
# built over the same tokens agree across processes
CHAIN_SEED = 0x3C6EF372 & 0x7FFFFFFF


def block_hash_chain(tokens, block_size: int) -> np.ndarray:
    """Per-block chained content hashes of a token sequence.

    Within a block, order is captured by a per-position multiplier (one
    vectorized ``mix32`` pass over all blocks at once); across blocks
    the digests fold sequentially into the parent chain — only this
    short loop (#blocks iterations) is sequential.  Returns int64
    values in ``[0, 2^31)``; trailing tokens short of a full block are
    ignored (the cache stores whole KV blocks only).
    """
    t = np.asarray(tokens, np.int64)
    n = t.size // block_size
    if n == 0:
        return np.zeros(0, np.int64)
    t = t[:n * block_size].reshape(n, block_size)
    pos = mix32((np.arange(block_size, dtype=np.int64) + 131) & 0x7FFFFFFF)
    with np.errstate(over="ignore"):          # int64 wrap is deterministic
        digests = np.bitwise_xor.reduce(
            mix32(((t + 1) * (pos + 1)) & 0x7FFFFFFF), axis=1)
    out = np.empty(n, np.int64)
    h = CHAIN_SEED
    for k in range(n):
        h = int(mix32((h ^ int(digests[k])) & 0x7FFFFFFF))
        out[k] = h
    return out


@dataclasses.dataclass
class CacheEntry:
    """One cached prefix block: content identity + pinned pool slot."""
    chain: int            # chain hash of the prefix ending at this block
    parent: int           # parent chain hash (CHAIN_SEED for block 0)
    tokens: np.ndarray    # exact block tokens — hash collisions cannot alias
    slot: int             # FlexSeg pool slot, cache-pinned in the manager


class PrefixCache:
    """Set-associative content->physical directory over the KV pool."""

    def __init__(self, manager, num_sets: Optional[int] = None,
                 assoc: int = 4, hash_name: Optional[str] = None):
        self.mgr = manager
        cfg = manager.cfg
        self.assoc = assoc
        # directory capacity ~ the pool: every slot could in principle
        # be cached, and a too-small directory would thrash via SRRIP
        # instead of via pool pressure
        self.num_sets = (max(1, cfg.total_slots // assoc)
                         if num_sets is None else num_sets)
        self.hash_name = hash_name or cfg.hash_name
        self.hash = get_hash(self.hash_name)
        self.srrip = SRRIP(self.num_sets, assoc)
        self.ways: List[List[Optional[CacheEntry]]] = [
            [None] * assoc for _ in range(self.num_sets)]
        self._n = 0
        self.stats = defaultdict(int)

    @property
    def n_entries(self) -> int:
        return self._n

    def __getstate__(self):
        """Pickle support (engine snapshot/restore): drop the resolved
        hash callable, re-derive from the stored name on load.  ``mgr``
        pickles along WITH the cache — inside an engine snapshot the
        memo keeps it the same object as the engine's manager."""
        state = dict(self.__dict__)
        state.pop("hash", None)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self.hash = get_hash(self.hash_name)

    # -------------------------------------------------------------- lookup
    def _find(self, chain: int, parent: int, tokens: np.ndarray
              ) -> Optional[CacheEntry]:
        st = int(self.hash(int(chain), self.num_sets))
        for w, e in enumerate(self.ways[st]):
            if (e is not None and e.chain == chain and e.parent == parent
                    and np.array_equal(e.tokens, tokens)):
                self.srrip.on_hit(st, w)        # re-referenced: promote
                return e
        return None

    def match(self, tokens, chains: Optional[np.ndarray] = None
              ) -> List[CacheEntry]:
        """Longest cached prefix of ``tokens``: one entry per matched
        block, walking the chain from the root and stopping at the
        first miss.  Every matched way is SRRIP-promoted."""
        bs = self.mgr.cfg.block_size
        t = np.asarray(tokens, np.int64)
        if chains is None:
            chains = block_hash_chain(t, bs)
        out: List[CacheEntry] = []
        parent = CHAIN_SEED
        for k in range(t.size // bs):
            e = self._find(int(chains[k]), parent, t[k * bs:(k + 1) * bs])
            if e is None:
                break
            out.append(e)
            parent = int(chains[k])
        return out

    # ------------------------------------------------------------- insert
    def _evictable(self, e: CacheEntry) -> bool:
        # cache-only: the pin is the sole reference — no live attacher
        return self.mgr.slot_refcount.get(e.slot, 0) == 1

    def _evict(self, st: int, way: int) -> None:
        e = self.ways[st][way]
        self.ways[st][way] = None
        self._n -= 1
        self.srrip.on_remove(st, way)
        self.mgr.cache_unpin_slot(e.slot)
        self.stats["evictions"] += 1

    def insert(self, chain: int, parent: int, tokens, seq_id: int,
               block_idx: int) -> bool:
        """Publish a freshly installed prompt block.

        Pins the block's physical slot under cache ownership (migrating
        it out of the RestSeg if needed — restrictive slots cannot be
        shared).  A full set evicts an UNREFERENCED way via SRRIP; a
        pin that fails because the FlexSeg has no free slot to migrate
        into reclaims unreferenced entries (``evict_one``) and retries.
        When every way is live-referenced, every entry is attached, or
        the block is swapped, the insert bypasses — the cache never
        blocks a live sequence.  Returns True iff a new entry was
        placed.
        """
        tok = np.asarray(tokens, np.int64)
        if self._find(chain, parent, tok) is not None:
            return False                       # already cached: dedup
        st = int(self.hash(int(chain), self.num_sets))
        row = self.ways[st]
        way = next((w for w, e in enumerate(row) if e is None), None)
        if way is None:
            mask = np.fromiter((e is not None and self._evictable(e)
                                for e in row), bool, self.assoc)
            if not mask.any():
                self.stats["insert_bypass"] += 1
                return False
            way = int(self.srrip.victim(st, mask))
            self._evict(st, way)
        slot = self.mgr.cache_pin_block(seq_id, block_idx)
        # pin failure with an EMPTY FlexSeg free list is a capacity
        # miss (a REST block with nowhere to migrate): reclaim our own
        # unreferenced entries and retry — old resident prefixes must
        # not starve new ones.  Any other failure (swapped, unmapped,
        # slot already cached) is final; the free-list guard exits the
        # loop after at most one eviction in those cases.
        while slot is None and not self.mgr.flex_free \
                and self.evict_one():
            slot = self.mgr.cache_pin_block(seq_id, block_idx)
        if slot is None:
            self.stats["insert_bypass"] += 1
            return False
        row[way] = CacheEntry(chain=int(chain), parent=int(parent),
                              tokens=np.array(tok, copy=True), slot=slot)
        self._n += 1
        self.srrip.on_insert(st, way)
        self.stats["inserts"] += 1
        return True

    # ----------------------------------------------------------- eviction
    def evict_one(self) -> bool:
        """Reclaim ONE unreferenced entry (capacity ladder rung): frees
        its pool slot back to the FlexSeg.  Returns False when every
        entry is attached by a live sequence."""
        for st in range(self.num_sets):
            row = self.ways[st]
            mask = np.fromiter((e is not None and self._evictable(e)
                                for e in row), bool, self.assoc)
            if mask.any():
                self._evict(st, int(self.srrip.victim(st, mask)))
                return True
        return False

    def evictable_count(self) -> int:
        return sum(1 for row in self.ways for e in row
                   if e is not None and self._evictable(e))

    # --------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Directory <-> manager consistency: every entry sits in its
        hash set, pins a distinct slot the manager also believes is
        cache-owned, and the counts agree (the manager's own
        ``check_invariants`` asserts refcount == attachers + pin)."""
        m = self.mgr
        slots: List[int] = []
        n = 0
        for st in range(self.num_sets):
            for e in self.ways[st]:
                if e is None:
                    continue
                n += 1
                assert int(self.hash(int(e.chain), self.num_sets)) == st, \
                    f"entry chain {e.chain} filed in the wrong set {st}"
                assert e.slot in m.cached_slots, \
                    f"cache entry slot {e.slot} not pinned in the manager"
                assert m.slot_refcount.get(e.slot, 0) >= 1, \
                    f"cached slot {e.slot} lost its pin refcount"
                slots.append(e.slot)
        assert len(slots) == len(set(slots)), \
            "two cache entries share one pool slot"
        assert set(slots) == m.cached_slots, \
            (f"directory slots {sorted(set(slots))} != manager "
             f"cached_slots {sorted(m.cached_slots)}")
        assert n == self._n, "entry counter drifted"
