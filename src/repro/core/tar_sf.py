"""Tag Array (TAR) + Set Filter (SF): RestSeg translation structures.

Device-side, purely functional (jax.numpy).  The host allocator in
``kv_manager.py`` keeps a numpy mirror of the same arrays; both sides share
the hash functions in ``hashes.py`` so they agree bit-for-bit.

Encoding: a TAR entry stores ``vpn + 1`` (0 = invalid/empty way).  ``meta``
carries the paper's 10 metadata bits (permissions etc.); we use bit0 =
writable, bit1 = shared.

Paper §5.2 (RestSeg Walk):
  set   = hash(vpn) % n_sets
  SF[set] == 0  -> miss without touching TAR   (set filtering)
  else          -> compare vpn+1 against the M way tags (tag matching)
  slot  = set * assoc + way                     (restrictive mapping)

Swap consistency (PR 6): a swapped-out (host-tier) block is NEVER
tagged here — swap-out clears its TAR way and decrements SF, so a
RestSeg walk for it misses cleanly and the fault path re-allocates.
The host allocator's numpy mirror and these device arrays stay in
lockstep through the dirty-delta sync; ``check_invariants`` asserts
the mirror after every preempt/resume in tests.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .hashes import get_hash


class RestSegState(NamedTuple):
    """Translation state of one RestSeg (device arrays)."""

    tar: jnp.ndarray    # (n_sets, assoc) int32: vpn+1, 0 = empty
    sf: jnp.ndarray     # (n_sets,)       int32: set occupancy counter
    meta: jnp.ndarray   # (n_sets, assoc) int32: 10 metadata bits

    @property
    def n_sets(self) -> int:
        return self.tar.shape[0]

    @property
    def assoc(self) -> int:
        return self.tar.shape[1]


def init_restseg(n_sets: int, assoc: int) -> RestSegState:
    return RestSegState(
        tar=jnp.zeros((n_sets, assoc), jnp.int32),
        sf=jnp.zeros((n_sets,), jnp.int32),
        meta=jnp.zeros((n_sets, assoc), jnp.int32),
    )


class RSWResult(NamedTuple):
    hit: jnp.ndarray        # bool  — vpn resides in the RestSeg
    slot: jnp.ndarray       # int32 — global RestSeg slot (set*assoc+way); 0 if miss
    way: jnp.ndarray        # int32 — way index; -1 if miss
    sf_skipped: jnp.ndarray # bool  — SF counter was 0: TAR lookup skipped
    tar_touched: jnp.ndarray  # int32 — tag comparisons actually performed


def probe_rows(tags: jnp.ndarray, counters: jnp.ndarray, vpn: jnp.ndarray):
    """SF ∥ TAR probe of PRE-GATHERED set rows.

    ``tags (..., assoc)`` and ``counters (...)`` are the TAR row and SF
    counter of each vpn's set (gathered by the caller — ``rsw`` gathers
    from the full tables, the sharded lookup from its local set chunk).
    This is the single source of truth for the paper's tag-match / set-
    filter semantics: a zero tag can never match (tags store ``vpn+1``),
    and an SF counter of 0 skips the TAR compare entirely.
    Returns ``(hit, way, sf_skipped)`` shaped like ``vpn``.
    """
    eq = tags == (vpn[..., None].astype(jnp.int32) + 1)
    nonempty = counters > 0
    hit = jnp.any(eq, axis=-1) & nonempty
    way = jnp.where(hit, jnp.argmax(eq, axis=-1).astype(jnp.int32), -1)
    return hit, way, ~nonempty


def rsw(state: RestSegState, vpn: jnp.ndarray, hash_name: str = "modulo") -> RSWResult:
    """Batched RestSeg Walk.  ``vpn``: int32 array of any shape.

    Two *parallel* small lookups (SF ∥ TAR) versus the flexible walk's four
    serial ones — the paper's core latency argument.  ``sf_skipped`` and
    ``tar_touched`` feed the Fig. 23-style locality/traffic benchmarks.
    """
    h = get_hash(hash_name)
    set_idx = h(vpn.astype(jnp.int32), state.n_sets).astype(jnp.int32)
    counters = state.sf[set_idx]                      # (..., )
    tags = state.tar[set_idx]                         # (..., assoc)
    hit, way, sf_skipped = probe_rows(tags, counters, vpn)
    slot = jnp.where(hit, set_idx * state.assoc + jnp.maximum(way, 0), 0)
    tar_touched = jnp.where(~sf_skipped, state.assoc, 0).astype(jnp.int32)
    return RSWResult(hit=hit, slot=slot.astype(jnp.int32), way=way,
                     sf_skipped=sf_skipped, tar_touched=tar_touched)


def insert(state: RestSegState, vpn: jnp.ndarray, way: jnp.ndarray,
           hash_name: str = "modulo", meta_bits: int = 1) -> RestSegState:
    """Functional single-entry insert at a chosen way (allocation is decided
    host-side; this is the device mirror used in tests/property checks)."""
    h = get_hash(hash_name)
    vpn = jnp.asarray(vpn, jnp.int32)
    way = jnp.asarray(way, jnp.int32)
    set_idx = h(vpn, state.n_sets).astype(jnp.int32)
    was_empty = state.tar[set_idx, way] == 0
    tar = state.tar.at[set_idx, way].set(vpn + 1)
    meta = state.meta.at[set_idx, way].set(meta_bits)
    sf = state.sf.at[set_idx].add(jnp.where(was_empty, 1, 0).astype(jnp.int32))
    return RestSegState(tar=tar, sf=sf, meta=meta)


def remove(state: RestSegState, vpn: jnp.ndarray,
           hash_name: str = "modulo") -> RestSegState:
    res = rsw(state, jnp.asarray(vpn, jnp.int32)[None], hash_name)
    hit = res.hit[0]
    set_idx = get_hash(hash_name)(jnp.asarray(vpn, jnp.int32), state.n_sets)
    way = jnp.maximum(res.way[0], 0)
    tar = state.tar.at[set_idx, way].set(
        jnp.where(hit, 0, state.tar[set_idx, way]))
    meta = state.meta.at[set_idx, way].set(
        jnp.where(hit, 0, state.meta[set_idx, way]))
    sf = state.sf.at[set_idx].add(jnp.where(hit, -1, 0).astype(jnp.int32))
    return RestSegState(tar=tar, sf=sf, meta=meta)


def structure_bytes(state: RestSegState, vpn_space_bits: int = 32) -> dict:
    """Actual byte footprint of the packed structures (Fig. 13 accounting)."""
    n_sets, assoc = state.tar.shape
    set_bits = max(1, (n_sets - 1).bit_length())
    tag_bits = max(1, vpn_space_bits - set_bits) + 10
    counter_bits = max(1, (assoc).bit_length())
    return {
        "tar_bytes": (n_sets * assoc * tag_bits + 7) // 8,
        "sf_bytes": (n_sets * counter_bits + 7) // 8,
    }
