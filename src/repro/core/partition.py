"""Set-index / block-range partitioning of the hybrid mapping over shards.

SPMD serving (DESIGN.md §sharded-serving) shards the translation
structures and the KV-block pool over the mesh's ``model`` axis, exploiting
the property that makes the restrictive mapping compact in the first
place: ``set = hash(vpn) % n_sets`` is position-derived, so the TAR/SF
tables partition trivially by *set index* and the flat flex table by
*vpn range* — no shard ever needs another shard's rows to answer its
part of a lookup (the SPARTA-style divide-and-conquer).

Shard ``m`` of ``M`` owns:

* restrictive sets ``[m*spm, (m+1)*spm)``  (``spm = ceil(n_sets / M)``),
  i.e. logical RestSeg slots ``[m*spm*assoc, (m+1)*spm*assoc)``,
* flex pool slots ``[m*fpm, (m+1)*fpm)`` of the flex region
  (``fpm = ceil(flex_slots / M)``),
* vpn rows ``[m*vpm, (m+1)*vpm)`` of the flat flex table
  (``vpm = ceil(vpn_space / M)``).

LOGICAL slot numbering — what the host :class:`HybridKVManager` and
``StepTranslation`` carry — is unchanged by sharding: slots
``[0, rest_slots)`` are RestSeg (``set * assoc + way``), the rest FlexSeg.
Only the *device pool layout* changes: each shard's slots are made
contiguous so the pool shards with a plain ``P(None, "model")`` spec.
:meth:`phys` is the (static) permutation from logical slot to that
shard-contiguous physical slot; it is the identity when ``M == 1`` (in
hybrid mode, where ``rest_slots == n_sets * assoc``).

All sizes are padded per shard (ceil division) so every shard's chunk
has identical shape — padded TAR rows stay zero (a tag is ``vpn+1 >= 1``,
so zero rows can never spuriously hit) and padded flex entries stay -1
(unmapped), which keeps the padded lookup bit-identical to the unpadded
one.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class Partition:
    """Static partition geometry of one hybrid mapping over ``n_shards``."""

    n_shards: int
    n_sets: int          # REAL set count (the hash modulus — never padded)
    assoc: int
    rest_slots: int      # logical RestSeg slots (0 in flexible_only mode)
    flex_slots: int      # logical FlexSeg slots
    vpn_space: int       # flat flex-table length (max_seqs * blocks_per_seq)

    def __post_init__(self) -> None:
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.rest_slots not in (0, self.n_sets * self.assoc):
            raise ValueError(
                f"rest_slots {self.rest_slots} inconsistent with "
                f"{self.n_sets} sets x {self.assoc} ways")

    @classmethod
    def for_hybrid(cls, cfg, n_shards: int) -> "Partition":
        """Build from a :class:`core.segments.HybridConfig`."""
        return cls(n_shards=n_shards, n_sets=cfg.num_sets, assoc=cfg.assoc,
                   rest_slots=cfg.rest_slots, flex_slots=cfg.flex_slots,
                   vpn_space=cfg.vpn_space)

    # ------------------------------------------------- per-shard geometry
    @property
    def sets_per_shard(self) -> int:
        return _ceil_div(self.n_sets, self.n_shards)

    @property
    def n_sets_padded(self) -> int:
        return self.sets_per_shard * self.n_shards

    @property
    def rest_per_shard(self) -> int:
        return self.sets_per_shard * self.assoc

    @property
    def flex_per_shard(self) -> int:
        return _ceil_div(self.flex_slots, self.n_shards)

    @property
    def slots_per_shard(self) -> int:
        """Physical pool slots per shard (rest chunk followed by flex chunk)."""
        return self.rest_per_shard + self.flex_per_shard

    @property
    def pool_slots(self) -> int:
        """Padded device pool size (>= rest_slots + flex_slots)."""
        return self.n_shards * self.slots_per_shard

    @property
    def vpns_per_shard(self) -> int:
        return _ceil_div(self.vpn_space, self.n_shards)

    @property
    def vpn_padded(self) -> int:
        return self.vpns_per_shard * self.n_shards

    # --------------------------------------------------------- ownership
    def shard_of_set(self, set_idx):
        return set_idx // self.sets_per_shard

    def shard_of_vpn(self, vpn):
        return vpn // self.vpns_per_shard

    def shard_of_slot(self, slot):
        """Owning shard of a LOGICAL pool slot (undefined for slot < 0)."""
        return self.phys(slot) // self.slots_per_shard

    # ------------------------------------------------ slot renumbering
    def phys(self, slot):
        """Logical pool slot -> shard-contiguous physical device slot.

        Works on python ints, numpy arrays and traced jax arrays alike;
        negative (unmapped) slots pass through unchanged.  Identity when
        ``n_shards == 1`` and ``rest_slots == n_sets * assoc``.
        """
        xp = jnp if isinstance(slot, jnp.ndarray) else np
        spm, assoc = self.sets_per_shard, self.assoc
        fpm = max(1, self.flex_per_shard)   # avoid //0 when no flex region
        cps = self.slots_per_shard
        i_r = (slot // assoc) // spm
        p_rest = i_r * cps + (slot - i_r * (spm * assoc))
        f_off = slot - self.rest_slots
        i_f = f_off // fpm
        p_flex = i_f * cps + spm * assoc + (f_off - i_f * fpm)
        p = xp.where(slot < self.rest_slots, p_rest, p_flex)
        return xp.where(slot >= 0, p, slot)


__all__ = ["Partition"]
