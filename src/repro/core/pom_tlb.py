"""POM-TLB baseline: a large software-managed set-associative TLB (paper §7).

A 64K-entry, 16-way part-of-memory TLB that caches vpn->slot translations in
front of the flexible walk.  On a hit, one set read resolves the
translation; on a miss, the full flexible walk runs and the entry is filled
(host-side fill mirrors the paper's software management).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from .hashes import modulo_hash


class POMTLBState(NamedTuple):
    keys: jnp.ndarray    # (n_sets, ways) int32: vpn+1, 0 empty
    values: jnp.ndarray  # (n_sets, ways) int32 slot

    @property
    def n_sets(self) -> int:
        return self.keys.shape[0]

    @property
    def ways(self) -> int:
        return self.keys.shape[1]

    def lookup(self, vpn: jnp.ndarray):
        idx = modulo_hash(vpn.astype(jnp.int32), self.n_sets)
        keys = self.keys[idx]                     # (..., ways)
        eq = keys == (vpn[..., None].astype(jnp.int32) + 1)
        hit = jnp.any(eq, axis=-1)
        way = jnp.argmax(eq, axis=-1)
        slot = jnp.where(hit, jnp.take_along_axis(
            self.values[idx], way[..., None], axis=-1)[..., 0], -1)
        accesses = jnp.ones(vpn.shape, jnp.int32)
        return slot.astype(jnp.int32), hit, accesses


class POMTLB:
    """Host-side manager with SRRIP-ish (LRU-approx) replacement."""

    def __init__(self, entries: int = 65536, ways: int = 16):
        self.n_sets = max(1, entries // ways)
        self.ways = ways
        self.keys = np.zeros((self.n_sets, ways), np.int32)
        self.values = np.zeros((self.n_sets, ways), np.int32)
        self.stamp = np.zeros((self.n_sets, ways), np.int64)
        self._clock = 0
        self.hits = 0
        self.misses = 0

    def lookup_fill(self, vpn: int, slot_on_miss: int) -> tuple:
        """Probe; on miss, fill with ``slot_on_miss``. Returns (slot, hit)."""
        self._clock += 1
        s = vpn % self.n_sets
        key = vpn + 1
        row = self.keys[s]
        w = np.nonzero(row == key)[0]
        if w.size:
            self.hits += 1
            self.stamp[s, w[0]] = self._clock
            return int(self.values[s, w[0]]), True
        self.misses += 1
        empty = np.nonzero(row == 0)[0]
        victim = int(empty[0]) if empty.size else int(np.argmin(self.stamp[s]))
        self.keys[s, victim] = key
        self.values[s, victim] = slot_on_miss
        self.stamp[s, victim] = self._clock
        return slot_on_miss, False

    def invalidate(self, vpn: int) -> None:
        s = vpn % self.n_sets
        w = np.nonzero(self.keys[s] == vpn + 1)[0]
        if w.size:
            self.keys[s, w[0]] = 0
            self.values[s, w[0]] = 0

    def table_bytes(self, entry_bytes: int = 8) -> int:
        return self.n_sets * self.ways * entry_bytes

    def device_state(self) -> POMTLBState:
        return POMTLBState(keys=jnp.asarray(self.keys),
                           values=jnp.asarray(self.values))
