"""Elastic Cuckoo Hash table baseline (paper's ECH comparison, §7).

n-way cuckoo hashing: a key may live in exactly one nest per table; lookup
probes all n tables *in parallel* (n independent gathers — more traffic than
one RSW, which is the paper's Fig. 5/20 observation: ECH issues ~62% more
memory requests than radix while being lower latency).  Insert displaces
residents along a cuckoo path, host-side, with bounded kicks and elastic
resize on failure.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import numpy as np
import jax.numpy as jnp

from .hashes import mix32

_SALTS = (0x1E3779B9, 0x05EBCA6B, 0x42B2AE35, 0x27D4EB2F)  # int32-safe


def _ech_hash(key, salt: int, capacity: int):
    return mix32((key ^ salt) & 0x7FFFFFFF) % capacity


class ECHState(NamedTuple):
    keys: jnp.ndarray    # (n_tables, capacity) int32: vpn+1, 0 empty
    values: jnp.ndarray  # (n_tables, capacity) int32 physical slot

    @property
    def n_tables(self) -> int:
        return self.keys.shape[0]

    @property
    def capacity(self) -> int:
        return self.keys.shape[1]

    def lookup(self, vpn: jnp.ndarray):
        """Parallel n-way probe.  Returns (slot, hit, accesses)."""
        k = vpn.astype(jnp.int32) + 1
        slot = jnp.full(vpn.shape, -1, jnp.int32)
        hit = jnp.zeros(vpn.shape, bool)
        for t in range(self.n_tables):
            idx = _ech_hash(vpn.astype(jnp.int32), _SALTS[t % 4], self.capacity)
            found = self.keys[t, idx] == k
            slot = jnp.where(found & ~hit, self.values[t, idx], slot)
            hit = hit | found
        accesses = jnp.full(vpn.shape, self.n_tables, jnp.int32)
        return slot, hit, accesses


class ElasticCuckooTable:
    """Host-side manager with elastic resize (numpy)."""

    def __init__(self, capacity: int = 256, n_tables: int = 4,
                 max_kicks: int = 32, occupancy_limit: float = 0.6):
        self.n_tables = n_tables
        self.capacity = capacity
        self.max_kicks = max_kicks
        self.occupancy_limit = occupancy_limit
        self.keys = np.zeros((n_tables, capacity), np.int32)
        self.values = np.zeros((n_tables, capacity), np.int32)
        self.size = 0
        self.resizes = 0

    def _occupancy(self) -> float:
        return self.size / (self.n_tables * self.capacity)

    def insert(self, vpn: int, slot: int) -> None:
        if self._occupancy() >= self.occupancy_limit:
            self._resize()
        key = vpn + 1
        # update in place if present
        for t in range(self.n_tables):
            idx = _ech_hash(np.int32(vpn), _SALTS[t % 4], self.capacity)
            if self.keys[t, idx] == key:
                self.values[t, idx] = slot
                return
        cur_key, cur_val = key, slot
        t = 0
        for _ in range(self.max_kicks):
            idx = _ech_hash(np.int32(cur_key - 1), _SALTS[t % 4], self.capacity)
            if self.keys[t, idx] == 0:
                self.keys[t, idx] = cur_key
                self.values[t, idx] = cur_val
                self.size += 1
                return
            cur_key, self.keys[t, idx] = int(self.keys[t, idx]), cur_key
            cur_val, self.values[t, idx] = int(self.values[t, idx]), cur_val
            t = (t + 1) % self.n_tables
        self._resize()
        self.insert(cur_key - 1, cur_val)

    def remove(self, vpn: int) -> None:
        key = vpn + 1
        for t in range(self.n_tables):
            idx = _ech_hash(np.int32(vpn), _SALTS[t % 4], self.capacity)
            if self.keys[t, idx] == key:
                self.keys[t, idx] = 0
                self.values[t, idx] = 0
                self.size -= 1
                return

    def lookup_host(self, vpn: int) -> Tuple[int, bool]:
        key = vpn + 1
        for t in range(self.n_tables):
            idx = _ech_hash(np.int32(vpn), _SALTS[t % 4], self.capacity)
            if self.keys[t, idx] == key:
                return int(self.values[t, idx]), True
        return -1, False

    def _resize(self) -> None:
        """Elastic doubling with rehash (the 'elastic' in ECH)."""
        old_keys, old_values = self.keys, self.values
        self.capacity *= 2
        self.resizes += 1
        self.keys = np.zeros((self.n_tables, self.capacity), np.int32)
        self.values = np.zeros((self.n_tables, self.capacity), np.int32)
        self.size = 0
        for t in range(self.n_tables):
            for i in np.nonzero(old_keys[t])[0]:
                self.insert(int(old_keys[t, i]) - 1, int(old_values[t, i]))

    def table_bytes(self, entry_bytes: int = 8) -> int:
        return self.n_tables * self.capacity * entry_bytes

    def device_state(self) -> ECHState:
        return ECHState(keys=jnp.asarray(self.keys),
                        values=jnp.asarray(self.values))
