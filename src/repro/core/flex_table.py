"""FlexSeg translation structures: flat block table + radix-walk baseline.

Two device-side representations of the flexible mapping:

* ``FlexTable``  — flat (max_seqs, max_blocks_per_seq) table, one gather per
  translation.  This is what the production serve path uses for FlexSeg
  blocks (the vLLM-style block table).
* ``RadixTable`` — 4-level radix tree over the vpn, requiring four *serial*
  dependent gathers per translation.  This reproduces the paper's baseline
  page-table walk (PTW) cost structure for the benchmarks: the serial
  dependency chain is real in the lowered HLO (each gather's index depends
  on the previous gather's result).

Swap consistency (PR 6): a swapped-out (host-tier) block is -1
(unmapped) in the flat table — the flex slot is freed at swap-out and
re-acquired at resume/fault time, so a stale slot can never be read
through the table while its data is on the host.  The SWAP bookkeeping
(which vpns are restorable, and their write bits) lives host-side in
``kv_manager.py``; see DESIGN.md §tiered-KV-and-overload.
"""
from __future__ import annotations

from typing import List, NamedTuple, Tuple

import numpy as np
import jax.numpy as jnp


class FlexTable(NamedTuple):
    table: jnp.ndarray  # (max_seqs, max_blocks_per_seq) int32 slot, -1 unmapped

    def lookup_vpn(self, vpn: jnp.ndarray, max_blocks_per_seq: int):
        seq = vpn // max_blocks_per_seq
        blk = vpn % max_blocks_per_seq
        slot = self.table[seq, blk]
        return slot, slot >= 0


def init_flex_table(max_seqs: int, max_blocks_per_seq: int) -> FlexTable:
    return FlexTable(table=-jnp.ones((max_seqs, max_blocks_per_seq), jnp.int32))


# ---------------------------------------------------------------------------
# Radix ("x86-64 page-table") baseline
# ---------------------------------------------------------------------------

class RadixTable(NamedTuple):
    """Multi-level radix table stored as per-level node pools.

    ``levels[i]`` has shape (n_nodes_i, fanout) int32.  An entry at level i
    holds the node index for level i+1 (or -1).  The leaf level holds the
    physical slot (or -1).  A walk is ``levels`` dependent gathers — the
    serial pointer chase of the paper's Fig. 1.
    """
    levels: Tuple[jnp.ndarray, ...]
    fanout: int

    @property
    def num_levels(self) -> int:
        return len(self.levels)

    def walk(self, vpn: jnp.ndarray):
        """Serial radix walk.  Returns (slot, hit, accesses)."""
        L = self.num_levels
        f = self.fanout
        # vpn digit for level 0 is the most significant
        node = jnp.zeros_like(vpn)
        ok = jnp.ones(vpn.shape, bool)
        accesses = jnp.zeros(vpn.shape, jnp.int32)
        for i in range(L):
            shift = f ** (L - 1 - i)
            digit = (vpn // shift) % f
            entry = self.levels[i][jnp.maximum(node, 0), digit]
            accesses = accesses + jnp.where(ok, 1, 0)
            ok = ok & (entry >= 0)
            node = entry
        slot = jnp.where(ok, node, -1)
        return slot.astype(jnp.int32), ok, accesses


class RadixBuilder:
    """Host-side (numpy) incremental builder mirroring ``RadixTable``."""

    def __init__(self, num_levels: int = 4, fanout: int = 8):
        self.num_levels = num_levels
        self.fanout = fanout
        self.levels: List[np.ndarray] = [
            -np.ones((1, fanout), np.int32)  # root pre-allocated
        ] + [
            -np.ones((0, fanout), np.int32) for _ in range(num_levels - 1)
        ]

    def _alloc_node(self, level: int) -> int:
        arr = self.levels[level]
        self.levels[level] = np.concatenate(
            [arr, -np.ones((1, self.fanout), np.int32)], axis=0)
        return arr.shape[0]

    def map(self, vpn: int, slot: int) -> None:
        node = 0
        for i in range(self.num_levels):
            shift = self.fanout ** (self.num_levels - 1 - i)
            digit = (vpn // shift) % self.fanout
            if i == self.num_levels - 1:
                self.levels[i][node, digit] = slot
                return
            nxt = self.levels[i][node, digit]
            if nxt < 0:
                nxt = self._alloc_node(i + 1)
                self.levels[i][node, digit] = nxt
            node = nxt

    def unmap(self, vpn: int) -> None:
        node = 0
        for i in range(self.num_levels):
            shift = self.fanout ** (self.num_levels - 1 - i)
            digit = (vpn // shift) % self.fanout
            if i == self.num_levels - 1:
                self.levels[i][node, digit] = -1
                return
            node = self.levels[i][node, digit]
            if node < 0:
                return

    def table_bytes(self, entry_bytes: int = 4) -> int:
        return sum(a.size * entry_bytes for a in self.levels)

    def device_table(self) -> RadixTable:
        return RadixTable(
            levels=tuple(jnp.asarray(a) for a in self.levels),
            fanout=self.fanout,
        )
