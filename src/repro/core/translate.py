"""Hybrid translation flow (paper §5.4) — device side, pure JAX.

On every translation request the MMU-analogue runs the RestSeg walk (RSW)
*in parallel* with the flexible path; only requests that miss the RestSeg
pay the flexible walk.  This module is:

* the production translation used by ``serve_step`` (flat flex table), and
* the oracle (``ref``) for the ``utopia_rsw`` Pallas kernel, and
* the instrumented path used by the paper-table benchmarks (radix/ECH/
  POM-TLB flexible backends, access & byte accounting).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from .tar_sf import RestSegState, rsw
from .flex_table import FlexTable, RadixTable
from .ech import ECHState
from .pom_tlb import POMTLBState


class TranslationState(NamedTuple):
    """Everything the device needs to translate vpn -> global pool slot."""

    rest: RestSegState
    flex: FlexTable
    rest_base: jnp.ndarray      # () int32: RestSeg slot offset in pool (0)
    max_blocks_per_seq: int
    hash_name: str = "modulo"


class TranslateResult(NamedTuple):
    slot: jnp.ndarray        # int32 global pool slot (-1 unmapped)
    mapped: jnp.ndarray      # bool
    in_rest: jnp.ndarray     # bool — resolved by the RestSeg walk
    accesses: jnp.ndarray    # int32 translation-structure accesses performed
    bytes_touched: jnp.ndarray  # int32 translation metadata bytes moved


def translate(state: TranslationState, vpn: jnp.ndarray,
              tag_entry_bytes: int = 6, flex_entry_bytes: int = 8
              ) -> TranslateResult:
    """Hybrid translate.  ``vpn`` int32 array, any shape.

    Access accounting: RSW = SF probe (1 access, counter bytes) + TAR set
    read when SF > 0 (assoc tags); flexible walk = 1 flat-table access (the
    radix variant is benchmarked separately via ``translate_radix``).
    """
    r = rsw(state.rest, vpn, state.hash_name)
    flex_slot, flex_mapped = state.flex.lookup_vpn(vpn, state.max_blocks_per_seq)

    slot = jnp.where(r.hit, state.rest_base + r.slot,
                     jnp.where(flex_mapped, flex_slot, -1))
    mapped = r.hit | flex_mapped

    sf_acc = jnp.ones_like(vpn)
    tar_acc = jnp.where(r.sf_skipped, 0, 1)
    flex_acc = jnp.where(r.hit, 0, 1)          # flexible walk only on RSW miss
    accesses = sf_acc + tar_acc + flex_acc
    bytes_touched = (sf_acc                    # 1-byte SF counter
                     + r.tar_touched * tag_entry_bytes
                     + flex_acc * flex_entry_bytes)
    return TranslateResult(slot=slot.astype(jnp.int32), mapped=mapped,
                           in_rest=r.hit, accesses=accesses.astype(jnp.int32),
                           bytes_touched=bytes_touched.astype(jnp.int32))


# --- benchmark variants: alternative flexible backends ---------------------

def translate_radix(rest: Optional[RestSegState], radix: RadixTable,
                    vpn: jnp.ndarray, hash_name: str = "modulo",
                    entry_bytes: int = 8,
                    rest_base: int = 0) -> TranslateResult:
    """Hybrid (or pure when rest=None) translation over the radix baseline.

    ``rest_base`` is the RestSeg's slot offset in the global pool, exactly
    as in ``translate()`` — RSW hits resolve to ``rest_base + r.slot``.
    """
    flex_slot, flex_ok, walk_acc = radix.walk(vpn)
    if rest is None:
        return TranslateResult(slot=flex_slot, mapped=flex_ok,
                               in_rest=jnp.zeros(vpn.shape, bool),
                               accesses=walk_acc,
                               bytes_touched=walk_acc * entry_bytes)
    r = rsw(rest, vpn, hash_name)
    slot = jnp.where(r.hit, rest_base + r.slot, flex_slot)
    mapped = r.hit | flex_ok
    accesses = 1 + jnp.where(r.sf_skipped, 0, 1) + jnp.where(r.hit, 0, walk_acc)
    byt = 1 + r.tar_touched * 6 + jnp.where(r.hit, 0, walk_acc * entry_bytes)
    return TranslateResult(slot=slot, mapped=mapped, in_rest=r.hit,
                           accesses=accesses.astype(jnp.int32),
                           bytes_touched=byt.astype(jnp.int32))


def translate_ech(ech: ECHState, vpn: jnp.ndarray,
                  entry_bytes: int = 8) -> TranslateResult:
    slot, hit, acc = ech.lookup(vpn)
    return TranslateResult(slot=slot, mapped=hit,
                           in_rest=jnp.zeros(vpn.shape, bool),
                           accesses=acc, bytes_touched=acc * entry_bytes)


def translate_pom(pom: POMTLBState, radix: RadixTable, vpn: jnp.ndarray,
                  entry_bytes: int = 8) -> TranslateResult:
    """POM-TLB probe backed by the radix walk on miss."""
    slot, hit, acc = pom.lookup(vpn)
    r_slot, r_ok, r_acc = radix.walk(vpn)
    out_slot = jnp.where(hit, slot, r_slot)
    mapped = hit | r_ok
    accesses = acc + jnp.where(hit, 0, r_acc)
    return TranslateResult(slot=out_slot, mapped=mapped,
                           in_rest=jnp.zeros(vpn.shape, bool),
                           accesses=accesses.astype(jnp.int32),
                           bytes_touched=(accesses * entry_bytes).astype(jnp.int32))
